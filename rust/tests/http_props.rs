//! Protocol-conformance battery for the HTTP/JSON gateway (DESIGN.md
//! §Gateway) — runs with no artifacts and no XLA, in every build. The
//! contract under test:
//!
//! 1. **transport equivalence**: randomized valid requests produce
//!    bitwise-identical session results whether they ride the TCP line
//!    protocol or the HTTP gateway — same token ids streamed, same
//!    summary ids, same classify label — because both frontends are thin
//!    shells over one `ServerHandle`;
//! 2. **hostile inputs are boring**: malformed request lines, oversized
//!    headers and body claims, truncated chunked frames, bad JSON and
//!    mid-body disconnects each produce exactly one stable 4xx/5xx with
//!    a one-line `{"error": ...}` JSON body — and the acceptor keeps
//!    serving afterwards, every time;
//! 3. **the fault seam is shared**: the same `FaultSpec` sock schedule
//!    that drives the TCP chaos tests drives SSE streaming — a scheduled
//!    drop ends the stream at its exact event ordinal, a scheduled stall
//!    only delays it, and the spent schedule leaves the frontend serving.
//!
//! The ledger-conservation twin of this battery (a vanished SSE client
//! must free pages and admission slot) lives in `faults_props.rs`
//! alongside the other §Faults properties.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use sinkhorn::server::json::{
    ClassifyRequest, ClassifyResponse, ErrorBody, FromJson, GenerateRequest, GenerateSummary,
    SchemaResponse, ToJson, TokEvent,
};
use sinkhorn::server::{
    BatchPolicy, FallbackConfig, FaultPlan, FaultSpec, HttpConfig, HttpFrontend, Server,
    TcpFrontend, DEADLINE_MSG,
};
use sinkhorn::util::prop::{forall, Gen};

/// Tiny deterministic shapes (the same fixture as `faults_props.rs`).
fn tiny_cfg() -> FallbackConfig {
    FallbackConfig { seq_len: 32, d_model: 16, nb: 4, prefix_share: false, ..Default::default() }
}

fn start_server() -> Server {
    let policy = BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() };
    Server::start_fallback(tiny_cfg(), policy).unwrap()
}

/// One parsed HTTP response: status, headers (lowercased names), body
/// (chunked transfer decoded when present).
#[derive(Debug)]
struct RawResponse {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl RawResponse {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    fn error_body(&self) -> ErrorBody {
        ErrorBody::from_json(std::str::from_utf8(&self.body).unwrap()).unwrap()
    }
}

/// Read one full response off `reader` (headers + content-length or
/// chunked body).
fn read_response(reader: &mut impl BufRead) -> RawResponse {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    assert!(status_line.starts_with("HTTP/1.1 "), "bad status line: {status_line:?}");
    let status: u16 = status_line[9..12].parse().unwrap();
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (name, value) = h.split_once(':').unwrap();
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let mut resp = RawResponse { status, headers, body: Vec::new() };
    if resp.header("transfer-encoding").map(|v| v.contains("chunked")).unwrap_or(false) {
        loop {
            let mut sz = String::new();
            reader.read_line(&mut sz).unwrap();
            let n = usize::from_str_radix(sz.trim(), 16).unwrap();
            if n == 0 {
                let mut blank = String::new();
                reader.read_line(&mut blank).unwrap();
                break;
            }
            let start = resp.body.len();
            resp.body.resize(start + n, 0);
            reader.read_exact(&mut resp.body[start..]).unwrap();
            let mut crlf = [0u8; 2];
            reader.read_exact(&mut crlf).unwrap();
        }
    } else if let Some(n) = resp.header("content-length") {
        let n: usize = n.parse().unwrap();
        resp.body.resize(n, 0);
        reader.read_exact(&mut resp.body).unwrap();
    }
    resp
}

/// Fire one request on a fresh connection and read the full response.
fn roundtrip(addr: std::net::SocketAddr, raw: &[u8]) -> RawResponse {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(raw).unwrap();
    let mut reader = BufReader::new(conn);
    read_response(&mut reader)
}

fn post(path: &str, body: &str) -> Vec<u8> {
    format!(
        "POST {path} HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Split the chunk-decoded SSE body into (event, data) pairs.
fn sse_events(body: &[u8]) -> Vec<(String, String)> {
    let text = std::str::from_utf8(body).unwrap();
    text.split("\n\n")
        .filter(|b| !b.is_empty())
        .map(|block| {
            let mut event = String::new();
            let mut data = String::new();
            for line in block.lines() {
                if let Some(v) = line.strip_prefix("event: ") {
                    event = v.to_string();
                } else if let Some(v) = line.strip_prefix("data: ") {
                    data = v.to_string();
                }
            }
            (event, data)
        })
        .collect()
}

#[derive(Debug)]
struct ReqCase {
    prompt: Vec<i32>,
    max_new: usize,
}

fn gen_req(g: &mut Gen) -> ReqCase {
    let plen = g.usize(1, 7);
    ReqCase {
        prompt: (0..plen).map(|_| g.usize(0, 64) as i32).collect(),
        max_new: g.usize(2, 9),
    }
}

/// Property 1: randomized valid requests round-trip bitwise over both
/// transports — streamed ids, summary ids, and the classify label all
/// agree, because there is exactly one scheduler behind both wires.
#[test]
fn randomized_requests_round_trip_bitwise_vs_tcp() {
    let server = start_server();
    let tcp = TcpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
    let http = HttpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
    forall(12, 0x177_8, gen_req, |c| {
        let ids = c.prompt.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ");

        // --- generate over TCP ---
        let mut conn = TcpStream::connect(tcp.addr).unwrap();
        conn.write_all(format!("gen {} {ids}\n", c.max_new).as_bytes()).unwrap();
        let mut reader = BufReader::new(conn);
        let mut tcp_streamed: Vec<i32> = Vec::new();
        let tcp_summary: Vec<i32> = loop {
            let mut l = String::new();
            reader.read_line(&mut l).unwrap();
            if let Some(rest) = l.strip_prefix("tok ") {
                tcp_streamed.push(rest.split_whitespace().nth(1).unwrap().parse().unwrap());
            } else {
                let toks = l
                    .split_whitespace()
                    .find_map(|p| p.strip_prefix("tokens="))
                    .ok_or_else(|| format!("tcp summary missing tokens=: {l:?}"))?;
                break toks.split(',').map(|s| s.parse().unwrap()).collect();
            }
        };

        // --- generate over HTTP/SSE ---
        let body =
            GenerateRequest { max_new: c.max_new, tokens: c.prompt.clone(), deadline_ms: None }
                .to_json();
        let resp = roundtrip(http.addr, &post("/v1/generate", &body));
        if resp.status != 200 {
            return Err(format!("http generate got {}: {:?}", resp.status, resp.error_body()));
        }
        let events = sse_events(&resp.body);
        let (last_event, last_data) = events.last().ok_or("empty SSE stream")?;
        if last_event != "done" {
            return Err(format!("stream ended with {last_event:?}: {last_data}"));
        }
        let http_summary = GenerateSummary::from_json(last_data).map_err(|e| e.to_string())?;
        let http_streamed: Vec<i32> = events[..events.len() - 1]
            .iter()
            .map(|(e, d)| {
                assert_eq!(e, "tok", "unexpected event in stream");
                TokEvent::from_json(d).unwrap().id
            })
            .collect();

        // bitwise equivalence, across and within transports
        if tcp_streamed != tcp_summary || http_streamed != http_summary.tokens {
            return Err("streamed ids diverged from that transport's own summary".into());
        }
        if tcp_summary != http_summary.tokens {
            return Err(format!(
                "transports diverged: tcp {tcp_summary:?} vs http {:?}",
                http_summary.tokens
            ));
        }

        // --- classify over both ---
        let mut conn = TcpStream::connect(tcp.addr).unwrap();
        let full: Vec<i32> = (0..32).map(|i| (i + c.prompt[0]) % 64).collect();
        let line = full.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ");
        conn.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut reader = BufReader::new(conn);
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        let tcp_label: i32 = l
            .split_whitespace()
            .find_map(|p| p.strip_prefix("label="))
            .ok_or_else(|| format!("tcp classify got {l:?}"))?
            .parse()
            .unwrap();
        let creq = ClassifyRequest { tokens: full }.to_json();
        let resp = roundtrip(http.addr, &post("/v1/classify", &creq));
        if resp.status != 200 {
            return Err(format!("http classify got {}", resp.status));
        }
        let cresp =
            ClassifyResponse::from_json(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        if cresp.label != tcp_label {
            return Err(format!("labels diverged: tcp {tcp_label} vs http {}", cresp.label));
        }
        Ok(())
    });
    drop(http);
    drop(tcp);
    server.shutdown().unwrap();
}

/// Property 2: every hostile input maps to one stable 4xx/5xx with a
/// parseable one-line JSON error body — and after the whole corpus the
/// acceptor still serves a clean request. No wedging, no echoes.
#[test]
fn hostile_inputs_yield_stable_errors_and_never_wedge_the_acceptor() {
    let server = start_server();
    let http = HttpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
    let corpus: Vec<(Vec<u8>, u16)> = vec![
        // malformed request lines
        (b"GARBAGE\r\n\r\n".to_vec(), 400),
        (b"GET /too many spaces HTTP/1.1\r\n\r\n".to_vec(), 400),
        (b"get /v1/model HTTP/1.1\r\n\r\n".to_vec(), 400),
        (b"GET /v1/model SPDY/3\r\n\r\n".to_vec(), 505),
        // routing misses
        (b"GET /v1/frobnicate HTTP/1.1\r\n\r\n".to_vec(), 404),
        (b"GET /v1/classify HTTP/1.1\r\n\r\n".to_vec(), 405),
        // oversized dimensions, refused before buffering
        (
            format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(8192)).into_bytes(),
            431,
        ),
        (
            format!("GET /v1/model HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "b".repeat(8192)).into_bytes(),
            431,
        ),
        (b"POST /v1/classify HTTP/1.1\r\nContent-Length: 104857600\r\n\r\n".to_vec(), 413),
        (
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nfffffff\r\n".to_vec(),
            413,
        ),
        // truncated chunked frame (size line, then silence + close)
        (
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nff\r\nshort".to_vec(),
            400,
        ),
        // bad JSON bodies on a well-formed request
        (post("/v1/classify", "{\"tokens\": [1, 2"), 400),
        (post("/v1/classify", "not json at all"), 400),
        (post("/v1/classify", "{}"), 400),
        (post("/v1/classify", "{\"tokens\":[1]} trailing"), 400),
        (post("/v1/generate", "{\"max_new\": 0, \"tokens\": [1]}"), 400),
        // non-UTF-8 body
        (
            [&b"POST /v1/classify HTTP/1.1\r\nContent-Length: 4\r\n\r\n"[..], &[0xff, 0xfe, 1, 2]]
                .concat(),
            400,
        ),
    ];
    for (raw, want_status) in &corpus {
        let resp = roundtrip(http.addr, raw);
        assert_eq!(
            resp.status,
            *want_status,
            "corpus entry {:?}...",
            String::from_utf8_lossy(&raw[..raw.len().min(40)])
        );
        let eb = resp.error_body(); // must parse as the typed error shape
        assert!(!eb.error.is_empty() && eb.error.len() <= 120, "bad error line: {:?}", eb.error);
        assert!(!eb.error.contains('\n'), "multi-line error leaked: {:?}", eb.error);
    }

    // mid-body disconnect: claim bytes, send half, vanish
    let mut conn = TcpStream::connect(http.addr).unwrap();
    conn.write_all(b"POST /v1/classify HTTP/1.1\r\nContent-Length: 100\r\n\r\nhalf").unwrap();
    drop(conn);
    // mid-headers disconnect
    let mut conn = TcpStream::connect(http.addr).unwrap();
    conn.write_all(b"POST /v1/classify HTTP/1.1\r\nContent-").unwrap();
    drop(conn);

    // the acceptor is untouched: a clean request round-trips
    let creq = ClassifyRequest { tokens: (0..32).collect() }.to_json();
    let resp = roundtrip(http.addr, &post("/v1/classify", &creq));
    assert_eq!(resp.status, 200, "acceptor wedged after hostile corpus");
    ClassifyResponse::from_json(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    drop(http);
    server.shutdown().unwrap();
}

/// Keep-alive conformance: multiple requests ride one connection; a
/// parse failure mid-connection closes it (no trustworthy framing left)
/// after exactly one stable error.
#[test]
fn keep_alive_serves_sequential_requests_and_closes_on_parse_failure() {
    let server = start_server();
    let http = HttpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
    let mut conn = TcpStream::connect(http.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    // three requests, one connection
    conn.write_all(b"GET /v1/model HTTP/1.1\r\n\r\n").unwrap();
    let r1 = read_response(&mut reader);
    assert_eq!((r1.status, r1.header("connection")), (200, Some("keep-alive")));
    conn.write_all(b"GET /v1/schema HTTP/1.1\r\n\r\n").unwrap();
    let r2 = read_response(&mut reader);
    assert_eq!(r2.status, 200);
    let schema = SchemaResponse::from_json(std::str::from_utf8(&r2.body).unwrap()).unwrap();
    assert_eq!(schema.routes.len(), 5, "schema must list every route");
    let creq = ClassifyRequest { tokens: (0..32).collect() }.to_json();
    conn.write_all(&post("/v1/classify", &creq)).unwrap();
    assert_eq!(read_response(&mut reader).status, 200);
    // then garbage: one stable error with Connection: close, then EOF
    conn.write_all(b"GARBAGE\r\n\r\n").unwrap();
    let r4 = read_response(&mut reader);
    assert_eq!((r4.status, r4.header("connection")), (400, Some("close")));
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "bytes after the terminal error: {rest:?}");
    drop(http);
    server.shutdown().unwrap();
}

/// `deadline_ms` is honored end to end: an already-expired deadline
/// resolves as the stable 504 with the same `error=` line the TCP
/// frontend would print — no 200, no SSE header, no stream.
#[test]
fn expired_deadline_maps_to_504_before_any_stream_commits() {
    let server = start_server();
    let http = HttpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
    let body = GenerateRequest { max_new: 8, tokens: vec![1, 2, 3], deadline_ms: Some(0) }
        .to_json();
    let resp = roundtrip(http.addr, &post("/v1/generate", &body));
    assert_eq!(resp.status, 504);
    assert_eq!(resp.header("content-type"), Some("application/json"));
    assert_eq!(resp.error_body().error, DEADLINE_MSG);
    // the frontend is still serving
    let creq = ClassifyRequest { tokens: (0..32).collect() }.to_json();
    assert_eq!(roundtrip(http.addr, &post("/v1/classify", &creq)).status, 200);
    drop(http);
    server.shutdown().unwrap();
}

/// Property 3: the shared `sock_point` seam, through SSE. A schedule of
/// `stall@0, drop@2` delays the first event and ends the stream at
/// exactly the third — the client sees two `tok` events and EOF, never a
/// `done` event or a chunked terminator. The spent schedule leaves the
/// next request streaming to completion.
#[test]
fn http_injected_sock_faults_close_or_delay_sse_deterministically() {
    let server = start_server();
    let spec = FaultSpec {
        sock_drop: vec![2],
        sock_stall: vec![0],
        stall_for: Duration::from_millis(30),
        ..Default::default()
    };
    let cfg = HttpConfig { faults: FaultPlan::from_spec(&spec), ..Default::default() };
    let http = HttpFrontend::start_with("127.0.0.1:0", server.handle.clone(), cfg).unwrap();

    let body =
        GenerateRequest { max_new: 10, tokens: vec![1, 2, 3], deadline_ms: None }.to_json();
    let mut conn = TcpStream::connect(http.addr).unwrap();
    conn.write_all(&post("/v1/generate", &body)).unwrap();
    let mut reader = BufReader::new(conn);
    // status + headers arrive (the stream committed on the first token)
    let mut status = String::new();
    reader.read_line(&mut status).unwrap();
    assert!(status.starts_with("HTTP/1.1 200"), "got {status:?}");
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
    }
    // then raw chunks until the injected drop severs the connection
    let mut raw = Vec::new();
    reader.read_to_end(&mut raw).unwrap();
    let mut events = Vec::new();
    let mut rest = &raw[..];
    while let Some(nl) = rest.iter().position(|&b| b == b'\n') {
        let size_line = std::str::from_utf8(&rest[..nl]).unwrap().trim();
        let Ok(n) = usize::from_str_radix(size_line, 16) else { break };
        assert_ne!(n, 0, "terminator must not arrive after a drop");
        if rest.len() < nl + 1 + n + 2 {
            break; // chunk truncated by the drop — acceptable tail
        }
        events.push(String::from_utf8_lossy(&rest[nl + 1..nl + 1 + n]).to_string());
        rest = &rest[nl + 1 + n + 2..];
    }
    assert_eq!(events.len(), 2, "drop at ordinal 2 ends the stream: {events:?}");
    assert!(
        events.iter().all(|e| e.starts_with("event: tok\n")),
        "only tok events before the drop: {events:?}"
    );

    // the schedule is spent: a fresh request streams to its done event
    let body = GenerateRequest { max_new: 4, tokens: vec![1, 2, 3], deadline_ms: None }.to_json();
    let resp = roundtrip(http.addr, &post("/v1/generate", &body));
    assert_eq!(resp.status, 200);
    let events = sse_events(&resp.body);
    assert_eq!(events.last().unwrap().0, "done", "events: {events:?}");
    drop(http);
    server.shutdown().unwrap();
}
