//! Batched inference serving (the L3 "router" role): client threads submit
//! token sequences; a dynamic batcher groups them; a single executor thread
//! owning the execution backend classifies whole batches at once. The
//! backend is either the PJRT runtime over compiled artifacts or, when no
//! HLO artifact is present, the pure-Rust blocked engine
//! ([`fallback`] — works on any machine).

pub mod batch;
pub mod fallback;
pub mod service;
pub mod tcp;

pub use batch::{gather, BatchPolicy};
pub use fallback::{FallbackConfig, FallbackModel};
pub use service::{Response, Server, ServerHandle};
pub use tcp::TcpFrontend;
