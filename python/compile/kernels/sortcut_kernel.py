"""Pallas kernel for SortCut attention (paper §3.4).

Every query attends to only the first ``n_cut`` *sorted* key/value blocks —
a hard, differentiable, data-driven truncation: O(ell * n_cut * b) time,
linear in sequence length.

Grid is ``(G, nq)``: one program per (batch*head, query block). The
truncated key/value tensors (``n_cut*b`` rows) are small by construction
(that is the whole point of SortCut) so each program keeps them fully
resident in VMEM next to its ``(bq, d)`` query tile.

Backward: SortCut runs in encoder-only settings (classification) where the
bwd cost is dwarfed by training-step overhead, so the custom VJP
differentiates the jnp reference (pinned to the kernel by tests) instead of
a second kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(q_ref, k_ref, v_ref, y_ref):
    # slab layout: the whole (G, bq, d) query slab for one query-block
    # position, with the full truncated (G, nc, d) KV resident in VMEM
    q = q_ref[...].astype(jnp.float32)  # (G, bq, d)
    k = k_ref[...].astype(jnp.float32)  # (G, nc, d)
    v = v_ref[...].astype(jnp.float32)  # (G, nc, d)
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("gtd,gud->gtu", q, k) * scale
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    y_ref[...] = jnp.einsum("gtu,gud->gtd", p, v).astype(y_ref.dtype)


def _pallas_sortcut(q, k_cut, v_cut, *, bq):
    g, ell, d = q.shape
    nc = k_cut.shape[1]
    nq = ell // bq
    qspec = pl.BlockSpec((g, bq, d), lambda i: (0, i, 0))
    kspec = pl.BlockSpec((g, nc, d), lambda i: (0, 0, 0))
    return pl.pallas_call(
        _kernel,
        grid=(nq,),
        in_specs=[qspec, kspec, kspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((g, ell, d), q.dtype),
        interpret=True,
    )(q, k_cut, v_cut)


@functools.lru_cache(maxsize=None)
def _make(bq: int):
    ref_fn = jax.vmap(ref.sortcut_attention)

    @jax.custom_vjp
    def attn(q, k_cut, v_cut):
        return _pallas_sortcut(q, k_cut, v_cut, bq=bq)

    def fwd(q, k_cut, v_cut):
        return attn(q, k_cut, v_cut), (q, k_cut, v_cut)

    def bwd(res, dy):
        _, vjp = jax.vjp(ref_fn, *res)
        return vjp(dy)

    attn.defvjp(fwd, bwd)
    return attn


def sortcut_attention(q, k_cut, v_cut, block_q: int = 0):
    """SortCut attention.

    Args:
      q: ``(G, ell, d)`` queries (full sequence).
      k_cut, v_cut: ``(G, n_cut*b, d)`` — first ``n_cut`` sorted KV blocks.
      block_q: query tile length (defaults to the KV length, capped by ell).

    Returns ``(G, ell, d)``.
    """
    ell = q.shape[1]
    if block_q <= 0:
        block_q = min(ell, max(8, k_cut.shape[1]))
    while ell % block_q != 0:
        block_q //= 2
    return _make(int(block_q))(q, k_cut, v_cut)
