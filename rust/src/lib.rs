//! # Sinkhorn Transformer — Sparse Sinkhorn Attention, full-stack
//!
//! Reproduction of *Sparse Sinkhorn Attention* (Tay, Bahri, Yang, Metzler,
//! Juan — ICML 2020) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): Sinkhorn
//!   balancing, block-sparse sorted+local attention (fwd *and* bwd),
//!   SortCut attention. AOT-lowered, never run from Python at runtime.
//! * **L2** — JAX models (`python/compile/`): SortNet, multi-head Sinkhorn
//!   attention (+ vanilla/local/Sparse-Transformer baselines), LM /
//!   classifier / seq2seq stacks, hand-rolled Adam train step.
//! * **L3** — this crate: the coordinator. Loads the compiled HLO
//!   artifacts via PJRT ([`runtime`]), generates data ([`data`]), drives
//!   training/eval ([`coordinator`]), serves batched inference
//!   ([`server`]), regenerates every table and figure of the paper
//!   ([`bench`]), and carries a pure-Rust reference implementation of the
//!   algorithm ([`sinkhorn`]) for property tests and analytic memory
//!   models.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod server;
pub mod sinkhorn;
pub mod util;
