//! Property and differential tests for the paged KV-cache (DESIGN.md
//! §Pages) — run with no artifacts and no XLA, in every build. Two suites:
//!
//! **Pool invariants under randomized churn** — alloc/clone/drop/COW
//! sequences over [`PagePool`], [`Page`] and [`PageTable`] must keep the
//! pool's ledger exactly equal to an independently computed ground truth
//! (unique live buffers counted once), never underflow a refcount, return
//! every freed buffer to the free list exactly once
//! (`pages_in_use + free_pages == created`, always), never mutate a
//! buffer that another handle can still read, and keep an unshared paged
//! [`DecodeState`]'s real allocation equal to the analytic
//! `memory::decode_state_resident_bytes` at every length.
//!
//! **Differential battery** — a paged [`DecodeState`] stepped next to a
//! monolithic twin on identical inputs must be *bitwise* identical per
//! step: across block-boundary fills and mid-block tails, every SortCut
//! width, engine thread counts {1, 3}, and — at the stack level —
//! randomized shared-prefix session cohorts, where prefix-shared sessions
//! must emit token-for-token what unshared sessions emit while pinning
//! strictly fewer pool pages.

use sinkhorn::server::{FallbackConfig, FallbackModel, GenSession};
use sinkhorn::sinkhorn::memory::{decode_state_resident_bytes, kv_pages_at};
use sinkhorn::sinkhorn::{DecodeReq, DecodeState, Mat, PagePool, PageTable, SinkhornEngine};
use sinkhorn::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
}

/// Ground truth for the pool ledger: count each live buffer once, however
/// many pages or tables share it.
fn unique_live_elems(tables: &[PageTable]) -> (usize, usize) {
    let mut seen: Vec<*const f32> = Vec::new();
    let mut elems = 0usize;
    for t in tables {
        for p in t.pages() {
            if !seen.contains(&p.buf_ptr()) {
                seen.push(p.buf_ptr());
                elems += p.elems();
            }
        }
    }
    (seen.len(), elems)
}

/// Randomized table churn: create, fill, fork, COW-write, and drop
/// tables, checking the pool ledger against the deduplicated ground
/// truth after every operation, and the conservation law
/// `pages_in_use + free_pages == created` throughout.
#[test]
fn pool_ledger_survives_randomized_table_churn() {
    let mut rng = Rng::new(0x9A6E5);
    let pool = PagePool::new();
    let block_elems = 12usize;
    let mut tables: Vec<PageTable> = Vec::new();
    for step in 0..400 {
        match rng.next_u64() % 5 {
            // new table, randomly paged
            0 => tables.push(PageTable::new(&pool, block_elems, 1 + (rng.next_u64() % 3) as usize)),
            // write the next block of a random table (lazy alloc)
            1 if !tables.is_empty() => {
                let i = (rng.next_u64() as usize) % tables.len();
                let b = tables[i].resident_pages() * tables[i].page_elems() / block_elems;
                let blk = tables[i].block_mut(b.min(30));
                blk[0] = step as f32;
            }
            // fork a random table: refcounts bump, ledger unchanged
            2 if !tables.is_empty() => {
                let i = (rng.next_u64() as usize) % tables.len();
                let before = pool.stats();
                let f = tables[i].fork();
                assert_eq!(pool.stats(), before, "fork must not touch the ledger");
                tables.push(f);
            }
            // COW-write block 0 of a random table; any sibling sharing it
            // must keep its bytes
            3 if !tables.is_empty() => {
                let i = (rng.next_u64() as usize) % tables.len();
                if tables[i].resident_pages() > 0 {
                    let witness: Vec<(usize, Vec<f32>)> = (0..tables.len())
                        .filter(|&j| j != i)
                        .filter(|&j| tables[j].resident_pages() > 0)
                        .map(|j| (j, tables[j].block(0).to_vec()))
                        .collect();
                    tables[i].block_mut(0)[1] = -(step as f32);
                    for (j, w) in witness {
                        assert_eq!(
                            tables[j].block(0),
                            &w[..],
                            "COW write through table {i} mutated table {j}"
                        );
                    }
                }
            }
            // drop a random table: uniquely-held pages return to the free
            // list; shared ones survive in their siblings
            _ if !tables.is_empty() => {
                let i = (rng.next_u64() as usize) % tables.len();
                tables.swap_remove(i);
            }
            _ => {}
        }
        let (want_pages, want_elems) = unique_live_elems(&tables);
        let s = pool.stats();
        assert_eq!(s.pages_in_use, want_pages, "ledger drifted at step {step}");
        assert_eq!(s.elems_in_use, want_elems, "byte ledger drifted at step {step}");
        assert_eq!(
            s.pages_in_use + s.free_pages,
            s.created,
            "a page leaked or double-freed at step {step}"
        );
        assert!(s.freed >= s.free_pages, "free list grew without Drop at step {step}");
        for t in &tables {
            for p in t.pages() {
                assert!(p.ref_count() >= 1, "live page with underflowed refcount");
            }
        }
    }
    drop(tables);
    let s = pool.stats();
    assert_eq!(s.pages_in_use, 0, "all pages must return after the last drop");
    assert_eq!(s.free_pages, s.created, "every created page ends on the free list once");
}

/// An unshared paged `DecodeState`'s real allocation equals the analytic
/// resident model at every length — pages appear with `len`, not with
/// capacity (the O(len) vs O(max_len) claim, per step).
#[test]
fn paged_state_allocation_tracks_length_not_capacity() {
    let mut rng = Rng::new(0x9A6E6);
    for (nb, b, d, cut, bpp) in
        [(4usize, 6usize, 8usize, None, 1usize), (3, 4, 5, Some(2), 2), (5, 3, 7, Some(5), 3)]
    {
        let ell = nb * b;
        let (q, k, v) = (rand_mat(&mut rng, ell, d), rand_mat(&mut rng, ell, d), rand_mat(&mut rng, ell, d));
        let logits = rand_mat(&mut rng, nb, nb);
        let pool = PagePool::new();
        let mut st = DecodeState::new_paged(b, d, nb, 5, cut, &pool, bpp);
        let eng = SinkhornEngine::serial();
        assert_eq!(st.f32_elems() * 4, decode_state_resident_bytes(b, d, nb, cut, bpp, 0));
        for t in 0..ell {
            let mut row = vec![0.0f32; d];
            eng.decode_step_into(vec![DecodeReq {
                state: &mut st,
                q: q.row(t),
                k: k.row(t),
                v: v.row(t),
                sort_logits: &logits,
                out: &mut row,
            }]);
            let len = t + 1;
            assert_eq!(
                st.f32_elems() * 4,
                decode_state_resident_bytes(b, d, nb, cut, bpp, len),
                "allocation drifted from the resident model at len {len} \
                 (nb={nb} b={b} cut={cut:?} bpp={bpp})"
            );
            assert_eq!(st.resident_pages(), 2 * kv_pages_at(len, b, bpp) + 2);
        }
    }
}

/// The core differential: a paged state and a monolithic twin stepped on
/// identical inputs are bitwise identical per step — outputs and sorted
/// caches — across mid-block and block-aligned fills, every SortCut
/// width, page sizes {1, 2} blocks, and engine thread counts {1, 3}.
#[test]
fn paged_decode_is_bitwise_identical_to_monolithic_per_step() {
    let mut rng = Rng::new(0x9A6E7);
    let (nb, b, d) = (4usize, 5usize, 6usize);
    let ell = nb * b;
    let (q, k, v) = (rand_mat(&mut rng, ell, d), rand_mat(&mut rng, ell, d), rand_mat(&mut rng, ell, d));
    let logits = rand_mat(&mut rng, nb, nb);
    let cuts: Vec<Option<usize>> =
        std::iter::once(None).chain((1..=nb).map(Some)).collect();
    for total in [ell, ell - b / 2, b + 1] {
        for &cut in &cuts {
            for bpp in [1usize, 2] {
                let mut per_thread: Vec<Vec<Vec<f32>>> = Vec::new();
                for threads in [1usize, 3] {
                    let eng = SinkhornEngine::new(threads);
                    let pool = PagePool::new();
                    let mut mono = DecodeState::new(b, d, nb, 5, cut);
                    let mut paged = DecodeState::new_paged(b, d, nb, 5, cut, &pool, bpp);
                    let mut outs = Vec::new();
                    for t in 0..total {
                        let mut row_m = vec![f32::NAN; d];
                        let mut row_p = vec![f32::NAN; d];
                        // one batch, both storage modes, identical inputs
                        let reqs = vec![
                            DecodeReq {
                                state: &mut mono,
                                q: q.row(t),
                                k: k.row(t),
                                v: v.row(t),
                                sort_logits: &logits,
                                out: &mut row_m,
                            },
                            DecodeReq {
                                state: &mut paged,
                                q: q.row(t),
                                k: k.row(t),
                                v: v.row(t),
                                sort_logits: &logits,
                                out: &mut row_p,
                            },
                        ];
                        eng.decode_step_into(reqs);
                        assert_eq!(
                            row_m, row_p,
                            "paged output diverged at step {t} (total={total} cut={cut:?} \
                             bpp={bpp} threads={threads})"
                        );
                        assert_eq!(
                            mono.sorted_cache(),
                            paged.sorted_cache(),
                            "sorted-gather caches diverged at step {t} (cut={cut:?} bpp={bpp})"
                        );
                        outs.push(row_m);
                    }
                    per_thread.push(outs);
                }
                assert_eq!(
                    per_thread[0], per_thread[1],
                    "thread count changed the decode bytes (total={total} cut={cut:?} bpp={bpp})"
                );
            }
        }
    }
}

/// Forking after every block boundary keeps the fork bitwise equal to an
/// independently stepped twin while sharing pages until writes diverge
/// them — the COW contract at the decode-state level.
#[test]
fn forked_states_diverge_bitwise_cleanly_at_every_boundary() {
    let mut rng = Rng::new(0x9A6E8);
    let (nb, b, d) = (3usize, 4usize, 5usize);
    let ell = nb * b;
    let (q, k, v) = (rand_mat(&mut rng, ell, d), rand_mat(&mut rng, ell, d), rand_mat(&mut rng, ell, d));
    let logits = rand_mat(&mut rng, nb, nb);
    let eng = SinkhornEngine::serial();
    let step = |st: &mut DecodeState, t: usize, out: &mut [f32]| {
        eng.decode_step_into(vec![DecodeReq {
            state: st,
            q: q.row(t),
            k: k.row(t),
            v: v.row(t),
            sort_logits: &logits,
            out,
        }]);
    };
    for fork_at in [b, 2 * b] {
        let pool = PagePool::new();
        let mut parent = DecodeState::new_paged(b, d, nb, 5, None, &pool, 1);
        let mut fresh = DecodeState::new(b, d, nb, 5, None);
        let mut row = vec![0.0f32; d];
        let mut row_f = vec![0.0f32; d];
        for t in 0..fork_at {
            step(&mut parent, t, &mut row);
            step(&mut fresh, t, &mut row_f);
        }
        let before = pool.stats().pages_in_use;
        let mut child = parent.fork();
        assert_eq!(pool.stats().pages_in_use, before, "fork must allocate nothing");
        // parent and child continue on the same inputs: identical bytes,
        // and both identical to the never-forked monolithic twin
        for t in fork_at..ell {
            let mut row_c = vec![0.0f32; d];
            step(&mut parent, t, &mut row);
            step(&mut child, t, &mut row_c);
            step(&mut fresh, t, &mut row_f);
            assert_eq!(row, row_c, "fork_at={fork_at} step {t}: child diverged from parent");
            assert_eq!(row, row_f, "fork_at={fork_at} step {t}: paged diverged from mono");
        }
    }
}

fn cohort_cfg(prefix_share: bool, threads: usize) -> FallbackConfig {
    FallbackConfig {
        seq_len: 32,
        d_model: 16,
        nb: 4,
        vocab: 64,
        depth: 2,
        n_heads: 2,
        d_ff: 32,
        threads,
        prefix_share,
        ..Default::default()
    }
}

/// Step a cohort to completion; returns every session's generation and
/// the pool pages pinned at completion (sessions still resident — the
/// honest residency comparison point, since the no-share model defers
/// all its allocation to the tick loop).
fn run_cohort(m: &FallbackModel, reqs: &[(Vec<i32>, usize)]) -> (Vec<Vec<i32>>, usize) {
    let mut sessions: Vec<GenSession> =
        reqs.iter().map(|(p, n)| m.open_session(p, *n)).collect();
    let mut scratch = m.new_batch_scratch();
    loop {
        let mut live: Vec<&mut GenSession> =
            sessions.iter_mut().filter(|s| !s.done()).collect();
        if live.is_empty() {
            break;
        }
        m.step_sessions(&mut live, &mut scratch);
    }
    let pages = m.pool_stats().pages_in_use;
    (sessions.into_iter().map(GenSession::into_generated).collect(), pages)
}

/// Randomized shared-prefix cohorts at the stack level: sessions opened
/// on a common prompt must generate token-for-token what sessions opened
/// without prefix sharing generate (both equal to single-request
/// `generate`), while the sharing model pins strictly fewer pool pages —
/// for engine thread counts {1, 3}.
#[test]
fn shared_prefix_cohorts_match_unshared_bitwise_with_fewer_pages() {
    let mut rng = Rng::new(0x9A6E9);
    for trial in 0..3 {
        let plen = 10 + (rng.next_u64() % 10) as usize; // > one block of 8
        let prompt: Vec<i32> = (0..plen).map(|_| (rng.next_u64() % 64) as i32).collect();
        let reqs: Vec<(Vec<i32>, usize)> = (0..3 + (rng.next_u64() % 3) as usize)
            .map(|_| (prompt.clone(), 2 + (rng.next_u64() % 4) as usize))
            .collect();
        for threads in [1usize, 3] {
            let shared = FallbackModel::new(cohort_cfg(true, threads)).unwrap();
            let unshared = FallbackModel::new(cohort_cfg(false, threads)).unwrap();
            let want: Vec<Vec<i32>> =
                reqs.iter().map(|(p, n)| shared.generate(p, *n)).collect();
            let (got_shared, ps) = run_cohort(&shared, &reqs);
            let (got_unshared, pu) = run_cohort(&unshared, &reqs);
            assert_eq!(
                got_shared, got_unshared,
                "trial {trial} threads {threads}: prefix sharing changed a token"
            );
            assert_eq!(
                got_shared, want,
                "trial {trial} threads {threads}: cohort diverged from generate"
            );
            assert!(
                ps < pu,
                "trial {trial} threads {threads}: sharing cohort must pin strictly \
                 fewer pages ({ps} vs {pu})"
            );
        }
    }
}
