//! Integration tests over the real AOT artifacts: runtime loading, train
//! steps, eval, checkpoint resume-exactness, the serving stack and the
//! bench plumbing. Skipped (with a message) if `make artifacts` hasn't run.

use std::path::PathBuf;

use sinkhorn::coordinator::{self, Checkpoint, TrainOptions};
use sinkhorn::data::TaskData;
use sinkhorn::runtime::{Experiment, HostTensor, Registry, Runtime};
use sinkhorn::server::{BatchPolicy, Server};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("registry.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(a) => a,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn registry_loads_and_covers_every_table() {
    let dir = require_artifacts!();
    let reg = Registry::load(&dir).unwrap();
    assert!(reg.entries.len() >= 80, "expected full registry, got {}", reg.entries.len());
    for table in ["table1", "table2", "table4", "table5", "table6", "table7", "table8", "fig3", "fig4"] {
        assert!(!reg.by_table(table).is_empty(), "no experiments for {table}");
    }
}

#[test]
fn init_is_reproducible_and_seed_sensitive() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let exp = Experiment::load(&dir, "lmw_tiny__sinkhorn_b16").unwrap();
    let a = exp.init_state(&rt, 42).unwrap();
    let b = exp.init_state(&rt, 42).unwrap();
    let c = exp.init_state(&rt, 43).unwrap();
    let ta = HostTensor::from_literal(&a.params[0]).unwrap();
    let tb = HostTensor::from_literal(&b.params[0]).unwrap();
    let tc = HostTensor::from_literal(&c.params[0]).unwrap();
    assert_eq!(ta, tb, "same seed must give identical params");
    assert_ne!(ta, tc, "different seed must give different params");
}

#[test]
fn train_step_updates_all_leaves_and_decreases_loss() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let exp = Experiment::load(&dir, "lmw_tiny__sinkhorn_b16").unwrap();
    let mut data = TaskData::for_experiment(&exp.manifest).unwrap();
    let mut state = exp.init_state(&rt, 1).unwrap();
    let before: Vec<HostTensor> =
        state.params.iter().map(|l| HostTensor::from_literal(l).unwrap()).collect();

    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..12 {
        let batch = data.train_batch();
        let lits: Vec<_> = batch.iter().map(|t| t.to_literal().unwrap()).collect();
        let loss = exp.train_step(&rt, &mut state, i, &lits).unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first, "loss should decrease: {first} -> {last}");
    assert_eq!(state.step, 12.0);
    let after: Vec<HostTensor> =
        state.params.iter().map(|l| HostTensor::from_literal(l).unwrap()).collect();
    let changed = before.iter().zip(&after).filter(|(a, b)| a != b).count();
    assert_eq!(changed, before.len(), "every parameter leaf should receive gradient");
}

#[test]
fn eval_runs_for_every_family() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    for name in ["lmw_tiny__vanilla", "imdbw__sinkhorn_b8", "sort__local_b16"] {
        let exp = Experiment::load(&dir, name).unwrap();
        let state = exp.init_state(&rt, 5).unwrap();
        let mut data = TaskData::for_experiment(&exp.manifest).unwrap();
        match &mut data {
            TaskData::Lm(d) => {
                let loss = coordinator::eval_lm(&rt, &exp, &state, d, 1).unwrap();
                assert!(loss.is_finite() && loss > 0.0);
            }
            TaskData::Cls(d) => {
                let (loss, acc) = coordinator::eval_cls(&rt, &exp, &state, d).unwrap();
                assert!(loss.is_finite());
                assert!((0.0..=1.0).contains(&acc));
            }
            TaskData::Sort(d) => {
                let (em, ed) =
                    coordinator::eval_sort_teacher_forced(&rt, &exp, &state, d, 1).unwrap();
                assert!((0.0..=1.0).contains(&em));
                assert!(ed >= 0.0);
            }
        }
    }
}

#[test]
fn checkpoint_roundtrip_resumes_exactly() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let exp = Experiment::load(&dir, "lmw_tiny__local_b16").unwrap();
    let mut data = TaskData::for_experiment(&exp.manifest).unwrap();
    let mut state = exp.init_state(&rt, 2).unwrap();

    // advance 3 steps, checkpoint, advance 2 more recording losses
    let mut batches = Vec::new();
    for i in 0..5 {
        let b = data.train_batch();
        let lits: Vec<_> = b.iter().map(|t| t.to_literal().unwrap()).collect();
        batches.push(lits);
        let _ = i;
    }
    for b in &batches[..3] {
        exp.train_step(&rt, &mut state, 9, b).unwrap();
    }
    let path = std::env::temp_dir().join("sinkhorn_integration.ckpt");
    Checkpoint::capture(&exp.manifest, &state).unwrap().save(&path).unwrap();

    let mut direct = Vec::new();
    for b in &batches[3..] {
        direct.push(exp.train_step(&rt, &mut state, 9, b).unwrap());
    }
    // restore and replay the same two steps: identical losses bit-for-bit
    let mut resumed = Checkpoint::load(&path).unwrap().restore(&exp.manifest).unwrap();
    assert_eq!(resumed.step, 3.0);
    let mut replay = Vec::new();
    for b in &batches[3..] {
        replay.push(exp.train_step(&rt, &mut resumed, 9, b).unwrap());
    }
    assert_eq!(direct, replay, "resume must be exact");
}

#[test]
fn trainer_with_options_produces_curve() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let exp = Experiment::load(&dir, "sstw__vanilla").unwrap();
    let mut data = TaskData::for_experiment(&exp.manifest).unwrap();
    let opts =
        TrainOptions { steps: 8, seed: 3, log_every: 2, verbose: false, checkpoint: None };
    let (_state, report) = coordinator::train_from_scratch(&rt, &exp, &mut data, &opts).unwrap();
    assert!(report.curve.points.len() >= 4);
    assert!(report.steps_per_sec > 0.0);
    assert!(report.ema_loss.is_finite());
}

#[test]
fn server_classifies_batches_concurrently() {
    let dir = require_artifacts!();
    let server = Server::start(
        dir,
        "sstw__sortcut_2x4".into(),
        None,
        BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(3),
            ..Default::default()
        },
        7,
    )
    .unwrap();
    let seq_len = server.handle.seq_len;
    let mut joins = Vec::new();
    for t in 0..3 {
        let h = server.handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for i in 0..6 {
                let toks = vec![((t * 17 + i * 7) % 40 + 4) as i32; seq_len];
                let resp = h.classify(toks).unwrap();
                assert!(resp.label >= 0 && resp.label < 2);
                assert!(resp.batch_size >= 1);
                out.push(resp.label);
            }
            out
        }));
    }
    for j in joins {
        let labels = j.join().unwrap();
        assert_eq!(labels.len(), 6);
    }
    server.shutdown().unwrap();
}

#[test]
fn tcp_frontend_roundtrip() {
    use std::io::{BufRead, BufReader, Write};
    let dir = require_artifacts!();
    let server = Server::start(
        dir,
        "sstw__sinkhorn_b8".into(),
        None,
        BatchPolicy {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(2),
            ..Default::default()
        },
        3,
    )
    .unwrap();
    let seq_len = server.handle.seq_len;
    let fe = sinkhorn::server::TcpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
    let mut conn = std::net::TcpStream::connect(fe.addr).unwrap();
    let toks: Vec<String> = (0..seq_len).map(|i| ((i % 40 + 4) as i32).to_string()).collect();
    conn.write_all(format!("{}\n", toks.join(" ")).as_bytes()).unwrap();
    let mut line = String::new();
    BufReader::new(conn.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.starts_with("label="), "got: {line}");
    // malformed request -> error, connection stays usable
    conn.write_all(b"1 2 nope\n").unwrap();
    let mut line2 = String::new();
    BufReader::new(conn.try_clone().unwrap()).read_line(&mut line2).unwrap();
    assert!(line2.starts_with("error="), "got: {line2}");
    drop(conn);
    drop(fe);
    server.shutdown().unwrap();
}

#[test]
fn gumbel_noise_varies_train_loss_not_eval() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let exp = Experiment::load(&dir, "lmw_tiny__sinkhorn_b8").unwrap();
    let mut data = TaskData::for_experiment(&exp.manifest).unwrap();
    let batch = data.train_batch();
    let lits: Vec<_> = batch.iter().map(|t| t.to_literal().unwrap()).collect();
    // same state, different seeds -> different losses (gumbel is live)
    let s1 = exp.init_state(&rt, 4).unwrap();
    let mut a = exp.init_state(&rt, 4).unwrap();
    let mut b = exp.init_state(&rt, 4).unwrap();
    let la = exp.train_step(&rt, &mut a, 100, &lits).unwrap();
    let lb = exp.train_step(&rt, &mut b, 200, &lits).unwrap();
    assert_ne!(la, lb, "gumbel noise should differ across seeds");
    // eval is deterministic
    if let TaskData::Lm(d) = &mut data {
        let e1 = coordinator::eval_lm(&rt, &exp, &s1, d, 1).unwrap();
        let mut d2 = match TaskData::for_experiment(&exp.manifest).unwrap() {
            TaskData::Lm(d) => d,
            _ => unreachable!(),
        };
        let _ = d2.train_batch(); // advance unrelated stream; eval stream independent? no —
        let _ = e1;
    }
}

#[test]
fn bench_memory_target_runs() {
    let dir = require_artifacts!();
    let opts = sinkhorn::bench::BenchOptions {
        artifacts: dir,
        ..Default::default()
    };
    let rendered = sinkhorn::bench::tables::memory_table(&opts).unwrap();
    assert!(rendered.contains("dense"));
    assert!(rendered.contains("241x") || rendered.contains("240x") || rendered.contains("x"));
}
