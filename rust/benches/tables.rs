//! `cargo bench` target: regenerates every paper table/figure at reduced
//! step budgets (a fast regression of the full `sinkhorn bench --target all`
//! run used for EXPERIMENTS.md). Pass harness args after `--`:
//!   cargo bench --bench tables -- --target table1 --scale 0.3
//!
//! No criterion offline — this is a plain main() harness on
//! `sinkhorn::bench` (see util::stats for the timing substrate).

use sinkhorn::bench::{tables, BenchOptions};
use sinkhorn::runtime::artifacts_dir;
use sinkhorn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let opts = BenchOptions {
        artifacts: args.opt_str("artifacts").map(Into::into).unwrap_or_else(artifacts_dir),
        // default: quick regression pass (≈1/8 of the full budget)
        scale: args.f64("scale", 0.125)?,
        steps: args.opt_str("steps").map(|s| s.parse()).transpose()?,
        seed: 17,
        eval_batches: args.usize("eval-batches", 2)?,
        verbose: args.bool("verbose"),
        // teacher-forced seq2seq eval keeps the bench fast; the example
        // sort_seq2seq and `sinkhorn bench table1` do true greedy decode
        fast_decode: !args.has("full-decode"),
        smoke: args.bool("smoke"),
    };
    // runtime-free targets (engine, memory) run even without artifacts/XLA
    let target = args.str("target", "all");
    let needs_rt = target == "all" || tables::target_needs_runtime(&target);
    let (rt, reg) = tables::load_backend(&opts.artifacts, needs_rt);
    let t0 = std::time::Instant::now();
    if target == "all" {
        tables::run_all(rt.as_ref(), reg.as_ref(), &opts)?;
    } else {
        tables::run_target(rt.as_ref(), reg.as_ref(), &opts, &target)?;
    }
    if let Some(rt) = &rt {
        let (csecs, cn) = *rt.compile_stats.borrow();
        println!("[bench tables] compile: {cn} graphs, {csecs:.1}s");
    }
    println!("[bench tables] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
