//! Microbenchmarks of the pure-Rust blocked engine (DESIGN.md §Engine,
//! §Streaming): naive reference vs the streaming engine (1 thread) vs
//! parallel, plus the SortCut truncated path and the gather kernel in
//! isolation. Runs on any machine — no artifacts, no XLA. The
//! `bench engine` CLI target prints the paper-shaped table (and
//! `BENCH_engine.json`); this harness is for quick iteration on one shape.
//!
//! Run: cargo bench --bench engine [-- --ell N --nb N --d N --iters N]

use sinkhorn::sinkhorn::{
    engine::{gather_block_into, ENGINE_TOL},
    sinkhorn, sinkhorn_attention, sortcut_attention, BlockedView, Mat, SinkhornEngine,
};
use sinkhorn::util::cli::Args;
use sinkhorn::util::rng::Rng;
use sinkhorn::util::stats::{percentile, time_iters};

fn report(label: &str, secs: &mut [f64]) {
    let p50 = percentile(secs, 50.0) * 1e3;
    let p95 = percentile(secs, 95.0) * 1e3;
    println!("{label:<46} p50 {p50:>9.3}ms  p95 {p95:>9.3}ms");
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let ell = args.usize("ell", 2048)?;
    let nb = args.usize("nb", 16)?;
    let d = args.usize("d", 64)?;
    let n_cut = args.usize("n-cut", 2)?;
    let iters = args.usize("iters", 5)?;
    anyhow::ensure!(ell % nb == 0, "--nb must divide --ell");

    let mut rng = Rng::new(7);
    let mk = |rng: &mut Rng| Mat::from_fn(ell, d, |_, _| rng.normal() as f32 * 0.5);
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let r = sinkhorn(&Mat::from_fn(nb, nb, |_, _| rng.normal() as f32), 8);

    let fused = SinkhornEngine::serial();
    let par = SinkhornEngine::auto();
    println!(
        "== engine hot path: ell={ell} nb={nb} d={d} (parallel: {} threads) ==",
        par.threads()
    );

    // correctness gate before timing anything: engine within the epsilon
    // contract of the naive oracle, parallel bit-equal to serial
    let want = sinkhorn_attention(&q, &k, &v, &r, nb, false);
    let got = fused.attention(&q, &k, &v, &r, nb, false);
    let diff = want.max_abs_diff(&got);
    anyhow::ensure!(diff <= ENGINE_TOL, "streaming engine diverged from naive: max-abs {diff}");
    anyhow::ensure!(
        par.attention(&q, &k, &v, &r, nb, false) == got,
        "parallel must equal the serial engine bit for bit"
    );

    let mut t = time_iters(1, iters, || drop(sinkhorn_attention(&q, &k, &v, &r, nb, false)));
    report("attention: naive reference", &mut t);

    let mut out = Mat::zeros(ell, d);
    let mut t = time_iters(1, iters, || fused.attention_into(&q, &k, &v, &r, nb, false, &mut out));
    report("attention: fused (1 thread)", &mut t);

    let mut t = time_iters(1, iters, || par.attention_into(&q, &k, &v, &r, nb, false, &mut out));
    report(&format!("attention: parallel ({} threads)", par.threads()), &mut t);

    let mut t = time_iters(1, iters, || drop(sortcut_attention(&q, &k, &v, &r, nb, n_cut)));
    report(&format!("sortcut n_cut={n_cut}: naive reference"), &mut t);

    let mut t =
        time_iters(1, iters, || par.sortcut_attention_into(&q, &k, &v, &r, nb, n_cut, &mut out));
    report(&format!("sortcut n_cut={n_cut}: parallel engine"), &mut t);

    // the fused gather kernel in isolation (the old clone-scale-add cost)
    let kb = BlockedView::from_seq(&k, nb);
    let b = ell / nb;
    let mut tile = vec![0.0f32; b * d];
    let mut t = time_iters(2, iters.max(10), || {
        for i in 0..nb {
            gather_block_into(r.row(i), &kb, &mut tile);
        }
    });
    report("sort: fused gather, all nb blocks", &mut t);
    Ok(())
}
