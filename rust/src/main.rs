//! `sinkhorn` — the coordinator CLI.
//!
//! Subcommands:
//!   list                         show registered experiments
//!   train  --exp NAME            train one experiment (AOT graphs, no python)
//!   eval   --exp NAME --ckpt F   evaluate a checkpoint
//!   bench  --target tableN|figN|memory|engine|decode|model|serve|backends|all   regenerate paper tables
//!   serve  --exp NAME            run the batched inference demo
//!   serve  --fallback            serve the pure-Rust engine (no artifacts;
//!                                classify + gen verbs over TCP — see rust/README.md)
//!   inspect --exp NAME           dump manifest facts

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use sinkhorn::bench::{self, tables};
use sinkhorn::coordinator::{self, Checkpoint, TrainOptions};
use sinkhorn::data::TaskData;
use sinkhorn::runtime::{artifacts_dir, Experiment, Registry, Runtime};
use sinkhorn::server::{BatchPolicy, ExecMode, Server};
use sinkhorn::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let artifacts = args
        .opt_str("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    match args.subcommand.as_deref() {
        Some("list") => cmd_list(&artifacts),
        Some("train") => cmd_train(args, &artifacts),
        Some("eval") => cmd_eval(args, &artifacts),
        Some("bench") => cmd_bench(args, &artifacts),
        Some("serve") => cmd_serve(args, &artifacts),
        Some("inspect") => cmd_inspect(args, &artifacts),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'\n");
            }
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "sinkhorn — Sparse Sinkhorn Attention (ICML 2020) coordinator

USAGE: sinkhorn <subcommand> [flags]

  list                              experiments in the registry
  train  --exp NAME [--steps N] [--seed S] [--ckpt out.ckpt] [--verbose]
  eval   --exp NAME --ckpt F [--eval-batches N]
  bench  --target table1..table8|fig3|fig4|memory|engine|decode|model|serve|pages|backends|all
         [--scale F] [--steps N] [--fast-decode] [--smoke] [--verbose]
         (engine + decode + model + serve + pages + backends + memory run
          without artifacts/XLA; --smoke = tiny CI shapes, gates on,
          BENCH_*.json untouched)
  serve  --exp NAME | --fallback [--seq-len L] [--nb N] [--threads T]
         [--depth L] [--heads H] [--d-ff F]
         [--backend sinkhorn|routing|local]
         [--ckpt F] [--requests N] [--max-batch B] [--max-wait-ms T]
         [--max-sessions S] [--queue-depth Q] [--mem-budget-mb M]
         [--page-bytes B] [--no-paged] [--no-prefix-share]
         [--gen-deadline-ms D] [--stall-timeout-ms T] [--drain-ms T]
         [--prefill-chunk-tokens N]
         [--idle-timeout-ms T] [--request-batch] [--port P]
         [--http-port P] [--wait]
         (--fallback serves the pure-Rust stack; no artifacts needed.
          --backend picks the sort backend for every layer (DESIGN.md
          §Backends): sinkhorn = the paper's balanced SortNet (default),
          routing = online k-means block clustering, local = the
          window-only baseline. The 'model' verb reports it as
          sort_backend=<name>; an unknown name fails fast with one
          stable 'error=' line.
          The continuous-batching scheduler multiplexes generations
          token by token: --max-sessions caps concurrent decode slots,
          --mem-budget-mb budgets them by real decode-state bytes —
          per-session page reservations on the default paged KV-cache,
          worst-case states with --no-paged —
          --page-bytes sizes K/V pages (0 = one Sinkhorn block each),
          --no-prefix-share disables copy-on-write prompt-prefix reuse,
          --queue-depth bounds the admission queue (overflow -> busy=),
          --prefill-chunk-tokens ingests prompts in block-parallel
          chunks of up to N tokens between decode ticks (DESIGN.md
          §Prefill; 0 = default = one decode step per tick) — streams
          are bit-identical either way, long prompts just stop
          starving active sessions of ticks,
          --request-batch falls back to the legacy wave executor.
          Failure policy (DESIGN.md §Faults): --gen-deadline-ms caps
          each generation's wall clock (0 = none; per-request
          'deadline=<ms>' overrides), --stall-timeout-ms retires
          sessions whose client stopped reading, --drain-ms bounds
          graceful shutdown, --idle-timeout-ms closes silent TCP
          connections (0 = never).
          TCP verbs: '<ids...>' classifies,
          'gen <n> [deadline=<ms>] <ids...>' streams 'tok <i> <id>'
          lines then the 'tokens=' summary, 'model' describes,
          'shutdown' begins a graceful drain ('ok=draining'; with
          --wait the process exits once drained) — full line protocol
          in rust/README.md.
          --http-port serves the HTTP/JSON gateway on its own port
          (POST /v1/classify, POST /v1/generate as SSE 'tok' events +
          'done' summary, GET /v1/model, GET /v1/schema,
          POST /v1/shutdown — routes and the status<->error mapping in
          rust/README.md, DESIGN.md §Gateway); both frontends share one
          scheduler, so TCP and HTTP traffic batch together)
  inspect --exp NAME

  global: --artifacts DIR (default ./artifacts or $SINKHORN_ARTIFACTS)"
    );
}

fn cmd_list(artifacts: &PathBuf) -> Result<()> {
    let reg = Registry::load(artifacts)?;
    println!("{} experiments in {}", reg.entries.len(), artifacts.display());
    let mut cur = String::new();
    for e in &reg.entries {
        if e.table != cur {
            cur = e.table.clone();
            println!("\n[{cur}]");
        }
        println!("  {}", e.name);
    }
    Ok(())
}

fn cmd_train(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let name = args.opt_str("exp").ok_or_else(|| anyhow!("--exp required"))?;
    let rt = Runtime::cpu()?;
    let exp = Experiment::load(artifacts, &name)?;
    let mut data = TaskData::for_experiment(&exp.manifest)?;
    let default_steps = exp.manifest.train_cfg.usize_of("default_steps").unwrap_or(200);
    let opts = TrainOptions {
        steps: args.usize("steps", default_steps)?,
        seed: args.u64("seed", 17)? as i32,
        log_every: args.usize("log-every", 10)?,
        verbose: true,
        checkpoint: args.opt_str("ckpt").map(PathBuf::from),
    };
    println!(
        "training {name}: {} params, {} steps",
        exp.manifest.n_params(),
        opts.steps
    );
    let (_state, report) = coordinator::train_from_scratch(&rt, &exp, &mut data, &opts)?;
    println!(
        "done in {:.1}s ({:.2} steps/s); loss curve: {}",
        report.secs,
        report.steps_per_sec,
        report.curve.sparkline(40)
    );
    Ok(())
}

fn cmd_eval(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let name = args.opt_str("exp").ok_or_else(|| anyhow!("--exp required"))?;
    let ckpt = args.opt_str("ckpt").ok_or_else(|| anyhow!("--ckpt required"))?;
    let rt = Runtime::cpu()?;
    let exp = Experiment::load(artifacts, &name)?;
    let state = Checkpoint::load(&PathBuf::from(ckpt))?.restore(&exp.manifest)?;
    let n = args.usize("eval-batches", 4)?;
    let mut data = TaskData::for_experiment(&exp.manifest)?;
    match &mut data {
        TaskData::Lm(d) => {
            let loss = coordinator::eval_lm(&rt, &exp, &state, d, n)?;
            println!(
                "loss {loss:.4} nats | ppl {:.3} | bpc {:.4}",
                coordinator::perplexity(loss),
                coordinator::bpc(loss)
            );
        }
        TaskData::Cls(d) => {
            let (loss, acc) = coordinator::eval_cls(&rt, &exp, &state, d)?;
            println!("loss {loss:.4} | accuracy {:.2}%", acc * 100.0);
        }
        TaskData::Sort(d) => {
            let (em, ed) = coordinator::eval_sort(&rt, &exp, &state, d, n)?;
            println!("exact match {:.2}% | edit distance {ed:.4}", em * 100.0);
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let target = args.str("target", "all");
    let opts = bench::BenchOptions {
        artifacts: artifacts.clone(),
        scale: args.f64("scale", 1.0)?,
        steps: args.opt_str("steps").map(|s| s.parse()).transpose()?,
        seed: args.u64("seed", 17)? as i32,
        eval_batches: args.usize("eval-batches", 4)?,
        verbose: args.bool("verbose"),
        fast_decode: args.bool("fast-decode"),
        smoke: args.bool("smoke"),
    };
    // runtime + registry are optional (and skipped entirely for the
    // runtime-free targets): engine/memory run on any machine, including
    // offline `xla` stub builds
    let needs_rt = target == "all" || tables::target_needs_runtime(&target);
    let (rt, reg) = tables::load_backend(artifacts, needs_rt);
    if target == "all" {
        tables::run_all(rt.as_ref(), reg.as_ref(), &opts)?;
    } else {
        tables::run_target(rt.as_ref(), reg.as_ref(), &opts, &target)?;
    }
    if let Some(rt) = &rt {
        let (csecs, cn) = *rt.compile_stats.borrow();
        println!("[runtime] compiled {cn} graphs in {csecs:.1}s total");
    }
    Ok(())
}

fn cmd_serve(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let n_requests = args.usize("requests", 256)?;
    let policy = BatchPolicy {
        max_batch: args.usize("max-batch", 32)?,
        max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms", 5)?),
        // the continuous-batching scheduler is the default executor for
        // the pure-Rust backend (DESIGN.md §Scheduler); --request-batch
        // selects the legacy wave executor
        mode: if args.bool("request-batch") {
            ExecMode::RequestBatch
        } else {
            ExecMode::Continuous
        },
        max_sessions: args.usize("max-sessions", 8)?,
        queue_depth: args.usize("queue-depth", 64)?,
        mem_budget: args.usize("mem-budget-mb", 0)?.saturating_mul(1 << 20),
        // failure policy (DESIGN.md §Faults): 0 disables the deadline
        gen_deadline: match args.u64("gen-deadline-ms", 0)? {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
        stall_timeout: std::time::Duration::from_millis(args.u64("stall-timeout-ms", 30_000)?),
        drain: std::time::Duration::from_millis(args.u64("drain-ms", 5_000)?),
        // chunked prompt ingestion between ticks (DESIGN.md §Prefill);
        // 0 = legacy one-decode-step-per-tick prefill
        prefill_chunk_tokens: args.usize("prefill-chunk-tokens", 0)?,
    };
    let seed = args.u64("seed", 17)?;
    // --fallback forces the pure-Rust engine backend; otherwise Server
    // falls back by itself when the experiment's artifacts are unusable
    let server = if args.bool("fallback") {
        let seq_len = args.usize("seq-len", 128)?;
        // an unknown backend fails fast with the stable one-line error=
        // payload (strategy.rs pins its exact shape), so scripts driving
        // the CLI can match on it like the TCP error paths
        let backend = match sinkhorn::sinkhorn::Backend::parse(&args.str("backend", "sinkhorn")) {
            Ok(b) => b,
            Err(line) => {
                eprintln!("{line}");
                std::process::exit(2);
            }
        };
        let cfg = sinkhorn::server::FallbackConfig {
            seq_len,
            nb: args.usize("nb", sinkhorn::server::FallbackConfig::blocks_for(seq_len))?,
            threads: args.usize("threads", 0)?,
            depth: args.usize("depth", 1)?,
            n_heads: args.usize("heads", 1)?,
            d_ff: args.usize("d-ff", 0)?,
            paged: !args.bool("no-paged"),
            page_bytes: args.usize("page-bytes", 0)?,
            prefix_share: !args.bool("no-prefix-share"),
            seed,
            backend,
            ..Default::default()
        };
        println!(
            "serving pure-Rust fallback stack (backend {}, seq_len {}, nb {}, depth {}, \
             heads {}, d_ff {}, paged {}, prefix_share {})",
            cfg.backend.name(),
            cfg.seq_len,
            cfg.nb,
            cfg.depth,
            cfg.n_heads,
            cfg.d_ff,
            cfg.paged,
            cfg.prefix_share
        );
        Server::start_fallback(cfg, policy)?
    } else {
        let name = args.opt_str("exp").ok_or_else(|| anyhow!("--exp required (or --fallback)"))?;
        Server::start(
            artifacts.clone(),
            name,
            args.opt_str("ckpt").map(PathBuf::from),
            policy,
            seed as i32,
        )?
    };
    // optional HTTP/JSON gateway (typed routes + SSE streaming; see
    // server::http, DESIGN.md §Gateway)
    let http = match args.opt_str("http-port") {
        Some(p) => {
            let http_cfg = sinkhorn::server::HttpConfig {
                idle_timeout: match args.u64("idle-timeout-ms", 120_000)? {
                    0 => None,
                    ms => Some(std::time::Duration::from_millis(ms)),
                },
                ..Default::default()
            };
            let fe = sinkhorn::server::HttpFrontend::start_with(
                &format!("127.0.0.1:{p}"),
                server.handle.clone(),
                http_cfg,
            )?;
            println!("http frontend listening on {}", fe.addr);
            Some(fe)
        }
        None => None,
    };
    // optional TCP frontend (line protocol; see server::tcp)
    let tcp = match args.opt_str("port") {
        Some(p) => {
            let tcp_cfg = sinkhorn::server::TcpConfig {
                idle_timeout: match args.u64("idle-timeout-ms", 120_000)? {
                    0 => None,
                    ms => Some(std::time::Duration::from_millis(ms)),
                },
                ..Default::default()
            };
            let fe = sinkhorn::server::TcpFrontend::start_with(
                &format!("127.0.0.1:{p}"),
                server.handle.clone(),
                tcp_cfg,
            )?;
            println!("tcp frontend listening on {}", fe.addr);
            Some(fe)
        }
        None => None,
    };
    if args.bool("wait") {
        // serve until the executor exits — a TCP `shutdown` verb begins
        // the graceful drain that ends it (DESIGN.md §Faults)
        println!("serving until shutdown...");
        while !server.is_finished() {
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        drop(http);
        drop(tcp);
        return server.shutdown();
    }
    // demo traffic: the experiment's own dataset when artifacts exist,
    // seeded synthetic requests otherwise. Only the *artifact load* may
    // fail soft (that's the fallback case); a dataset error on a loaded
    // experiment is a real configuration bug and must abort.
    let seq_len = server.handle.seq_len;
    let mut data = match args.opt_str("exp") {
        Some(name) => match Experiment::load(artifacts, &name) {
            Ok(exp) => Some(TaskData::for_experiment(&exp.manifest)?),
            Err(_) => None,
        },
        None => None,
    };
    let mut rng = sinkhorn::util::rng::Rng::new(seed ^ 0x5E7E);
    let mut latencies = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..n_requests {
        let toks = match &mut data {
            Some(d) => {
                // one request = the first row of a generated batch; the
                // dataset's row length may differ from the server's
                // seq_len (e.g. fallback backend), so slice the row, not
                // the flat buffer, and let the server pad/truncate
                let batch = d.train_batch();
                let row_len = batch[0].shape().get(1).copied().unwrap_or(seq_len);
                batch[0].as_i32()?[..row_len.min(seq_len)].to_vec()
            }
            None => (0..seq_len).map(|_| rng.range_i64(0, 256) as i32).collect(),
        };
        let resp = server.handle.classify(toks)?;
        latencies.push(resp.total.as_secs_f64() * 1e3);
    }
    drop(http);
    drop(tcp);
    let total = t0.elapsed().as_secs_f64();
    if latencies.is_empty() {
        println!("served 0 requests (nothing to report)");
    } else {
        let p50 = sinkhorn::util::stats::percentile(&mut latencies.clone(), 50.0);
        let p99 = sinkhorn::util::stats::percentile(&mut latencies.clone(), 99.0);
        println!(
            "served {n_requests} requests in {total:.2}s ({:.1} req/s) | p50 {p50:.2}ms \
             p99 {p99:.2}ms",
            n_requests as f64 / total
        );
    }
    server.shutdown()?;
    Ok(())
}

fn cmd_inspect(args: &Args, artifacts: &PathBuf) -> Result<()> {
    let name = args.opt_str("exp").ok_or_else(|| anyhow!("--exp required"))?;
    let exp = Experiment::load(artifacts, &name)?;
    let m = &exp.manifest;
    println!("name    : {}", m.name);
    println!("family  : {:?}   table: {}", m.family, m.table);
    println!("variant : {}", m.variant());
    println!("params  : {} leaves, {} total", m.n_leaves(), m.n_params());
    println!("cfg     : {}", m.cfg.to_string());
    println!("train   : {}", m.train_cfg.to_string());
    println!("train inputs:");
    for s in &m.train_batch_inputs {
        println!("  {} {:?} {:?}", s.name, s.shape, s.dtype);
    }
    println!("eval outputs: {:?}", m.eval_outputs);
    if m.n_leaves() == 0 {
        bail!("manifest has no parameters — corrupt artifact?");
    }
    Ok(())
}
