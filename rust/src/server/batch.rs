//! Dynamic batcher: groups inference requests into batches under a
//! (max_batch, max_wait) policy — the classic serving trade-off between
//! latency and throughput. How a gathered batch is *executed* depends on
//! the executor mode ([`ExecMode`], DESIGN.md §Scheduler):
//!
//! * the **artifact** executor runs shape-specialized compiled graphs, so
//!   it assembles full `batch_size` tensors and pads short batches with
//!   dummy rows that are dropped on the way out;
//! * the pure-Rust **request-batch** executor runs each gathered batch to
//!   completion (no padding — the fallback paths take ragged rows
//!   directly), which head-of-line-blocks on the longest generation;
//! * the **continuous** scheduler uses gathering only for intake when its
//!   session table is idle; admitted generations are advanced token by
//!   token, one batched engine pass per tick, under the policy's
//!   slot/queue/memory dimensions below.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Which executor loop the pure-Rust backend runs (DESIGN.md §Scheduler).
/// The artifact backend always uses the request-batch loop — its compiled
/// graphs have no incremental decode entry to tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Token-level continuous batching: a session table, one batched
    /// decode tick at a time, admission control, immediate slot reuse.
    Continuous,
    /// The legacy wave executor: each gathered batch of generate requests
    /// runs to completion before the next is pulled (kept for the
    /// `bench --target serve` comparison and as an escape hatch).
    RequestBatch,
}

/// Batching + scheduling policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Target intake batch size (the artifact executor clamps this to the
    /// compiled graph's batch dim; the scheduler uses it as the per-tick
    /// intake drain bound).
    pub max_batch: usize,
    /// Max time the first request in a gathered batch waits for company.
    pub max_wait: Duration,
    /// Executor mode for the pure-Rust backend.
    pub mode: ExecMode,
    /// Continuous scheduler: slot cap on concurrently active decode
    /// sessions (the memory budget below can clamp it further).
    pub max_sessions: usize,
    /// Continuous scheduler: bound on generations waiting for a slot;
    /// arrivals beyond `slots + queue_depth` in flight get the stable
    /// busy reply instead of waiting unboundedly.
    pub queue_depth: usize,
    /// Continuous scheduler: decode-state memory budget in bytes. Paged
    /// models reserve each session's actual resident peak at admission
    /// (`memory::paged_session_peak_bytes`, net of shared prefix pages —
    /// DESIGN.md §Pages); monolithic models divide the budget by the
    /// worst-case `memory::stack_decode_state_bytes` up front. `0` = no
    /// memory clamp, slots are capped by `max_sessions` alone.
    pub mem_budget: usize,
    /// Continuous scheduler: default wall-clock deadline applied to every
    /// generation from arrival (DESIGN.md §Faults). A request-level
    /// `deadline=` option overrides it; overrunners retire with the
    /// stable `deadline exceeded` error. `None` = no default deadline.
    pub gen_deadline: Option<Duration>,
    /// Continuous scheduler: how long a session's bounded outbox may stay
    /// full (a client that stopped reading) before the session is retired
    /// with the stable `slow client timeout` error. The tick loop never
    /// blocks on a full outbox — the session just pauses (DESIGN.md
    /// §Faults).
    pub stall_timeout: Duration,
    /// Graceful-drain window after shutdown begins: in-flight sessions
    /// may finish for this long; survivors are then aborted with the
    /// stable `server shutting down` error (DESIGN.md §Faults).
    pub drain: Duration,
    /// Continuous scheduler: per-session prompt-token budget for chunked
    /// prefill between decode ticks (DESIGN.md §Prefill, Sarathi-style).
    /// `> 0` routes prompt ingestion through the block-parallel
    /// [`crate::sinkhorn::SinkhornStack::prefill`] path, at most this
    /// many tokens per session per tick, so a long prompt is absorbed in
    /// budgeted chunks without starving active sessions' token cadence.
    /// `0` (the default) keeps the legacy behavior: prompts ride the
    /// tick loop one `decode_step` per tick. Both paths are bit-identical
    /// per stream.
    pub prefill_chunk_tokens: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(5),
            mode: ExecMode::Continuous,
            max_sessions: 8,
            queue_depth: 64,
            mem_budget: 0,
            gen_deadline: None,
            stall_timeout: Duration::from_secs(30),
            drain: Duration::from_secs(5),
            prefill_chunk_tokens: 0,
        }
    }
}

impl BatchPolicy {
    /// Cap `max_batch` at the executor's capacity (e.g. the compiled
    /// graph's batch dimension).
    pub fn clamped(self, cap: usize) -> BatchPolicy {
        BatchPolicy { max_batch: self.max_batch.min(cap), ..self }
    }
}

/// Pull up to `max_batch` items from `rx`, waiting at most `max_wait`
/// after the first item arrives. Blocks indefinitely for the first item;
/// returns `None` when the channel is closed and drained.
pub fn gather<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn gathers_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy =
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50), ..Default::default() };
        let b = gather(&rx, &policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = gather(&rx, &policy).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_partial_batch_on_deadline() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        };
        let t0 = Instant::now();
        let b = gather(&rx, &policy).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn clamped_caps_but_keeps_wait() {
        let base =
            BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(9), ..Default::default() };
        let p = base.clamped(16);
        assert_eq!(p.max_batch, 16);
        assert_eq!(p.max_wait, Duration::from_millis(9));
        assert_eq!(BatchPolicy::default().clamped(1000).max_batch, 32);
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(gather(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = gather(&rx, &BatchPolicy::default()).unwrap();
        assert_eq!(b, vec![7]);
        assert!(gather(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn conservation_under_concurrent_producers() {
        // queue conservation: every sent item appears in exactly one batch
        let (tx, rx) = channel();
        let n_producers = 4;
        let per = 50;
        let mut joins = Vec::new();
        for p in 0..n_producers {
            let tx = tx.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                    if i % 7 == 0 {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
            }));
        }
        drop(tx);
        let policy =
            BatchPolicy { max_batch: 9, max_wait: Duration::from_millis(1), ..Default::default() };
        let mut seen = std::collections::HashSet::new();
        while let Some(batch) = gather(&rx, &policy) {
            assert!(batch.len() <= 9);
            for x in batch {
                assert!(seen.insert(x), "duplicate {x}");
            }
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(seen.len(), n_producers * per, "dropped items");
    }

    #[test]
    fn batch_never_exceeds_graph_capacity() {
        let (tx, rx) = channel();
        for i in 0..100 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let policy =
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1), ..Default::default() };
        let mut count = 0;
        while let Some(b) = gather(&rx, &policy) {
            assert_eq!(b.len(), 1);
            count += 1;
        }
        assert_eq!(count, 100);
    }
}
