//! HTTP/1.1 gateway frontend (DESIGN.md §Gateway).
//!
//! The standard-tooling front door over the same [`ServerHandle`] the
//! TCP line protocol serves: typed JSON requests in (`server::json`),
//! typed JSON responses out, generations streamed as Server-Sent
//! Events over chunked transfer encoding. The route table:
//!
//!   `POST /v1/classify`  {"tokens": [...]}            -> ClassifyResponse
//!   `POST /v1/generate`  {"max_new", "tokens",
//!                         "deadline_ms"?}             -> SSE `tok` events,
//!                                                        then `done` summary
//!   `GET  /v1/model`                                  -> ModelResponse
//!   `GET  /v1/schema`                                 -> machine-readable
//!                                                        route/field listing
//!   `POST /v1/shutdown`                               -> {"ok":"draining"}
//!
//! The table is declared once through the [`routes!`] macro and drives
//! both dispatch and the `/v1/schema` reply, so the schema can never
//! drift from what the dispatcher actually serves.
//!
//! **Failure plane.** Every stable `error=` message of the fault plane
//! (DESIGN.md §Faults) maps to a stable HTTP status and a
//! `{"error": "<same line>"}` JSON body ([`status_for_error`]); the
//! body text is the *same* stable string the TCP frontend emits, so a
//! client can match on either transport. Parser rejections are equally
//! boring: one 4xx with a one-line JSON body, clipped like
//! [`super::tcp::error_line`], never an echo of hostile bytes. Size
//! caps bound every dimension of a request *before* buffering it
//! ([`MAX_REQUEST_LINE`], [`MAX_HEADER_BYTES`], [`HttpConfig::max_body`])
//! — an oversized claim is refused without allocating the claim.
//!
//! **Streaming.** A generate response rides the existing bounded-outbox
//! stream subscriber (DESIGN.md §Faults): the handler blocks on the
//! first token, so admission-time failures (busy, immediate deadline)
//! still get their proper status line; once a token exists the reply
//! commits to `200` + `text/event-stream` and later failures arrive as
//! a terminal SSE `error` event carrying the same stable body. Each
//! event is one chunk, flushed as the scheduler emits it. A client that
//! vanishes mid-stream fails the next chunk write, which cancels the
//! generation — the session retires, its pages return, its admission
//! slot frees (the PR 7 cancel path). The [`FaultPlan::sock_point`]
//! seam is consulted once per event, exactly like the TCP frontend, so
//! the chaos battery drives injected disconnects and stalls through
//! both transports with one schedule.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::faults::{FaultPlan, SockFault, SESSION_PANIC_MSG, STEP_PANIC_MSG};
use super::json::{
    ClassifyRequest, ClassifyResponse, ErrorBody, FieldSchema, FromJson, GenerateRequest,
    GenerateSummary, ModelResponse, RouteSchema, SchemaResponse, ShutdownResponse, ToJson,
    TokEvent,
};
use super::service::{
    GenOptions, ServerHandle, BUSY_MSG, CANCELLED_MSG, DEADLINE_MSG, SHUTDOWN_MSG, STALL_MSG,
};
use super::tcp::IDLE_MSG;
use crate::sinkhorn::pages::ALLOC_FAIL_MSG;

/// Cap on the request line (`METHOD SP PATH SP VERSION`); longer gets
/// the stable 431.
pub const MAX_REQUEST_LINE: usize = 4096;
/// Cap on one header line and on the total header block.
pub const MAX_HEADER_LINE: usize = 4096;
pub const MAX_HEADER_BYTES: usize = 16384;
/// Cap on the header count; more is a 431.
pub const MAX_HEADERS: usize = 64;

/// Per-connection policy (the HTTP twin of [`super::tcp::TcpConfig`]).
#[derive(Clone)]
pub struct HttpConfig {
    /// Read silence between requests longer than this closes the
    /// connection with a 408 `{"error":"idle timeout"}`. `None` = never.
    pub idle_timeout: Option<Duration>,
    /// OS-level write timeout; a timed-out write mid-stream is treated
    /// as a dead client (the generation is cancelled). `None` = block.
    pub write_timeout: Option<Duration>,
    /// Request-body cap (`Content-Length` claim or chunked total);
    /// above it the request is refused with 413 *without reading* the
    /// body.
    pub max_body: usize,
    /// Fault schedule consulted once per SSE event write
    /// ([`FaultPlan::sock_point`]); [`FaultPlan::none`] in production.
    pub faults: FaultPlan,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            idle_timeout: Some(Duration::from_secs(120)),
            write_timeout: Some(Duration::from_secs(30)),
            max_body: 1 << 20,
            faults: FaultPlan::none(),
        }
    }
}

/// One parsed request, body fully read (and capped) off the wire.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub keep_alive: bool,
    pub body: Vec<u8>,
}

/// A request-level failure: the status to send and the stable one-line
/// message for the JSON body.
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    fn new(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError { status, msg: msg.into() }
    }
}

/// Reason phrases for every status the gateway emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Content Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Map a stable scheduler/fault-plane message (DESIGN.md §Faults) to
/// its HTTP status. Every `error=` line the TCP frontend can emit has a
/// row here; anything unrecognized is an internal 500 (the same
/// "never leak internals" posture as [`super::faults::panic_msg`]).
pub fn status_for_error(msg: &str) -> u16 {
    match msg {
        m if m == BUSY_MSG => 429,
        m if m == DEADLINE_MSG => 504,
        m if m == CANCELLED_MSG => 499,
        m if m == STALL_MSG => 408,
        m if m == IDLE_MSG => 408,
        m if m == SHUTDOWN_MSG => 503,
        m if m == STEP_PANIC_MSG || m == SESSION_PANIC_MSG || m == ALLOC_FAIL_MSG => 500,
        _ => 500,
    }
}

/// One stable line for a handler error: outermost message only, capped
/// at 120 chars — the JSON twin of [`super::tcp::error_line`].
fn clip_error(e: &anyhow::Error) -> String {
    let msg = e.to_string();
    let first = msg.lines().next().unwrap_or("internal error");
    first.chars().take(120).collect()
}

/// Render `{"error": ...}` for a handler failure at its mapped status.
pub fn error_response(e: &anyhow::Error) -> (u16, String) {
    let msg = clip_error(e);
    let status = status_for_error(&msg);
    (status, ErrorBody { error: msg }.to_json())
}

// ---------------------------------------------------------------------
// route table
// ---------------------------------------------------------------------

/// Field descriptor for the `/v1/schema` listing.
pub struct Field {
    pub name: &'static str,
    pub kind: &'static str,
    pub required: bool,
}

/// Which handler a route dispatches to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Handler {
    Classify,
    Generate,
    Model,
    Schema,
    Shutdown,
}

/// One row of the dispatch table.
pub struct Route {
    pub method: &'static str,
    pub path: &'static str,
    pub handler: Handler,
    /// Whether a 200 reply may stream as `text/event-stream`.
    pub stream: bool,
    pub request_fields: &'static [Field],
    pub response_fields: &'static [Field],
}

/// Declare the dispatch table once: method, path, handler, stream flag
/// and the request/response field schemas. The same rows drive
/// [`dispatch`] and the `GET /v1/schema` reply, so the published schema
/// is the dispatcher, not documentation about it.
macro_rules! routes {
    ($($method:literal $path:literal => $handler:ident, stream: $stream:literal,
        req: [$(($rn:literal, $rk:literal, $rr:literal)),* $(,)?],
        resp: [$(($pn:literal, $pk:literal)),* $(,)?];)*) => {
        /// The gateway's route table (see [`routes!`]).
        pub const ROUTES: &[Route] = &[
            $(Route {
                method: $method,
                path: $path,
                handler: Handler::$handler,
                stream: $stream,
                request_fields: &[$(Field { name: $rn, kind: $rk, required: $rr }),*],
                response_fields: &[$(Field { name: $pn, kind: $pk, required: true }),*],
            }),*
        ];
    };
}

routes! {
    "POST" "/v1/classify" => Classify, stream: false,
        req: [("tokens", "[i32]", true)],
        resp: [("label", "i32"), ("batch", "u64"), ("queue_us", "u64"), ("total_us", "u64")];
    "POST" "/v1/generate" => Generate, stream: true,
        req: [("max_new", "u64", true), ("tokens", "[i32]", true), ("deadline_ms", "u64", false)],
        resp: [("tokens", "[i32]"), ("batch", "u64"), ("queue_us", "u64"), ("total_us", "u64")];
    "GET" "/v1/model" => Model, stream: false,
        req: [],
        resp: [("info", "str")];
    "GET" "/v1/schema" => Schema, stream: false,
        req: [],
        resp: [("routes", "[route]")];
    "POST" "/v1/shutdown" => Shutdown, stream: false,
        req: [],
        resp: [("ok", "str")];
}

/// Build the `/v1/schema` body from the route table.
pub fn schema_response() -> SchemaResponse {
    fn fields(fs: &[Field]) -> Vec<FieldSchema> {
        fs.iter()
            .map(|f| FieldSchema {
                name: f.name.into(),
                kind: f.kind.into(),
                required: f.required,
            })
            .collect()
    }
    SchemaResponse {
        routes: ROUTES
            .iter()
            .map(|r| RouteSchema {
                method: r.method.into(),
                path: r.path.into(),
                stream: r.stream,
                request: fields(r.request_fields),
                response: fields(r.response_fields),
            })
            .collect(),
    }
}

/// Resolve `(method, path)` against the table: the route, 405 when the
/// path exists under another method, 404 otherwise.
pub fn dispatch(method: &str, path: &str) -> Result<&'static Route, HttpError> {
    // the path (minus any query string) is matched exactly
    let path = path.split('?').next().unwrap_or(path);
    let mut path_seen = false;
    for r in ROUTES {
        if r.path == path {
            if r.method == method {
                return Ok(r);
            }
            path_seen = true;
        }
    }
    if path_seen {
        Err(HttpError::new(405, format!("method not allowed on {path}")))
    } else {
        Err(HttpError::new(404, "no such route"))
    }
}

// ---------------------------------------------------------------------
// wire reading
// ---------------------------------------------------------------------

/// True for the error kinds an expired read/write timeout surfaces as.
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read one CRLF- (or LF-) terminated line of at most `cap` bytes.
/// `Ok(None)` is clean EOF before any byte.
fn read_line_capped(
    r: &mut impl BufRead,
    cap: usize,
    over_status: u16,
    over_msg: &str,
) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    let mut one = [0u8; 1];
    loop {
        match r.read(&mut one) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(HttpError::new(400, "truncated request"));
            }
            Ok(_) => {
                if one[0] == b'\n' {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    let s = String::from_utf8(buf)
                        .map_err(|_| HttpError::new(400, "request is not valid UTF-8"))?;
                    return Ok(Some(s));
                }
                buf.push(one[0]);
                if buf.len() > cap {
                    return Err(HttpError::new(over_status, over_msg.to_string()));
                }
            }
            Err(e) if is_timeout(&e) => {
                return Err(HttpError::new(408, IDLE_MSG));
            }
            Err(_) => return Err(HttpError::new(400, "truncated request")),
        }
    }
}

/// Read the body declared by `Content-Length` (already validated
/// against the cap).
fn read_exact_body(r: &mut impl BufRead, n: usize) -> Result<Vec<u8>, HttpError> {
    let mut body = vec![0u8; n];
    r.read_exact(&mut body).map_err(|e| {
        if is_timeout(&e) {
            HttpError::new(408, IDLE_MSG)
        } else {
            HttpError::new(400, "truncated body")
        }
    })?;
    Ok(body)
}

/// Read a `Transfer-Encoding: chunked` body: hex-size line, that many
/// bytes, CRLF, repeated until the 0 chunk (then trailers until a blank
/// line). Total capped at `max_body`; truncation anywhere is the stable
/// 400.
fn read_chunked_body(r: &mut impl BufRead, max_body: usize) -> Result<Vec<u8>, HttpError> {
    let mut body = Vec::new();
    loop {
        let line = read_line_capped(r, MAX_HEADER_LINE, 400, "bad chunk size")?
            .ok_or_else(|| HttpError::new(400, "truncated chunked body"))?;
        // chunk extensions (";...") are tolerated and ignored
        let size_part = line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_part, 16)
            .map_err(|_| HttpError::new(400, "bad chunk size"))?;
        if size == 0 {
            // trailers: lines until the blank terminator
            loop {
                match read_line_capped(r, MAX_HEADER_LINE, 431, "trailer too large")? {
                    None => return Err(HttpError::new(400, "truncated chunked body")),
                    Some(l) if l.is_empty() => return Ok(body),
                    Some(_) => {}
                }
            }
        }
        if body.len().saturating_add(size) > max_body {
            return Err(HttpError::new(413, "body too large"));
        }
        let start = body.len();
        body.resize(start + size, 0);
        r.read_exact(&mut body[start..])
            .map_err(|_| HttpError::new(400, "truncated chunked body"))?;
        // the CRLF after the chunk data
        let mut crlf = [0u8; 2];
        match r.read_exact(&mut crlf) {
            Ok(()) if &crlf == b"\r\n" => {}
            Ok(()) if crlf[0] == b'\n' => {
                // bare-LF framing: we consumed one byte of the next
                // size line — reject rather than guess
                return Err(HttpError::new(400, "bad chunk framing"));
            }
            _ => return Err(HttpError::new(400, "truncated chunked body")),
        }
    }
}

/// Read one full request off the connection. `Ok(None)` = the client
/// closed cleanly between requests. `writer` is only used for the
/// `Expect: 100-continue` interim reply.
pub fn read_request(
    r: &mut impl BufRead,
    writer: &mut impl Write,
    cfg: &HttpConfig,
) -> Result<Option<HttpRequest>, HttpError> {
    // tolerate blank line(s) before the request line (RFC 9112 §2.2)
    let line = loop {
        match read_line_capped(r, MAX_REQUEST_LINE, 431, "request line too long")? {
            None => return Ok(None),
            Some(l) if l.is_empty() => continue,
            Some(l) => break l,
        }
    };
    let mut parts = line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && !p.is_empty() => {
            (m.to_string(), p.to_string(), v)
        }
        _ => return Err(HttpError::new(400, "malformed request line")),
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, "malformed request line"));
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpError::new(505, "unsupported protocol version")),
    };

    // headers
    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    let mut keep_alive = http11; // 1.1 defaults on, 1.0 defaults off
    let mut expect_continue = false;
    let (mut n_headers, mut header_bytes) = (0usize, 0usize);
    loop {
        let Some(h) = read_line_capped(r, MAX_HEADER_LINE, 431, "header too large")? else {
            return Err(HttpError::new(400, "truncated request"));
        };
        if h.is_empty() {
            break;
        }
        n_headers += 1;
        header_bytes += h.len();
        if n_headers > MAX_HEADERS || header_bytes > MAX_HEADER_BYTES {
            return Err(HttpError::new(431, "too many headers"));
        }
        let Some((name, value)) = h.split_once(':') else {
            return Err(HttpError::new(400, "malformed header"));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                let n: u64 = value
                    .parse()
                    .map_err(|_| HttpError::new(400, "bad content-length"))?;
                if n > cfg.max_body as u64 {
                    // refuse the claim before buffering any of it
                    return Err(HttpError::new(413, "body too large"));
                }
                content_length = Some(n as usize);
            }
            "transfer-encoding" => {
                if value.eq_ignore_ascii_case("chunked") {
                    chunked = true;
                } else {
                    return Err(HttpError::new(400, "unsupported transfer-encoding"));
                }
            }
            "connection" => {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
            "expect" => {
                if value.eq_ignore_ascii_case("100-continue") {
                    expect_continue = true;
                }
            }
            _ => {}
        }
    }
    if chunked && content_length.is_some() {
        return Err(HttpError::new(400, "both content-length and chunked"));
    }
    if expect_continue && (chunked || content_length.unwrap_or(0) > 0) {
        let _ = writer.write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
        let _ = writer.flush();
    }
    let body = if chunked {
        read_chunked_body(r, cfg.max_body)?
    } else {
        match content_length {
            Some(n) => read_exact_body(r, n)?,
            None => Vec::new(),
        }
    };
    Ok(Some(HttpRequest { method, path, keep_alive, body }))
}

// ---------------------------------------------------------------------
// wire writing
// ---------------------------------------------------------------------

/// Write one complete non-streaming response.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        status_reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Write the SSE stream header: 200, `text/event-stream`, chunked.
fn write_sse_header(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nTransfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n",
    )?;
    w.flush()
}

/// Write one SSE event (`event: <name>` + `data: <json>`) as a single
/// chunk, flushed.
fn write_sse_event(w: &mut impl Write, event: &str, data: &str) -> std::io::Result<()> {
    let payload = format!("event: {event}\ndata: {data}\n\n");
    let chunk = format!("{:x}\r\n{payload}\r\n", payload.len());
    w.write_all(chunk.as_bytes())?;
    w.flush()
}

/// Terminate the chunked SSE stream.
fn write_sse_end(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

// ---------------------------------------------------------------------
// frontend
// ---------------------------------------------------------------------

/// A listening HTTP frontend, lifecycle identical to
/// [`super::tcp::TcpFrontend`]: `drop` raises the stop flag, pokes its
/// own listener to unblock `accept`, and joins the acceptor thread.
pub struct HttpFrontend {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<JoinHandle<()>>,
}

impl HttpFrontend {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve under the default
    /// [`HttpConfig`].
    pub fn start(addr: &str, handle: ServerHandle) -> Result<HttpFrontend> {
        HttpFrontend::start_with(addr, handle, HttpConfig::default())
    }

    /// [`Self::start`] with explicit policy (timeouts, body cap, faults).
    pub fn start_with(
        addr: &str,
        handle: ServerHandle,
        cfg: HttpConfig,
    ) -> Result<HttpFrontend> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("binding {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = stop.clone();
        let accept_join = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop_accept.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { break };
                let h = handle.clone();
                let c = cfg.clone();
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, h, &c);
                });
            }
        });
        Ok(HttpFrontend { addr: local, stop, accept_join: Some(accept_join) })
    }
}

impl Drop for HttpFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

/// Decode a request body as UTF-8 then as `T`; failures are stable
/// 400s (the JSON decoder's message is already one clipped line).
fn body_as<T: FromJson>(body: &[u8]) -> Result<T, HttpError> {
    let s = std::str::from_utf8(body)
        .map_err(|_| HttpError::new(400, "body is not valid UTF-8"))?;
    T::from_json(s).map_err(|e| HttpError::new(400, clip_error(&e)))
}

fn serve_conn(stream: TcpStream, handle: ServerHandle, cfg: &HttpConfig) -> Result<()> {
    stream.set_read_timeout(cfg.idle_timeout)?;
    stream.set_write_timeout(cfg.write_timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let req = match read_request(&mut reader, &mut writer, cfg) {
            Ok(None) => return Ok(()), // clean EOF between requests
            Ok(Some(req)) => req,
            Err(he) => {
                // one stable JSON error, then close — a connection that
                // failed mid-parse has no trustworthy framing left
                let body = ErrorBody { error: he.msg }.to_json();
                let _ = write_response(&mut writer, he.status, &body, false);
                return Ok(());
            }
        };
        let keep = req.keep_alive;
        match dispatch(&req.method, &req.path) {
            Err(he) => {
                let body = ErrorBody { error: he.msg }.to_json();
                write_response(&mut writer, he.status, &body, keep)?;
            }
            Ok(route) => match route.handler {
                Handler::Classify => match body_as::<ClassifyRequest>(&req.body) {
                    Err(he) => {
                        let body = ErrorBody { error: he.msg }.to_json();
                        write_response(&mut writer, he.status, &body, keep)?;
                    }
                    Ok(creq) => match handle.classify(creq.tokens) {
                        Ok(r) => {
                            let body = ClassifyResponse {
                                label: r.label,
                                batch: r.batch_size,
                                queue_us: r.queue.as_micros() as u64,
                                total_us: r.total.as_micros() as u64,
                            }
                            .to_json();
                            write_response(&mut writer, 200, &body, keep)?;
                        }
                        Err(e) => {
                            let (status, body) = error_response(&e);
                            write_response(&mut writer, status, &body, keep)?;
                        }
                    },
                },
                Handler::Generate => match body_as::<GenerateRequest>(&req.body) {
                    Err(he) => {
                        let body = ErrorBody { error: he.msg }.to_json();
                        write_response(&mut writer, he.status, &body, keep)?;
                    }
                    Ok(greq) => {
                        if greq.max_new == 0 {
                            let body =
                                ErrorBody { error: "gen count must be positive".into() }.to_json();
                            write_response(&mut writer, 400, &body, keep)?;
                        } else {
                            serve_generate(&mut writer, &handle, cfg, greq, keep)?;
                        }
                    }
                },
                Handler::Model => match handle.model_info() {
                    Ok(r) => {
                        let body = ModelResponse {
                            info: r.info.unwrap_or_else(|| "backend=unknown".into()),
                        }
                        .to_json();
                        write_response(&mut writer, 200, &body, keep)?;
                    }
                    Err(e) => {
                        let (status, body) = error_response(&e);
                        write_response(&mut writer, status, &body, keep)?;
                    }
                },
                Handler::Schema => {
                    write_response(&mut writer, 200, &schema_response().to_json(), keep)?;
                }
                Handler::Shutdown => match handle.begin_shutdown() {
                    Ok(()) => {
                        let body = ShutdownResponse { ok: "draining".into() }.to_json();
                        write_response(&mut writer, 200, &body, keep)?;
                    }
                    Err(e) => {
                        let (status, body) = error_response(&e);
                        write_response(&mut writer, status, &body, keep)?;
                    }
                },
            },
        }
        if !keep {
            return Ok(());
        }
    }
}

/// The generate handler: admission failures and token-free terminal
/// results reply plain JSON at their mapped status; once the first
/// token arrives the reply commits to SSE (`tok` events, then `done` or
/// `error`). See the module docs for the streaming failure contract.
fn serve_generate(
    writer: &mut TcpStream,
    handle: &ServerHandle,
    cfg: &HttpConfig,
    greq: GenerateRequest,
    keep: bool,
) -> Result<()> {
    let opts = GenOptions {
        deadline: greq.deadline_ms.map(Duration::from_millis),
        ..GenOptions::default()
    };
    let sg = match handle.generate_streaming_with(greq.tokens, greq.max_new, opts) {
        Err(e) => {
            let (status, body) = error_response(&e);
            write_response(writer, status, &body, keep)?;
            return Ok(());
        }
        Ok(sg) => sg,
    };
    // block for the first token: a generation that dies before emitting
    // anything (immediate deadline, early fault) still gets its proper
    // status line instead of a 200 stream that only carries an error
    let first = sg.tokens.recv();
    match first {
        Err(_) => {
            // no tokens ever — the terminal result is the whole reply
            // (e.g. the request-batch executor, which streams nothing)
            match sg.reply.recv() {
                Ok(Ok(r)) => {
                    let body = summary_json(&r);
                    write_response(writer, 200, &body, keep)?;
                }
                Ok(Err(e)) => {
                    let (status, body) = error_response(&e);
                    write_response(writer, status, &body, keep)?;
                }
                Err(_) => {
                    let (status, body) = error_response(&anyhow!("server dropped request"));
                    write_response(writer, status, &body, keep)?;
                }
            }
            return Ok(());
        }
        Ok((i0, id0)) => {
            write_sse_header(writer)?;
            let mut pending = Some((i0, id0));
            loop {
                let Some((i, id)) = pending.take() else { break };
                // the same injection seam as the TCP frontend: drop =
                // this client vanishes mid-stream, stall = it stops
                // draining for a while (DESIGN.md §Faults)
                match cfg.faults.sock_point() {
                    Some(SockFault::Drop) => {
                        // the simulated client vanished: cancel and tear
                        // down the connection, exactly like a failed write
                        sg.cancel.cancel();
                        return Err(anyhow!("injected socket drop"));
                    }
                    Some(SockFault::Stall(d)) => std::thread::sleep(d),
                    None => {}
                }
                let data = TokEvent { index: i, id }.to_json();
                if let Err(e) = write_sse_event(writer, "tok", &data) {
                    // dead or hopelessly slow client: cancel so the
                    // scheduler retires the session and frees its pages
                    sg.cancel.cancel();
                    return Err(e.into());
                }
                pending = sg.tokens.iter().next();
            }
            // token channel closed: the terminal event is due
            let (event, data) = match sg.reply.recv() {
                Ok(Ok(r)) => ("done", summary_json(&r)),
                Ok(Err(e)) => ("error", ErrorBody { error: clip_error(&e) }.to_json()),
                Err(_) => ("error", ErrorBody { error: "server dropped request".into() }.to_json()),
            };
            if let Err(e) = write_sse_event(writer, event, &data) {
                sg.cancel.cancel();
                return Err(e.into());
            }
            write_sse_end(writer)?;
        }
    }
    Ok(())
}

fn summary_json(r: &super::service::Response) -> String {
    GenerateSummary {
        tokens: r.gen.clone().unwrap_or_default(),
        batch: r.batch_size,
        queue_us: r.queue.as_micros() as u64,
        total_us: r.total.as_micros() as u64,
    }
    .to_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_stable_error_has_a_status_row() {
        // the full fault-plane vocabulary (DESIGN.md §Faults) maps, and
        // no stable message falls through to the 500 catch-all
        for (msg, want) in [
            (BUSY_MSG, 429),
            (DEADLINE_MSG, 504),
            (CANCELLED_MSG, 499),
            (STALL_MSG, 408),
            (IDLE_MSG, 408),
            (SHUTDOWN_MSG, 503),
            (STEP_PANIC_MSG, 500),
            (SESSION_PANIC_MSG, 500),
            (ALLOC_FAIL_MSG, 500),
        ] {
            assert_eq!(status_for_error(msg), want, "{msg}");
            assert_ne!(status_reason(want), "Unknown", "status {want} needs a reason phrase");
        }
        assert_eq!(status_for_error("anything else"), 500);
    }

    #[test]
    fn error_response_clips_and_maps() {
        let (status, body) = error_response(&anyhow!("{}", BUSY_MSG));
        assert_eq!(status, 429);
        assert_eq!(body, format!("{{\"error\":\"{BUSY_MSG}\"}}"));
        // context chains never leak: outermost frame only, capped
        let chained = anyhow::Error::msg("root /internal/path").context("request failed");
        let (status, body) = error_response(&chained);
        assert_eq!((status, body.as_str()), (500, "{\"error\":\"request failed\"}"));
        let long = anyhow!("{}", "x".repeat(500));
        let (_, body) = error_response(&long);
        assert!(body.len() < 140, "echoed too much: {body}");
    }

    #[test]
    fn dispatch_routes_405_and_404() {
        assert_eq!(dispatch("POST", "/v1/classify").unwrap().handler, Handler::Classify);
        assert_eq!(dispatch("GET", "/v1/model").unwrap().handler, Handler::Model);
        // query strings are ignored for matching
        assert_eq!(dispatch("GET", "/v1/schema?pretty=1").unwrap().handler, Handler::Schema);
        let e = dispatch("GET", "/v1/classify").unwrap_err();
        assert_eq!(e.status, 405);
        let e = dispatch("POST", "/v1/frobnicate").unwrap_err();
        assert_eq!((e.status, e.msg.as_str()), (404, "no such route"));
    }

    #[test]
    fn schema_lists_every_route() {
        let s = schema_response();
        assert_eq!(s.routes.len(), ROUTES.len());
        let gen = s.routes.iter().find(|r| r.path == "/v1/generate").unwrap();
        assert!(gen.stream);
        assert_eq!(gen.method, "POST");
        let names: Vec<&str> = gen.request.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["max_new", "tokens", "deadline_ms"]);
        assert!(!gen.request[2].required, "deadline_ms is optional");
        // and it round-trips through the typed codec the clients use
        let enc = s.to_json();
        let back = SchemaResponse::from_json(&enc).unwrap();
        assert_eq!(back, s);
    }

    fn parse_ok(raw: &str) -> HttpRequest {
        let mut r = std::io::BufReader::new(raw.as_bytes());
        let mut sink = Vec::new();
        read_request(&mut r, &mut sink, &HttpConfig::default()).unwrap().unwrap()
    }

    fn parse_err(raw: &[u8]) -> HttpError {
        let mut r = std::io::BufReader::new(raw);
        let mut sink = Vec::new();
        read_request(&mut r, &mut sink, &HttpConfig::default()).unwrap_err()
    }

    #[test]
    fn parses_content_length_and_chunked_bodies() {
        let req = parse_ok(
            "POST /v1/classify HTTP/1.1\r\nContent-Length: 14\r\n\r\n{\"tokens\":[1]}",
        );
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/classify");
        assert!(req.keep_alive);
        assert_eq!(req.body, b"{\"tokens\":[1]}");

        let req = parse_ok(
            "POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n7\r\n{\"token\r\n7\r\ns\":[1]}\r\n0\r\n\r\n",
        );
        assert_eq!(req.body, b"{\"tokens\":[1]}");

        // HTTP/1.0 defaults to close; Connection: close overrides 1.1
        let req = parse_ok("GET /v1/model HTTP/1.0\r\n\r\n");
        assert!(!req.keep_alive);
        let req = parse_ok("GET /v1/model HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!req.keep_alive);
    }

    #[test]
    fn malformed_inputs_get_stable_statuses() {
        assert_eq!(parse_err(b"GARBAGE\r\n\r\n").status, 400);
        assert_eq!(parse_err(b"GET /too many spaces HTTP/1.1\r\n\r\n").status, 400);
        assert_eq!(parse_err(b"get /v1/model HTTP/1.1\r\n\r\n").status, 400);
        assert_eq!(parse_err(b"GET /v1/model SPDY/3\r\n\r\n").status, 505);
        assert_eq!(parse_err(b"GET /v1/model HTTP/1.1\r\nno colon here\r\n\r\n").status, 400);
        assert_eq!(
            parse_err(b"POST /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n").status,
            400
        );
        // truncated: headers never terminated, body shorter than claimed
        assert_eq!(parse_err(b"GET /v1/model HTTP/1.1\r\nAccept: x\r\n").status, 400);
        assert_eq!(
            parse_err(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort").status,
            400
        );
        // truncated chunked frames
        assert_eq!(
            parse_err(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nff\r\nnope").status,
            400
        );
        assert_eq!(
            parse_err(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\n").status,
            400
        );
        // both framings at once
        assert_eq!(
            parse_err(
                b"POST /x HTTP/1.1\r\nContent-Length: 3\r\nTransfer-Encoding: chunked\r\n\r\nabc"
            )
            .status,
            400
        );
    }

    #[test]
    fn size_caps_refuse_before_buffering() {
        // a 100MB Content-Length claim is refused at the header, 413
        let e = parse_err(b"POST /x HTTP/1.1\r\nContent-Length: 104857600\r\n\r\n");
        assert_eq!((e.status, e.msg.as_str()), (413, "body too large"));
        // an over-long request line is a 431
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_REQUEST_LINE + 10));
        assert_eq!(parse_err(long.as_bytes()).status, 431);
        // an oversized header line is a 431
        let fat = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "b".repeat(MAX_HEADER_LINE + 10));
        assert_eq!(parse_err(fat.as_bytes()).status, 431);
        // too many headers is a 431
        let many = format!(
            "GET / HTTP/1.1\r\n{}\r\n",
            (0..MAX_HEADERS + 1).map(|i| format!("X-{i}: v\r\n")).collect::<String>()
        );
        assert_eq!(parse_err(many.as_bytes()).status, 431);
        // an oversized chunked total is a 413 at the cap, not after
        let chunky = format!(
            "POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n{:x}\r\n",
            (1usize << 20) + 1
        );
        assert_eq!(parse_err(chunky.as_bytes()).status, 413);
    }

    #[test]
    fn clean_eof_is_none_not_an_error() {
        let mut r = std::io::BufReader::new(&b""[..]);
        let mut sink = Vec::new();
        assert!(read_request(&mut r, &mut sink, &HttpConfig::default()).unwrap().is_none());
        // blank lines before EOF are tolerated (RFC 9112 §2.2)
        let mut r = std::io::BufReader::new(&b"\r\n\r\n"[..]);
        assert!(read_request(&mut r, &mut sink, &HttpConfig::default()).unwrap().is_none());
    }

    #[test]
    fn expect_continue_gets_the_interim_reply() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nExpect: 100-continue\r\n\r\nhi";
        let mut r = std::io::BufReader::new(&raw[..]);
        let mut sink = Vec::new();
        let req = read_request(&mut r, &mut sink, &HttpConfig::default()).unwrap().unwrap();
        assert_eq!(req.body, b"hi");
        assert_eq!(&sink[..], b"HTTP/1.1 100 Continue\r\n\r\n");
    }
}
