//! Cross-backend property battery for the pluggable sort backends
//! (DESIGN.md §Backends) — runs with no artifacts and no XLA, in every
//! build. The contract under test:
//!
//! 1. every backend's stack forward sits within 1e-5 max-abs of its
//!    *naive* reference — `reference_stack_forward_with` driven by the
//!    from-scratch mixing oracles (balance.rs for `sinkhorn`,
//!    `routing_mixing` for `routing`, the zero matrix for `local`) — and
//!    the engine attention matches the seed `sinkhorn_attention` under
//!    each backend's mixing matrix;
//! 2. the `sinkhorn` backend routed through the `SortStrategy` trait is
//!    **bitwise identical** to the pre-refactor path: installing it
//!    explicitly changes nothing vs the default stack (whose bitwise
//!    legacy pin lives in `model_props`), forward and per-step decode;
//! 3. every backend is bit-deterministic across engine thread counts;
//! 4. every backend's incremental decode matches the full-prefix
//!    per-token oracle `reference_stack_decode_with`, including SortCut
//!    widths (all three backends are prefix-stable);
//! 5. the `local` backend's decode is bitwise history-independent — its
//!    full-prefix oracle *is* the windowed computation, so a long
//!    session reproduces a fresh block-only session bit for bit;
//! 6. routing cluster assignments are deterministic under the seeded
//!    RNG, prefix-stable, and the strategy's mixing equals the
//!    from-scratch `routing_mixing` oracle bit for bit;
//! 7. mono and paged decode stores agree bitwise per step under every
//!    backend (the §Pages parity contract, extended to the new
//!    strategies).

use sinkhorn::sinkhorn::engine::ENGINE_TOL as TOL;
use sinkhorn::sinkhorn::{
    causal_sinkhorn, reference_stack_decode_with, reference_stack_forward_with,
    routing_assignments, routing_mixing, sinkhorn_attention, Backend, Mat, PagePool, RoutingSort,
    SinkhornEngine, SinkhornStack, SortStrategy, StackConfig, ALL_BACKENDS,
};
use sinkhorn::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
}

fn cfg(
    nb: usize,
    b: usize,
    d_model: usize,
    n_heads: usize,
    depth: usize,
    d_ff: usize,
) -> StackConfig {
    StackConfig {
        seq_len: nb * b,
        d_model,
        n_heads,
        depth,
        d_ff,
        nb,
        sinkhorn_iters: 5,
        causal: false,
        n_cut: None,
    }
}

/// A backend's naive mixing rule as a `reference_stack_forward_with`
/// closure — re-derived from the independent oracles (balance.rs,
/// `routing_mixing`, the zero matrix), never by calling the strategy
/// under test.
fn naive_mix(backend: Backend, nb: usize, causal: bool, iters: usize) -> impl Fn(usize, &Mat) -> Mat {
    let k = RoutingSort::for_blocks(nb).k;
    move |_li, logits: &Mat| match backend {
        Backend::Sinkhorn => {
            if causal {
                causal_sinkhorn(logits, iters, true)
            } else {
                sinkhorn::sinkhorn::balance::sinkhorn(logits, iters)
            }
        }
        Backend::Routing => routing_mixing(logits, logits.rows, k, causal),
        Backend::Local => Mat::zeros(logits.rows, logits.rows),
    }
}

#[test]
fn every_backend_forward_matches_its_naive_reference() {
    let mut rng = Rng::new(0xBAC0);
    for (nb, b, heads, d_head, depth, d_ff) in
        [(4usize, 4usize, 2usize, 4usize, 2usize, 17usize), (6, 3, 1, 8, 1, 0), (9, 2, 2, 3, 2, 11)]
    {
        for causal in [false, true] {
            let mut c = cfg(nb, b, heads * d_head, heads, depth, d_ff);
            c.causal = causal;
            let x = rand_mat(&mut rng, c.seq_len, c.d_model);
            for backend in ALL_BACKENDS {
                let mut stack =
                    SinkhornStack::seeded(c.clone(), 0xBE ^ nb as u64, SinkhornEngine::serial())
                        .unwrap();
                stack.set_strategy(backend.strategy(nb));
                let want = reference_stack_forward_with(
                    &x,
                    &stack.cfg,
                    &stack.layers,
                    naive_mix(backend, nb, causal, c.sinkhorn_iters),
                );
                let mut got = x.clone();
                stack.forward(&mut got);
                let diff = got.max_abs_diff(&want);
                assert!(
                    diff <= TOL,
                    "{} backend (nb={nb}, b={b}, heads={heads}, depth={depth}, d_ff={d_ff}, \
                     causal={causal}): max-abs {diff} vs naive reference",
                    backend.name()
                );
            }
        }
    }
}

#[test]
fn every_backend_engine_attention_matches_the_naive_attention() {
    let mut rng = Rng::new(0xBAC1);
    let (nb, b, d) = (6usize, 5usize, 16usize);
    let ell = nb * b;
    let (q, k, v) =
        (rand_mat(&mut rng, ell, d), rand_mat(&mut rng, ell, d), rand_mat(&mut rng, ell, d));
    let feats = rand_mat(&mut rng, nb, nb);
    let eng = SinkhornEngine::serial();
    for backend in ALL_BACKENDS {
        let strat = backend.strategy(nb);
        for causal in [false, true] {
            let r = strat.mix(&feats, 5, causal);
            let want = sinkhorn_attention(&q, &k, &v, &r, nb, causal);
            let got = eng.attention(&q, &k, &v, &r, nb, causal);
            let diff = got.max_abs_diff(&want);
            assert!(
                diff <= TOL,
                "{} backend (causal={causal}): engine vs naive max-abs {diff}",
                backend.name()
            );
        }
    }
}

/// The acceptance pin: the `sinkhorn` backend routed through the trait is
/// bitwise the pre-refactor path. The default stack (no `set_strategy`
/// call) *is* that path — `model_props` pins it bit for bit against the
/// reconstructed legacy math, and `strategy.rs` unit tests pin the trait
/// methods against the raw balance.rs calls — so installing the strategy
/// explicitly must change nothing: forward and per-step decode.
#[test]
fn sinkhorn_backend_through_trait_is_bitwise_the_prerefactor_path() {
    let mut c = cfg(4, 3, 8, 2, 2, 13);
    c.n_cut = Some(2);
    let mut rng = Rng::new(0xBAC2);
    let x = rand_mat(&mut rng, c.seq_len, c.d_model);
    let mut default_stack =
        SinkhornStack::seeded(c.clone(), 7, SinkhornEngine::serial()).unwrap();
    let mut explicit = SinkhornStack::seeded(c.clone(), 7, SinkhornEngine::serial()).unwrap();
    explicit.set_strategy(Backend::Sinkhorn.strategy(c.nb));
    assert_eq!(explicit.uniform_backend(), Some(Backend::Sinkhorn));

    let mut a = x.clone();
    default_stack.forward(&mut a);
    let mut b = x.clone();
    explicit.forward(&mut b);
    assert_eq!(a, b, "explicit sinkhorn strategy drifted from the default forward");

    let mut st_d = default_stack.decode_state();
    let mut st_e = explicit.decode_state();
    let mut sc_d = default_stack.new_decode_scratch();
    let mut sc_e = explicit.new_decode_scratch();
    let mut out_d = vec![0.0f32; c.d_model];
    let mut out_e = vec![0.0f32; c.d_model];
    for t in 0..c.seq_len {
        default_stack.decode_step(&mut st_d, x.row(t), &mut sc_d, &mut out_d);
        explicit.decode_step(&mut st_e, x.row(t), &mut sc_e, &mut out_e);
        assert_eq!(out_d, out_e, "decode step {t} drifted under the explicit strategy");
    }
}

#[test]
fn every_backend_is_thread_invariant_bitwise() {
    let c = cfg(4, 4, 6, 2, 2, 9);
    let mut rng = Rng::new(0xBAC3);
    let x = rand_mat(&mut rng, c.seq_len, c.d_model);
    for backend in ALL_BACKENDS {
        let forward = |threads: usize| -> Mat {
            let mut stack =
                SinkhornStack::seeded(c.clone(), 0x7E, SinkhornEngine::new(threads)).unwrap();
            stack.set_strategy(backend.strategy(c.nb));
            let mut y = x.clone();
            stack.forward(&mut y);
            y
        };
        let serial = forward(1);
        for threads in [2usize, 5] {
            assert_eq!(
                forward(threads),
                serial,
                "{} backend not thread-invariant at {threads} threads",
                backend.name()
            );
        }
    }
}

#[test]
fn every_backend_decode_matches_the_full_prefix_oracle() {
    let mut rng = Rng::new(0xBAC4);
    let shapes: [(usize, usize, usize, usize, usize, usize, Option<usize>); 3] = [
        (3, 4, 2, 4, 2, 11, None),   // full layers, mid-block end below
        (4, 3, 1, 6, 1, 0, None),    // bare single layer
        (4, 2, 2, 3, 2, 7, Some(2)), // SortCut: all three backends are prefix-stable
    ];
    for (nb, b, heads, d_head, depth, d_ff, cut) in shapes {
        let mut c = cfg(nb, b, heads * d_head, heads, depth, d_ff);
        c.n_cut = cut;
        let total = nb * b - b / 2; // end mid-block
        let x = rand_mat(&mut rng, total, c.d_model);
        for backend in ALL_BACKENDS {
            let mut stack =
                SinkhornStack::seeded(c.clone(), 0xD0 ^ depth as u64, SinkhornEngine::serial())
                    .unwrap();
            stack.set_strategy(backend.strategy(nb));
            let k_clusters = RoutingSort::for_blocks(nb).k;
            let iters = c.sinkhorn_iters;
            let want =
                reference_stack_decode_with(&x, &stack.cfg, &stack.layers, |_li, sl, m| {
                    match backend {
                        Backend::Sinkhorn => {
                            let sub = Mat::from_fn(m, m, |a, cc| sl[(a, cc)]);
                            causal_sinkhorn(&sub, iters, true)
                        }
                        Backend::Routing => routing_mixing(sl, m, k_clusters, true),
                        Backend::Local => Mat::zeros(m, m),
                    }
                });
            let mut st = stack.decode_state();
            let mut scratch = stack.new_decode_scratch();
            let mut out = vec![0.0f32; c.d_model];
            for t in 0..total {
                stack.decode_step(&mut st, x.row(t), &mut scratch, &mut out);
                for (e, &got) in out.iter().enumerate() {
                    let dv = (got - want[(t, e)]).abs();
                    assert!(
                        dv <= TOL,
                        "{} backend (nb={nb}, b={b}, depth={depth}, cut={cut:?}) step {t} \
                         col {e}: diverged from the full-prefix oracle by {dv}",
                        backend.name()
                    );
                }
            }
        }
    }
}

/// The `local` baseline's full-prefix oracle *is* the windowed
/// computation: the zero mixing matrix caches no sorted term
/// (`sorted_rows == 0` at every boundary), so token `t` of block `i` in
/// a long session must reproduce — bit for bit — the same rows decoded
/// into a fresh state that never saw blocks `< i`.
#[test]
fn local_backend_decode_is_bitwise_history_independent() {
    let c = cfg(4, 5, 6, 2, 2, 9);
    let b = c.seq_len / c.nb;
    let mut rng = Rng::new(0xBAC5);
    let x = rand_mat(&mut rng, c.seq_len, c.d_model);
    let mut stack = SinkhornStack::seeded(c.clone(), 0x10CA1, SinkhornEngine::serial()).unwrap();
    stack.set_strategy(Backend::Local.strategy(c.nb));

    let mut st = stack.decode_state();
    let mut scratch = stack.new_decode_scratch();
    let mut out = vec![0.0f32; c.d_model];
    let mut full = Vec::new();
    for t in 0..c.seq_len {
        stack.decode_step(&mut st, x.row(t), &mut scratch, &mut out);
        full.push(out.clone());
    }
    for blk in 0..c.nb {
        let mut fresh = stack.decode_state();
        let mut fresh_scratch = stack.new_decode_scratch();
        for (off, t) in (blk * b..(blk + 1) * b).enumerate() {
            stack.decode_step(&mut fresh, x.row(t), &mut fresh_scratch, &mut out);
            assert_eq!(
                out, full[t],
                "block {blk} token {off}: local decode read history outside its window"
            );
        }
    }
}

#[test]
fn routing_assignments_are_stable_under_the_seeded_rng_and_prefix_stable() {
    let mut rng = Rng::new(0x2007);
    for nb in [4usize, 9, 12] {
        let feats = rand_mat(&mut rng, nb, nb);
        let s = RoutingSort::for_blocks(nb);
        let full = routing_assignments(&feats, nb, s.k);
        // deterministic: no RNG at inference time, same feats -> same clusters
        assert_eq!(full, routing_assignments(&feats, nb, s.k), "nb={nb}: clustering not stable");
        // online: the assignment of block i depends only on blocks <= i
        for m in 1..=nb {
            assert_eq!(
                &routing_assignments(&feats, m, s.k)[..],
                &full[..m],
                "nb={nb}: assignments not prefix-stable at m={m}"
            );
        }
        // the strategy's mixing is the from-scratch oracle, bit for bit
        for causal in [false, true] {
            assert_eq!(
                s.mix(&feats, 5, causal),
                routing_mixing(&feats, nb, s.k, causal),
                "nb={nb} causal={causal}: strategy vs routing_mixing oracle"
            );
        }
        // mix_prefix agrees with the top-left of every longer prefix (the
        // decode boundary-recompute soundness condition)
        let full_prefix = s.mix_prefix(&feats, nb, 5);
        for m in 1..=nb {
            let pm = s.mix_prefix(&feats, m, 5);
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(
                        pm[(i, j)],
                        full_prefix[(i, j)],
                        "nb={nb} m={m}: mix_prefix not prefix-stable at ({i}, {j})"
                    );
                }
            }
        }
    }
}

#[test]
fn paged_and_mono_decode_agree_bitwise_per_step_for_every_backend() {
    let c = cfg(4, 3, 8, 2, 2, 7);
    let mut rng = Rng::new(0xBAC6);
    let x = rand_mat(&mut rng, c.seq_len, c.d_model);
    for backend in ALL_BACKENDS {
        for bpp in [1usize, 2] {
            let mut stack =
                SinkhornStack::seeded(c.clone(), 0xAA, SinkhornEngine::serial()).unwrap();
            stack.set_strategy(backend.strategy(c.nb));
            let pool = PagePool::new();
            let mut mono = stack.decode_state();
            let mut paged = stack.decode_state_paged(&pool, bpp);
            let mut sc_m = stack.new_decode_scratch();
            let mut sc_p = stack.new_decode_scratch();
            let mut out_m = vec![0.0f32; c.d_model];
            let mut out_p = vec![0.0f32; c.d_model];
            for t in 0..c.seq_len {
                stack.decode_step(&mut mono, x.row(t), &mut sc_m, &mut out_m);
                stack.decode_step(&mut paged, x.row(t), &mut sc_p, &mut out_p);
                assert_eq!(
                    out_m, out_p,
                    "{} backend: mono vs paged diverged at step {t} (bpp={bpp})",
                    backend.name()
                );
            }
        }
    }
}
