//! TCP line-protocol frontend for the inference service.
//!
//! One request per UTF-8 line; the full protocol (every request form and
//! every reply, with a scripted example) is documented in
//! `rust/README.md`. Summary:
//!
//!   classify:  `<id> <id> <id> ...`            (bare space-separated ids)
//!   generate:  `gen <max_new> [deadline=<ms>] <id> <id> ...`
//!   info:      `model`                          (served model description)
//!   drain:     `shutdown`                       (begin graceful shutdown)
//!
//!   replies:   `label=<k> batch=<n> queue_us=<q> total_us=<t>`
//!              `tok <i> <id>` (zero or more, streamed per generated token)
//!              `tokens=<id>,<id>,... batch=<n> queue_us=<q> total_us=<t>`
//!              `backend=<fallback|artifact> <key>=<value> ...`
//!              `ok=draining`
//!              `busy=generation queue full`
//!              `error=<one stable line>`
//!
//! A `gen` request is the protocol's one multi-line reply (DESIGN.md
//! §Scheduler): under the continuous scheduler the frontend writes one
//! `tok <i> <id>` line the moment token `i` is produced, then the
//! historical `tokens=...` summary line — kept for compatibility, so a
//! client that only reads the summary still works by skipping `tok `
//! lines (the request-batch executor and the artifact backend emit no
//! `tok ` lines at all). Admission overflow gets the stable one-line
//! `busy=` reply ([`busy_line`]).
//!
//! Error replies are deliberately boring: one line, outermost message
//! only, length-capped ([`error_line`]) — internal context chains and
//! hostile request bytes never echo back to clients.
//!
//! Each accepted connection gets its own thread that forwards requests to
//! the shared [`ServerHandle`] (the dynamic batcher merges concurrent
//! streams into executor batches, classify and generate alike). The
//! frontend is the serving stack's client-failure boundary (DESIGN.md
//! §Faults): accepted sockets carry read/write timeouts
//! ([`TcpConfig`]) — an idle connection gets the stable
//! `error=idle timeout` line and closes; a write failure mid-stream
//! (client gone, or a write timeout on a sink that stopped draining)
//! cancels the in-flight generation so the scheduler retires it and its
//! pages return. A seeded [`FaultPlan`] injects mid-stream disconnects
//! and stalls at the same seam for the chaos tests.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::faults::{FaultPlan, SockFault};
use super::service::{GenOptions, ServerHandle, BUSY_MSG};

/// Stable error for a connection that sent nothing for the configured
/// idle window: one `error=idle timeout` line, then close.
pub const IDLE_MSG: &str = "idle timeout";

/// Per-connection socket policy (DESIGN.md §Faults).
#[derive(Clone)]
pub struct TcpConfig {
    /// How long a connection may sit between requests before it is closed
    /// with the stable [`IDLE_MSG`] line. `None` = never.
    pub idle_timeout: Option<Duration>,
    /// OS-level write timeout on reply/token writes; a timed-out write is
    /// treated like a dead client (the generation is cancelled). `None` =
    /// block forever.
    pub write_timeout: Option<Duration>,
    /// Fault-injection schedule consulted once per `tok` line write
    /// ([`FaultPlan::sock_point`]); [`FaultPlan::none`] in production.
    pub faults: FaultPlan,
}

impl Default for TcpConfig {
    fn default() -> Self {
        TcpConfig {
            idle_timeout: Some(Duration::from_secs(120)),
            write_timeout: Some(Duration::from_secs(30)),
            faults: FaultPlan::none(),
        }
    }
}

/// A listening TCP frontend. `TcpListener::incoming` has no portable
/// cancellation, so shutdown works by *poke*: `drop` raises the stop
/// flag, makes one throwaway connection to its own listener to unblock
/// `accept`, and joins the acceptor — the thread no longer outlives the
/// frontend. Connection handlers exit when clients disconnect or idle
/// out; requests after the backing [`ServerHandle`]'s server shuts down
/// get `error=` replies.
pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<JoinHandle<()>>,
}

/// A parsed protocol line.
#[derive(Debug, Clone, PartialEq)]
pub enum ParsedRequest {
    /// The original bare-ids form: classify the sequence.
    Classify(Vec<i32>),
    /// `gen <max_new> [deadline=<ms>] <ids...>`: greedily decode up to
    /// `max_new` tokens, optionally under a per-request wall-clock
    /// deadline (DESIGN.md §Faults).
    Generate { max_new: usize, tokens: Vec<i32>, deadline_ms: Option<u64> },
    /// `model`: describe the served model (backend, depth, heads, config).
    ModelInfo,
    /// `shutdown`: begin graceful drain shutdown; replies `ok=draining`.
    Shutdown,
}

/// Longest slice of client input echoed back inside an error message.
const ECHO_CAP: usize = 24;

/// Clip a client token for inclusion in an error reply: at most
/// [`ECHO_CAP`] characters, so an overflowing or garbage line cannot
/// inflate the response.
fn clip(t: &str) -> String {
    if t.chars().count() <= ECHO_CAP {
        t.to_string()
    } else {
        let head: String = t.chars().take(ECHO_CAP).collect();
        format!("{head}...")
    }
}

fn parse_id(t: &str) -> Result<i32> {
    t.parse::<i32>().map_err(|_| anyhow!("bad token '{}'", clip(t)))
}

/// Parse one request line. Rejections are stable one-line messages:
/// `empty request`, `bad token '...'` (non-numeric or overflowing ids),
/// `unknown verb '...'`, `gen needs a token count`, `bad count '...'`,
/// `bad deadline '...'`, `model takes no arguments`, `shutdown takes no
/// arguments`.
pub fn parse_request(line: &str) -> Result<ParsedRequest> {
    let mut toks = line.split_whitespace().peekable();
    let Some(first) = toks.next() else {
        bail!("empty request");
    };
    if first == "model" {
        if toks.next().is_some() {
            bail!("model takes no arguments");
        }
        return Ok(ParsedRequest::ModelInfo);
    }
    if first == "shutdown" {
        if toks.next().is_some() {
            bail!("shutdown takes no arguments");
        }
        return Ok(ParsedRequest::Shutdown);
    }
    if first == "gen" {
        let n = toks.next().context("gen needs a token count")?;
        let max_new: usize = n.parse().map_err(|_| anyhow!("bad count '{}'", clip(n)))?;
        if max_new == 0 {
            bail!("gen count must be positive");
        }
        let mut deadline_ms = None;
        if let Some(opt) = toks.peek().and_then(|t| t.strip_prefix("deadline=")) {
            deadline_ms =
                Some(opt.parse::<u64>().map_err(|_| anyhow!("bad deadline '{}'", clip(opt)))?);
            toks.next();
        }
        let tokens = toks.map(parse_id).collect::<Result<Vec<i32>>>()?;
        return Ok(ParsedRequest::Generate { max_new, tokens, deadline_ms });
    }
    // bare ids = classify. A leading token that does not even look like a
    // number is a verb we don't know, not a bad id.
    if first.parse::<i32>().is_err()
        && !first.starts_with(|c: char| c.is_ascii_digit() || c == '-' || c == '+')
    {
        bail!("unknown verb '{}'", clip(first));
    }
    let tokens =
        std::iter::once(first).chain(toks).map(parse_id).collect::<Result<Vec<i32>>>()?;
    Ok(ParsedRequest::Classify(tokens))
}

/// Render a classify response line.
pub fn format_response(label: i32, batch: usize, queue_us: u128, total_us: u128) -> String {
    format!("label={label} batch={batch} queue_us={queue_us} total_us={total_us}\n")
}

/// Render a generate response line (`tokens=` stays empty when the
/// capacity-clamped budget produced nothing).
pub fn format_gen_response(
    tokens: &[i32],
    batch: usize,
    queue_us: u128,
    total_us: u128,
) -> String {
    let ids =
        tokens.iter().map(|t| t.to_string()).collect::<Vec<String>>().join(",");
    format!("tokens={ids} batch={batch} queue_us={queue_us} total_us={total_us}\n")
}

/// Render an error reply: exactly one line, the *outermost* error message
/// only (never the `{:#}` context chain, which names internal modules and
/// file paths), capped at 120 characters. Every `error=` the frontend
/// emits goes through here.
pub fn error_line(e: &anyhow::Error) -> String {
    let msg = e.to_string();
    let first = msg.lines().next().unwrap_or("internal error");
    let capped: String = first.chars().take(120).collect();
    format!("error={capped}\n")
}

/// The stable admission-overflow reply (DESIGN.md §Scheduler): scripts
/// match on this exact line to implement backoff.
pub fn busy_line() -> String {
    format!("busy={BUSY_MSG}\n")
}

/// Render a generate-path failure: admission overflow gets the stable
/// [`busy_line`]; everything else the ordinary [`error_line`].
pub fn gen_error_line(e: &anyhow::Error) -> String {
    if e.to_string() == BUSY_MSG {
        busy_line()
    } else {
        error_line(e)
    }
}

/// One streamed token line: `tok <index> <id>` (DESIGN.md §Scheduler).
pub fn format_tok_line(index: usize, id: i32) -> String {
    format!("tok {index} {id}\n")
}

impl TcpFrontend {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve
    /// under the default [`TcpConfig`].
    pub fn start(addr: &str, handle: ServerHandle) -> Result<TcpFrontend> {
        TcpFrontend::start_with(addr, handle, TcpConfig::default())
    }

    /// [`Self::start`] with explicit socket policy (timeouts, faults).
    pub fn start_with(
        addr: &str,
        handle: ServerHandle,
        cfg: TcpConfig,
    ) -> Result<TcpFrontend> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = stop.clone();
        let accept_join = std::thread::spawn(move || {
            for conn in listener.incoming() {
                // the shutdown poke connects and is dropped unserved
                if stop_accept.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { break };
                let h = handle.clone();
                let c = cfg.clone();
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, h, &c);
                });
            }
        });
        Ok(TcpFrontend { addr: local, stop, accept_join: Some(accept_join) })
    }
}

impl Drop for TcpFrontend {
    /// Stop accepting and join the acceptor: raise the stop flag, then
    /// poke our own listener with a throwaway connection so the blocking
    /// `accept` wakes up and observes the flag. In-flight connection
    /// handlers are unaffected — they finish their clients on their own
    /// threads.
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

/// True for the error kinds an expired `SO_RCVTIMEO`/`SO_SNDTIMEO`
/// surfaces as (platform-dependent: `WouldBlock` on Unix, `TimedOut`
/// elsewhere).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

fn serve_conn(stream: TcpStream, handle: ServerHandle, cfg: &TcpConfig) -> Result<()> {
    stream.set_read_timeout(cfg.idle_timeout)?;
    stream.set_write_timeout(cfg.write_timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e) if is_timeout(&e) => {
                // idle cap: tell the client why before closing (best
                // effort — it may be gone entirely)
                let _ = writer.write_all(error_line(&anyhow!("{IDLE_MSG}")).as_bytes());
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
        let reply = match parse_request(&line) {
            Err(e) => error_line(&e),
            Ok(ParsedRequest::Classify(tokens)) => match handle.classify(tokens) {
                Ok(r) => format_response(
                    r.label,
                    r.batch_size,
                    r.queue.as_micros(),
                    r.total.as_micros(),
                ),
                Err(e) => error_line(&e),
            },
            Ok(ParsedRequest::Generate { max_new, tokens, deadline_ms }) => {
                // the streamed reply: one `tok <i> <id>` line per produced
                // token (flushed immediately — the continuous scheduler
                // emits them as its ticks complete), then the historical
                // `tokens=` summary line for compatibility
                let opts = GenOptions {
                    deadline: deadline_ms.map(Duration::from_millis),
                    ..GenOptions::default()
                };
                match handle.generate_streaming_with(tokens, max_new, opts) {
                    Err(e) => gen_error_line(&e),
                    Ok(sg) => {
                        for (i, id) in sg.tokens.iter() {
                            // the injection seam the chaos tests drive:
                            // drop = this client vanishes mid-stream,
                            // stall = it stops draining for a while
                            match cfg.faults.sock_point() {
                                Some(SockFault::Drop) => {
                                    sg.cancel.cancel();
                                    return Ok(());
                                }
                                Some(SockFault::Stall(d)) => std::thread::sleep(d),
                                None => {}
                            }
                            let w = writer
                                .write_all(format_tok_line(i, id).as_bytes())
                                .and_then(|()| writer.flush());
                            if let Err(e) = w {
                                // dead or hopelessly slow client: retire
                                // the generation, free its pages
                                sg.cancel.cancel();
                                return Err(e.into());
                            }
                        }
                        // the token channel closed: the summary reply is due
                        match sg.reply.recv() {
                            Ok(Ok(r)) => format_gen_response(
                                r.gen.as_deref().unwrap_or(&[]),
                                r.batch_size,
                                r.queue.as_micros(),
                                r.total.as_micros(),
                            ),
                            Ok(Err(e)) => gen_error_line(&e),
                            Err(_) => gen_error_line(&anyhow!("server dropped request")),
                        }
                    }
                }
            }
            Ok(ParsedRequest::ModelInfo) => match handle.model_info() {
                // the payload is already one `key=value ...` line
                Ok(r) => format!("{}\n", r.info.as_deref().unwrap_or("backend=unknown")),
                Err(e) => error_line(&e),
            },
            Ok(ParsedRequest::Shutdown) => match handle.begin_shutdown() {
                Ok(()) => "ok=draining\n".to_string(),
                Err(e) => error_line(&e),
            },
        };
        if let Err(e) = writer.write_all(reply.as_bytes()).and_then(|()| writer.flush()) {
            return Err(e.into());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_classify_valid() {
        assert_eq!(
            parse_request("1 2 3\n").unwrap(),
            ParsedRequest::Classify(vec![1, 2, 3])
        );
        assert_eq!(parse_request("  7  \n").unwrap(), ParsedRequest::Classify(vec![7]));
        assert_eq!(parse_request("-4 +2\n").unwrap(), ParsedRequest::Classify(vec![-4, 2]));
    }

    #[test]
    fn parse_gen_valid() {
        assert_eq!(
            parse_request("gen 5 1 2 3\n").unwrap(),
            ParsedRequest::Generate { max_new: 5, tokens: vec![1, 2, 3], deadline_ms: None }
        );
        // empty prompt is allowed: the model decodes from PAD
        assert_eq!(
            parse_request("gen 2\n").unwrap(),
            ParsedRequest::Generate { max_new: 2, tokens: vec![], deadline_ms: None }
        );
    }

    #[test]
    fn parse_gen_deadline_option() {
        assert_eq!(
            parse_request("gen 5 deadline=250 1 2\n").unwrap(),
            ParsedRequest::Generate { max_new: 5, tokens: vec![1, 2], deadline_ms: Some(250) }
        );
        // deadline with an empty prompt
        assert_eq!(
            parse_request("gen 3 deadline=0\n").unwrap(),
            ParsedRequest::Generate { max_new: 3, tokens: vec![], deadline_ms: Some(0) }
        );
        let e = parse_request("gen 5 deadline=soon 1\n").unwrap_err();
        assert_eq!(e.to_string(), "bad deadline 'soon'");
        // the option is only recognized right after the count — anywhere
        // else it is a (bad) token like any other garbage
        let e = parse_request("gen 5 1 deadline=9\n").unwrap_err();
        assert_eq!(e.to_string(), "bad token 'deadline=9'");
    }

    #[test]
    fn parse_shutdown_valid_and_strict() {
        assert_eq!(parse_request("shutdown\n").unwrap(), ParsedRequest::Shutdown);
        assert_eq!(parse_request("  shutdown  \n").unwrap(), ParsedRequest::Shutdown);
        let e = parse_request("shutdown now\n").unwrap_err();
        assert_eq!(e.to_string(), "shutdown takes no arguments");
    }

    #[test]
    fn parse_model_info_valid_and_strict() {
        assert_eq!(parse_request("model\n").unwrap(), ParsedRequest::ModelInfo);
        assert_eq!(parse_request("  model  \n").unwrap(), ParsedRequest::ModelInfo);
        let e = parse_request("model 1 2\n").unwrap_err();
        assert_eq!(e.to_string(), "model takes no arguments");
    }

    #[test]
    fn parse_rejects_empty_lines() {
        for line in ["", "\n", "   \n", " \t \n"] {
            let e = parse_request(line).unwrap_err();
            assert_eq!(e.to_string(), "empty request", "line {line:?}");
        }
    }

    #[test]
    fn parse_rejects_overflowing_ids() {
        // i32 overflow in classify and gen positions, usize overflow in count
        let e = parse_request("1 99999999999999999999 3\n").unwrap_err();
        assert_eq!(e.to_string(), "bad token '99999999999999999999'");
        let e = parse_request("gen 3 99999999999999999999\n").unwrap_err();
        assert_eq!(e.to_string(), "bad token '99999999999999999999'");
        let e = parse_request("gen 99999999999999999999999999 1\n").unwrap_err();
        assert!(e.to_string().starts_with("bad count '"), "{e}");
    }

    #[test]
    fn parse_rejects_unknown_verbs_and_bad_counts() {
        let e = parse_request("frobnicate 1 2\n").unwrap_err();
        assert_eq!(e.to_string(), "unknown verb 'frobnicate'");
        // numeric-looking garbage stays a token error, not a verb error
        let e = parse_request("12x 3\n").unwrap_err();
        assert_eq!(e.to_string(), "bad token '12x'");
        let e = parse_request("gen x 1\n").unwrap_err();
        assert_eq!(e.to_string(), "bad count 'x'");
        let e = parse_request("gen 0 1\n").unwrap_err();
        assert_eq!(e.to_string(), "gen count must be positive");
        let e = parse_request("gen\n").unwrap_err();
        assert_eq!(e.to_string(), "gen needs a token count");
    }

    #[test]
    fn error_replies_are_one_stable_line() {
        // hostile input is clipped before it reaches the reply
        let long = "z".repeat(500);
        let e = parse_request(&format!("{long} 1\n")).unwrap_err();
        let reply = error_line(&e);
        assert!(reply.len() < 60, "echoed too much: {reply}");
        assert_eq!(reply.matches('\n').count(), 1);
        assert!(reply.starts_with("error=unknown verb 'zzzz"));
        // context chains never leak: only the outermost frame is rendered
        let chained = anyhow::Error::msg("root cause with /internal/path")
            .context("middle frame")
            .context("request failed");
        let reply = error_line(&chained);
        assert_eq!(reply, "error=request failed\n");
    }

    #[test]
    fn response_formats() {
        assert_eq!(
            format_response(1, 8, 120, 4500),
            "label=1 batch=8 queue_us=120 total_us=4500\n"
        );
        assert_eq!(
            format_gen_response(&[4, 8, 15], 2, 10, 99),
            "tokens=4,8,15 batch=2 queue_us=10 total_us=99\n"
        );
        assert_eq!(format_gen_response(&[], 1, 0, 1), "tokens= batch=1 queue_us=0 total_us=1\n");
        assert_eq!(format_tok_line(0, 42), "tok 0 42\n");
        assert_eq!(format_tok_line(7, -3), "tok 7 -3\n");
    }

    #[test]
    fn busy_maps_to_its_own_stable_line() {
        assert_eq!(busy_line(), "busy=generation queue full\n");
        // the scheduler's admission error maps to busy=, nothing else does
        assert_eq!(gen_error_line(&anyhow!("{}", BUSY_MSG)), busy_line());
        let other = anyhow!("exec failed: boom");
        assert_eq!(gen_error_line(&other), error_line(&other));
        assert_eq!(busy_line().matches('\n').count(), 1);
    }

    /// End to end over a real socket: a `gen` request streams `tok` lines
    /// (indices in order, ids matching the summary), then the `tokens=`
    /// summary; classify stays single-line on the same connection.
    #[test]
    fn tcp_gen_streams_tok_lines_then_summary() {
        use crate::server::{BatchPolicy, FallbackConfig, Server};
        use std::io::{BufRead, BufReader, Write};
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        let fe = TcpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
        let mut conn = std::net::TcpStream::connect(fe.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"gen 4 1 2 3\n").unwrap();
        let mut tok_ids = Vec::new();
        let summary = loop {
            let mut l = String::new();
            reader.read_line(&mut l).unwrap();
            if let Some(rest) = l.strip_prefix("tok ") {
                let mut parts = rest.split_whitespace();
                let idx: usize = parts.next().unwrap().parse().unwrap();
                let id: i32 = parts.next().unwrap().parse().unwrap();
                assert_eq!(idx, tok_ids.len(), "tok indices must stream in order");
                tok_ids.push(id);
            } else {
                break l;
            }
        };
        assert!(summary.starts_with("tokens="), "got: {summary}");
        assert_eq!(tok_ids.len(), 4);
        let summary_ids: Vec<i32> = summary
            .split_whitespace()
            .next()
            .unwrap()
            .trim_start_matches("tokens=")
            .split(',')
            .map(|t| t.parse().unwrap())
            .collect();
        assert_eq!(tok_ids, summary_ids, "streamed ids must match the summary line");
        // the connection stays usable for single-line verbs
        conn.write_all(b"5 6 7\n").unwrap();
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        assert!(l.starts_with("label="), "got: {l}");
        drop(conn);
        drop(fe);
        server.shutdown().unwrap();
    }

    /// Dropping the frontend joins its acceptor (the shutdown poke): the
    /// listener is actually closed, so the port refuses new connections.
    #[test]
    fn dropping_the_frontend_stops_accepting() {
        use crate::server::{BatchPolicy, FallbackConfig, Server};
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        let fe = TcpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
        let addr = fe.addr;
        drop(fe); // blocks until the acceptor thread has exited
        // the listener is gone: connect now fails (or is reset on first
        // use when the OS raced us an accept into the dead backlog)
        let refused = match std::net::TcpStream::connect(addr) {
            Err(_) => true,
            Ok(mut s) => {
                let _ = s.write_all(b"model\n");
                let mut buf = String::new();
                BufReader::new(&mut s).read_line(&mut buf).map(|n| n == 0).unwrap_or(true)
            }
        };
        assert!(refused, "acceptor survived the frontend drop");
        server.shutdown().unwrap();
    }

    /// An idle connection is closed with the stable one-line reason.
    #[test]
    fn idle_connection_gets_the_stable_timeout_line() {
        use crate::server::{BatchPolicy, FallbackConfig, Server};
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        let tcfg = TcpConfig { idle_timeout: Some(Duration::from_millis(50)), ..Default::default() };
        let fe = TcpFrontend::start_with("127.0.0.1:0", server.handle.clone(), tcfg).unwrap();
        let conn = std::net::TcpStream::connect(fe.addr).unwrap();
        let mut reader = BufReader::new(conn);
        let mut l = String::new();
        reader.read_line(&mut l).unwrap(); // blocks until the server times us out
        assert_eq!(l, format!("error={IDLE_MSG}\n"));
        // then the connection closes for good
        l.clear();
        assert_eq!(reader.read_line(&mut l).unwrap(), 0);
        drop(fe);
        server.shutdown().unwrap();
    }

    /// The shutdown verb begins a drain: the reply is `ok=draining` and
    /// the executor exits on its own (no `Server::shutdown` call needed
    /// to unblock it).
    #[test]
    fn shutdown_verb_drains_the_server() {
        use crate::server::{BatchPolicy, FallbackConfig, Server};
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        let fe = TcpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
        let mut conn = std::net::TcpStream::connect(fe.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"shutdown\n").unwrap();
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        assert_eq!(l, "ok=draining\n");
        // the drained executor refuses further work with the stable error
        conn.write_all(b"gen 3 1 2\n").unwrap();
        l.clear();
        reader.read_line(&mut l).unwrap();
        assert!(
            l == format!("error={}\n", crate::server::service::SHUTDOWN_MSG)
                || l.starts_with("error=server "),
            "got: {l}"
        );
        let t0 = std::time::Instant::now();
        while !server.is_finished() {
            assert!(t0.elapsed() < Duration::from_secs(10), "drain never finished");
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(fe);
        server.shutdown().unwrap();
    }
}
