"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the *correctness ground truth*: each Pallas kernel in
``sinkhorn_kernel.py`` / ``attention_kernel.py`` / ``sortcut_kernel.py`` is
tested (pytest + hypothesis) to match its oracle here to float tolerance.
They are also used as the backward rule (``jax.vjp``) for the small kernels
where a dedicated backward Pallas kernel is not worth the VMEM traffic
(Sinkhorn balancing is O(N_B^2 * k) — tiny next to the O(ell*b) attention).

Shape conventions (single head; batching/heads handled by the callers):
  - ``ell``  : sequence length
  - ``nb``   : number of blocks (paper: N_B)
  - ``b``    : block length, ``ell = nb * b``
  - ``d``    : head dimension
  - blocked tensors are ``(nb, b, d)``; sort matrices are ``(nb, nb)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Sinkhorn balancing (paper §3.1.1 / §3.3.2)
# ---------------------------------------------------------------------------


def sinkhorn_log(logits: jnp.ndarray, n_iters: int) -> jnp.ndarray:
    """Log-domain Sinkhorn normalization of ``logits`` (nb, nb).

    Returns a (relaxed) doubly-stochastic matrix ``S = lim F_c(F_r(exp R))``.
    ``n_iters == 0`` reproduces the paper's ablation row (6): plain
    ``softmax`` over rows (exp + row-normalize once) so the result is at
    least row-stochastic and usable as a mixing matrix.
    """
    log_s = logits
    if n_iters == 0:
        return jax.nn.softmax(log_s, axis=-1)
    for _ in range(n_iters):
        log_s = log_s - jax.nn.logsumexp(log_s, axis=-1, keepdims=True)  # rows
        log_s = log_s - jax.nn.logsumexp(log_s, axis=-2, keepdims=True)  # cols
    return jnp.exp(log_s)


def causal_mask(nb: int, strict: bool = False) -> jnp.ndarray:
    """(nb, nb) mask: dest block i may receive src block j iff j <= i.

    With ``strict=True`` the diagonal is excluded (j < i): used for the
    *sorted-key* term of causal attention, where keeping j == i would mix a
    block's own future tokens into its keys. Paper §3.3: "if block i is
    sorted into a new position p < i, then it is being masked out" — i.e.
    content may only move to later (or equal) positions.
    """
    i = jnp.arange(nb)[:, None]
    j = jnp.arange(nb)[None, :]
    return (j < i) if strict else (j <= i)


def causal_sinkhorn_log(logits: jnp.ndarray, n_iters: int, strict: bool = False) -> jnp.ndarray:
    """Causal Sinkhorn balancing (paper §3.3.2): masked iterative
    normalization in which *no normalizer may see the future*.

    Row normalization is naturally causal (row i comes from block i's own
    pooled — already causal — descriptor). Column normalization is NOT:
    a full column sum at entry (i, j) would include rows i' > i, whose
    logits encode future block content. We therefore use a *cumulative*
    column normalizer: entry (i, j) is normalized by
    ``logsumexp over rows j..i of column j`` only. (Subtracting the full
    column max for stability cancels exactly in both value and gradient,
    so it does not reintroduce leakage beyond float rounding.)

    Rows with empty support (row 0 when ``strict``) come out all-zero; the
    attention layer must handle such fully-masked sorted blocks.
    """
    mask = causal_mask(logits.shape[-1], strict=strict)
    neg = jnp.asarray(NEG_INF, logits.dtype)
    log_s = jnp.where(mask, logits, neg)
    if n_iters == 0:
        s = jax.nn.softmax(log_s, axis=-1)
        return jnp.where(mask, s, 0.0)
    for _ in range(n_iters):
        row = jax.nn.logsumexp(log_s, axis=-1, keepdims=True)
        log_s = jnp.where(mask, log_s - jnp.maximum(row, neg), neg)
        # causal (cumulative) column normalization. The cumulative sum is
        # expressed as a lower-triangular matmul rather than jnp.cumsum:
        # identical math, but xla_extension 0.5.1's CPU compiler handles
        # the matmul in milliseconds where the scan form took minutes.
        cmax = jnp.maximum(jnp.max(log_s, axis=-2, keepdims=True), neg)
        e = jnp.where(mask, jnp.exp(log_s - cmax), 0.0)
        nb_ = logits.shape[-1]
        tril = jnp.tril(jnp.ones((nb_, nb_), logits.dtype))
        csum = jnp.einsum("ik,...kj->...ij", tril, e)
        ncol = jnp.log(csum + 1e-30) + cmax
        log_s = jnp.where(mask, log_s - jnp.maximum(ncol, neg), neg)
    # exp(-1e9) == 0 exactly in f32, but clamp for bf16 safety
    return jnp.where(mask, jnp.exp(log_s), 0.0)


# ---------------------------------------------------------------------------
# Block sort application (paper §3.1.2)
# ---------------------------------------------------------------------------


def block_sort(r: jnp.ndarray, x_blk: jnp.ndarray) -> jnp.ndarray:
    """Apply sort matrix: ``X_S = U(R B(X))``; (nb,nb) x (nb,b,d) -> (nb,b,d)."""
    return jnp.einsum("ij,jbd->ibd", r, x_blk)


# ---------------------------------------------------------------------------
# Sparse Sinkhorn attention (paper §3.2)
# ---------------------------------------------------------------------------


def sinkhorn_attention(
    q_blk: jnp.ndarray,
    k_blk: jnp.ndarray,
    v_blk: jnp.ndarray,
    k_sorted: jnp.ndarray,
    v_sorted: jnp.ndarray,
    sorted_valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Single-head sparse sinkhorn attention over blocked inputs.

    Query block i attends to ``concat(k_sorted[i], k_blk[i])`` (2b keys):
    the quasi-global sorted term plus the standard local term, one softmax
    over both (paper eq. for A_ij with the secondary local term).

    ``sorted_valid``: optional (nb,) bool — False where the sorted block has
    no support (fully masked row of a strict-causal R); its 'sorted' logits
    are masked to -inf.
    """
    d = q_blk.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q_blk.dtype))
    ls = jnp.einsum("ibd,ijd->ibj", q_blk, k_sorted) * scale  # (nb, b, b)
    ll = jnp.einsum("ibd,ijd->ibj", q_blk, k_blk) * scale  # (nb, b, b)
    if sorted_valid is not None:
        ls = jnp.where(sorted_valid[:, None, None], ls, NEG_INF)
    logits = jnp.concatenate([ls, ll], axis=-1)  # (nb, b, 2b)
    p = jax.nn.softmax(logits, axis=-1)
    b = q_blk.shape[1]
    y = jnp.einsum("ibj,ijd->ibd", p[..., :b], v_sorted) + jnp.einsum(
        "ibj,ijd->ibd", p[..., b:], v_blk
    )
    return y


def causal_sinkhorn_attention(
    q_blk: jnp.ndarray,
    k_blk: jnp.ndarray,
    v_blk: jnp.ndarray,
    k_sorted: jnp.ndarray,
    v_sorted: jnp.ndarray,
    sorted_valid: jnp.ndarray,
) -> jnp.ndarray:
    """Causal variant: local term gets the within-block causal mask; the
    sorted term is already strictly-past by construction (strict-causal R),
    with fully-masked rows disabled through ``sorted_valid``."""
    d = q_blk.shape[-1]
    b = q_blk.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q_blk.dtype))
    ls = jnp.einsum("ibd,ijd->ibj", q_blk, k_sorted) * scale
    ll = jnp.einsum("ibd,ijd->ibj", q_blk, k_blk) * scale
    ls = jnp.where(sorted_valid[:, None, None], ls, NEG_INF)
    tri = jnp.tril(jnp.ones((b, b), bool))  # query t sees local key u iff u <= t
    ll = jnp.where(tri[None], ll, NEG_INF)
    logits = jnp.concatenate([ls, ll], axis=-1)
    p = jax.nn.softmax(logits, axis=-1)
    y = jnp.einsum("ibj,ijd->ibd", p[..., :b], v_sorted) + jnp.einsum(
        "ibj,ijd->ibd", p[..., b:], v_blk
    )
    return y


def local_attention(q_blk, k_blk, v_blk, causal: bool = False) -> jnp.ndarray:
    """Plain block-local attention baseline (Luong-style windows)."""
    d = q_blk.shape[-1]
    b = q_blk.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q_blk.dtype))
    ll = jnp.einsum("ibd,ijd->ibj", q_blk, k_blk) * scale
    if causal:
        tri = jnp.tril(jnp.ones((b, b), bool))
        ll = jnp.where(tri[None], ll, NEG_INF)
    p = jax.nn.softmax(ll, axis=-1)
    return jnp.einsum("ibj,ijd->ibd", p, v_blk)


# ---------------------------------------------------------------------------
# SortCut attention (paper §3.4)
# ---------------------------------------------------------------------------


def sortcut_attention(q: jnp.ndarray, k_cut: jnp.ndarray, v_cut: jnp.ndarray) -> jnp.ndarray:
    """Y = softmax(Q K_cut^T) V_cut — queries are the full (ell, d) sequence,
    keys/values the first ``n`` *sorted* blocks flattened to (n*b, d)."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = (q @ k_cut.T) * scale
    p = jax.nn.softmax(logits, axis=-1)
    return p @ v_cut


# ---------------------------------------------------------------------------
# Dense attention oracle (baseline / mixture second term)
# ---------------------------------------------------------------------------


def dense_attention(q, k, v, causal: bool = False) -> jnp.ndarray:
    """Vanilla O(ell^2) scaled dot-product attention, (ell, d) inputs."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = (q @ k.T) * scale
    if causal:
        ell = q.shape[0]
        tri = jnp.tril(jnp.ones((ell, ell), bool))
        logits = jnp.where(tri, logits, NEG_INF)
    return jax.nn.softmax(logits, axis=-1) @ v
