//! The training loop: feeds generated batches into the AOT train-step
//! graph, tracks the loss curve, optionally checkpoints. Pure Rust hot
//! path — Python was only involved at `make artifacts` time.

use std::path::PathBuf;

use anyhow::Result;

use crate::data::TaskData;
use crate::runtime::{Experiment, Runtime, TrainState};
use crate::util::stats::{Ema, Timer};

use super::checkpoint::Checkpoint;
use super::metrics::LossCurve;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub seed: i32,
    /// record the loss every `log_every` steps (always records the last)
    pub log_every: usize,
    pub verbose: bool,
    /// save a checkpoint here when done
    pub checkpoint: Option<PathBuf>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { steps: 100, seed: 0, log_every: 10, verbose: false, checkpoint: None }
    }
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub curve: LossCurve,
    pub steps: usize,
    pub secs: f64,
    pub steps_per_sec: f64,
    /// EMA(0.1) of the loss at the end of training.
    pub ema_loss: f64,
}

/// Train `exp` from `state` for `opts.steps` steps.
pub fn train(
    rt: &Runtime,
    exp: &Experiment,
    data: &mut TaskData,
    state: &mut TrainState,
    opts: &TrainOptions,
) -> Result<TrainReport> {
    let timer = Timer::start();
    let mut curve = LossCurve::default();
    let mut ema = Ema::new(0.1);
    let start_step = state.step as usize;

    for i in 0..opts.steps {
        let batch = data.train_batch();
        let lits = batch.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
        // per-step seed: distinct gumbel noise each step, reproducible
        let seed = opts.seed.wrapping_add((start_step + i) as i32);
        let loss = exp.train_step(rt, state, seed, &lits)?;
        if !loss.is_finite() {
            anyhow::bail!("loss diverged (step {}): {loss}", start_step + i);
        }
        let sm = ema.push(loss as f64);
        if i % opts.log_every.max(1) == 0 || i + 1 == opts.steps {
            curve.push(start_step + i, loss as f64);
            if opts.verbose {
                println!(
                    "  step {:>5}  loss {:>8.4}  ema {:>8.4}",
                    start_step + i,
                    loss,
                    sm
                );
            }
        }
    }
    let secs = timer.secs();
    curve.secs = secs;

    if let Some(path) = &opts.checkpoint {
        Checkpoint::capture(&exp.manifest, state)?.save(path)?;
        if opts.verbose {
            println!("  checkpoint -> {}", path.display());
        }
    }

    Ok(TrainReport {
        curve,
        steps: opts.steps,
        secs,
        steps_per_sec: opts.steps as f64 / secs.max(1e-9),
        ema_loss: ema.get().unwrap_or(f64::NAN),
    })
}

/// Convenience: init + train in one call (most bench targets).
pub fn train_from_scratch(
    rt: &Runtime,
    exp: &Experiment,
    data: &mut TaskData,
    opts: &TrainOptions,
) -> Result<(TrainState, TrainReport)> {
    let mut state = exp.init_state(rt, opts.seed)?;
    let report = train(rt, exp, data, &mut state, opts)?;
    Ok((state, report))
}
