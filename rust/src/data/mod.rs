//! Data pipeline: synthetic task generators + batching, one per paper task
//! (DESIGN.md §4 lists each substitution). All generators are seeded by
//! *dataset*, not by experiment, so every attention variant in a table
//! trains and evaluates on identical data.

pub mod batcher;
pub mod classify;
pub mod corpus;
pub mod images;
pub mod sorting;
pub mod tokenizer;

use anyhow::{bail, Result};

use crate::runtime::{Family, HostTensor, Manifest};
use batcher::Batcher;
use classify::{CharSentimentTask, Example, NliTask, SentimentTask};
use corpus::{CharCorpus, Corpus};
use images::ImageTask;
use sorting::SortTask;

/// FNV-1a — stable dataset seeds from name prefixes.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Stream of LM sequences (word corpus, char corpus, or images).
enum LmSource {
    Word(Corpus),
    Char(CharCorpus),
    Image(ImageTask),
}

impl LmSource {
    fn sequence(&mut self, len: usize) -> Vec<i32> {
        match self {
            LmSource::Word(c) => c.sequence(len),
            LmSource::Char(c) => c.sequence(len),
            LmSource::Image(t) => {
                // autoregressive over pixels: prepend BOS so len = ell+1
                let mut v = vec![tokenizer::BOS];
                v.extend(t.image());
                v.truncate(len);
                v
            }
        }
    }
}

/// Language-modeling data (Tables 2/4/5, 8, Figs 3/4).
pub struct LmData {
    train: LmSource,
    eval: LmSource,
    ell: usize,
    batch: usize,
    eval_batch: usize,
}

impl LmData {
    /// (batch, ell+1) token tensor.
    pub fn train_batch(&mut self) -> Vec<HostTensor> {
        let mut data = Vec::with_capacity(self.batch * (self.ell + 1));
        for _ in 0..self.batch {
            data.extend(self.train.sequence(self.ell + 1));
        }
        vec![HostTensor::i32(&[self.batch, self.ell + 1], data)]
    }

    pub fn eval_batches(&mut self, n: usize) -> Vec<Vec<HostTensor>> {
        (0..n)
            .map(|_| {
                let mut data = Vec::with_capacity(self.eval_batch * (self.ell + 1));
                for _ in 0..self.eval_batch {
                    data.extend(self.eval.sequence(self.ell + 1));
                }
                vec![HostTensor::i32(&[self.eval_batch, self.ell + 1], data)]
            })
            .collect()
    }
}

/// Classification data (Tables 6/7).
pub struct ClsData {
    train_set: Vec<Example>,
    eval_set: Vec<Example>,
    batcher: Batcher,
    ell: usize,
    eval_batch: usize,
}

impl ClsData {
    fn to_tensors(examples: &[Example], ell: usize) -> Vec<HostTensor> {
        let bsz = examples.len();
        let mut toks = Vec::with_capacity(bsz * ell);
        let mut labels = Vec::with_capacity(bsz);
        for e in examples {
            assert_eq!(e.tokens.len(), ell);
            toks.extend_from_slice(&e.tokens);
            labels.push(e.label);
        }
        vec![HostTensor::i32(&[bsz, ell], toks), HostTensor::i32(&[bsz], labels)]
    }

    pub fn train_batch(&mut self) -> Vec<HostTensor> {
        let idx = self.batcher.next_indices().to_vec();
        let exs: Vec<Example> = idx.iter().map(|&i| self.train_set[i].clone()).collect();
        Self::to_tensors(&exs, self.ell)
    }

    pub fn eval_batches(&self) -> Vec<Vec<HostTensor>> {
        self.eval_set
            .chunks(self.eval_batch)
            .filter(|c| c.len() == self.eval_batch)
            .map(|c| Self::to_tensors(c, self.ell))
            .collect()
    }

    pub fn n_eval(&self) -> usize {
        (self.eval_set.len() / self.eval_batch) * self.eval_batch
    }
}

/// Sorting seq2seq data (Table 1): train at `ell`, evaluate at `ell_eval`.
pub struct SortData {
    train_task: SortTask,
    eval_task: SortTask,
    ell: usize,
    ell_eval: usize,
    batch: usize,
    eval_batch: usize,
}

/// One sorting eval batch: sources plus gold sorted sequences.
pub struct SortEvalBatch {
    /// (eval_batch, ell_eval) i32
    pub src: HostTensor,
    pub golds: Vec<Vec<i32>>,
}

impl SortData {
    pub fn train_batch(&mut self) -> Vec<HostTensor> {
        let (src, tgt) = self.train_task.batch(self.batch, self.ell);
        vec![
            HostTensor::i32(&[self.batch, self.ell], src),
            HostTensor::i32(&[self.batch, self.ell + 1], tgt),
        ]
    }

    pub fn eval_batches(&mut self, n: usize) -> Vec<SortEvalBatch> {
        (0..n)
            .map(|_| {
                let mut src = Vec::with_capacity(self.eval_batch * self.ell_eval);
                let mut golds = Vec::with_capacity(self.eval_batch);
                for _ in 0..self.eval_batch {
                    let ex = self.eval_task.example(self.ell_eval);
                    src.extend_from_slice(&ex.src);
                    golds.push(ex.tgt[1..].to_vec()); // drop BOS
                }
                SortEvalBatch {
                    src: HostTensor::i32(&[self.eval_batch, self.ell_eval], src),
                    golds,
                }
            })
            .collect()
    }

    pub fn eval_len(&self) -> usize {
        self.ell_eval
    }

    pub fn eval_batch_size(&self) -> usize {
        self.eval_batch
    }
}

/// All task data behind one facade, constructed from a manifest.
pub enum TaskData {
    Lm(LmData),
    Cls(ClsData),
    Sort(SortData),
}

/// Which synthetic dataset an experiment name maps to.
fn dataset_key(name: &str) -> &'static str {
    let prefix = name.split("__").next().unwrap_or(name);
    match prefix {
        p if p.starts_with("sort") => "sort",
        p if p.starts_with("lmw") || p.starts_with("abl") || p.starts_with("fig") => "lmw",
        p if p.starts_with("lmc") => "lmc",
        p if p.starts_with("img") => "img",
        p if p.starts_with("imdbw") => "imdbw",
        p if p.starts_with("imdbc") => "imdbc",
        p if p.starts_with("sstw") => "sstw",
        p if p.starts_with("sstc") => "sstc",
        p if p.starts_with("snli") => "snli",
        p if p.starts_with("mnli") => "mnli",
        _ => "lmw",
    }
}

const CLS_TRAIN_N: usize = 2048;
const CLS_EVAL_N: usize = 512;

impl TaskData {
    pub fn for_experiment(m: &Manifest) -> Result<TaskData> {
        let key = dataset_key(&m.name);
        let vocab = m.cfg_usize("vocab")?;
        let ell = m.cfg_usize("ell")?;
        let batch = m.train_cfg.usize_of("batch")?;
        let eval_batch = m.train_cfg.usize_of("eval_batch").unwrap_or(batch);
        let tseed = fnv1a(key); // train stream
        let eseed = fnv1a(key) ^ 0xEEEE_EEEE; // held-out stream

        let data = match (m.family, key) {
            (Family::Seq2seq, _) => {
                let ell_eval = m.eval_cfg.usize_of("ell").unwrap_or(2 * ell);
                TaskData::Sort(SortData {
                    train_task: SortTask::new(vocab, tseed),
                    eval_task: SortTask::new(vocab, eseed),
                    ell,
                    ell_eval,
                    batch,
                    eval_batch,
                })
            }
            (Family::Lm, "lmc") => TaskData::Lm(LmData {
                train: LmSource::Char(CharCorpus::new(256, tseed)),
                eval: LmSource::Char(CharCorpus::new(256, eseed)),
                ell,
                batch,
                eval_batch,
            }),
            (Family::Lm, "img") => TaskData::Lm(LmData {
                train: LmSource::Image(ImageTask::for_seq_len(ell, tseed)),
                eval: LmSource::Image(ImageTask::for_seq_len(ell, eseed)),
                ell,
                batch,
                eval_batch,
            }),
            (Family::Lm, _) => TaskData::Lm(LmData {
                train: LmSource::Word(Corpus::new(vocab, tseed)),
                eval: LmSource::Word(Corpus::new(vocab, eseed)),
                ell,
                batch,
                eval_batch,
            }),
            (Family::Cls, key) => {
                let (train_set, eval_set) = match key {
                    "imdbw" | "sstw" => {
                        let mut tr = SentimentTask::new(vocab, tseed);
                        let mut ev = SentimentTask::new(vocab, eseed);
                        (tr.dataset(CLS_TRAIN_N, ell), ev.dataset(CLS_EVAL_N, ell))
                    }
                    "imdbc" | "sstc" => {
                        let mut tr = CharSentimentTask::new(tseed);
                        let mut ev = CharSentimentTask::new(eseed);
                        (tr.dataset(CLS_TRAIN_N, ell), ev.dataset(CLS_EVAL_N, ell))
                    }
                    "snli" | "mnli" => {
                        let hard = key == "mnli";
                        let mut tr = NliTask::new(vocab, tseed, hard);
                        let mut ev = NliTask::new(vocab, eseed, hard);
                        (tr.dataset(CLS_TRAIN_N, ell), ev.dataset(CLS_EVAL_N, ell))
                    }
                    other => bail!("no classification dataset for '{other}'"),
                };
                TaskData::Cls(ClsData {
                    train_set,
                    eval_set,
                    batcher: Batcher::new(CLS_TRAIN_N, batch, tseed ^ 7),
                    ell,
                    eval_batch,
                })
            }
        };
        Ok(data)
    }

    pub fn train_batch(&mut self) -> Vec<HostTensor> {
        match self {
            TaskData::Lm(d) => d.train_batch(),
            TaskData::Cls(d) => d.train_batch(),
            TaskData::Sort(d) => d.train_batch(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_key_mapping() {
        assert_eq!(dataset_key("sort__vanilla"), "sort");
        assert_eq!(dataset_key("lmw_tiny__sinkhorn_b16"), "lmw");
        assert_eq!(dataset_key("abl_p1__sinkhorn_b16"), "lmw");
        assert_eq!(dataset_key("fig4_k10__sinkhorn_b16"), "lmw");
        assert_eq!(dataset_key("imdbc__sortcut_2x16"), "imdbc");
        assert_eq!(dataset_key("mnli__vanilla"), "mnli");
    }

    #[test]
    fn same_dataset_across_variants() {
        // two variants of the same table must see identical data
        assert_eq!(fnv1a(dataset_key("lmw_tiny__vanilla")), fnv1a(dataset_key("lmw_small__mixture")));
        assert_ne!(fnv1a("lmw"), fnv1a("lmc"));
    }
}
