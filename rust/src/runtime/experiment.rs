//! An `Experiment` bundles one (task, attention-variant) pair's compiled
//! graphs and drives them: reproducible init, train steps, evaluation.
//!
//! Train-graph calling convention (see python/compile/aot.py):
//!   inputs : params..., m..., v..., step:f32, seed:i32, batch...
//!   outputs: params'..., m'..., v'..., step', loss

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::client::Runtime;
use super::manifest::Manifest;
use super::tensor::{zero_literal, HostTensor};

/// Mutable optimizer state held between steps (literals stay host-side;
/// PJRT CPU shares the memory space so uploads are cheap copies).
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    pub step: f32,
}

impl TrainState {
    pub fn n_leaves(&self) -> usize {
        self.params.len()
    }
}

pub struct Experiment {
    pub manifest: Manifest,
}

impl Experiment {
    pub fn load(artifacts_dir: &Path, name: &str) -> Result<Self> {
        Ok(Experiment { manifest: Manifest::load(artifacts_dir, name)? })
    }

    /// Run the init graph: reproducible parameter init from a seed, with
    /// fresh zero Adam slots.
    pub fn init_state(&self, rt: &Runtime, seed: i32) -> Result<TrainState> {
        let exe = rt.load(&self.manifest.init_hlo)?;
        let seed_lit = HostTensor::scalar_i32(seed).to_literal()?;
        let params = rt.execute(&exe, &[&seed_lit])?;
        if params.len() != self.manifest.n_leaves() {
            bail!(
                "init graph returned {} leaves, manifest says {}",
                params.len(),
                self.manifest.n_leaves()
            );
        }
        let m = self.manifest.params.iter().map(zero_literal).collect();
        let v = self.manifest.params.iter().map(zero_literal).collect();
        Ok(TrainState { params, m, v, step: 0.0 })
    }

    /// One optimizer step. Returns the training loss.
    pub fn train_step(
        &self,
        rt: &Runtime,
        state: &mut TrainState,
        seed: i32,
        batch: &[xla::Literal],
    ) -> Result<f32> {
        let n = self.manifest.n_leaves();
        if batch.len() != self.manifest.train_batch_inputs.len() {
            bail!(
                "train batch arity {} != manifest {}",
                batch.len(),
                self.manifest.train_batch_inputs.len()
            );
        }
        let exe = rt.load(&self.manifest.train_hlo)?;
        let step_lit = HostTensor::scalar_f32(state.step).to_literal()?;
        let seed_lit = HostTensor::scalar_i32(seed).to_literal()?;

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 2 + batch.len());
        args.extend(state.params.iter());
        args.extend(state.m.iter());
        args.extend(state.v.iter());
        args.push(&step_lit);
        args.push(&seed_lit);
        args.extend(batch.iter());

        let mut out = rt.execute(&exe, &args).context("train step")?;
        if out.len() != 3 * n + 2 {
            bail!("train graph returned {} outputs, expected {}", out.len(), 3 * n + 2);
        }
        let loss = HostTensor::from_literal(&out[3 * n + 1])?.as_f32()?[0];
        let step = HostTensor::from_literal(&out[3 * n])?.as_f32()?[0];
        // replace state with the updated leaves (reverse-order pops avoid
        // shifting the vec)
        out.truncate(3 * n);
        let mut it = out.into_iter();
        state.params = it.by_ref().take(n).collect();
        state.m = it.by_ref().take(n).collect();
        state.v = it.by_ref().take(n).collect();
        state.step = step;
        Ok(loss)
    }

    /// Run the eval graph on one batch; returns the raw output literals
    /// (family-specific: lm -> [loss]; cls -> [loss, n_correct];
    /// seq2seq -> [loss, pred]).
    pub fn eval(
        &self,
        rt: &Runtime,
        params: &[xla::Literal],
        batch: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        if batch.len() != self.manifest.eval_batch_inputs.len() {
            bail!(
                "eval batch arity {} != manifest {}",
                batch.len(),
                self.manifest.eval_batch_inputs.len()
            );
        }
        let exe = rt.load(&self.manifest.eval_hlo)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(params.len() + batch.len());
        args.extend(params.iter());
        args.extend(batch.iter());
        rt.execute(&exe, &args).context("eval step")
    }
}
