//! Quickstart: load a Sparse Sinkhorn Attention experiment, initialize
//! parameters reproducibly, take a few train steps and evaluate — all from
//! Rust over the AOT-compiled XLA graphs (no Python at runtime).
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use anyhow::Result;
use sinkhorn::coordinator::{self, TrainOptions};
use sinkhorn::data::TaskData;
use sinkhorn::runtime::{artifacts_dir, Experiment, Runtime};

fn main() -> Result<()> {
    let artifacts = artifacts_dir();
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // the paper's core model: Sinkhorn Transformer, block length 16, on
    // the word-level LM task
    let exp = Experiment::load(&artifacts, "lmw_tiny__sinkhorn_b16")?;
    let m = &exp.manifest;
    println!(
        "experiment {} — variant {}, {} parameters in {} leaves",
        m.name,
        m.variant(),
        m.n_params(),
        m.n_leaves()
    );

    let mut data = TaskData::for_experiment(m)?;
    let opts = TrainOptions { steps: 30, seed: 7, log_every: 5, verbose: true, checkpoint: None };
    let (state, report) = coordinator::train_from_scratch(&rt, &exp, &mut data, &opts)?;
    println!(
        "trained {} steps in {:.1}s ({:.2} steps/s)",
        report.steps, report.secs, report.steps_per_sec
    );
    assert!(report.curve.decreased(), "loss should decrease in 30 steps");

    if let TaskData::Lm(d) = &mut data {
        let loss = coordinator::eval_lm(&rt, &exp, &state, d, 2)?;
        println!(
            "held-out loss {:.4} nats -> perplexity {:.2}",
            loss,
            coordinator::perplexity(loss)
        );
    }
    println!("quickstart OK");
    Ok(())
}
