//! Evaluation drivers: LM perplexity/bpc/bpd, classification accuracy, and
//! greedy seq2seq decoding with EM/edit-distance scoring (Table 1).

use anyhow::{bail, Result};

use crate::data::sorting::score_predictions;
use crate::data::tokenizer::BOS;
use crate::data::{ClsData, LmData, SortData};
use crate::runtime::{Experiment, HostTensor, Runtime, TrainState};

/// Mean eval loss (nats/token) over `n_batches` held-out LM batches.
pub fn eval_lm(
    rt: &Runtime,
    exp: &Experiment,
    state: &TrainState,
    data: &mut LmData,
    n_batches: usize,
) -> Result<f64> {
    let mut total = 0.0;
    let batches = data.eval_batches(n_batches);
    let n = batches.len();
    for batch in batches {
        let lits = batch.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
        let out = exp.eval(rt, &state.params, &lits)?;
        total += HostTensor::from_literal(&out[0])?.as_f32()?[0] as f64;
    }
    Ok(total / n.max(1) as f64)
}

/// Classification: (mean loss, accuracy) over the held-out set.
pub fn eval_cls(
    rt: &Runtime,
    exp: &Experiment,
    state: &TrainState,
    data: &ClsData,
) -> Result<(f64, f64)> {
    let batches = data.eval_batches();
    if batches.is_empty() {
        bail!("no eval batches");
    }
    let mut total_loss = 0.0;
    let mut correct = 0i64;
    let mut seen = 0usize;
    for batch in &batches {
        let lits = batch.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
        let out = exp.eval(rt, &state.params, &lits)?;
        total_loss += HostTensor::from_literal(&out[0])?.as_f32()?[0] as f64;
        correct += HostTensor::from_literal(&out[1])?.as_i32()?[0] as i64;
        seen += batch[1].len();
    }
    Ok((total_loss / batches.len() as f64, correct as f64 / seen as f64))
}

/// Greedy autoregressive decode for the sorting task, scored with exact
/// match and normalized edit distance. The eval graph returns per-position
/// argmax under teacher forcing; the coordinator feeds its own predictions
/// back in, position by position (true decoding — no gold leakage).
pub fn eval_sort(
    rt: &Runtime,
    exp: &Experiment,
    state: &TrainState,
    data: &mut SortData,
    n_batches: usize,
) -> Result<(f64, f64)> {
    let lt = data.eval_len();
    let bsz = data.eval_batch_size();
    let mut all_preds: Vec<Vec<i32>> = Vec::new();
    let mut all_golds: Vec<Vec<i32>> = Vec::new();

    for batch in data.eval_batches(n_batches) {
        let src_lit = batch.src.to_literal()?;
        // decoder input starts as [BOS, 0, 0, ...]
        let mut tgt_in = vec![0i32; bsz * lt];
        for r in 0..bsz {
            tgt_in[r * lt] = BOS;
        }
        let mut preds = vec![vec![0i32; lt]; bsz];
        for t in 0..lt {
            let tgt_lit = HostTensor::i32(&[bsz, lt], tgt_in.clone()).to_literal()?;
            let out = exp.eval(rt, &state.params, &[src_lit.clone(), tgt_lit])?;
            let pred = HostTensor::from_literal(&out[1])?;
            let pred = pred.as_i32()?;
            for r in 0..bsz {
                let tok = pred[r * lt + t];
                preds[r][t] = tok;
                if t + 1 < lt {
                    tgt_in[r * lt + t + 1] = tok;
                }
            }
        }
        all_preds.extend(preds);
        all_golds.extend(batch.golds);
    }
    let (em, ed) = score_predictions(&all_preds, &all_golds);
    Ok((em, ed))
}

/// Faster proxy used while iterating: teacher-forced argmax accuracy
/// (single eval call per batch; upper-bounds true greedy decoding).
pub fn eval_sort_teacher_forced(
    rt: &Runtime,
    exp: &Experiment,
    state: &TrainState,
    data: &mut SortData,
    n_batches: usize,
) -> Result<(f64, f64)> {
    let lt = data.eval_len();
    let bsz = data.eval_batch_size();
    let mut all_preds: Vec<Vec<i32>> = Vec::new();
    let mut all_golds: Vec<Vec<i32>> = Vec::new();
    for batch in data.eval_batches(n_batches) {
        let src_lit = batch.src.to_literal()?;
        let mut tgt_in = vec![0i32; bsz * lt];
        for (r, gold) in batch.golds.iter().enumerate() {
            tgt_in[r * lt] = BOS;
            for t in 1..lt {
                tgt_in[r * lt + t] = gold[t - 1];
            }
        }
        let tgt_lit = HostTensor::i32(&[bsz, lt], tgt_in).to_literal()?;
        let out = exp.eval(rt, &state.params, &[src_lit, tgt_lit])?;
        let pred = HostTensor::from_literal(&out[1])?;
        let pred = pred.as_i32()?;
        for r in 0..bsz {
            all_preds.push(pred[r * lt..(r + 1) * lt].to_vec());
        }
        all_golds.extend(batch.golds);
    }
    let (em, ed) = score_predictions(&all_preds, &all_golds);
    Ok((em, ed))
}
