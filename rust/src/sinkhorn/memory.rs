//! Analytic memory/FLOP model for the paper's §4 complexity analysis and
//! the TPU-side performance estimates in DESIGN.md §Perf.
//!
//! The paper's claim: vanilla attention materializes an O(ell^2) score
//! matrix; Sinkhorn attention only B^2 per block pair (local + sorted)
//! plus the N_B^2 sort matrix; SortCut is O(ell * n_cut * b). The
//! `bench memory` target prints these side by side with *measured*
//! allocation counts from the pure-Rust reference implementation.

/// Attention-variant cost model for one head over one sequence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cost {
    /// f32 elements of attention score matrices materialized.
    pub score_elems: usize,
    /// extra f32 elements for sort machinery (R matrix, sorted K/V copies).
    pub aux_elems: usize,
    /// multiply-accumulate count for score + combine matmuls.
    pub macs: usize,
}

impl Cost {
    pub fn total_elems(&self) -> usize {
        self.score_elems + self.aux_elems
    }

    pub fn bytes(&self) -> usize {
        self.total_elems() * 4
    }
}

/// Vanilla dense attention: ell x ell scores, 2*ell^2*d MACs.
pub fn dense(ell: usize, d: usize) -> Cost {
    Cost { score_elems: ell * ell, aux_elems: 0, macs: 2 * ell * ell * d }
}

/// Block-local attention: nb blocks of b^2 scores.
pub fn local(ell: usize, nb: usize, d: usize) -> Cost {
    let b = ell / nb;
    Cost { score_elems: nb * b * b, aux_elems: 0, macs: 2 * nb * b * b * d }
}

/// Sparse Transformer (fixed scheme): local + column summary of stride c.
pub fn sparse_fixed(ell: usize, nb: usize, c: usize, d: usize) -> Cost {
    let b = ell / nb;
    let local_scores = nb * b * b;
    let summary_cols = nb * c; // every block exposes c summary positions
    let fixed_scores = ell * summary_cols;
    Cost {
        score_elems: local_scores + fixed_scores,
        aux_elems: 0,
        macs: 2 * (local_scores + fixed_scores) * d,
    }
}

/// Sparse Sinkhorn attention: per block 2*b^2 scores (sorted + local), an
/// nb^2 sort matrix and sorted K/V copies (2*ell*d).
pub fn sinkhorn(ell: usize, nb: usize, d: usize) -> Cost {
    let b = ell / nb;
    Cost {
        score_elems: nb * 2 * b * b,
        aux_elems: nb * nb + 2 * ell * d,
        macs: 2 * nb * 2 * b * b * d   // attention matmuls
            + 2 * nb * nb * b * d, // block-sort mixes for K and V
    }
}

/// SortCut: ell x (n_cut*b) scores + sort machinery.
pub fn sortcut(ell: usize, nb: usize, n_cut: usize, d: usize) -> Cost {
    let b = ell / nb;
    let kv = n_cut * b;
    Cost {
        score_elems: ell * kv,
        aux_elems: nb * nb + 2 * kv * d,
        macs: 2 * ell * kv * d + 2 * nb * n_cut * b * d,
    }
}

/// The paper's headline illustration (§1 fn 1): ell=1024, N_B=16 blocks of
/// b=64 gives a ~240x memory saving factor vs dense. We expose the same
/// ratio computation for the bench + tests.
pub fn saving_factor(ell: usize, nb: usize) -> f64 {
    let b = ell / nb;
    (ell * ell) as f64 / (b * b + nb * nb) as f64
}

/// Estimated VMEM working set (bytes) of one L1 kernel program — the
/// quantity that must fit in a TPU core's ~16 MiB VMEM (DESIGN.md §Perf):
/// 5 tiles of (b, d) (q, ks, kl, vs, vl) + the (b, 2b) score tile.
pub fn kernel_vmem_bytes(b: usize, d: usize) -> usize {
    (5 * b * d + 2 * b * b) * 4
}

/// Working-set bytes of one `engine::Workspace` — the per-worker scratch
/// of the streaming blocked engine (DESIGN.md §Perf, §Streaming): two
/// gathered `(b, d)` tiles plus the streaming-softmax state — the
/// `(b, STREAM_TILE_W)` logit tile and the per-row running max and
/// denominator. **Linear in `b`**: the pre-streaming engine staged a
/// `(b, 2b)` joint-logits tile and a `(b, d)` combine scratch
/// (`(3bd + 2b²)·4` bytes); the flash-style loop reduces scores
/// `STREAM_TILE_W` keys at a time and accumulates context directly into
/// the output, so neither buffer exists anymore. The engine's measured
/// allocation (`engine::workspace_f32_elems`) is asserted equal to this
/// model in `tests/engine_props.rs`.
pub fn engine_workspace_bytes(b: usize, d: usize) -> usize {
    (2 * b * d + b * super::engine::STREAM_TILE_W + 2 * b) * 4
}

/// Working-set bytes of one per-sequence `decode::DecodeState` at token
/// capacity `nb_cap * b` (DESIGN.md §Decode): the block-aligned K/V cache
/// (`2·nb_cap·b·d`), the cached balanced sort matrix (`nb_cap²`), and the
/// gathered sorted-K/V cache — one block in full-causal mode, `n_cut`
/// blocks under SortCut (`2·cache·b·d`). Linear in the sequence capacity
/// (the KV cache) but — the decode win — *constant per step*: no `(ℓ, ℓ)`
/// or even `(b, 2b)` score buffer ever exists, and the per-step scratch is
/// just the engine workspace at query rows = 1
/// (`engine_workspace_bytes(1, d)`). The decoder's measured allocation
/// (`decode::DecodeState::f32_elems`) is asserted equal to this model in
/// `tests/decode_props.rs`.
pub fn decode_state_bytes(b: usize, d: usize, nb_cap: usize, n_cut: Option<usize>) -> usize {
    let cache_blocks = n_cut.unwrap_or(1);
    (2 * nb_cap * b * d + nb_cap * nb_cap + 2 * cache_blocks * b * d) * 4
}

/// Multiply-accumulates of one incremental decode step (DESIGN.md
/// §Decode): the 1-row query against the cached sorted segment
/// (`cut_blocks·b` keys; 1 in full-causal mode) plus at most `b` local
/// keys, for both the logit and the combine contraction — independent of
/// the sequence length, which is the whole point vs the
/// O(ℓ·b·d)-per-token full-recompute baseline that `bench --target
/// decode` measures.
pub fn decode_step_macs(b: usize, d: usize, cut_blocks: usize) -> usize {
    2 * (cut_blocks + 1) * b * d
}

/// Parameter count of a depth-L [`SinkhornStack`]'s layers (DESIGN.md
/// §Model) — per layer: per-head q/k/v/output projections (`4·d²` f32
/// regardless of the head split), the SortNet head (`d·nb`), and, for
/// full layers (`d_ff > 0`), two LayerNorms plus the GELU FFN. Embeddings
/// and task heads belong to the caller. The stack's measured
/// `SinkhornStack::n_params` is asserted equal in `tests/model_props.rs`.
///
/// [`SinkhornStack`]: super::model::SinkhornStack
pub fn stack_params(cfg: &super::model::StackConfig) -> usize {
    let (d, dh) = (cfg.d_model, cfg.d_head());
    let proj = 3 * cfg.n_heads * d * dh + cfg.n_heads * dh * d;
    let per_layer = proj
        + d * cfg.nb
        + if cfg.d_ff > 0 {
            2 * d // ln1
            + 2 * d // ffn ln
            + d * cfg.d_ff + cfg.d_ff // w1 + b1
            + cfg.d_ff * d + d // w2 + b2
        } else {
            0
        };
    cfg.depth * per_layer
}

/// Working-set f32 elements of one `model::StackScratch` with `threads`
/// per-worker engine workspaces (DESIGN.md §Model, §Perf): the pooled
/// activation buffers — LayerNorm image, per-head q/k/v/context tiles,
/// summed projection, FFN pre/post rows, block descriptors — plus
/// `threads` engine workspaces at the layer block shape
/// `(seq_len / nb, d_head)`. Sized once for the deepest layer and reused
/// across every layer of a forward pass. Asserted equal to the measured
/// `StackScratch::f32_elems` in `tests/model_props.rs`.
pub fn stack_scratch_elems(cfg: &super::model::StackConfig, threads: usize) -> usize {
    let (ell, d) = (cfg.seq_len, cfg.d_model);
    let b = cfg.block_rows();
    ell * d // h
        + 4 * cfg.n_heads * ell * cfg.d_head() // qh/kh/vh/ctx
        + ell * d // proj
        + 2 * ell * cfg.d_ff // ff_pre + ff_act
        + if cfg.d_ff > 0 { ell * d } else { 0 } // ff_out
        + cfg.nb * d // blk
        + threads.max(1) * super::engine::workspace_f32_elems(b, cfg.d_head())
}

/// Working-set bytes of a depth-L `model::StackDecodeState` (DESIGN.md
/// §Model, §Decode): per layer, one single-layer decode state per head
/// ([`decode_state_bytes`] at the head dimension), the layer's raw
/// `(nb, nb)` sort-logit matrix, and the `d_model`-wide running block
/// descriptor. Still linear in the sequence capacity (the per-head KV
/// caches) and constant per step. Asserted equal to the measured
/// `StackDecodeState::f32_elems` in `tests/model_props.rs`.
pub fn stack_decode_state_bytes(
    depth: usize,
    n_heads: usize,
    b: usize,
    d_head: usize,
    nb_cap: usize,
    n_cut: Option<usize>,
) -> usize {
    depth
        * (n_heads * decode_state_bytes(b, d_head, nb_cap, n_cut)
            + nb_cap * nb_cap * 4
            + n_heads * d_head * 4)
}

/// Bytes of one K/V page (DESIGN.md §Pages): `blocks_per_page` complete
/// `(b, d_head)` blocks of one head's K or V.
pub fn kv_page_bytes(b: usize, d_head: usize, blocks_per_page: usize) -> usize {
    blocks_per_page * b * d_head * 4
}

/// Bytes of one sorted-gather cut page: the full gathered cache for one
/// head's K or V side — one block in full-causal mode, `n_cut` blocks
/// under SortCut (mirrors the monolithic cache shape exactly).
pub fn cut_page_bytes(b: usize, d_head: usize, n_cut: Option<usize>) -> usize {
    n_cut.unwrap_or(1) * b * d_head * 4
}

/// K/V pages resident per table at sequence length `len`: pages appear on
/// the first write into a block, so this is `ceil(started_blocks /
/// blocks_per_page)` — the O(len) half of the paged-vs-monolithic claim.
pub fn kv_pages_at(len: usize, b: usize, blocks_per_page: usize) -> usize {
    let started_blocks = len.div_ceil(b);
    started_blocks.div_ceil(blocks_per_page)
}

/// Resident bytes of one *paged* `decode::DecodeState` at sequence length
/// `len` (DESIGN.md §Pages): the always-owned `(nb_cap, nb_cap)` balance
/// matrix plus the lazily-paged K/V tables and — from the first step's
/// rebalance on — the two sorted-gather cut pages. The monolithic
/// [`decode_state_bytes`] is the `len = capacity` ceiling of this model;
/// the measured `DecodeState::f32_elems` of an unshared paged state is
/// asserted equal in `tests/pages_props.rs`.
pub fn decode_state_resident_bytes(
    b: usize,
    d: usize,
    nb_cap: usize,
    n_cut: Option<usize>,
    blocks_per_page: usize,
    len: usize,
) -> usize {
    nb_cap * nb_cap * 4
        + 2 * kv_pages_at(len, b, blocks_per_page) * kv_page_bytes(b, d, blocks_per_page)
        + if len > 0 { 2 * cut_page_bytes(b, d, n_cut) } else { 0 }
}

/// Resident bytes of a depth-L *paged* `model::StackDecodeState` at
/// sequence length `len`: per layer, one paged decode state per head plus
/// the owned sort-logit matrix and block descriptor (exactly the
/// monolithic [`stack_decode_state_bytes`] layout with the per-head term
/// swapped for [`decode_state_resident_bytes`]).
pub fn stack_paged_resident_bytes(
    depth: usize,
    n_heads: usize,
    b: usize,
    d_head: usize,
    nb_cap: usize,
    n_cut: Option<usize>,
    blocks_per_page: usize,
    len: usize,
) -> usize {
    depth
        * (n_heads * decode_state_resident_bytes(b, d_head, nb_cap, n_cut, blocks_per_page, len)
            + nb_cap * nb_cap * 4
            + n_heads * d_head * 4)
}

/// Peak *new* bytes a paged session will pin if it runs to `target_len`
/// tokens, given that its first `shared_len` tokens fork an existing
/// session's pages (DESIGN.md §Pages, §Scheduler). Only *full* shared K/V
/// pages are discounted — they are append-complete, so no copy-on-write
/// can ever split them; partially-filled pages and the sorted-gather cut
/// pages may still diverge, so the estimate conservatively charges them
/// to the new session. This is the scheduler's reservation unit: admit
/// while `sum(reservations) + peak <= budget`.
pub fn paged_session_peak_bytes(
    depth: usize,
    n_heads: usize,
    b: usize,
    d_head: usize,
    nb_cap: usize,
    n_cut: Option<usize>,
    blocks_per_page: usize,
    target_len: usize,
    shared_len: usize,
) -> usize {
    let full = stack_paged_resident_bytes(
        depth,
        n_heads,
        b,
        d_head,
        nb_cap,
        n_cut,
        blocks_per_page,
        target_len,
    );
    let shared_blocks = shared_len.min(target_len) / b;
    let shared_pages = shared_blocks / blocks_per_page;
    let shared =
        depth * n_heads * 2 * shared_pages * kv_page_bytes(b, d_head, blocks_per_page);
    full.saturating_sub(shared)
}

/// Admission math of the continuous-batching decode scheduler (DESIGN.md
/// §Scheduler): how many concurrent sessions a decode-state byte budget
/// admits, given the per-session cost [`stack_decode_state_bytes`] and
/// the operator's slot cap. `budget_bytes == 0` means "no memory clamp"
/// (slots are bounded by `slot_cap` alone); the result is never zero — a
/// server that can admit nothing serves nothing, so one slot is always
/// granted and the operator's budget is treated as a floor of one
/// session. The paged scheduler path supersedes this with per-session
/// reservations ([`paged_session_peak_bytes`]); this worst-case clamp
/// remains the monolithic fallback.
pub fn admitted_sessions(budget_bytes: usize, session_bytes: usize, slot_cap: usize) -> usize {
    let by_mem = if budget_bytes == 0 {
        slot_cap
    } else {
        (budget_bytes / session_bytes.max(1)).min(slot_cap)
    };
    by_mem.max(1)
}

/// The scheduler's paged-admission ledger (DESIGN.md §Scheduler,
/// §Faults): bytes reserved against the operator's budget by sessions
/// currently admitted. Faults made the ad-hoc counter version dangerous —
/// every retirement path (completion, deadline, cancellation, panic
/// containment, drain abort) must release exactly what admission
/// reserved, so the pairing is centralized here and underflow (a
/// double-release or a release never reserved) is a hard assertion
/// instead of a silent `saturating_sub` that would mask a leak.
#[derive(Debug, Clone, Copy)]
pub struct Reservations {
    budget: usize,
    reserved: usize,
}

impl Reservations {
    /// `budget == 0` means unmetered (every `fits` succeeds).
    pub fn new(budget: usize) -> Reservations {
        Reservations { budget, reserved: 0 }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn reserved(&self) -> usize {
        self.reserved
    }

    /// Would `need` more bytes stay within budget?
    pub fn fits(&self, need: usize) -> bool {
        self.budget == 0 || self.reserved + need <= self.budget
    }

    /// Charge an admitted session. The scheduler may deliberately reserve
    /// past budget for its floor-of-one session, so this does not check
    /// `fits` — the caller decides policy, the ledger just counts.
    pub fn reserve(&mut self, bytes: usize) {
        self.reserved += bytes;
    }

    /// Release a retired session's charge.
    pub fn release(&mut self, bytes: usize) {
        assert!(
            bytes <= self.reserved,
            "reservation underflow: releasing {bytes} of {} reserved",
            self.reserved
        );
        self.reserved -= bytes;
    }

    /// True iff every reservation has been released — the chaos battery
    /// asserts this after each fault schedule drains.
    pub fn is_empty(&self) -> bool {
        self.reserved == 0
    }
}

/// MXU utilization proxy: fraction of the kernel's MACs that land in
/// >=8x8x8-shaped matmuls (all of them, for b,d >= 8 — the point is the
/// tiles are MXU-shaped by construction).
pub fn mxu_mac_fraction(b: usize, d: usize) -> f64 {
    if b >= 8 && d >= 8 {
        1.0
    } else {
        // degenerate tiles fall back to VPU element ops
        (b.min(8) * d.min(8)) as f64 / 64.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservations_pair_reserve_with_release() {
        let mut r = Reservations::new(100);
        assert!(r.fits(100));
        r.reserve(60);
        assert!(r.fits(40) && !r.fits(41));
        r.reserve(60); // floor-of-one may exceed budget deliberately
        assert_eq!(r.reserved(), 120);
        r.release(60);
        r.release(60);
        assert!(r.is_empty());
        // budget 0 = unmetered
        assert!(Reservations::new(0).fits(usize::MAX));
    }

    #[test]
    #[should_panic(expected = "reservation underflow")]
    fn reservation_underflow_is_a_hard_error() {
        let mut r = Reservations::new(0);
        r.reserve(10);
        r.release(11);
    }

    #[test]
    fn paper_saving_factor_illustration() {
        // paper §1 footnote: ell=1024, N_B=64-token blocks -> ~240x.
        // (1024^2) / (64^2 + 16^2) = 240.9 with nb=16 blocks of b=64.
        let f = saving_factor(1024, 16);
        assert!((f - 240.9).abs() < 1.0, "{f}");
    }

    #[test]
    fn sinkhorn_beats_dense_when_long() {
        let d = 64;
        let dense_c = dense(2048, d);
        let sink_c = sinkhorn(2048, 32, d);
        // the paper's claim is about attention *score* memory; the sorted
        // K/V copies (aux) are linear in ell and dominate only at small d
        assert!(sink_c.score_elems < dense_c.score_elems / 10);
        assert!(sink_c.total_elems() < dense_c.total_elems() / 4);
        assert!(sink_c.macs < dense_c.macs);
    }

    #[test]
    fn local_is_lower_bound_for_sinkhorn_scores() {
        // sinkhorn materializes exactly 2x the local scores
        let (ell, nb, d) = (512, 16, 32);
        assert_eq!(sinkhorn(ell, nb, d).score_elems, 2 * local(ell, nb, d).score_elems);
    }

    #[test]
    fn sortcut_linear_in_ell() {
        let d = 32;
        let c1 = sortcut(1024, 16, 2, d);
        let c2 = sortcut(2048, 32, 2, d);
        // same block size b=64, same cut => scores scale linearly with ell
        assert_eq!(c2.score_elems, 2 * c1.score_elems);
    }

    #[test]
    fn vmem_fits_tpu_for_paper_blocks() {
        // b=64, d=64 head tiles comfortably fit 16 MiB VMEM
        assert!(kernel_vmem_bytes(64, 64) < 16 << 20);
        assert!(kernel_vmem_bytes(256, 128) < 16 << 20);
    }

    #[test]
    fn mxu_fraction_full_for_mxu_shaped_tiles() {
        assert_eq!(mxu_mac_fraction(64, 64), 1.0);
        assert!(mxu_mac_fraction(4, 64) < 1.0);
    }

    #[test]
    fn decode_step_cost_is_sequence_length_free() {
        let (b, d) = (64, 64);
        // full-causal: one cached sorted block + the local window, both
        // contractions — no term grows with the prefix length
        assert_eq!(decode_step_macs(b, d, 1), 2 * 2 * b * d);
        // sortcut widens only the cached segment, not the local window
        assert_eq!(decode_step_macs(b, d, 4), 2 * 5 * b * d);
        // the dense incremental alternative scores the whole prefix per
        // token: 2·ell·d MACs — already 32x the sinkhorn step at ell=4096
        let dense_step = 2 * 4096 * d;
        assert!(dense_step >= 32 * decode_step_macs(b, d, 1));
    }

    #[test]
    fn decode_state_dominated_by_kv_cache() {
        // the cached sort matrix + gathered blocks must stay a small
        // constant factor over the unavoidable KV cache
        for (b, d, nb) in [(64usize, 64usize, 16usize), (128, 64, 32)] {
            let kv_only = 2 * nb * b * d * 4;
            let full = decode_state_bytes(b, d, nb, None);
            let cut = decode_state_bytes(b, d, nb, Some(4));
            assert!(full < kv_only * 2, "b={b}");
            assert!(cut < kv_only * 2, "b={b}");
            assert!(cut > full, "sortcut caches more gathered blocks");
        }
    }

    #[test]
    fn admission_math_clamps_by_memory_and_slots() {
        let per = stack_decode_state_bytes(2, 2, 8, 8, 4, None);
        // no budget: slot cap rules
        assert_eq!(admitted_sessions(0, per, 8), 8);
        // budget for exactly 3 sessions, cap above it: memory rules
        assert_eq!(admitted_sessions(3 * per + per / 2, per, 8), 3);
        // budget for many, cap below: slots rule
        assert_eq!(admitted_sessions(100 * per, per, 4), 4);
        // starvation floor: even a zero/undersized budget grants one slot
        assert_eq!(admitted_sessions(1, per, 8), 1);
        assert_eq!(admitted_sessions(per - 1, per, 8), 1);
        // degenerate per-session cost cannot divide by zero
        assert_eq!(admitted_sessions(1024, 0, 8), 8);
    }

    #[test]
    fn paged_resident_follows_length_not_capacity() {
        let (b, d, nb) = (8usize, 16usize, 32usize);
        // empty session: only the balance matrix is resident
        assert_eq!(decode_state_resident_bytes(b, d, nb, None, 1, 0), nb * nb * 4);
        // one token: one K + one V page + both cut pages
        let one = decode_state_resident_bytes(b, d, nb, None, 1, 1);
        assert_eq!(one, (nb * nb + 2 * b * d + 2 * b * d) * 4);
        // a full session converges on the monolithic worst case
        let full = decode_state_resident_bytes(b, d, nb, None, 1, nb * b);
        assert_eq!(full, decode_state_bytes(b, d, nb, None));
        // short sessions resident O(len): an 1/8-full session pins ~1/8
        // the KV bytes of the monolithic allocation
        let short = decode_state_resident_bytes(b, d, nb, None, 1, nb * b / 8);
        assert!(short * 4 < full, "short={short} full={full}");
        // page granularity rounds up, never down
        for len in 1..=3 * b {
            assert_eq!(kv_pages_at(len, b, 2), len.div_ceil(b).div_ceil(2));
        }
    }

    #[test]
    fn prefix_sharing_discounts_only_full_pages() {
        let (depth, heads, b, dh, nb) = (2usize, 2usize, 8usize, 8usize, 16usize);
        let target = nb * b;
        let unshared = paged_session_peak_bytes(depth, heads, b, dh, nb, None, 1, target, 0);
        assert_eq!(
            unshared,
            stack_paged_resident_bytes(depth, heads, b, dh, nb, None, 1, target)
        );
        // sharing 4 full blocks discounts 4 K + 4 V pages per head per layer
        let shared = paged_session_peak_bytes(depth, heads, b, dh, nb, None, 1, target, 4 * b);
        assert_eq!(unshared - shared, depth * heads * 2 * 4 * kv_page_bytes(b, dh, 1));
        // a sub-block prefix shares no complete page: no discount
        assert_eq!(
            paged_session_peak_bytes(depth, heads, b, dh, nb, None, 1, target, b - 1),
            unshared
        );
        // with 4 blocks per page, a 4-block prefix is one full page
        let bpp = paged_session_peak_bytes(depth, heads, b, dh, nb, None, 4, target, 4 * b);
        let bpp_unshared = paged_session_peak_bytes(depth, heads, b, dh, nb, None, 4, target, 0);
        assert_eq!(bpp_unshared - bpp, depth * heads * 2 * kv_page_bytes(b, dh, 4));
    }

    #[test]
    fn engine_workspace_linear_in_b() {
        // streaming softmax: no b^2 logits tile left, so doubling the
        // block size exactly doubles the per-worker scratch
        for (b, d) in [(64, 64), (256, 64), (16, 32)] {
            assert_eq!(engine_workspace_bytes(2 * b, d), 2 * engine_workspace_bytes(b, d));
        }
    }

    #[test]
    fn engine_workspace_beats_materialized_logits() {
        // the pre-streaming engine staged (3bd + 2b^2) f32s per worker;
        // the streaming workspace must undercut it at production blocks
        for (b, d) in [(64, 64), (256, 64), (1024, 64)] {
            let old = (3 * b * d + 2 * b * b) * 4;
            assert!(engine_workspace_bytes(b, d) < old, "b={b} d={d}");
        }
    }
}
