"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/modes; explicit tests pin the paper's edge
cases (causal masking, empty sorted support, iteration counts).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention_kernel as ak
from compile.kernels import ref
from compile.kernels import sinkhorn_kernel as sk
from compile.kernels import sortcut_kernel as sck

settings.register_profile("kernels", deadline=None, max_examples=12, derandomize=True)
settings.load_profile("kernels")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# sinkhorn balancing kernel
# ---------------------------------------------------------------------------


@given(
    g=st.integers(1, 6),
    nb=st.sampled_from([2, 4, 8, 16]),
    iters=st.sampled_from([0, 1, 5, 13]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sinkhorn_matches_ref(g, nb, iters, seed):
    r = rand(jax.random.PRNGKey(seed), (g, nb, nb)) * 2.0
    out = sk.sinkhorn_balance(r, iters)
    want = jax.vmap(lambda x: ref.sinkhorn_log(x, iters))(r)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


@given(
    g=st.integers(1, 4),
    nb=st.sampled_from([3, 4, 8]),
    iters=st.sampled_from([0, 2, 8]),
    strict=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_causal_sinkhorn_matches_ref(g, nb, iters, strict, seed):
    r = rand(jax.random.PRNGKey(seed), (g, nb, nb)) * 2.0
    out = sk.sinkhorn_balance(r, iters, causal=True, strict=strict)
    want = jax.vmap(lambda x: ref.causal_sinkhorn_log(x, iters, strict=strict))(r)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


def test_sinkhorn_rows_cols_near_one():
    r = rand(jax.random.PRNGKey(0), (4, 8, 8)) * 3.0
    s = sk.sinkhorn_balance(r, 25)
    np.testing.assert_allclose(s.sum(-1), 1.0, atol=5e-3)
    np.testing.assert_allclose(s.sum(-2), 1.0, atol=5e-3)
    assert (np.asarray(s) >= 0).all()


def test_causal_sinkhorn_strict_zero_upper():
    r = rand(jax.random.PRNGKey(1), (2, 6, 6))
    s = np.asarray(sk.sinkhorn_balance(r, 6, causal=True, strict=True))
    for i in range(6):
        for j in range(i, 6):
            assert s[:, i, j].max() == 0.0, (i, j)


def test_sinkhorn_grad_matches_ref_vjp():
    r = rand(jax.random.PRNGKey(2), (3, 4, 4))
    g1 = jax.grad(lambda x: (sk.sinkhorn_balance(x, 5) ** 2).sum())(r)
    g2 = jax.grad(lambda x: (jax.vmap(lambda y: ref.sinkhorn_log(y, 5))(x) ** 2).sum())(r)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# block attention kernel (both grid modes)
# ---------------------------------------------------------------------------


def _attention_case(seed, g, nb, b, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = rand(ks[0], (g, nb, b, d))
    k = rand(ks[1], (g, nb, b, d))
    v = rand(ks[2], (g, nb, b, d))
    s = jax.vmap(lambda x: ref.sinkhorn_log(x, 5))(rand(ks[3], (g, nb, nb)))
    ksort = jnp.einsum("gij,gjbd->gibd", s, k)
    vsort = jnp.einsum("gij,gjbd->gibd", s, v)
    return q, k, v, ksort, vsort


@pytest.mark.parametrize("mode", ["slab", "tile"])
@given(
    g=st.integers(1, 4),
    nb=st.sampled_from([2, 4]),
    b=st.sampled_from([2, 4, 8]),
    d=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_fwd_matches_ref(mode, g, nb, b, d, seed):
    q, k, v, ksort, vsort = _attention_case(seed, g, nb, b, d)
    valid = jnp.ones((g, nb))
    out = ak.sinkhorn_block_attention(q, k, v, ksort, vsort, valid, mode=mode)
    want = jax.vmap(ref.sinkhorn_attention)(q, k, v, ksort, vsort)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["slab", "tile"])
def test_causal_attention_matches_ref(mode):
    g, nb, b, d = 3, 4, 4, 8
    q, k, v, _, _ = _attention_case(7, g, nb, b, d)
    s = jax.vmap(lambda x: ref.causal_sinkhorn_log(x, 5, strict=True))(
        rand(jax.random.PRNGKey(9), (g, nb, nb))
    )
    ksort = jnp.einsum("gij,gjbd->gibd", s, k)
    vsort = jnp.einsum("gij,gjbd->gibd", s, v)
    valid = (s.sum(-1) > 1e-6).astype(jnp.float32)
    out = ak.sinkhorn_block_attention(q, k, v, ksort, vsort, valid, causal=True, mode=mode)
    want = jax.vmap(ref.causal_sinkhorn_attention)(q, k, v, ksort, vsort, valid > 0.5)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["slab", "tile"])
def test_attention_grads_match_ref(mode):
    g, nb, b, d = 2, 3, 4, 8
    q, k, v, _, _ = _attention_case(11, g, nb, b, d)
    r = rand(jax.random.PRNGKey(12), (g, nb, nb))

    def loss_kernel(q, k, v, r):
        s = sk.sinkhorn_balance(r, 5)
        ks_ = jnp.einsum("gij,gjbd->gibd", s, k)
        vs_ = jnp.einsum("gij,gjbd->gibd", s, v)
        y = ak.sinkhorn_block_attention(q, k, v, ks_, vs_, jnp.ones((g, nb)), mode=mode)
        return (y ** 2).sum()

    def loss_ref(q, k, v, r):
        s = jax.vmap(lambda x: ref.sinkhorn_log(x, 5))(r)
        ks_ = jnp.einsum("gij,gjbd->gibd", s, k)
        vs_ = jnp.einsum("gij,gjbd->gibd", s, v)
        y = jax.vmap(ref.sinkhorn_attention)(q, k, v, ks_, vs_)
        return (y ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(q, k, v, r)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(q, k, v, r)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-4)


def test_local_attention_is_sinkhorn_with_zero_sort():
    g, nb, b, d = 2, 4, 4, 8
    q, k, v, _, _ = _attention_case(13, g, nb, b, d)
    out = ak.local_block_attention(q, k, v)
    want = jax.vmap(lambda q_, k_, v_: ref.local_attention(q_, k_, v_))(q, k, v)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_invalid_sorted_block_ignored():
    # with valid=0 everywhere and k_sorted garbage, output must equal local
    g, nb, b, d = 2, 3, 4, 8
    q, k, v, _, _ = _attention_case(17, g, nb, b, d)
    garbage = jnp.full((g, nb, b, d), 1e3)
    out = ak.sinkhorn_block_attention(q, k, v, garbage, garbage, jnp.zeros((g, nb)))
    want = jax.vmap(lambda q_, k_, v_: ref.local_attention(q_, k_, v_))(q, k, v)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_attention_bf16_close():
    g, nb, b, d = 2, 2, 4, 8
    q, k, v, ksort, vsort = _attention_case(19, g, nb, b, d)
    cast = lambda x: x.astype(jnp.bfloat16)
    out = ak.sinkhorn_block_attention(
        cast(q), cast(k), cast(v), cast(ksort), cast(vsort), jnp.ones((g, nb), jnp.bfloat16)
    )
    want = jax.vmap(ref.sinkhorn_attention)(q, k, v, ksort, vsort)
    np.testing.assert_allclose(np.asarray(out, np.float32), want, rtol=0.1, atol=0.05)


# ---------------------------------------------------------------------------
# sortcut kernel
# ---------------------------------------------------------------------------


@given(
    g=st.integers(1, 4),
    ell=st.sampled_from([16, 32, 64]),
    ncut=st.sampled_from([4, 8]),
    d=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sortcut_matches_ref(g, ell, ncut, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = rand(ks[0], (g, ell, d))
    kc = rand(ks[1], (g, ncut, d))
    vc = rand(ks[2], (g, ncut, d))
    out = sck.sortcut_attention(q, kc, vc)
    want = jax.vmap(ref.sortcut_attention)(q, kc, vc)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_sortcut_grad_matches_ref():
    g, ell, ncut, d = 2, 16, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, kc, vc = rand(ks[0], (g, ell, d)), rand(ks[1], (g, ncut, d)), rand(ks[2], (g, ncut, d))
    g1 = jax.grad(lambda a, b, c: (sck.sortcut_attention(a, b, c) ** 2).sum(), argnums=(0, 1, 2))(
        q, kc, vc
    )
    g2 = jax.grad(
        lambda a, b, c: (jax.vmap(ref.sortcut_attention)(a, b, c) ** 2).sum(), argnums=(0, 1, 2)
    )(q, kc, vc)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_sortcut_uneven_block_q_fallback():
    # ell not divisible by the default block: block_q halves until it fits
    g, ell, ncut, d = 1, 24, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, kc, vc = rand(ks[0], (g, ell, d)), rand(ks[1], (g, ncut, d)), rand(ks[2], (g, ncut, d))
    out = sck.sortcut_attention(q, kc, vc)
    want = jax.vmap(ref.sortcut_attention)(q, kc, vc)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)
