//! Algorithmic sorting task (paper §5.1, Table 1): seq2seq transduction —
//! input a random integer sequence, output its sorted order. Mirrors
//! Tensor2Tensor's `algorithmic_sort_problem`, including the length-
//! generalization probe (train at ell, evaluate at 2*ell).

use crate::util::rng::Rng;

use super::tokenizer::BOS;

/// Digits live in [FIRST_DIGIT, vocab); 0..3 are pad/unk/bos/sep specials.
pub const FIRST_DIGIT: i32 = 4;

pub struct SortTask {
    pub vocab: usize,
    rng: Rng,
}

/// One example: src digits and the decoder target `[BOS, sorted...]`.
#[derive(Debug, Clone)]
pub struct SortExample {
    pub src: Vec<i32>,
    /// length = src.len() + 1 (BOS-prefixed sorted sequence)
    pub tgt: Vec<i32>,
}

impl SortTask {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab as i32 > FIRST_DIGIT + 2, "vocab too small for digits");
        SortTask { vocab, rng: Rng::new(seed) }
    }

    pub fn example(&mut self, len: usize) -> SortExample {
        let hi = self.vocab as i64;
        let src: Vec<i32> = (0..len)
            .map(|_| self.rng.range_i64(FIRST_DIGIT as i64, hi) as i32)
            .collect();
        let mut sorted = src.clone();
        sorted.sort_unstable();
        let mut tgt = Vec::with_capacity(len + 1);
        tgt.push(BOS);
        tgt.extend_from_slice(&sorted);
        SortExample { src, tgt }
    }

    /// A batch as two row-major id buffers: src (bsz, len), tgt (bsz, len+1).
    pub fn batch(&mut self, bsz: usize, len: usize) -> (Vec<i32>, Vec<i32>) {
        let mut src = Vec::with_capacity(bsz * len);
        let mut tgt = Vec::with_capacity(bsz * (len + 1));
        for _ in 0..bsz {
            let ex = self.example(len);
            src.extend_from_slice(&ex.src);
            tgt.extend_from_slice(&ex.tgt);
        }
        (src, tgt)
    }
}

/// Exact-match + mean normalized edit distance between predictions and the
/// ground-truth sorted sequences (the Table 1 metrics).
pub fn score_predictions(preds: &[Vec<i32>], golds: &[Vec<i32>]) -> (f64, f64) {
    assert_eq!(preds.len(), golds.len());
    let mut em = 0usize;
    let mut ed_sum = 0.0;
    for (p, g) in preds.iter().zip(golds) {
        if p == g {
            em += 1;
        }
        ed_sum += crate::util::edit_distance(p, g) as f64 / g.len().max(1) as f64;
    }
    (em as f64 / preds.len() as f64, ed_sum / preds.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn target_is_sorted_permutation() {
        forall(
            32,
            0x50,
            |g| {
                let mut t = SortTask::new(20, g.rng.next_u64());
                t.example(8 + g.usize(0, 56))
            },
            |ex| {
                if ex.tgt[0] != BOS {
                    return Err("missing BOS".into());
                }
                let body = &ex.tgt[1..];
                if !body.windows(2).all(|w| w[0] <= w[1]) {
                    return Err("target not sorted".into());
                }
                let mut a = ex.src.clone();
                let mut b = body.to_vec();
                a.sort_unstable();
                b.sort_unstable();
                if a != b {
                    return Err("target not a permutation of source".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn digits_in_vocab_range() {
        let mut t = SortTask::new(20, 7);
        let ex = t.example(64);
        assert!(ex.src.iter().all(|&d| (FIRST_DIGIT..20).contains(&d)));
    }

    #[test]
    fn batch_shapes() {
        let mut t = SortTask::new(20, 3);
        let (src, tgt) = t.batch(4, 16);
        assert_eq!(src.len(), 4 * 16);
        assert_eq!(tgt.len(), 4 * 17);
    }

    #[test]
    fn scoring() {
        let golds = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let preds = vec![vec![1, 2, 3], vec![4, 6, 5]];
        let (em, ed) = score_predictions(&preds, &golds);
        assert!((em - 0.5).abs() < 1e-12);
        assert!(ed > 0.0 && ed < 1.0);
    }
}
