//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation section (plus the §4 memory analysis) on the synthetic
//! testbed. Each target trains the registered experiments from scratch,
//! evaluates with the task's metric, and prints paper-vs-measured rows.

pub mod paper;
pub mod tables;

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::coordinator::{self, TrainOptions};
use crate::data::TaskData;
use crate::runtime::{Experiment, Registry, Runtime};

/// Bench-wide options (from the CLI).
#[derive(Debug, Clone)]
pub struct BenchOptions {
    pub artifacts: PathBuf,
    /// multiplies each experiment's default_steps
    pub scale: f64,
    /// hard override of the step count (takes precedence over scale)
    pub steps: Option<usize>,
    pub seed: i32,
    pub eval_batches: usize,
    pub verbose: bool,
    /// use teacher-forced seq2seq eval (fast) instead of true greedy decode
    pub fast_decode: bool,
    /// CI smoke mode: tiny shapes, one rep, correctness gates still on —
    /// and no `BENCH_*.json` emission, so the real perf trajectory files
    /// are never polluted by smoke numbers (`make bench-smoke`)
    pub smoke: bool,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            artifacts: crate::runtime::artifacts_dir(),
            scale: 1.0,
            steps: None,
            seed: 17,
            eval_batches: 4,
            verbose: false,
            fast_decode: false,
            smoke: false,
        }
    }
}

/// Result of one experiment run: the task metric(s).
#[derive(Debug, Clone)]
pub struct ExpResult {
    pub name: String,
    pub variant: String,
    pub n_params: usize,
    pub train_loss: f64,
    pub steps_per_sec: f64,
    /// primary metric (ppl / bpc / bpd / accuracy / EM)
    pub metric: f64,
    /// secondary metric (edit distance for table 1)
    pub metric2: Option<f64>,
}

/// Train + evaluate one experiment end to end.
pub fn run_experiment(rt: &Runtime, opts: &BenchOptions, name: &str) -> Result<ExpResult> {
    let exp = Experiment::load(&opts.artifacts, name)?;
    let m = &exp.manifest;
    let default_steps = m.train_cfg.usize_of("default_steps").unwrap_or(200);
    let steps = opts.steps.unwrap_or(((default_steps as f64 * opts.scale) as usize).max(10));

    let mut data = TaskData::for_experiment(m)?;
    if opts.verbose {
        println!("[{name}] training {steps} steps ({} params)...", m.n_params());
    }
    let topts = TrainOptions {
        steps,
        seed: opts.seed,
        log_every: (steps / 10).max(1),
        verbose: opts.verbose,
        checkpoint: None,
    };
    let (state, report) = coordinator::train_from_scratch(rt, &exp, &mut data, &topts)?;

    let (metric, metric2) = match &mut data {
        TaskData::Lm(d) => {
            let loss = coordinator::eval_lm(rt, &exp, &state, d, opts.eval_batches)?;
            let key = name.split("__").next().unwrap_or("");
            let metric = if key.starts_with("lmc") {
                coordinator::bpc(loss)
            } else if key.starts_with("img") {
                coordinator::bpd(loss)
            } else {
                coordinator::perplexity(loss)
            };
            (metric, None)
        }
        TaskData::Cls(d) => {
            let (_loss, acc) = coordinator::eval_cls(rt, &exp, &state, d)?;
            (acc * 100.0, None)
        }
        TaskData::Sort(d) => {
            let (em, ed) = if opts.fast_decode {
                coordinator::eval_sort_teacher_forced(rt, &exp, &state, d, opts.eval_batches)?
            } else {
                coordinator::eval_sort(rt, &exp, &state, d, opts.eval_batches)?
            };
            (em * 100.0, Some(ed))
        }
    };

    if opts.verbose {
        println!(
            "[{name}] metric {metric:.4}{} ({:.2} steps/s)",
            metric2.map(|e| format!(" ed {e:.4}")).unwrap_or_default(),
            report.steps_per_sec
        );
    }
    Ok(ExpResult {
        name: name.to_string(),
        variant: name.split("__").nth(1).unwrap_or("?").to_string(),
        n_params: m.n_params(),
        train_loss: report.ema_loss,
        steps_per_sec: report.steps_per_sec,
        metric,
        metric2,
    })
}

/// Run every experiment of one table; preserves registry order.
pub fn run_table_experiments(
    rt: &Runtime,
    reg: &Registry,
    opts: &BenchOptions,
    table: &str,
    name_filter: Option<&str>,
) -> Result<Vec<ExpResult>> {
    let entries = reg.by_table(table);
    if entries.is_empty() {
        bail!("no experiments registered for '{table}'");
    }
    let mut out = Vec::new();
    for e in entries {
        if let Some(f) = name_filter {
            if !e.name.contains(f) {
                continue;
            }
        }
        out.push(run_experiment(rt, opts, &e.name)?);
    }
    Ok(out)
}

/// Write a rendered table + raw rows under `artifacts/results/`.
pub fn save_result(artifacts: &Path, tag: &str, rendered: &str) -> Result<()> {
    let dir = artifacts.join("results");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{tag}.txt")), rendered)?;
    Ok(())
}
