//! Synthetic language-modeling corpus (stand-in for LM1B — DESIGN.md §4).
//!
//! The generator plants exactly the structure the paper's comparison
//! hinges on:
//!   * a Zipf (power-law) unigram distribution over the vocabulary,
//!   * 2nd-order Markov local syntax (what local attention can model),
//!   * **long-range topic recurrence**: each sequence samples a few topic
//!     tokens that re-appear periodically across the whole sequence —
//!     context a block-local window cannot see but quasi-global (sorted)
//!     attention can exploit.
//!
//! Word-level mode emits token ids directly; char-level mode renders each
//! word id to a deterministic pseudo-word string (same long-range
//! structure at ~4x the sequence length).

use crate::util::rng::Rng;

use super::tokenizer::{CharVocab, N_SPECIALS};

/// Word-level corpus generator.
pub struct Corpus {
    pub vocab: usize,
    rng: Rng,
    zipf_cache: Vec<f64>,
    /// per-state transition bias tables (tiny 2nd-order hash chain)
    n_states: usize,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        Corpus { vocab, rng: Rng::new(seed), zipf_cache: Vec::new(), n_states: 64 }
    }

    fn markov_next(&mut self, prev1: usize, prev2: usize) -> usize {
        // deterministic "grammar": the state hash biases a band of the
        // vocabulary, mixed with the global zipf draw
        let state = (prev1.wrapping_mul(31).wrapping_add(prev2)) % self.n_states;
        if self.rng.bool(0.55) {
            // local-syntax draw: band of 8 tokens owned by this state
            let base = (state * 97) % (self.vocab.saturating_sub(16)).max(1);
            base + self.rng.usize_below(8)
        } else {
            self.rng.zipf(self.vocab, 1.1, &mut self.zipf_cache)
        }
    }

    /// One training sequence of `len` token ids in `[N_SPECIALS, vocab)`.
    pub fn sequence(&mut self, len: usize) -> Vec<i32> {
        let reserved = N_SPECIALS as usize;
        let eff_vocab = self.vocab - reserved;
        // sample 2-4 topic tokens for long-range recurrence
        let n_topics = 2 + self.rng.usize_below(3);
        let topics: Vec<usize> =
            (0..n_topics).map(|_| self.rng.usize_below(eff_vocab)).collect();
        let period = 12 + self.rng.usize_below(12);

        let mut seq = Vec::with_capacity(len);
        let (mut p1, mut p2) = (0usize, 1usize);
        for t in 0..len {
            let tok = if t > 0 && t % period == 0 {
                // long-range dependency: topic token recurs
                topics[(t / period) % n_topics]
            } else {
                self.markov_next(p1, p2)
            };
            p2 = p1;
            p1 = tok;
            seq.push((tok % eff_vocab) as i32 + N_SPECIALS as i32);
        }
        seq
    }
}

/// Char-level corpus: word-level sequences rendered to pseudo-words.
pub struct CharCorpus {
    inner: Corpus,
    cv: CharVocab,
}

impl CharCorpus {
    pub fn new(word_vocab: usize, seed: u64) -> Self {
        CharCorpus { inner: Corpus::new(word_vocab, seed), cv: CharVocab::ascii() }
    }

    pub fn char_vocab_len(&self) -> usize {
        self.cv.len()
    }

    /// Deterministic word-id -> string rendering (letters base-20, so the
    /// char model can learn the id structure).
    pub fn render_word(id: i32) -> String {
        let letters = b"etaoinshrdlucmfwypvb";
        let mut x = id as usize;
        let mut s = String::new();
        loop {
            s.push(letters[x % letters.len()] as char);
            x /= letters.len();
            if x == 0 {
                break;
            }
        }
        s
    }

    /// One char-level sequence of exactly `len` char ids.
    pub fn sequence(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len + 8);
        while out.len() < len {
            let words = self.inner.sequence(16);
            for w in words {
                for c in Self::render_word(w).chars() {
                    out.push(self.cv.encode(c));
                }
                out.push(self.cv.encode(' '));
                if out.len() >= len {
                    break;
                }
            }
        }
        out.truncate(len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(512, 1);
        for _ in 0..5 {
            let s = c.sequence(128);
            assert_eq!(s.len(), 128);
            assert!(s.iter().all(|&t| (N_SPECIALS..512).contains(&t)));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(256, 9);
        let mut b = Corpus::new(256, 9);
        assert_eq!(a.sequence(64), b.sequence(64));
    }

    #[test]
    fn topic_recurrence_present() {
        // at least one token must repeat at a fixed period in most seqs
        let mut c = Corpus::new(512, 3);
        let mut hits = 0;
        for _ in 0..20 {
            let s = c.sequence(128);
            let mut counts = std::collections::HashMap::new();
            for &t in &s {
                *counts.entry(t).or_insert(0usize) += 1;
            }
            if counts.values().any(|&n| n >= 4) {
                hits += 1;
            }
        }
        assert!(hits > 10, "long-range topics missing: {hits}/20");
    }

    #[test]
    fn zipf_head_heavy() {
        let mut c = Corpus::new(512, 5);
        let mut counts = vec![0usize; 512];
        for _ in 0..30 {
            for t in c.sequence(128) {
                counts[t as usize] += 1;
            }
        }
        let head: usize = counts[4..54].iter().sum();
        let tail: usize = counts[262..312].iter().sum();
        assert!(head > tail, "head {head} tail {tail}");
    }

    #[test]
    fn char_mode_len_and_range() {
        let mut c = CharCorpus::new(256, 2);
        let v = c.char_vocab_len() as i32;
        let s = c.sequence(256);
        assert_eq!(s.len(), 256);
        assert!(s.iter().all(|&t| t >= 1 && t < v));
    }

    #[test]
    fn render_word_unique_small_ids() {
        let mut seen = std::collections::HashSet::new();
        for id in 0..400 {
            assert!(seen.insert(CharCorpus::render_word(id)), "collision at {id}");
        }
    }
}
