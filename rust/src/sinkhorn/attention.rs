//! Pure-Rust single-head Sparse Sinkhorn Attention — mirrors
//! `python/compile/kernels/ref.py` and holds every naive oracle the
//! production paths are verified against:
//!
//! * [`sinkhorn_attention`] / [`local_attention`] / [`dense_attention`] /
//!   [`sortcut_attention`] — the batch attention semantics
//!   (`tests/engine_props.rs`); `sinkhorn_attention` takes *any* mixing
//!   matrix, so it doubles as the per-backend forward reference for the
//!   [`SortStrategy`](super::strategy::SortStrategy) backends
//!   (`tests/backends_props.rs`, `bench --target backends`);
//! * [`routing_mixing`] — an independent naive rewrite of the `routing`
//!   backend's online k-means mixing rule;
//! * [`causal_decode_attention`] / [`decode_attention_with`] — the
//!   full-prefix incremental-decode oracle, Sinkhorn-balanced or
//!   closure-parameterized per backend (`tests/decode_props.rs`);
//! * [`reference_stack_forward`] / [`reference_stack_decode`] and their
//!   `_with` strategy-parameterized forms — the depth-L stack oracles
//!   (`tests/model_props.rs`).
//!
//! This is the *naive reference path*: one materialized `Mat` per
//! intermediate, single-threaded, written for obviousness. The production
//! path is [`super::engine::SinkhornEngine`], which streams the joint
//! softmax over zero-copy views with a worker pool; its tiled kernels
//! reorder float summation, so the engine is verified to within 1e-5
//! max-abs of this module.

use super::balance::NEG_INF;
use super::matrix::{gelu, Mat, LN_EPS};
use super::model::{StackConfig, TransformerLayer};

/// Blocked sequence: `nb` blocks of a `(b, d)` matrix each.
#[derive(Debug, Clone)]
pub struct Blocked {
    pub blocks: Vec<Mat>,
}

impl Blocked {
    /// Split an `(ell, d)` matrix into `nb` blocks.
    pub fn from_seq(x: &Mat, nb: usize) -> Self {
        assert_eq!(x.rows % nb, 0, "nb must divide ell");
        let b = x.rows / nb;
        let blocks = (0..nb)
            .map(|i| {
                Mat::from_vec(
                    b,
                    x.cols,
                    x.data[i * b * x.cols..(i + 1) * b * x.cols].to_vec(),
                )
            })
            .collect();
        Blocked { blocks }
    }

    pub fn to_seq(&self) -> Mat {
        let b = self.blocks[0].rows;
        let d = self.blocks[0].cols;
        let mut data = Vec::with_capacity(self.blocks.len() * b * d);
        for blk in &self.blocks {
            data.extend_from_slice(&blk.data);
        }
        Mat::from_vec(self.blocks.len() * b, d, data)
    }

    /// Apply a sort matrix: out[i] = sum_j R[i,j] * blocks[j].
    ///
    /// Fused gather-matmul: the balanced `r` is nearly a permutation, so
    /// zero weights are skipped and each `w * block` is accumulated
    /// directly into the output tile — no block clone, no scale pass, no
    /// temporaries. Accumulation order (ascending `j`, multiply then add)
    /// matches the historical clone-scale-add loop, so results are
    /// bit-identical to it. (`engine::gather_block_into` is the tiled
    /// production version of this loop — it folds two source blocks per
    /// pass, which reorders the sum and lands under the engine's epsilon
    /// contract instead.)
    pub fn sort(&self, r: &Mat) -> Blocked {
        let nb = self.blocks.len();
        assert_eq!((r.rows, r.cols), (nb, nb));
        let b = self.blocks[0].rows;
        let d = self.blocks[0].cols;
        let blocks = (0..nb)
            .map(|i| {
                let mut acc = Mat::zeros(b, d);
                for j in 0..nb {
                    let w = r[(i, j)];
                    if w != 0.0 {
                        for (o, x) in acc.data.iter_mut().zip(&self.blocks[j].data) {
                            *o += w * *x;
                        }
                    }
                }
                acc
            })
            .collect();
        Blocked { blocks }
    }
}

/// Sparse Sinkhorn attention (single head) over an `(ell, d)` q/k/v.
///
/// `r`: (nb, nb) sort matrix (already balanced; caller picks causal or not).
/// `causal`: within-block causal mask on the local term; the sorted term is
/// masked per-block where `r`'s row has no support.
pub fn sinkhorn_attention(q: &Mat, k: &Mat, v: &Mat, r: &Mat, nb: usize, causal: bool) -> Mat {
    let kb = Blocked::from_seq(k, nb);
    let vb = Blocked::from_seq(v, nb);
    let qb = Blocked::from_seq(q, nb);
    let ks = kb.sort(r);
    let vs = vb.sort(r);
    let b = qb.blocks[0].rows;
    let d = qb.blocks[0].cols;
    let scale = 1.0 / (d as f32).sqrt();

    let mut out_blocks = Vec::with_capacity(nb);
    for i in 0..nb {
        let row_support: f32 = r.row(i).iter().sum();
        let valid = row_support > 1e-6;
        let mut ls = qb.blocks[i].matmul_t(&ks.blocks[i]); // (b, b)
        ls.scale(scale);
        if !valid {
            for x in &mut ls.data {
                *x = NEG_INF;
            }
        }
        let mut ll = qb.blocks[i].matmul_t(&kb.blocks[i]); // (b, b)
        ll.scale(scale);
        if causal {
            for t in 0..b {
                for u in (t + 1)..b {
                    ll[(t, u)] = NEG_INF;
                }
            }
        }
        // joint softmax over [sorted | local]
        let mut logits = Mat::zeros(b, 2 * b);
        for t in 0..b {
            logits.row_mut(t)[..b].copy_from_slice(ls.row(t));
            logits.row_mut(t)[b..].copy_from_slice(ll.row(t));
        }
        logits.softmax_rows();
        let ps = Mat::from_fn(b, b, |t, u| logits[(t, u)]);
        let pl = Mat::from_fn(b, b, |t, u| logits[(t, b + u)]);
        let mut y = ps.matmul(&vs.blocks[i]);
        y.add(&pl.matmul(&vb.blocks[i]));
        out_blocks.push(y);
    }
    Blocked { blocks: out_blocks }.to_seq()
}

/// Block-local attention baseline: identical to `sinkhorn_attention` with
/// an all-zero sort matrix (the sorted term fully masked).
pub fn local_attention(q: &Mat, k: &Mat, v: &Mat, nb: usize, causal: bool) -> Mat {
    let zero = Mat::zeros(nb, nb);
    sinkhorn_attention(q, k, v, &zero, nb, causal)
}

/// Naive reference for the `routing` backend's mixing rule
/// (`super::strategy::RoutingSort`): a from-scratch rewrite of the
/// deterministic online k-means over the first `m` descriptor rows of
/// `feats` — blocks `i < k` seed centroid `i`, later blocks join the
/// nearest centroid (squared euclidean over the full row, ties to the
/// lowest index) and pull it by the running mean `c += (x - c) / n` —
/// followed by uniform `1 / |cluster|` row weights (strictly earlier
/// members only when `causal`; the whole cluster, block `i` included,
/// otherwise). Written with its own loops so
/// `tests/backends_props.rs` can pin `RoutingSort` against an
/// independent derivation; both follow the same accumulation order, so
/// agreement is bitwise.
pub fn routing_mixing(feats: &Mat, m: usize, k: usize, causal: bool) -> Mat {
    assert!(m <= feats.rows, "routing_mixing needs the first m rows");
    let k = k.max(1);
    let d = feats.cols;
    let mut centroids: Vec<Vec<f32>> = Vec::new();
    let mut counts: Vec<usize> = Vec::new();
    let mut assign = vec![0usize; m];
    for i in 0..m {
        if centroids.len() < k {
            centroids.push((0..d).map(|e| feats[(i, e)]).collect());
            counts.push(1);
            assign[i] = centroids.len() - 1;
            continue;
        }
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..centroids.len() {
            let mut dist = 0.0f32;
            for e in 0..d {
                let diff = feats[(i, e)] - centroids[c][e];
                dist += diff * diff;
            }
            if dist < best_d {
                best_d = dist;
                best = c;
            }
        }
        counts[best] += 1;
        let n = counts[best] as f32;
        for e in 0..d {
            centroids[best][e] += (feats[(i, e)] - centroids[best][e]) / n;
        }
        assign[i] = best;
    }
    let mut r = Mat::zeros(m, m);
    for i in 0..m {
        let lim = if causal { i } else { m };
        let mut count = 0usize;
        for j in 0..lim {
            if assign[j] == assign[i] {
                count += 1;
            }
        }
        if count == 0 {
            continue;
        }
        let w = 1.0 / count as f32;
        for j in 0..lim {
            if assign[j] == assign[i] {
                r[(i, j)] = w;
            }
        }
    }
    r
}

/// Dense O(ell^2) attention baseline.
pub fn dense_attention(q: &Mat, k: &Mat, v: &Mat, causal: bool) -> Mat {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul_t(k);
    logits.scale(scale);
    if causal {
        for i in 0..logits.rows {
            for j in (i + 1)..logits.cols {
                logits[(i, j)] = NEG_INF;
            }
        }
    }
    logits.softmax_rows();
    logits.matmul(v)
}

/// Naive full-prefix causal decode oracle (DESIGN.md §Decode): row `t` is
/// the attention output of token `t` over tokens `0..=t` under the
/// incremental decode semantics, recomputed from scratch per position —
/// the obviously-correct reference `decode::DecodeState` is verified
/// against (`tests/decode_props.rs`) and the `bench --target decode`
/// full-recompute baseline mirrors.
///
/// Semantics per token `t` (block `i = t / b`, `m = i + 1` started blocks):
///
/// * `R = causal_sinkhorn(sort_logits[..m, ..m], n_iters, strict = true)` —
///   strict balancing is prefix-consistent (`balance.rs`), which is what
///   lets the incremental path cache rows across steps;
/// * sorted keys: with `n_cut = None`, row `i` of `R` gathered over the
///   blocks (empty for block 0 — the row has no strict support); with
///   `n_cut = Some(c)`, rows `0..min(c, m)` — SortCut decoding. Rows
///   without support are skipped, not zero-gathered;
/// * local keys: rows `i*b..=t` — the within-block causal window;
/// * one joint softmax over `[sorted | local]`, like the batch paths.
///
/// `ell` need not be a multiple of `b`: the final partial block decodes
/// like any other in-progress block. `sort_logits` must cover
/// `ceil(ell / b)` blocks.
pub fn causal_decode_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    sort_logits: &Mat,
    b: usize,
    n_iters: usize,
    n_cut: Option<usize>,
) -> Mat {
    // the historical Sinkhorn-balanced specialization, op-for-op: copy the
    // (m, m) logit corner, strict-causal balance it
    decode_attention_with(q, k, v, sort_logits, b, n_cut, |sl, m| {
        let sub = Mat::from_fn(m, m, |a, c| sl[(a, c)]);
        super::balance::causal_sinkhorn(&sub, n_iters, true)
    })
}

/// [`causal_decode_attention`] with the per-prefix mixing rule factored
/// out: `mix_prefix(sort_logits, m)` must return the strict `(m, m)`
/// mixing matrix over the first `m` started blocks — the naive
/// counterpart of `SortStrategy::mix_prefix`
/// (`super::strategy::SortStrategy`), which is what lets
/// `tests/backends_props.rs` replay the incremental decoder's semantics
/// under any backend. Everything else (row-support skip, naive gather,
/// one joint softmax over `[sorted | local]`) is shared.
pub fn decode_attention_with(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    sort_logits: &Mat,
    b: usize,
    n_cut: Option<usize>,
    mix_prefix: impl Fn(&Mat, usize) -> Mat,
) -> Mat {
    assert!(b > 0, "b must be positive");
    assert_eq!(q.rows, k.rows, "q/k rows");
    assert_eq!(q.rows, v.rows, "q/v rows");
    assert_eq!(q.cols, k.cols, "q/k cols");
    assert_eq!(k.cols, v.cols, "k/v cols");
    let (ell, d) = (q.rows, q.cols);
    let nb = (ell + b - 1) / b;
    assert!(
        sort_logits.rows >= nb && sort_logits.cols >= nb,
        "sort_logits must cover {nb} blocks"
    );
    if let Some(c) = n_cut {
        assert!(c >= 1, "n_cut must be positive");
    }
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Mat::zeros(ell, d);
    for t in 0..ell {
        let i = t / b;
        let m = i + 1;
        let r = mix_prefix(sort_logits, m);
        assert_eq!((r.rows, r.cols), (m, m), "mix_prefix must return an (m, m) matrix");
        // gather the sorted segment's keys/values (naive ascending-j order)
        let rows: Vec<usize> = match n_cut {
            None => vec![i],
            Some(c) => (0..c.min(m)).collect(),
        };
        let mut ks: Vec<f32> = Vec::new();
        let mut vs: Vec<f32> = Vec::new();
        for &row in &rows {
            let w = r.row(row);
            if w.iter().sum::<f32>() <= 1e-6 {
                continue; // no strict support: sorted term masked
            }
            let base = ks.len();
            ks.resize(base + b * d, 0.0);
            vs.resize(base + b * d, 0.0);
            for (j, &wv) in w.iter().enumerate() {
                if wv == 0.0 {
                    continue; // in particular the in-progress block j == i
                }
                for (e, (ko, vo)) in
                    ks[base..].iter_mut().zip(&mut vs[base..]).enumerate()
                {
                    *ko += wv * k.data[j * b * d + e];
                    *vo += wv * v.data[j * b * d + e];
                }
            }
        }
        let ns = ks.len() / d;
        let lo = i * b;
        let nl = t - lo + 1;
        // dense joint logits over [sorted | local], one softmax, combine
        let mut logits = Mat::zeros(1, ns + nl);
        for u in 0..ns {
            let mut acc = 0.0f32;
            for e in 0..d {
                acc += q[(t, e)] * ks[u * d + e];
            }
            logits[(0, u)] = acc * scale;
        }
        for u in 0..nl {
            let mut acc = 0.0f32;
            for e in 0..d {
                acc += q[(t, e)] * k[(lo + u, e)];
            }
            logits[(0, ns + u)] = acc * scale;
        }
        logits.softmax_rows();
        for u in 0..ns {
            let p = logits[(0, u)];
            if p != 0.0 {
                for e in 0..d {
                    out[(t, e)] += p * vs[u * d + e];
                }
            }
        }
        for u in 0..nl {
            let p = logits[(0, ns + u)];
            if p != 0.0 {
                for e in 0..d {
                    out[(t, e)] += p * v[(lo + u, e)];
                }
            }
        }
    }
    out
}

/// SortCut attention: queries attend to the first `n_cut` sorted blocks.
///
/// Only the first `n_cut` sort rows are mixed, straight into one
/// `(n_cut*b, d)` buffer per K/V — the historical path sorted all `nb`
/// blocks and then copied the cut twice (`blocks[..n_cut].to_vec()` +
/// `to_seq()`). Per-row accumulation order matches [`Blocked::sort`], so
/// results are unchanged.
pub fn sortcut_attention(q: &Mat, k: &Mat, v: &Mat, r: &Mat, nb: usize, n_cut: usize) -> Mat {
    assert!((1..=nb).contains(&n_cut), "n_cut must be in 1..=nb, got {n_cut}");
    assert_eq!((r.rows, r.cols), (nb, nb));
    let kb = Blocked::from_seq(k, nb);
    let vb = Blocked::from_seq(v, nb);
    let b = kb.blocks[0].rows;
    let d = kb.blocks[0].cols;
    let mut kcut = Mat::zeros(n_cut * b, d);
    let mut vcut = Mat::zeros(n_cut * b, d);
    for i in 0..n_cut {
        let ko = &mut kcut.data[i * b * d..(i + 1) * b * d];
        let vo = &mut vcut.data[i * b * d..(i + 1) * b * d];
        for j in 0..nb {
            let w = r[(i, j)];
            if w == 0.0 {
                continue;
            }
            for (o, x) in ko.iter_mut().zip(&kb.blocks[j].data) {
                *o += w * *x;
            }
            for (o, x) in vo.iter_mut().zip(&vb.blocks[j].data) {
                *o += w * *x;
            }
        }
    }
    dense_attention(q, &kcut, &vcut, false)
}

// --- naive per-layer stack oracles (DESIGN.md §Model) -----------------------
//
// The multi-layer stack (`super::model::SinkhornStack`) runs on the
// streaming engine and the tiled microkernels; these two functions are its
// obviously-correct references, built from the naive attention paths above
// and single-accumulator LayerNorm — one materialized `Mat` per
// intermediate, no views, no workspaces. `tests/model_props.rs` pins the
// engine stack within `ENGINE_TOL` of them.

/// Single-accumulator LayerNorm — the oracle counterpart of the
/// `LANES`-split `matrix::layernorm_into` (same `LN_EPS`, same affine
/// form, naive summation order).
fn naive_layernorm(x: &Mat, gamma: &[f32], beta: &[f32]) -> Mat {
    let n = x.cols as f32;
    let mut out = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let mut mean = 0.0f32;
        for &v in x.row(i) {
            mean += v;
        }
        mean /= n;
        let mut var = 0.0f32;
        for &v in x.row(i) {
            var += (v - mean) * (v - mean);
        }
        var /= n;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (j, o) in out.row_mut(i).iter_mut().enumerate() {
            *o = (x[(i, j)] - mean) * inv * gamma[j] + beta[j];
        }
    }
    out
}

/// One layer of the stack in oracle form, shared by the forward and decode
/// references: pre-norm (if any) → per-layer SortNet descriptors →
/// per-head attention via `attend` → summed output projections → residual
/// → pre-norm GELU FFN (if any). `attend(h, qh, kh, vh)` supplies the
/// attention semantics (batch sorted+local, SortCut, or per-step causal
/// decode).
fn reference_layer(
    x: &Mat,
    layer: &TransformerLayer,
    attend: impl Fn(&Mat, &Mat, &Mat, &Mat) -> Mat,
) -> Mat {
    let h = match &layer.ln1 {
        Some(ln) => naive_layernorm(x, &ln.gamma, &ln.beta),
        None => x.clone(),
    };
    let mut y = x.clone();
    for hd in 0..layer.wq.len() {
        let qh = h.matmul(&layer.wq[hd]);
        let kh = h.matmul(&layer.wk[hd]);
        let vh = h.matmul(&layer.wv[hd]);
        let ctx = attend(&h, &qh, &kh, &vh);
        y.add(&ctx.matmul(&layer.wo[hd]));
    }
    if let Some(ffn) = &layer.ffn {
        let h2 = naive_layernorm(&y, &ffn.ln.gamma, &ffn.ln.beta);
        let mut a = h2.matmul(&ffn.w1);
        for i in 0..a.rows {
            for (o, &bv) in a.row_mut(i).iter_mut().zip(&ffn.b1) {
                *o = gelu(*o + bv);
            }
        }
        let mut f = a.matmul(&ffn.w2);
        for i in 0..f.rows {
            for (o, &bv) in f.row_mut(i).iter_mut().zip(&ffn.b2) {
                *o += bv;
            }
        }
        y.add(&f);
    }
    y
}

/// Mean-pooled block descriptors → SortNet logits (the layer's raw sort
/// matrix before balancing).
fn reference_sort_logits(h: &Mat, sortnet: &Mat, nb: usize) -> Mat {
    let b = h.rows / nb;
    let mut blk = Mat::zeros(nb, h.cols);
    for i in 0..nb {
        for t in 0..b {
            let xr = h.row(i * b + t);
            for (c, o) in blk.row_mut(i).iter_mut().enumerate() {
                *o += xr[c];
            }
        }
    }
    blk.scale(1.0 / b as f32);
    blk.matmul(sortnet)
}

/// Naive per-layer oracle for the full stack forward
/// (`super::model::SinkhornStack::forward`): every layer built from the
/// naive attention paths ([`sinkhorn_attention`] / [`sortcut_attention`])
/// and single-accumulator LayerNorm. The engine stack must match this
/// within `ENGINE_TOL` (`tests/model_props.rs`).
pub fn reference_stack_forward(x: &Mat, cfg: &StackConfig, layers: &[TransformerLayer]) -> Mat {
    reference_stack_forward_with(x, cfg, layers, |_, logits| {
        if cfg.causal {
            super::balance::causal_sinkhorn(logits, cfg.sinkhorn_iters, true)
        } else {
            super::balance::sinkhorn(logits, cfg.sinkhorn_iters)
        }
    })
}

/// [`reference_stack_forward`] with the block-mixing rule factored out:
/// `mix(layer_index, logits)` maps a layer's raw SortNet logits to its
/// `(nb, nb)` mixing matrix (strict when `cfg.causal`) — the naive
/// counterpart of `SortStrategy::mix`
/// (`super::strategy::SortStrategy`), so `tests/backends_props.rs` can
/// oracle the engine stack under any backend, per layer.
pub fn reference_stack_forward_with(
    x: &Mat,
    cfg: &StackConfig,
    layers: &[TransformerLayer],
    mix: impl Fn(usize, &Mat) -> Mat,
) -> Mat {
    let mut y = x.clone();
    for (li, layer) in layers.iter().enumerate() {
        y = reference_layer(&y, layer, |h, qh, kh, vh| {
            let logits = reference_sort_logits(h, &layer.sortnet, cfg.nb);
            let r = mix(li, &logits);
            match cfg.n_cut {
                Some(c) => sortcut_attention(qh, kh, vh, &r, cfg.nb, c),
                None => sinkhorn_attention(qh, kh, vh, &r, cfg.nb, cfg.causal),
            }
        });
    }
    y
}

/// Naive full-prefix oracle for the stack's incremental decode
/// (`super::model::SinkhornStack::decode_step`): `x` holds the embedded
/// rows of the whole decoded prefix; row `t` of the result is the final
/// hidden state the incremental path must produce at step `t` (within
/// `ENGINE_TOL`). Per layer the decode-time SortNet rule is replayed over
/// the full prefix — block `i`'s mean pre-norm descriptor becomes
/// sort-logit row `i + 1` — and every head runs the per-step full-prefix
/// oracle [`causal_decode_attention`]. Sound because rows of the raw logit
/// matrix are written before the strict-causal balance first reads them
/// and never rewritten, so the final matrix reproduces, at every position,
/// exactly what the incremental path saw (module docs of
/// `super::decode`).
pub fn reference_stack_decode(x: &Mat, cfg: &StackConfig, layers: &[TransformerLayer]) -> Mat {
    reference_stack_decode_with(x, cfg, layers, |_, sl, m| {
        let sub = Mat::from_fn(m, m, |a, c| sl[(a, c)]);
        super::balance::causal_sinkhorn(&sub, cfg.sinkhorn_iters, true)
    })
}

/// [`reference_stack_decode`] with the per-prefix mixing rule factored
/// out: `mix_prefix(layer_index, sort_logits, m)` must return the strict
/// `(m, m)` mixing matrix over the first `m` started blocks — the naive
/// counterpart of `SortStrategy::mix_prefix`
/// (`super::strategy::SortStrategy`). The decode-time SortNet replay and
/// the per-head full-prefix attention ([`decode_attention_with`]) are
/// shared; only the balance rule varies per backend.
pub fn reference_stack_decode_with(
    x: &Mat,
    cfg: &StackConfig,
    layers: &[TransformerLayer],
    mix_prefix: impl Fn(usize, &Mat, usize) -> Mat,
) -> Mat {
    let b = cfg.block_rows();
    let nb = cfg.nb;
    let mut y = x.clone();
    for (li, layer) in layers.iter().enumerate() {
        // replay the decode-time SortNet rule over the whole prefix
        let h = match &layer.ln1 {
            Some(ln) => naive_layernorm(&y, &ln.gamma, &ln.beta),
            None => y.clone(),
        };
        let mut sort_logits = Mat::zeros(nb, nb);
        let mut desc = vec![0.0f32; y.cols];
        for t in 0..y.rows {
            for (c, a) in desc.iter_mut().enumerate() {
                *a += h[(t, c)];
            }
            if (t + 1) % b == 0 {
                let i = t / b;
                if i + 1 < nb {
                    for a in desc.iter_mut() {
                        *a /= b as f32;
                    }
                    let mut row = vec![0.0f32; nb];
                    for (c, &a) in desc.iter().enumerate() {
                        for (o, &wv) in row.iter_mut().zip(layer.sortnet.row(c)) {
                            *o += a * wv;
                        }
                    }
                    sort_logits.row_mut(i + 1).copy_from_slice(&row);
                }
                desc.fill(0.0);
            }
        }
        y = reference_layer(&y, layer, |_, qh, kh, vh| {
            decode_attention_with(qh, kh, vh, &sort_logits, b, cfg.n_cut, |sl, m| {
                mix_prefix(li, sl, m)
            })
        });
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::balance::{causal_sinkhorn, sinkhorn};
    use crate::util::prop::{forall, Gen};
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
    }

    struct Case {
        q: Mat,
        k: Mat,
        v: Mat,
        logits: Mat,
        nb: usize,
    }

    impl std::fmt::Debug for Case {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Case(ell={}, d={}, nb={})", self.q.rows, self.q.cols, self.nb)
        }
    }

    fn gen_case(g: &mut Gen) -> Case {
        let nb = 2 + g.usize(0, 3);
        let b = 2 + g.usize(0, 3);
        let d = 4 + g.usize(0, 4);
        let ell = nb * b;
        let mut rng = Rng::new(g.rng.next_u64());
        Case {
            q: rand_mat(&mut rng, ell, d),
            k: rand_mat(&mut rng, ell, d),
            v: rand_mat(&mut rng, ell, d),
            logits: rand_mat(&mut rng, nb, nb),
            nb,
        }
    }

    #[test]
    fn rows_are_convex_attention_outputs() {
        // every output row must be inside the range of V's values per dim
        forall(24, 0xA7, gen_case, |c| {
            let r = sinkhorn(&c.logits, 8);
            let y = sinkhorn_attention(&c.q, &c.k, &c.v, &r, c.nb, false);
            for col in 0..c.v.cols {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for row in 0..c.v.rows {
                    lo = lo.min(c.v[(row, col)]);
                    hi = hi.max(c.v[(row, col)]);
                }
                // sorted V values are convex mixes of V blocks, so the
                // bound still holds (up to fp slack)
                for row in 0..y.rows {
                    let x = y[(row, col)];
                    if x < lo - 1e-3 || x > hi + 1e-3 {
                        return Err(format!("out of hull: {x} not in [{lo},{hi}]"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn causal_no_future_leak() {
        // perturb a future token; outputs at earlier positions must not move
        forall(12, 0xC1, gen_case, |c| {
            let r = causal_sinkhorn(&c.logits, 6, true);
            let y1 = sinkhorn_attention(&c.q, &c.k, &c.v, &r, c.nb, true);
            let ell = c.q.rows;
            let t_perturb = ell - 1; // last token
            let mut k2 = c.k.clone();
            let mut v2 = c.v.clone();
            for j in 0..k2.cols {
                k2[(t_perturb, j)] += 3.0;
                v2[(t_perturb, j)] -= 2.0;
            }
            let y2 = sinkhorn_attention(&c.q, &k2, &v2, &r, c.nb, true);
            for t in 0..t_perturb {
                for j in 0..y1.cols {
                    if (y1[(t, j)] - y2[(t, j)]).abs() > 1e-4 {
                        return Err(format!("position {t} saw future (diff at col {j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn causal_r_sorting_respects_block_order() {
        // with the strict-causal R, perturbing block i must not affect
        // any position in earlier blocks
        forall(8, 0xCB, gen_case, |c| {
            let r = causal_sinkhorn(&c.logits, 6, true);
            let b = c.q.rows / c.nb;
            let tgt_block = c.nb - 1;
            let mut k2 = c.k.clone();
            for t in tgt_block * b..c.q.rows {
                for j in 0..k2.cols {
                    k2[(t, j)] += 1.0;
                }
            }
            let y1 = sinkhorn_attention(&c.q, &c.k, &c.v, &r, c.nb, true);
            let y2 = sinkhorn_attention(&c.q, &k2, &c.v, &r, c.nb, true);
            for t in 0..tgt_block * b {
                for j in 0..y1.cols {
                    if (y1[(t, j)] - y2[(t, j)]).abs() > 1e-4 {
                        return Err(format!("block leak at position {t}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn identity_sort_matches_doubled_local() {
        // R = I makes sorted keys == local keys: attention over duplicated
        // local keys equals plain local attention (softmax halves weights
        // but the convex combination is unchanged)
        forall(16, 0x1D, gen_case, |c| {
            let eye = Mat::eye(c.nb);
            let y_sink = sinkhorn_attention(&c.q, &c.k, &c.v, &eye, c.nb, false);
            let y_local = local_attention(&c.q, &c.k, &c.v, c.nb, false);
            let diff = y_sink.max_abs_diff(&y_local);
            if diff < 1e-4 {
                Ok(())
            } else {
                Err(format!("diff {diff}"))
            }
        });
    }

    #[test]
    fn single_block_local_equals_dense() {
        forall(16, 0x5B, gen_case, |c| {
            let y_local = local_attention(&c.q, &c.k, &c.v, 1, false);
            let y_dense = dense_attention(&c.q, &c.k, &c.v, false);
            let diff = y_local.max_abs_diff(&y_dense);
            if diff < 1e-4 {
                Ok(())
            } else {
                Err(format!("diff {diff}"))
            }
        });
    }

    #[test]
    fn sortcut_equals_dense_over_cut() {
        let mut rng = Rng::new(3);
        let (nb, b, d) = (4, 3, 8);
        let q = rand_mat(&mut rng, nb * b, d);
        let k = rand_mat(&mut rng, nb * b, d);
        let v = rand_mat(&mut rng, nb * b, d);
        let r = sinkhorn(&rand_mat(&mut rng, nb, nb), 8);
        let y = sortcut_attention(&q, &k, &v, &r, nb, 2);
        // manual: dense attention against first 2 sorted blocks
        let ks = Blocked::from_seq(&k, nb).sort(&r);
        let vs = Blocked::from_seq(&v, nb).sort(&r);
        let kc = Blocked { blocks: ks.blocks[..2].to_vec() }.to_seq();
        let vc = Blocked { blocks: vs.blocks[..2].to_vec() }.to_seq();
        let want = dense_attention(&q, &kc, &vc, false);
        assert!(y.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn decode_oracle_matches_batch_causal_at_complete_lengths() {
        // at ell = nb*b the per-step decode semantics collapse onto the
        // batch causal path (same strict R up to prefix-balance fp noise,
        // same [sorted | local-causal] joint softmax)
        forall(12, 0xDC0, gen_case, |c| {
            let r = causal_sinkhorn(&c.logits, 6, true);
            let batch = sinkhorn_attention(&c.q, &c.k, &c.v, &r, c.nb, true);
            let b = c.q.rows / c.nb;
            let dec = causal_decode_attention(&c.q, &c.k, &c.v, &c.logits, b, 6, None);
            let diff = batch.max_abs_diff(&dec);
            if diff < 1e-4 {
                Ok(())
            } else {
                Err(format!("decode oracle vs batch causal diff {diff}"))
            }
        });
    }

    #[test]
    fn decode_oracle_is_causal_on_partial_tails() {
        // perturbing the last token must not move any earlier row, even
        // when the sequence ends mid-block
        let mut rng = Rng::new(0xDC1);
        let (b, d, ell) = (4usize, 6usize, 14usize); // partial tail of 2
        let nb = (ell + b - 1) / b;
        let q = rand_mat(&mut rng, ell, d);
        let k = rand_mat(&mut rng, ell, d);
        let v = rand_mat(&mut rng, ell, d);
        let logits = rand_mat(&mut rng, nb, nb);
        for cut in [None, Some(1), Some(2)] {
            let y1 = causal_decode_attention(&q, &k, &v, &logits, b, 5, cut);
            let (mut k2, mut v2) = (k.clone(), v.clone());
            for c in 0..d {
                k2[(ell - 1, c)] += 3.0;
                v2[(ell - 1, c)] -= 2.0;
            }
            let y2 = causal_decode_attention(&q, &k2, &v2, &logits, b, 5, cut);
            for t in 0..ell - 1 {
                for c in 0..d {
                    assert!(
                        (y1[(t, c)] - y2[(t, c)]).abs() < 1e-5,
                        "cut={cut:?}: position {t} saw the future"
                    );
                }
            }
        }
    }

    #[test]
    fn block_roundtrip() {
        let mut rng = Rng::new(9);
        let x = rand_mat(&mut rng, 12, 5);
        let b = Blocked::from_seq(&x, 4);
        assert_eq!(b.to_seq(), x);
    }

    #[test]
    fn hard_permutation_sort_moves_blocks() {
        let mut rng = Rng::new(11);
        let x = rand_mat(&mut rng, 8, 3);
        let xb = Blocked::from_seq(&x, 4);
        // permutation sending block j=perm[i] to position i
        let perm = [2usize, 0, 3, 1];
        let r = Mat::from_fn(4, 4, |i, j| if perm[i] == j { 1.0 } else { 0.0 });
        let sorted = xb.sort(&r);
        for (i, &p) in perm.iter().enumerate() {
            assert_eq!(sorted.blocks[i], xb.blocks[p]);
        }
    }
}
