//! Batched inference serving (the L3 "router" role): client threads submit
//! requests — classify (token ids → label) or generate (prompt → greedily
//! decoded ids, DESIGN.md §Decode, optionally streamed token by token); a
//! single executor thread owning the execution backend serves them. The
//! pure-Rust backend ([`fallback`] — works on any machine, serves every
//! verb) runs a token-level **continuous-batching scheduler** by default:
//! a session table advances all in-flight generations one token per tick,
//! with memory-budgeted admission control (DESIGN.md §Scheduler). The
//! PJRT runtime over compiled artifacts (classify only) and the
//! [`batch::ExecMode::RequestBatch`] escape hatch run the legacy
//! wave executor instead. TCP line protocol: `rust/README.md`.

pub mod batch;
pub mod fallback;
pub mod service;
pub mod tcp;

pub use batch::{gather, BatchPolicy, ExecMode};
pub use fallback::{FallbackConfig, FallbackModel, GenSession};
pub use service::{Response, Server, ServerHandle, TokenEvent, BUSY_MSG};
pub use tcp::TcpFrontend;
