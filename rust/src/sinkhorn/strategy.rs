//! Pluggable sparse-attention sort backends (DESIGN.md §Backends).
//!
//! The blocked streaming engine ([`super::engine`]) never cared *how* the
//! `(nb, nb)` block-mixing matrix it gathers with was produced — it only
//! consumes the gather layout. This module factors that decision behind
//! [`SortStrategy`]: a backend maps the per-layer block descriptor
//! features (the SortNet-projected logits every layer already computes)
//! to the mixing matrix the engine's `[sorted | local]` task lists
//! execute. Three backends ship:
//!
//! * **[`SinkhornSort`]** — the paper's path and the reference
//!   implementation: differentiable Sinkhorn balancing of the SortNet
//!   logits ([`sinkhorn`] forward, strict [`causal_sinkhorn`] for
//!   causal/decode). Its [`SortStrategy::mix`] / [`SortStrategy::mix_prefix`]
//!   are the *exact* pre-trait calls, so a stack built with it is
//!   **bitwise identical** to the pre-refactor code
//!   (`tests/backends_props.rs` pins this).
//! * **[`RoutingSort`]** — online k-means clustering over the block
//!   descriptors, after Routing Transformers (PAPERS.md): blocks stream
//!   through a deterministic running-mean k-means (first `k` blocks seed
//!   the centroids; ties break to the lowest centroid index), and each
//!   query block mixes the blocks of its own cluster uniformly. The
//!   assignment of block `i` depends only on blocks `<= i`, so the
//!   strategy is prefix-stable by construction and the decode cache
//!   rules generalize unchanged. No RNG at inference time — determinism
//!   comes from the seeded model weights feeding the descriptors.
//! * **[`LocalSort`]** — the identity permutation with an empty sorted
//!   term: the all-zero mixing matrix masks the sorted segment entirely
//!   (the engine's row-support skip), leaving the paper's local-window
//!   baseline (Table 1's "local" row). Nearly free, and the correctness
//!   anchor every other backend is compared against.
//!
//! **Decode-cache contract** (DESIGN.md §Backends, §Decode): the
//! incremental decoder re-runs [`SortStrategy::mix_prefix`] only when a
//! block boundary fills and, under SortCut, freezes gathered cut rows
//! append-only. Both rules are sound only for strategies whose prefix
//! mixing is *prefix-stable* — `mix_prefix(feats, m)` agrees with the
//! top-left of `mix_prefix(feats, m')` for every `m' >= m` — which each
//! backend declares via [`SortStrategy::prefix_stable`] and the decoder
//! asserts before trusting a frozen cut.

use std::sync::Arc;

use super::balance::{causal_sinkhorn, sinkhorn};
use super::matrix::Mat;

/// The selectable sort backends, in CLI spelling (`--backend ...`,
/// `bench --target backends` rows, the `sort_backend=` key of the `model`
/// info verb).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Differentiable Sinkhorn balancing of SortNet logits (the paper).
    Sinkhorn,
    /// Online k-means block clustering (Routing Transformers).
    Routing,
    /// Local-window baseline: no sorted term at all.
    Local,
}

/// Every backend, in the order the CLI help, DESIGN.md §Backends and the
/// bench rows list them.
pub const ALL_BACKENDS: [Backend; 3] = [Backend::Sinkhorn, Backend::Routing, Backend::Local];

impl Backend {
    /// The stable CLI / bench-row / `key=value` spelling.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Sinkhorn => "sinkhorn",
            Backend::Routing => "routing",
            Backend::Local => "local",
        }
    }

    /// Parse a CLI `--backend` value. The error is the *stable* one-line
    /// `error=` payload (<= 120 chars, single line — the same contract as
    /// the TCP error paths in rust/README.md), printed verbatim by the
    /// CLI so scripts can match on it.
    pub fn parse(s: &str) -> Result<Backend, String> {
        match s {
            "sinkhorn" => Ok(Backend::Sinkhorn),
            "routing" => Ok(Backend::Routing),
            "local" => Ok(Backend::Local),
            other => {
                let mut shown: String = other.chars().take(32).collect();
                if shown.len() < other.len() {
                    shown.push_str("...");
                }
                // keep the line stable and short: non-printables collapse
                let shown: String =
                    shown.chars().map(|c| if c.is_ascii_graphic() { c } else { '?' }).collect();
                Err(format!("error=unknown backend '{shown}' (expected sinkhorn|routing|local)"))
            }
        }
    }

    /// Build this backend's strategy for a model with `nb` sort blocks.
    /// Routing picks `k = max(1, isqrt(nb))` clusters (the Routing
    /// Transformers √n rule at block granularity); the other backends
    /// ignore `nb`.
    pub fn strategy(self, nb: usize) -> Arc<dyn SortStrategy> {
        match self {
            Backend::Sinkhorn => Arc::new(SinkhornSort),
            Backend::Routing => Arc::new(RoutingSort::for_blocks(nb)),
            Backend::Local => Arc::new(LocalSort),
        }
    }
}

/// A sort backend: block descriptor features → the `(nb, nb)` mixing
/// matrix the engine's gather/window task lists consume.
///
/// `feats` is the layer's raw SortNet logit matrix — row `i` is block
/// `i`'s projected descriptor in the batch forward, and the
/// decode-rule-maintained row in incremental decoding (DESIGN.md
/// §Decode). Strategies read it; they never own descriptor state of
/// their own, which is what lets one `Arc`'d strategy serve every
/// session of a model concurrently (`Send + Sync`).
pub trait SortStrategy: Send + Sync {
    /// Which backend this is (stable naming for CLI/bench/info lines).
    fn backend(&self) -> Backend;

    /// Full mixing matrix for a batch forward pass over `nb` started
    /// blocks (`feats` is `(nb, nb)`). `causal == true` must produce a
    /// *strict* matrix: row `i` carries zero weight on blocks `j >= i`,
    /// so gathering never reads a block the queries may not see.
    /// `iters` is the model's configured balance-iteration count
    /// (ignored by backends that don't iterate).
    fn mix(&self, feats: &Mat, iters: usize, causal: bool) -> Mat;

    /// Strict mixing over the first `m` started blocks — the decode
    /// boundary recompute (DESIGN.md §Decode). Reads only rows `< m` of
    /// `feats` (rows of unstarted blocks may hold anything) and returns
    /// an `(m, m)` matrix whose row `i` weights only blocks `j < i`
    /// (never the in-progress block).
    fn mix_prefix(&self, feats: &Mat, m: usize, iters: usize) -> Mat;

    /// Does `mix_prefix(feats, m)` agree with the top-left of
    /// `mix_prefix(feats, m')` for every `m' >= m`? The decoder's
    /// boundary-recompute rule needs this to match the full-prefix
    /// oracle, and the SortCut frozen-cut cache is sound *only* when it
    /// holds (DESIGN.md §Backends) — a non-prefix-stable strategy is
    /// rejected at decode-state construction when a cut is configured.
    fn prefix_stable(&self) -> bool;
}

/// The reference backend: Sinkhorn balancing of the SortNet logits,
/// exactly as the pre-trait code called it — [`sinkhorn`] for the
/// non-causal forward, strict [`causal_sinkhorn`] for causal forwards
/// and every decode recompute. Bitwise identical to the pre-refactor
/// path (`tests/backends_props.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct SinkhornSort;

impl SortStrategy for SinkhornSort {
    fn backend(&self) -> Backend {
        Backend::Sinkhorn
    }

    fn mix(&self, feats: &Mat, iters: usize, causal: bool) -> Mat {
        if causal {
            causal_sinkhorn(feats, iters, true)
        } else {
            sinkhorn(feats, iters)
        }
    }

    fn mix_prefix(&self, feats: &Mat, m: usize, iters: usize) -> Mat {
        // the historical decode rebalance, kept bit for bit: copy the
        // (m, m) corner, strict-causal balance it
        let sub = Mat::from_fn(m, m, |a, c| feats[(a, c)]);
        causal_sinkhorn(&sub, iters, true)
    }

    fn prefix_stable(&self) -> bool {
        // strict causal balancing is prefix-consistent
        // (balance.rs::causal_prefix_consistent)
        true
    }
}

/// The local-window baseline (paper Table 1, "local"): the sorted term
/// is empty — an all-zero mixing matrix, which both the engine and the
/// naive reference treat as "mask the sorted segment" (row support
/// `<= 1e-6`). Equivalent to [`super::attention::local_attention`].
#[derive(Debug, Clone, Copy, Default)]
pub struct LocalSort;

impl SortStrategy for LocalSort {
    fn backend(&self) -> Backend {
        Backend::Local
    }

    fn mix(&self, feats: &Mat, _iters: usize, _causal: bool) -> Mat {
        Mat::zeros(feats.rows, feats.rows)
    }

    fn mix_prefix(&self, _feats: &Mat, m: usize, _iters: usize) -> Mat {
        Mat::zeros(m, m)
    }

    fn prefix_stable(&self) -> bool {
        // the zero matrix never changes: trivially prefix-stable
        true
    }
}

/// Online k-means block clustering, after Routing Transformers
/// (PAPERS.md): block descriptors stream through a deterministic
/// running-mean k-means and each block mixes the members of its own
/// cluster. See [`routing_assignments`] for the exact streaming rule.
///
/// Mixing weights: row `i` spreads weight `1 / |cluster|` uniformly over
/// its cluster's blocks — all of them in non-causal mode (including
/// block `i` itself: duplicating the local block in the sorted term is
/// harmless, exactly like the identity permutation), and only the
/// *earlier* members `j < i` in causal/decode mode (strictness). A row
/// whose cluster has no earlier member is all-zero, which masks its
/// sorted term — the same no-support rule as strict Sinkhorn's row 0.
#[derive(Debug, Clone, Copy)]
pub struct RoutingSort {
    /// cluster count (clamped to the streamed block count at use)
    pub k: usize,
}

impl RoutingSort {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "routing needs at least one cluster");
        RoutingSort { k }
    }

    /// The Routing Transformers √n rule at block granularity:
    /// `k = max(1, isqrt(nb))` clusters for `nb` blocks.
    pub fn for_blocks(nb: usize) -> Self {
        let mut k = 1usize;
        while (k + 1) * (k + 1) <= nb {
            k += 1;
        }
        RoutingSort { k }
    }

    fn mix_rows(&self, feats: &Mat, m: usize, causal: bool) -> Mat {
        let assign = routing_assignments(feats, m, self.k);
        let mut r = Mat::zeros(m, m);
        for i in 0..m {
            // causal rows weight strictly earlier members only (the
            // in-progress block must never be gathered); non-causal rows
            // weight the whole cluster, block i included
            let lim = if causal { i } else { m };
            let count = (0..lim).filter(|&j| assign[j] == assign[i]).count();
            if count == 0 {
                continue; // no visible cluster member: sorted term masked
            }
            let w = 1.0 / count as f32;
            for j in 0..lim {
                if assign[j] == assign[i] {
                    r[(i, j)] = w;
                }
            }
        }
        r
    }
}

impl SortStrategy for RoutingSort {
    fn backend(&self) -> Backend {
        Backend::Routing
    }

    fn mix(&self, feats: &Mat, _iters: usize, causal: bool) -> Mat {
        self.mix_rows(feats, feats.rows, causal)
    }

    fn mix_prefix(&self, feats: &Mat, m: usize, _iters: usize) -> Mat {
        self.mix_rows(feats, m, true)
    }

    fn prefix_stable(&self) -> bool {
        // assignment of block i depends only on blocks <= i, and row i's
        // weights only on assignments <= i — stable by construction
        // (tests/backends_props.rs::routing_assignments_are_prefix_stable)
        true
    }
}

/// The streaming k-means assignment rule shared by [`RoutingSort`] and
/// the naive reference ([`super::attention::routing_mixing`]), exposed so
/// the tests can pin assignment stability directly:
///
/// * blocks `i < k` seed centroid `i` with their own descriptor row;
/// * every later block joins the nearest centroid by squared euclidean
///   distance over the full feature row (ties break to the lowest
///   centroid index), then pulls it by the running mean
///   `c += (x - c) / n`.
///
/// Deterministic (no RNG) and *online*: block `i`'s assignment depends
/// only on rows `<= i`, which is what makes [`RoutingSort`]
/// prefix-stable.
pub fn routing_assignments(feats: &Mat, m: usize, k: usize) -> Vec<usize> {
    assert!(m <= feats.rows, "assignments need the first m rows");
    let k = k.max(1);
    let mut centroids: Vec<Vec<f32>> = Vec::new();
    let mut counts: Vec<usize> = Vec::new();
    let mut assign = Vec::with_capacity(m);
    for i in 0..m {
        let row = feats.row(i);
        if centroids.len() < k {
            centroids.push(row.to_vec());
            counts.push(1);
            assign.push(centroids.len() - 1);
            continue;
        }
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for (c, cent) in centroids.iter().enumerate() {
            let mut dist = 0.0f32;
            for (a, b) in row.iter().zip(cent) {
                let diff = a - b;
                dist += diff * diff;
            }
            if dist < best_d {
                best_d = dist;
                best = c;
            }
        }
        counts[best] += 1;
        let n = counts[best] as f32;
        for (cv, &xv) in centroids[best].iter_mut().zip(row) {
            *cv += (xv - *cv) / n;
        }
        assign.push(best);
    }
    assign
}

#[cfg(test)]
mod tests {
    // The cross-backend property battery (per-backend oracle gates,
    // thread invariance, decode parity, paged spot-checks) lives in
    // tests/backends_props.rs — this module covers the parse surface and
    // the small structural invariants.
    use super::*;
    use crate::util::rng::Rng;

    fn rand_feats(seed: u64, n: usize) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, n, |_, _| rng.normal() as f32 * 0.5)
    }

    #[test]
    fn parse_roundtrips_every_backend() {
        for b in ALL_BACKENDS {
            assert_eq!(Backend::parse(b.name()), Ok(b));
        }
    }

    #[test]
    fn parse_error_line_is_stable_and_short() {
        let err = Backend::parse("quantum").unwrap_err();
        assert_eq!(err, "error=unknown backend 'quantum' (expected sinkhorn|routing|local)");
        assert_eq!(err.lines().count(), 1, "error payload must stay one line");
        assert!(err.len() <= 120, "error line must stay <= 120 chars: {} long", err.len());
    }

    #[test]
    fn parse_error_clamps_hostile_input() {
        // long and non-printable inputs must not blow the line length or
        // smuggle control bytes into the stable payload
        let long = "x".repeat(500);
        let err = Backend::parse(&long).unwrap_err();
        assert!(err.len() <= 120, "got {} chars", err.len());
        assert_eq!(err.lines().count(), 1);
        let evil = Backend::parse("a\nb\tc").unwrap_err();
        assert_eq!(evil.lines().count(), 1, "control chars must be collapsed: {evil:?}");
        assert!(evil.starts_with("error=unknown backend "));
    }

    #[test]
    fn sinkhorn_strategy_is_the_exact_balance_call() {
        let feats = rand_feats(0xB1, 5);
        let s = SinkhornSort;
        assert_eq!(s.mix(&feats, 6, false), sinkhorn(&feats, 6));
        assert_eq!(s.mix(&feats, 6, true), causal_sinkhorn(&feats, 6, true));
        let sub = Mat::from_fn(3, 3, |a, c| feats[(a, c)]);
        assert_eq!(s.mix_prefix(&feats, 3, 6), causal_sinkhorn(&sub, 6, true));
    }

    #[test]
    fn local_mix_is_all_zero() {
        let feats = rand_feats(0xB2, 4);
        let s = LocalSort;
        for causal in [false, true] {
            let r = s.mix(&feats, 4, causal);
            assert_eq!((r.rows, r.cols), (4, 4));
            assert!(r.data.iter().all(|&x| x == 0.0));
        }
        assert!(s.mix_prefix(&feats, 2, 4).data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn routing_rows_are_strict_and_stochastic() {
        let feats = rand_feats(0xB3, 8);
        let s = RoutingSort::for_blocks(8); // k = 2
        assert_eq!(s.k, 2);
        let r = s.mix(&feats, 4, true);
        for i in 0..8 {
            for j in i..8 {
                assert_eq!(r[(i, j)], 0.0, "causal row {i} must be strict");
            }
            let sum: f32 = r.row(i).iter().sum();
            assert!(sum == 0.0 || (sum - 1.0).abs() < 1e-6, "row {i} sums to {sum}");
        }
        // non-causal rows always include the block itself: support >= 1
        let rf = s.mix(&feats, 4, false);
        for i in 0..8 {
            let sum: f32 = rf.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "non-causal row {i} sums to {sum}");
            assert!(rf[(i, i)] > 0.0, "row {i} must weight its own block");
        }
    }

    #[test]
    fn routing_first_k_blocks_seed_their_own_clusters() {
        let feats = rand_feats(0xB4, 6);
        let assign = routing_assignments(&feats, 6, 3);
        assert_eq!(&assign[..3], &[0, 1, 2]);
        assert!(assign[3..].iter().all(|&c| c < 3));
    }

    #[test]
    fn for_blocks_is_integer_sqrt() {
        for (nb, k) in [(1, 1), (3, 1), (4, 2), (8, 2), (9, 3), (16, 4), (24, 4)] {
            assert_eq!(RoutingSort::for_blocks(nb).k, k, "nb={nb}");
        }
    }
}
