//! Inference service: a router thread owns the PJRT runtime (the client is
//! not `Send`-shareable, so all execution funnels through one executor —
//! the vllm-router shape: N frontends -> channel -> batcher -> executor).
//!
//! Serves classification experiments: request = token ids, response =
//! predicted label + timing breakdown.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Checkpoint;
use crate::data::tokenizer::pad_to;
use crate::runtime::{Experiment, HostTensor, Runtime};

use super::batch::{gather, BatchPolicy};

/// One inference request.
struct Request {
    tokens: Vec<i32>,
    enqueued: Instant,
    resp: Sender<Result<Response>>,
}

/// Executor inbox message: a request, or an explicit stop. The sentinel
/// lets `shutdown` terminate the executor even while detached frontends
/// (e.g. the TCP acceptor) still hold live `ServerHandle` clones.
enum Msg {
    Req(Request),
    Stop,
}

/// Server reply.
#[derive(Debug, Clone)]
pub struct Response {
    pub label: i32,
    /// time spent waiting in the batcher
    pub queue: Duration,
    /// total time from submit to reply
    pub total: Duration,
    /// how many requests shared the executed batch
    pub batch_size: usize,
}

/// Handle to a running server; cloneable across client threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
    pub seq_len: usize,
}

impl ServerHandle {
    /// Blocking classify call.
    pub fn classify(&self, tokens: Vec<i32>) -> Result<Response> {
        let (rtx, rrx) = channel();
        let req = Request { tokens, enqueued: Instant::now(), resp: rtx };
        self.tx.send(Msg::Req(req)).map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

/// A running inference server (executor joins on drop of the handle + stop).
pub struct Server {
    pub handle: ServerHandle,
    join: Option<JoinHandle<Result<()>>>,
}

impl Server {
    /// Start the executor thread: loads the experiment, restores or inits
    /// parameters, then serves until all handles are dropped.
    pub fn start(
        artifacts: PathBuf,
        exp_name: String,
        checkpoint: Option<PathBuf>,
        policy: BatchPolicy,
        init_seed: i32,
    ) -> Result<Server> {
        // load the manifest up front so config errors surface synchronously
        let probe = Experiment::load(&artifacts, &exp_name)?;
        if probe.manifest.eval_outputs.len() < 3 {
            bail!("experiment '{exp_name}' has no pred output; re-run make artifacts");
        }
        let seq_len = probe.manifest.eval_batch_inputs[0].shape[1];
        let graph_batch = probe.manifest.eval_batch_inputs[0].shape[0];
        let policy = BatchPolicy { max_batch: policy.max_batch.min(graph_batch), ..policy };

        let (tx, rx) = channel::<Msg>();
        let join = std::thread::spawn(move || -> Result<()> {
            let rt = Runtime::cpu().context("server runtime")?;
            let exp = Experiment::load(&artifacts, &exp_name)?;
            let state = match checkpoint {
                Some(path) => Checkpoint::load(&path)?.restore(&exp.manifest)?,
                None => exp.init_state(&rt, init_seed)?,
            };
            // warm the compile cache before accepting traffic
            let zeros = HostTensor::i32(&[graph_batch, seq_len], vec![0; graph_batch * seq_len]);
            let zlabels = HostTensor::i32(&[graph_batch], vec![0; graph_batch]);
            exp.eval(&rt, &state.params, &[zeros.to_literal()?, zlabels.to_literal()?])?;

            'serve: while let Some(msgs) = gather(&rx, &policy) {
                let mut stop = false;
                let batch: Vec<Request> = msgs
                    .into_iter()
                    .filter_map(|m| match m {
                        Msg::Req(r) => Some(r),
                        Msg::Stop => {
                            stop = true;
                            None
                        }
                    })
                    .collect();
                if batch.is_empty() {
                    if stop {
                        break 'serve;
                    }
                    continue;
                }
                let n = batch.len();
                let exec_start = Instant::now();
                // assemble fixed-shape tensors, padding unused rows
                let mut toks = Vec::with_capacity(graph_batch * seq_len);
                for req in &batch {
                    toks.extend(pad_to(req.tokens.clone(), seq_len));
                }
                toks.resize(graph_batch * seq_len, 0);
                let labels = vec![0i32; graph_batch];
                let t_tok = HostTensor::i32(&[graph_batch, seq_len], toks);
                let t_lab = HostTensor::i32(&[graph_batch], labels);
                let result = exp
                    .eval(&rt, &state.params, &[t_tok.to_literal()?, t_lab.to_literal()?])
                    .and_then(|out| HostTensor::from_literal(&out[2]));
                match result {
                    Ok(pred) => {
                        let pred = pred.as_i32()?;
                        for (i, req) in batch.into_iter().enumerate() {
                            let _ = req.resp.send(Ok(Response {
                                label: pred[i],
                                queue: exec_start - req.enqueued,
                                total: req.enqueued.elapsed(),
                                batch_size: n,
                            }));
                        }
                    }
                    Err(e) => {
                        for req in batch {
                            let _ = req.resp.send(Err(anyhow!("exec failed: {e}")));
                        }
                    }
                }
                if stop {
                    break 'serve;
                }
            }
            Ok(())
        });

        Ok(Server { handle: ServerHandle { tx, seq_len }, join: Some(join) })
    }

    /// Close the intake channel and wait for the executor to drain.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.handle.tx.send(Msg::Stop);
        drop(self.handle);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}
