//! The paper's §5.1 workload as a standalone example: train the seq2seq
//! sorting task with Sparse Sinkhorn Attention in both encoder and
//! decoder, then *greedy-decode* sequences twice as long as training ones
//! (the paper's length-generalization probe) and report EM/edit-distance.
//!
//! Run: `cargo run --release --example sort_seq2seq -- [--steps N]`

use anyhow::Result;
use sinkhorn::coordinator::{self, TrainOptions};
use sinkhorn::data::TaskData;
use sinkhorn::runtime::{artifacts_dir, Experiment, Runtime};
use sinkhorn::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.usize("steps", 250)?;
    let artifacts = artifacts_dir();
    let rt = Runtime::cpu()?;

    for name in ["sort__sinkhorn_b8", "sort__local_b16"] {
        let exp = Experiment::load(&artifacts, name)?;
        let mut data = TaskData::for_experiment(&exp.manifest)?;
        println!("=== {name}: {} params, {steps} steps ===", exp.manifest.n_params());
        let opts = TrainOptions {
            steps,
            seed: 23,
            log_every: (steps / 10).max(1),
            verbose: true,
            checkpoint: None,
        };
        let (state, _) = coordinator::train_from_scratch(&rt, &exp, &mut data, &opts)?;

        let TaskData::Sort(mut d) = data else { anyhow::bail!("not a sort task") };
        // true greedy decode at 2x the training length
        let (em, ed) = coordinator::eval_sort(&rt, &exp, &state, &mut d, 1)?;
        println!(
            "  greedy decode @2x length: exact-match {:.1}%, edit distance {:.4}\n",
            em * 100.0,
            ed
        );
    }
    println!("sort_seq2seq OK");
    Ok(())
}
