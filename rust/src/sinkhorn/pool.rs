//! Small std-thread worker pool for the blocked engine (DESIGN.md
//! §Engine). The engine flattens its work to `(request, head, block)`
//! tasks, which are embarrassingly parallel, so the pool does static
//! round-robin partitioning — no work stealing, no locks, no `Send`
//! output channels — and joins via `std::thread::scope`, which lets tasks
//! borrow the caller's buffers (the disjoint `chunks_mut` of the output
//! matrices).
//!
//! Determinism: partitioning is by task index only, every task writes only
//! its own output chunk, and each worker's scratch state (the engine's
//! `Workspace`) is private and reset per task — so a given engine build
//! produces identical results for any thread count, bit for bit. (The
//! engine-vs-naive-reference comparison is a separate, epsilon-level
//! contract — see `engine`.)

/// Number of worker threads to use when the caller asks for "auto":
/// `$SINKHORN_THREADS` if set (>= 1), else the machine's available
/// parallelism.
pub fn auto_threads() -> usize {
    if let Ok(v) = std::env::var("SINKHORN_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A fixed-width worker pool. Cheap to construct; threads are scoped to
/// each [`WorkerPool::run`] call — scoping keeps borrowed task data safe
/// without `Arc`, at the cost of a spawn (tens of microseconds per
/// worker) on every call. Use a multi-thread pool only when per-task
/// work dominates that (bench-scale blocks do; tiny serving-scale blocks
/// don't — see `server::fallback` for an adaptive caller).
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// `threads == 0` selects [`auto_threads`].
    pub fn new(threads: usize) -> Self {
        WorkerPool { threads: if threads == 0 { auto_threads() } else { threads } }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `work` over `tasks`, partitioned round-robin across the pool.
    ///
    /// `init` builds one private scratch state per worker (preallocated
    /// buffers); `work(&mut state, task)` runs every task of that worker
    /// in submission order. Single-threaded pools (or single tasks) run
    /// inline on the caller's thread. Panics in workers propagate.
    pub fn run<T, S, I, W>(&self, tasks: Vec<T>, init: I, work: W)
    where
        T: Send,
        I: Fn() -> S + Sync,
        W: Fn(&mut S, T) + Sync,
    {
        let n_workers = self.threads.min(tasks.len()).max(1);
        if n_workers == 1 {
            let mut state = init();
            for t in tasks {
                work(&mut state, t);
            }
            return;
        }
        let mut buckets: Vec<Vec<T>> = (0..n_workers).map(|_| Vec::new()).collect();
        for (i, t) in tasks.into_iter().enumerate() {
            buckets[i % n_workers].push(t);
        }
        let (init, work) = (&init, &work);
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    let mut state = init();
                    for t in bucket {
                        work(&mut state, t);
                    }
                });
            }
        });
    }

    /// [`Self::run`] with *caller-owned* worker states: `states[i]` is
    /// handed to worker `i` as its private scratch, mutated in place, and
    /// survives the call — how the layer stack (`sinkhorn::model`) reuses
    /// one set of per-worker engine `Workspace`s across every layer of a
    /// forward pass instead of re-allocating them per `run`. `states` must
    /// hold at least one state per worker the call will use (at most
    /// [`Self::threads`]); extra states are left untouched. The same
    /// determinism argument as `run` applies: partitioning is by task
    /// index only, and states must not influence results (scratch only).
    pub fn run_with<T, S, W>(&self, tasks: Vec<T>, states: &mut [S], work: W)
    where
        T: Send,
        S: Send,
        W: Fn(&mut S, T) + Sync,
    {
        let n_workers = self.threads.min(tasks.len()).max(1);
        assert!(
            states.len() >= n_workers,
            "run_with needs {n_workers} worker states, got {}",
            states.len()
        );
        if n_workers == 1 {
            let state = &mut states[0];
            for t in tasks {
                work(state, t);
            }
            return;
        }
        let mut buckets: Vec<Vec<T>> = (0..n_workers).map(|_| Vec::new()).collect();
        for (i, t) in tasks.into_iter().enumerate() {
            buckets[i % n_workers].push(t);
        }
        let work = &work;
        std::thread::scope(|scope| {
            for (bucket, state) in buckets.into_iter().zip(states.iter_mut()) {
                scope.spawn(move || {
                    for t in bucket {
                        work(state, t);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_task_once() {
        let mut out = vec![0u32; 100];
        let chunks: Vec<(usize, &mut [u32])> =
            out.chunks_mut(1).enumerate().map(|(i, c)| (i, c)).collect();
        WorkerPool::new(4).run(chunks, || (), |_, (i, c)| c[0] = i as u32 + 1);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1);
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        let main_id = std::thread::current().id();
        let on_main = std::sync::Mutex::new(true);
        let tasks: Vec<usize> = (0..10).collect();
        WorkerPool { threads: 1 }.run(tasks, || (), |_, _| {
            if std::thread::current().id() != main_id {
                *on_main.lock().unwrap() = false;
            }
        });
        assert!(*on_main.lock().unwrap(), "threads=1 must not spawn");
    }

    #[test]
    fn init_runs_once_per_worker() {
        let inits = AtomicUsize::new(0);
        let tasks: Vec<usize> = (0..64).collect();
        WorkerPool::new(3).run(
            tasks,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
            },
            |_, _| {},
        );
        assert_eq!(inits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn more_workers_than_tasks_is_fine() {
        let done = AtomicUsize::new(0);
        WorkerPool::new(16).run(vec![1, 2], || (), |_, _| {
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn auto_threads_at_least_one() {
        assert!(auto_threads() >= 1);
    }

    #[test]
    fn run_with_reuses_caller_states_across_calls() {
        // the per-worker states survive the call and keep their mutations —
        // the cross-layer workspace-reuse contract of the model stack
        let mut states = vec![0usize; 3];
        let pool = WorkerPool::new(3);
        for round in 1..=4 {
            let mut out = vec![0usize; 12];
            let tasks: Vec<(usize, &mut usize)> = out.iter_mut().enumerate().collect();
            pool.run_with(tasks, &mut states, |s, (i, slot)| {
                *s += 1;
                *slot = i + 1;
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i + 1, "round {round}");
            }
        }
        // 4 rounds x 12 tasks accumulated into the same three states
        assert_eq!(states.iter().sum::<usize>(), 48);
    }

    #[test]
    #[should_panic(expected = "worker states")]
    fn run_with_rejects_too_few_states() {
        let mut states = vec![0u8; 1];
        WorkerPool::new(4).run_with(vec![1, 2, 3, 4, 5], &mut states, |_, _| {});
    }
}
