"""AOT export consistency: manifests must agree with the live model code
(leaf order/shapes from jax.eval_shape), and exported HLO text must carry
the expected entry-parameter count (3n+2+batch for train graphs)."""

import json
import os
import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, model, train

ARTIFACTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "registry.json")),
    reason="run `make artifacts` first",
)


def _manifest(name):
    with open(os.path.join(ARTIFACTS, f"{name}.manifest.json")) as f:
        return json.load(f)


def test_leaf_entries_deterministic():
    cfg = configs.BY_NAME["lmw_tiny__sinkhorn_b16"]["cfg"]
    shape = jax.eval_shape(lambda s: model.lm_init(jax.random.PRNGKey(s), cfg), jnp.int32(0))
    a = aot._leaf_entries(shape)
    b = aot._leaf_entries(shape)
    assert a == b
    assert all(e["dtype"] == "f32" for e in a)


@needs_artifacts
def test_manifest_matches_live_model():
    name = "lmw_tiny__sinkhorn_b16"
    m = _manifest(name)
    cfg = configs.BY_NAME[name]["cfg"]
    shape = jax.eval_shape(lambda s: model.lm_init(jax.random.PRNGKey(s), cfg), jnp.int32(0))
    live = aot._leaf_entries(shape)
    assert m["params"] == live, "manifest drifted from model code — re-run make artifacts"


@needs_artifacts
def test_registry_covers_all_experiments():
    with open(os.path.join(ARTIFACTS, "registry.json")) as f:
        reg = json.load(f)
    names = {e["name"] for e in reg["experiments"]}
    for e in configs.EXPERIMENTS:
        assert e["name"] in names, f"{e['name']} missing from registry"


@needs_artifacts
@pytest.mark.parametrize(
    "name", ["lmw_tiny__vanilla", "lmw_tiny__sinkhorn_b16", "sort__sinkhorn_b8", "imdbw__sortcut_2x8"]
)
def test_hlo_entry_arity(name):
    m = _manifest(name)
    n = m["n_leaves"]
    nb_inputs = len(m["train_batch_inputs"])
    path = os.path.join(ARTIFACTS, m["artifacts"]["train"])
    with open(path) as f:
        text = f.read()
    entry = re.search(r"\nENTRY [^{]*\{(.*)", text, re.S)
    assert entry, "no ENTRY computation in HLO text"
    n_params = len(set(re.findall(r"parameter\((\d+)\)", entry.group(1))))
    assert n_params == 3 * n + 2 + nb_inputs, (
        f"{name}: HLO has {n_params} entry params, manifest implies {3 * n + 2 + nb_inputs}"
    )


@needs_artifacts
def test_eval_hlo_arity_seq2seq_doubles_length():
    m = _manifest("sort__sinkhorn_b8")
    assert m["eval_batch_inputs"][0]["shape"][1] == 2 * m["cfg"]["ell"]


def test_batch_shapes_match_families():
    for fam, cfg_extra in [("lm", {}), ("cls", {"n_classes": 2}), ("seq2seq", {"ell_tgt": 16})]:
        cfg = dict(d_model=16, n_heads=2, d_ff=32, n_layers=1, vocab=32, ell=16,
                   block=4, nb=4, variant="vanilla", sinkhorn_iters=3, tau=0.75,
                   p_variant=4, share_kv=False, **cfg_extra)
        tcfg = dict(batch=4)
        shapes = train.batch_shapes(fam, cfg, tcfg)
        assert all(s.dtype == jnp.int32 for s in shapes)
        assert shapes[0].shape[0] == 4
