//! Epoch batcher for fixed datasets (classification): deterministic
//! shuffling per epoch, drop-last semantics so batch shapes stay static
//! (XLA graphs are shape-specialized).

use crate::util::rng::Rng;

/// Yields index batches over `n` examples, reshuffled every epoch.
pub struct Batcher {
    n: usize,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        assert!(batch <= n, "batch {batch} > dataset {n}");
        let mut b = Batcher { n, batch, order: (0..n).collect(), cursor: 0, rng: Rng::new(seed), epoch: 0 };
        b.rng.shuffle(&mut b.order);
        b
    }

    /// Next batch of example indices (always exactly `batch` long).
    pub fn next_indices(&mut self) -> &[usize] {
        if self.cursor + self.batch > self.n {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let s = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn batches_cover_dataset_each_epoch() {
        let mut b = Batcher::new(10, 5, 1);
        let mut seen = HashSet::new();
        seen.extend(b.next_indices().iter().copied());
        seen.extend(b.next_indices().iter().copied());
        assert_eq!(seen.len(), 10);
        assert_eq!(b.epoch, 0);
        b.next_indices();
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn drop_last_keeps_shape() {
        let mut b = Batcher::new(10, 4, 2);
        for _ in 0..20 {
            assert_eq!(b.next_indices().len(), 4);
        }
    }

    #[test]
    fn no_duplicates_within_epoch() {
        let mut b = Batcher::new(12, 4, 3);
        let mut seen = HashSet::new();
        for _ in 0..3 {
            for &i in b.next_indices() {
                assert!(seen.insert(i), "dup {i}");
            }
        }
    }

    #[test]
    fn deterministic() {
        let mut a = Batcher::new(20, 5, 9);
        let mut b = Batcher::new(20, 5, 9);
        assert_eq!(a.next_indices(), b.next_indices());
    }
}
