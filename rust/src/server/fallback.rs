//! Pure-Rust inference fallback: a single-layer Sinkhorn-attention
//! classifier that runs entirely on the blocked engine
//! (`sinkhorn::engine`, DESIGN.md §Engine) — no XLA, no compiled
//! artifacts, no Python. The server selects it when an experiment's HLO
//! artifacts (or the PJRT runtime itself) are unavailable, so the full
//! serving stack — TCP frontend, dynamic batcher, executor — works on any
//! machine straight from `cargo run`.
//!
//! The model is deliberately small and deterministic from its seed:
//! embedding + sinusoid-free learned-style positional table, one
//! multi-part attention step (SortNet -> Sinkhorn balance -> blocked
//! sorted+local attention), residual mean-pool, linear head. It is not
//! trained (there is no training path without XLA); what it demonstrates
//! and exercises is the *serving* pipeline and the engine hot path with
//! production shapes.
//!
//! Two serving verbs share the weights: `classify` (batch attention over
//! the padded sequence, pooled head) and `generate` (token-by-token greedy
//! decoding on the incremental decode path with a tied-embedding LM head —
//! DESIGN.md §Decode). Both are exposed through the TCP line protocol
//! (`super::tcp`, documented in `rust/README.md`).

use anyhow::Result;

use crate::sinkhorn::balance;
use crate::sinkhorn::matrix::Mat;
use crate::sinkhorn::{AttentionReq, DecodeScratch, DecodeState, SinkhornEngine, WorkerPool};
use crate::util::rng::Rng;

/// Configuration of the fallback classifier.
#[derive(Debug, Clone)]
pub struct FallbackConfig {
    /// token ids are wrapped into `[0, vocab)` so any client input is safe
    pub vocab: usize,
    /// fixed sequence length (requests are padded/truncated to this)
    pub seq_len: usize,
    pub d_model: usize,
    /// number of sort blocks; must divide `seq_len`
    pub nb: usize,
    pub n_classes: usize,
    /// Sinkhorn balance iterations for the sort matrix
    pub sinkhorn_iters: usize,
    pub seed: u64,
    /// engine worker threads (0 = auto)
    pub threads: usize,
}

impl Default for FallbackConfig {
    fn default() -> Self {
        let seq_len = 128;
        FallbackConfig {
            vocab: 512,
            seq_len,
            d_model: 64,
            // keep in sync with the `serve --fallback` CLI default, which
            // also derives nb from blocks_for(seq_len) — the auto-fallback
            // and the forced fallback must build the same model
            nb: Self::blocks_for(seq_len),
            n_classes: 2,
            sinkhorn_iters: 5,
            seed: 17,
            threads: 0,
        }
    }
}

/// f32-element work below which the engine's per-call thread spawn costs
/// more than it buys: per request for the single-request engine choice,
/// per *batch* (total flattened work) for `classify_batch`. One constant
/// so the two heuristics cannot drift apart.
const SERIAL_WORK_CUTOFF: usize = 1 << 17;

impl FallbackConfig {
    /// Largest power of two <= 16 dividing `seq_len` (a reasonable block
    /// count when the manifest doesn't pin one).
    pub fn blocks_for(seq_len: usize) -> usize {
        for nb in [16usize, 8, 4, 2] {
            if seq_len % nb == 0 {
                return nb;
            }
        }
        1
    }
}

/// The deterministic fallback classifier.
pub struct FallbackModel {
    pub cfg: FallbackConfig,
    engine: SinkhornEngine,
    /// request-level parallelism for the batched prep/head phases
    batch_pool: WorkerPool,
    /// batched attention phase: the whole batch's `(request, head, block)`
    /// tasks land in one pool pass (`SinkhornEngine::attention_batch_into`),
    /// so serving traffic saturates the workers even though each single
    /// request is too small to justify a per-request fan-out
    batch_engine: SinkhornEngine,
    /// (vocab, d) token embeddings
    embed: Mat,
    /// (seq_len, d) positional table
    pos: Mat,
    wq: Mat,
    wk: Mat,
    wv: Mat,
    wo: Mat,
    /// (d, nb) SortNet head: block descriptor -> destination-block logits
    sortnet: Mat,
    /// (d, n_classes) classification head
    w_cls: Mat,
}

impl FallbackModel {
    pub fn new(cfg: FallbackConfig) -> Result<FallbackModel> {
        if cfg.seq_len % cfg.nb != 0 {
            anyhow::bail!("fallback: nb {} must divide seq_len {}", cfg.nb, cfg.seq_len);
        }
        if cfg.vocab == 0 || cfg.n_classes == 0 {
            anyhow::bail!("fallback: vocab and n_classes must be positive");
        }
        let d = cfg.d_model;
        let mut rng = Rng::new(cfg.seed);
        let mut init = |rows: usize, cols: usize, scale: f64| {
            let mut r = rng.fork((rows * 31 + cols) as u64);
            Mat::from_fn(rows, cols, |_, _| (r.normal() * scale) as f32)
        };
        let wscale = 1.0 / (d as f64).sqrt();
        // At serving shapes (seq_len ~128) one request's blocks are
        // microseconds of work — below the pool's per-call thread-spawn
        // cost — so for *single* requests "auto" means serial unless the
        // request is big enough for the parallel engine to pay off. An
        // explicit threads count wins. Batches don't use this engine:
        // `classify_batch` amortizes the spawn over the whole batch's
        // (request, head, block) tasks via `batch_engine`.
        let engine = if cfg.threads == 0 && cfg.seq_len * cfg.d_model < SERIAL_WORK_CUTOFF {
            SinkhornEngine::serial()
        } else {
            SinkhornEngine::new(cfg.threads)
        };
        Ok(FallbackModel {
            engine,
            batch_pool: WorkerPool::new(cfg.threads),
            batch_engine: SinkhornEngine::new(cfg.threads),
            embed: init(cfg.vocab, d, 0.1),
            pos: init(cfg.seq_len, d, 0.05),
            wq: init(d, d, wscale),
            wk: init(d, d, wscale),
            wv: init(d, d, wscale),
            wo: init(d, d, wscale),
            sortnet: init(d, cfg.nb, wscale),
            w_cls: init(d, cfg.n_classes, wscale),
            cfg,
        })
    }

    /// Class logits for one request (tokens are wrapped into the vocab and
    /// padded/truncated to `seq_len`). Batched traffic goes through
    /// [`Self::classify_batch`] instead — same math, pooled scheduling.
    pub fn class_logits(&self, tokens: &[i32]) -> Vec<f32> {
        let p = self.prep(tokens);
        let mut ctx = Mat::zeros(self.cfg.seq_len, self.cfg.d_model);
        self.engine.attention_into(&p.q, &p.k, &p.v, &p.r, self.cfg.nb, false, &mut ctx);
        self.head(&p.x, &ctx)
    }

    /// Per-request prelude shared by the single and batched paths: embed
    /// tokens, project q/k/v, and balance the SortNet's sort matrix.
    fn prep(&self, tokens: &[i32]) -> Prep {
        let (ell, d, nb) = (self.cfg.seq_len, self.cfg.d_model, self.cfg.nb);
        // embed + position
        let mut x = Mat::zeros(ell, d);
        for t in 0..ell {
            let tok = tokens.get(t).copied().unwrap_or(0); // PAD
            let id = tok.rem_euclid(self.cfg.vocab as i32) as usize;
            let (er, pr) = (self.embed.row(id), self.pos.row(t));
            for (c, o) in x.row_mut(t).iter_mut().enumerate() {
                *o = er[c] + pr[c];
            }
        }
        let q = x.matmul(&self.wq);
        let k = x.matmul(&self.wk);
        let v = x.matmul(&self.wv);
        // SortNet: mean-pooled block descriptors -> (nb, nb) logits -> balance
        let b = ell / nb;
        let mut blk = Mat::zeros(nb, d);
        for i in 0..nb {
            for t in 0..b {
                let xr = x.row(i * b + t);
                for (c, o) in blk.row_mut(i).iter_mut().enumerate() {
                    *o += xr[c];
                }
            }
        }
        blk.scale(1.0 / b as f32);
        let r = balance::sinkhorn(&blk.matmul(&self.sortnet), self.cfg.sinkhorn_iters);
        Prep { x, q, k, v, r }
    }

    /// Output projection, residual mean-pool and classification head over
    /// a computed attention context.
    fn head(&self, x: &Mat, attn_ctx: &Mat) -> Vec<f32> {
        let (ell, d) = (self.cfg.seq_len, self.cfg.d_model);
        let ctx = attn_ctx.matmul(&self.wo);
        // residual + mean pool
        let mut h = vec![0.0f32; d];
        for t in 0..ell {
            let (xr, cr) = (x.row(t), ctx.row(t));
            for c in 0..d {
                h[c] += xr[c] + cr[c];
            }
        }
        for v in &mut h {
            *v /= ell as f32;
        }
        // linear head
        let mut logits = vec![0.0f32; self.cfg.n_classes];
        for (c, &hc) in h.iter().enumerate() {
            let wr = self.w_cls.row(c);
            for (j, l) in logits.iter_mut().enumerate() {
                *l += hc * wr[j];
            }
        }
        logits
    }

    /// Predicted label for one request.
    pub fn classify(&self, tokens: &[i32]) -> i32 {
        argmax(&self.class_logits(tokens))
    }

    /// Greedy autoregressive generation on the incremental decode path
    /// (DESIGN.md §Decode): feed `prompt` through a per-sequence
    /// [`DecodeState`] token by token, then keep sampling the argmax of
    /// the tied-embedding LM head (`h_t · Eᵀ` — the same embedding matrix
    /// that encodes the input, so the model needs no separate output
    /// projection) until `max_new` tokens exist or the positional table
    /// runs out. Returns only the newly generated ids.
    ///
    /// Capacity rule: the model has `seq_len` positions. The prompt is
    /// truncated to the first `seq_len - 1` tokens (mirroring `classify`'s
    /// head-truncation while always leaving room to generate), and the
    /// number of generated tokens is `min(max_new, seq_len - prompt_len)`.
    /// An empty prompt decodes from the PAD token 0. Deterministic: same
    /// prompt, same model seed, same output — batched or not.
    pub fn generate(&self, prompt: &[i32], max_new: usize) -> Vec<i32> {
        let mut scratch = DecodeScratch::new();
        self.generate_one(prompt, max_new, &mut scratch)
    }

    /// [`Self::generate`] for a batch of `(prompt, max_new)` requests
    /// (executor entry point): requests fan out over the worker pool, one
    /// sequence per task, each worker reusing one [`DecodeScratch`]. Per
    /// sequence the math is identical to the single-request path, so
    /// batched and single generations agree exactly.
    pub fn generate_batch(&self, reqs: &[(Vec<i32>, usize)]) -> Vec<Vec<i32>> {
        if reqs.is_empty() {
            return Vec::new();
        }
        let mut outs: Vec<Vec<i32>> = reqs.iter().map(|_| Vec::new()).collect();
        let tasks: Vec<(usize, &mut Vec<i32>)> = outs.iter_mut().enumerate().collect();
        self.batch_pool.run(tasks, DecodeScratch::new, |scratch, (i, slot)| {
            *slot = self.generate_one(&reqs[i].0, reqs[i].1, scratch);
        });
        outs
    }

    /// One sequence's greedy decode loop. Per step: embed the token, the
    /// engine's incremental step ([`DecodeState::step_into`] — cached
    /// causal Sinkhorn state, O(b·d)), then the tied LM head when a new
    /// token is due.
    ///
    /// Decode-time SortNet rule (DESIGN.md §Decode): the batch model feeds
    /// each block's own mean descriptor through the SortNet, but a block's
    /// descriptor only exists once the block is complete — so here the
    /// sort-logit row of block `i + 1` is produced from block `i`'s mean
    /// descriptor the moment block `i` fills. Rows are only ever written
    /// before the causal balance first reads them, and never rewritten.
    fn generate_one(&self, prompt: &[i32], max_new: usize, scratch: &mut DecodeScratch) -> Vec<i32> {
        let (ell_cap, d, nb) = (self.cfg.seq_len, self.cfg.d_model, self.cfg.nb);
        let b = ell_cap / nb;
        let seeded = [0i32]; // empty prompt: decode from PAD
        let prompt: &[i32] = if prompt.is_empty() { &seeded } else { prompt };
        let keep = prompt.len().min(ell_cap.saturating_sub(1).max(1));
        let budget = max_new.min(ell_cap - keep);
        if budget == 0 {
            return Vec::new();
        }
        let mut st = DecodeState::new(b, d, nb, self.cfg.sinkhorn_iters, None);
        let mut sort_logits = Mat::zeros(nb, nb);
        let mut desc_acc = vec![0.0f32; d];
        let mut x = vec![0.0f32; d];
        let mut ctx = vec![0.0f32; d];
        let mut h = vec![0.0f32; d];
        let mut gen: Vec<i32> = Vec::with_capacity(budget);
        // the final generated token needs no step of its own
        for t in 0..keep + budget - 1 {
            let tok = if t < keep { prompt[t] } else { gen[t - keep] };
            let id = tok.rem_euclid(self.cfg.vocab as i32) as usize;
            let (er, pr) = (self.embed.row(id), self.pos.row(t));
            for (c, xo) in x.iter_mut().enumerate() {
                *xo = er[c] + pr[c];
            }
            let q = row_times(&x, &self.wq);
            let kr = row_times(&x, &self.wk);
            let vr = row_times(&x, &self.wv);
            st.step_into(&q, &kr, &vr, &sort_logits, scratch, &mut ctx);
            for (c, a) in desc_acc.iter_mut().enumerate() {
                *a += x[c];
            }
            if (t + 1) % b == 0 {
                // block t/b filled: its mean descriptor becomes the next
                // block's sort-logit row
                let i = t / b;
                if i + 1 < nb {
                    for a in desc_acc.iter_mut() {
                        *a /= b as f32;
                    }
                    let row = row_times(&desc_acc, &self.sortnet);
                    sort_logits.row_mut(i + 1).copy_from_slice(&row);
                }
                desc_acc.fill(0.0);
            }
            if t + 1 >= keep {
                // tied-embedding LM head over h_t = x_t + ctx_t @ wo
                let proj = row_times(&ctx, &self.wo);
                for (c, ho) in h.iter_mut().enumerate() {
                    *ho = x[c] + proj[c];
                }
                let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
                for vtok in 0..self.cfg.vocab {
                    let ev = self.embed.row(vtok);
                    let mut acc = 0.0f32;
                    for (c, &hc) in h.iter().enumerate() {
                        acc += hc * ev[c];
                    }
                    if acc > best_v {
                        best_v = acc;
                        best = vtok;
                    }
                }
                gen.push(best as i32);
            }
        }
        gen
    }

    /// Labels for a batch of requests (executor entry point) — three
    /// phases, each one pool pass over the whole batch:
    ///
    /// 1. **prep** (request-parallel): embedding, q/k/v projections,
    ///    SortNet balance;
    /// 2. **attention** (batch×block-parallel): the batch is flattened to
    ///    `(request, head, block)` tasks via
    ///    [`SinkhornEngine::attention_batch_into`], so even a batch of
    ///    small requests keeps every worker busy — the previous scheme ran
    ///    whole requests serially through a per-request engine;
    /// 3. **head** (request-parallel): output projection, pooling, argmax.
    ///
    /// The per-block math is identical to the single-request path, so
    /// batched and single labels agree exactly.
    pub fn classify_batch(&self, batch: &[Vec<i32>]) -> Vec<i32> {
        if batch.is_empty() {
            return Vec::new();
        }
        let (ell, d, nb) = (self.cfg.seq_len, self.cfg.d_model, self.cfg.nb);
        // phase 1 — prep
        let mut preps: Vec<Option<Prep>> = batch.iter().map(|_| None).collect();
        {
            let tasks: Vec<(usize, &mut Option<Prep>)> = preps.iter_mut().enumerate().collect();
            self.batch_pool.run(tasks, || (), |_, (i, slot)| *slot = Some(self.prep(&batch[i])));
        }
        let preps: Vec<Prep> = preps.into_iter().map(|p| p.expect("prep phase ran")).collect();
        // phase 2 — attention over the flattened task domain
        let reqs: Vec<AttentionReq> = preps
            .iter()
            .map(|p| AttentionReq { q: &p.q, k: &p.k, v: &p.v, r: &p.r, nb, causal: false })
            .collect();
        let mut ctxs: Vec<Mat> = batch.iter().map(|_| Mat::zeros(ell, d)).collect();
        // a batch whose *total* flattened work sits below the thread-spawn
        // payoff runs serially — same cutoff as the single-request engine
        // choice, scaled by batch size; an explicit threads count still
        // wins via batch_engine
        if self.cfg.threads == 0 && batch.len() * ell * d < SERIAL_WORK_CUTOFF {
            SinkhornEngine::serial().attention_batch_into(&reqs, &mut ctxs);
        } else {
            self.batch_engine.attention_batch_into(&reqs, &mut ctxs);
        }
        // phase 3 — heads
        let mut labels = vec![0i32; batch.len()];
        let tasks: Vec<(usize, &mut i32)> = labels.iter_mut().enumerate().collect();
        self.batch_pool.run(tasks, || (), |_, (i, slot)| {
            *slot = argmax(&self.head(&preps[i].x, &ctxs[i]));
        });
        labels
    }
}

/// Per-request tensors produced by the prep phase and consumed by the
/// attention + head phases.
struct Prep {
    x: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    r: Mat,
}

/// Row-vector times matrix: `out[j] = Σ_c x[c] * w[c, j]` — the decode
/// loop's per-token projection (same accumulation order as `Mat::matmul`
/// on a 1-row left operand, so single and batched paths agree bitwise).
fn row_times(x: &[f32], w: &Mat) -> Vec<f32> {
    debug_assert_eq!(x.len(), w.rows);
    let mut out = vec![0.0f32; w.cols];
    for (c, &a) in x.iter().enumerate() {
        if a == 0.0 {
            continue;
        }
        let wr = w.row(c);
        for (o, &wv) in out.iter_mut().zip(wr) {
            *o += a * wv;
        }
    }
    out
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0;
    for (j, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = j;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> FallbackModel {
        FallbackModel::new(FallbackConfig {
            seq_len: 32,
            d_model: 16,
            nb: 4,
            vocab: 64,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn deterministic_across_instances() {
        let (a, b) = (model(), model());
        let toks: Vec<i32> = (0..32).map(|i| (i * 7) % 64).collect();
        assert_eq!(a.class_logits(&toks), b.class_logits(&toks));
        assert_eq!(a.classify(&toks), b.classify(&toks));
    }

    #[test]
    fn labels_in_range_and_inputs_matter() {
        let m = model();
        let mut seen = std::collections::HashSet::new();
        for s in 0..24 {
            let toks: Vec<i32> = (0..32).map(|i| (i * (s + 3) + s) % 64).collect();
            let label = m.classify(&toks);
            assert!((0..m.cfg.n_classes as i32).contains(&label));
            let lg = m.class_logits(&toks);
            assert!(lg.iter().all(|x| x.is_finite()));
            seen.insert(format!("{lg:?}"));
        }
        assert!(seen.len() > 1, "logits must depend on the input");
    }

    #[test]
    fn handles_short_long_and_hostile_token_ids() {
        let m = model();
        // short (padded), long (truncated), out-of-range ids (wrapped)
        let short = m.classify(&[1, 2, 3]);
        let long = m.classify(&vec![5; 500]);
        let hostile = m.classify(&[i32::MIN, i32::MAX, -1, 1 << 30]);
        for l in [short, long, hostile] {
            assert!((0..m.cfg.n_classes as i32).contains(&l));
        }
    }

    #[test]
    fn batch_matches_single() {
        let m = model();
        let reqs: Vec<Vec<i32>> = (0..5).map(|s| (0..32).map(|i| (i + s) % 64).collect()).collect();
        let batch = m.classify_batch(&reqs);
        for (r, &want) in reqs.iter().zip(&batch) {
            assert_eq!(m.classify(r), want);
        }
    }

    #[test]
    fn generate_is_deterministic_and_in_vocab() {
        let m = model();
        let prompt: Vec<i32> = (0..10).map(|i| (i * 5) % 64).collect();
        let a = m.generate(&prompt, 8);
        let b = m.generate(&prompt, 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|&t| (0..m.cfg.vocab as i32).contains(&t)));
    }

    #[test]
    fn generate_prefix_stable() {
        // greedy decoding is incremental: asking for fewer tokens yields a
        // prefix of asking for more
        let m = model();
        let prompt: Vec<i32> = (0..7).map(|i| i * 3 + 1).collect();
        let long = m.generate(&prompt, 6);
        for n in 1..6 {
            assert_eq!(&m.generate(&prompt, n)[..], &long[..n], "n={n}");
        }
    }

    #[test]
    fn generate_respects_capacity() {
        let m = model(); // seq_len = 32
        // near-capacity prompt: budget shrinks to the remaining positions
        let prompt: Vec<i32> = (0..30).map(|i| i % 64).collect();
        assert_eq!(m.generate(&prompt, 10).len(), 2);
        // over-capacity prompt: truncated to seq_len - 1, one token left
        let huge: Vec<i32> = (0..100).map(|i| i % 64).collect();
        assert_eq!(m.generate(&huge, 10).len(), 1);
        // zero tokens requested
        assert!(m.generate(&prompt, 0).is_empty());
    }

    #[test]
    fn generate_handles_empty_and_hostile_prompts() {
        let m = model();
        assert_eq!(m.generate(&[], 3).len(), 3);
        let hostile = m.generate(&[i32::MIN, i32::MAX, -1], 4);
        assert_eq!(hostile.len(), 4);
        assert!(hostile.iter().all(|&t| (0..m.cfg.vocab as i32).contains(&t)));
    }

    #[test]
    fn generate_batch_matches_single() {
        let m = model();
        let reqs: Vec<(Vec<i32>, usize)> = (0..5)
            .map(|s| ((0..8).map(|i| (i * 7 + s) % 64).collect(), 3 + s as usize % 3))
            .collect();
        let batch = m.generate_batch(&reqs);
        for ((prompt, max_new), got) in reqs.iter().zip(&batch) {
            assert_eq!(&m.generate(prompt, *max_new), got);
        }
    }

    #[test]
    fn blocks_for_divides() {
        for ell in [128, 96, 64, 30, 7] {
            assert_eq!(ell % FallbackConfig::blocks_for(ell), 0);
        }
    }

    #[test]
    fn rejects_bad_config() {
        assert!(FallbackModel::new(FallbackConfig { seq_len: 30, nb: 8, ..Default::default() })
            .is_err());
    }
}
