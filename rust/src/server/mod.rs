//! Batched inference serving (the L3 "router" role): client threads submit
//! requests — classify (token ids → label) or generate (prompt → greedily
//! decoded ids, DESIGN.md §Decode); a dynamic batcher groups them; a
//! single executor thread owning the execution backend runs whole batches
//! at once, split by verb. The backend is either the PJRT runtime over
//! compiled artifacts (classify only) or, when no HLO artifact is present,
//! the pure-Rust blocked engine ([`fallback`] — works on any machine,
//! serves both verbs). TCP line protocol: `rust/README.md`.

pub mod batch;
pub mod fallback;
pub mod service;
pub mod tcp;

pub use batch::{gather, BatchPolicy};
pub use fallback::{FallbackConfig, FallbackModel};
pub use service::{Response, Server, ServerHandle};
pub use tcp::TcpFrontend;
