"""Transformer building blocks (build-time JAX, hand-rolled — no flax/haiku).

Parameters are plain nested dicts of ``jnp.ndarray``; initializers take an
explicit PRNG key. The AOT exporter flattens these dicts with
``jax.tree_util`` and records the leaf order in the artifact manifest, so
the Rust coordinator can carry them opaquely.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in, d_out, scale=None):
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    wk, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(wk, (d_in, d_out), jnp.float32) * scale,
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def dense(p, x):
    return x @ p["w"] + p["b"]


def layernorm_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def ffn_init(key, d_model, d_ff):
    k1, k2 = jax.random.split(key)
    return {"in": dense_init(k1, d_model, d_ff), "out": dense_init(k2, d_ff, d_model)}


def ffn(p, x):
    return dense(p["out"], jax.nn.relu(dense(p["in"], x)))


def embedding_init(key, vocab, d_model):
    return {"table": jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02}


def embed(p, tokens):
    return p["table"][tokens]


def sinusoid_positions(ell: int, d_model: int) -> jnp.ndarray:
    """Fixed sinusoidal positional encodings (Vaswani et al., 2017)."""
    pos = np.arange(ell)[:, None].astype(np.float32)
    i = np.arange(d_model)[None, :].astype(np.float32)
    angle = pos / np.power(10000.0, (2 * (i // 2)) / d_model)
    enc = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return jnp.asarray(enc, jnp.float32)


def xent_loss(logits: jnp.ndarray, targets: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Mean token-level cross entropy; ``mask`` (same shape as targets,
    float 1/0) selects contributing positions."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
