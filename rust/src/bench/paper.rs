//! The paper's published numbers (Tables 1-8, Figures 3-4), kept verbatim
//! so every bench prints *paper vs measured* side by side. We reproduce
//! the *shape* of each comparison on a 1-core CPU testbed (DESIGN.md §4),
//! not the absolute values.

/// (variant-key, paper metric) pairs per table. Variant keys match the
/// suffix of our experiment names (after `__`), with block sizes scaled
/// 4x down (paper ell=256..1024 -> ours 64..256).
pub fn table1_paper() -> Vec<(&'static str, f64, f64)> {
    // (variant, edit distance, EM%)
    vec![
        ("vanilla", 0.4252, 45.69),
        ("local_b16", 0.4340, 21.12),
        ("sparse_b16", 0.4176, 46.88),
        ("sinkhorn_b4", 0.4156, 43.65),
        ("sinkhorn_b8", 0.4071, 48.23),
        ("sinkhorn_b16", 0.4054, 49.24),
    ]
}

/// LM1B subword ppl, (variant, base, big).
pub fn table2_paper() -> Vec<(&'static str, f64, f64)> {
    vec![
        ("vanilla", 41.57, 27.59),
        ("local_b8", 44.62, 30.14),
        ("local_b16", 44.23, 29.32),
        ("local_b32", 44.23, 28.97),
        ("sparse_b32", 41.89, 28.77),
        ("sinkhorn_b8", 42.64, 29.42),
        ("sinkhorn_b16", 41.29, 28.48),
        ("sinkhorn_b32", 40.79, 28.39),
        ("mixture", 40.11, 27.34),
    ]
}

/// Table 3: published comparison (model, #params, ppl). Closed-source
/// comparators are quoted; our rows are measured.
pub fn table3_paper() -> Vec<(&'static str, &'static str, f64)> {
    vec![
        ("Low Budget MoE", "5.0B", 34.10),
        ("Transformer (Big)", "141M", 30.44),
        ("Evolved Transformer (Big)", "151M", 28.60),
        ("High Budget MoE", "5.0B", 28.00),
        ("Mesh Tensorflow", "4.9B", 24.00),
        ("Sinkhorn Transformer", "450M", 28.39),
        ("Sinkhorn Transformer", "1.9B", 27.34),
    ]
}

/// char-level LM1B bpc, (variant, base, big).
pub fn table4_paper() -> Vec<(&'static str, f64, f64)> {
    vec![
        ("local_b32", 2.559, 1.825),
        ("vanilla", 1.283, 1.121),
        ("sparse_b32", 1.300, 1.134),
        ("sinkhorn_b32", 1.295, 1.132),
        ("mixture", 1.270, 1.119),
    ]
}

/// CIFAR-10 bpd.
pub fn table5_paper() -> Vec<(&'static str, f64)> {
    vec![
        ("local_b16", 4.200),
        ("vanilla", 3.198),
        ("sparse_b16", 3.227),
        ("sinkhorn_b16", 3.197),
        ("mixture", 3.199),
    ]
}

/// Table 6 accuracy: (variant, imdb_word, imdb_char, sst_word, sst_char).
pub fn table6_paper() -> Vec<(&'static str, [f64; 4])> {
    vec![
        ("vanilla", [85.12, 62.77, 76.83, 57.45]),
        ("sinkhorn_a", [82.51, 63.78, 74.08, 62.27]),
        ("sinkhorn_b", [82.00, 62.05, 76.15, 56.08]),
        ("sinkhorn_c", [83.54, 62.87, 77.52, 58.14]),
        ("sortcut_a", [84.32, 64.53, 73.85, 56.65]),
        ("sortcut_b", [80.12, 64.87, 74.31, 58.14]),
        ("sortcut_c", [84.43, 62.80, 75.81, 56.42]),
    ]
}

/// Table 7 accuracy: (variant, snli, mnli).
pub fn table7_paper() -> Vec<(&'static str, f64, f64)> {
    vec![
        ("vanilla", 78.87, 53.69),
        ("sinkhorn_a", 68.34, 52.15),
        ("sinkhorn_b", 77.77, 52.09),
        ("sinkhorn_c", 78.62, 54.25),
        ("sortcut_a", 75.84, 48.88),
        ("sortcut_b", 80.30, 49.78),
        ("sortcut_c", 79.39, 55.80),
    ]
}

/// Table 8 SortNet ablations, ppl at b=32 on LM1B.
pub fn table8_paper() -> Vec<(&'static str, f64)> {
    vec![
        ("p1", 41.70),
        ("p2", 41.38),
        ("p3", 41.34),
        ("p4 (default)", 41.29),
        ("sharekv", 42.26),
        ("noiters", 52.40),
    ]
}

/// Figure 3: temperature -> ppl trend (paper optimum at tau = 0.75).
pub const FIG3_PAPER_OPT_TAU: f64 = 0.75;

/// Figure 4: sinkhorn iterations -> ppl trend (paper optimum 5-10,
/// degradation at >20, catastrophic at 0).
pub const FIG4_PAPER_OPT_RANGE: (usize, usize) = (5, 10);
