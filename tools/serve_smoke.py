#!/usr/bin/env python3
"""End-to-end TCP smoke test of the serving stack (`make serve-smoke`,
wired into `make ci`): spawn the pure-Rust fallback server on an
ephemeral port, drive the line protocol over a real socket — classify,
a *streamed* generation (`tok <i> <id>` lines then the `tokens=`
summary), the `model` info verb, and the stable error replies — and
assert every reply shape. This is the one gate that exercises the
process boundary: CLI flag parsing, the TCP frontend, the continuous
scheduler, and the streaming protocol together (DESIGN.md §Scheduler).

A second phase re-spawns the server at capacity one (`--max-sessions 1
--queue-depth 0`) and drives it *over* admission: while connection A
streams a long generation, connection B's request must get the stable
`busy=` line back on a connection that stays usable, and the same
request retried after A retires must succeed — the admission overflow
and slot-reuse paths of DESIGN.md §Scheduler observed from outside the
process.

A third phase (`--chaos`, wired as `make chaos-smoke`) exercises the
fault-tolerance paths of DESIGN.md §Faults from outside the process: a
client killed mid-stream must not disturb a concurrent session, the
`shutdown` verb must reply `ok=draining`, refuse follow-up work with a
stable error, resolve the still-streaming connection (summary or
`error=server shutting down`), and the `--wait` process must then exit
0 on its own — the graceful-drain contract observed end to end.

Needs a Rust toolchain (it runs the built `sinkhorn serve` binary); the
Makefile target skips loudly when `cargo` is absent, like fmt-check.

Usage: python3 tools/serve_smoke.py [--chaos]
  (no flag: phases 1+2; --chaos: the chaos phase only)
Env: CARGO (default "cargo").
Exit code 0 on success, 1 on any failed assertion.
"""
import os
import re
import socket
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CARGO = os.environ.get("CARGO", "cargo")
ADDR_RE = re.compile(r"tcp frontend listening on 127\.0\.0\.1:(\d+)")
BUSY_LINE = "busy=generation queue full"


def fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def spawn_server(extra_flags):
    """Start `serve --fallback` on an ephemeral port; return (proc, port)."""
    cmd = [
        CARGO, "run", "--release", "--manifest-path", str(ROOT / "rust" / "Cargo.toml"),
        "--", "serve", "--fallback", "--port", "0", "--wait",
    ] + extra_flags
    print("+ " + " ".join(cmd))
    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, cwd=ROOT
    )
    deadline = time.time() + 600  # first run may compile
    while time.time() < deadline:
        line = proc.stdout.readline()
        if not line:
            fail(f"server exited early (rc={proc.poll()})")
        sys.stdout.write(f"[server] {line}")
        m = ADDR_RE.search(line)
        if m:
            return proc, int(m.group(1))
    fail("server never announced its TCP port")


def stop_server(proc) -> None:
    proc.terminate()
    try:
        proc.wait(timeout=10)
    except subprocess.TimeoutExpired:
        proc.kill()


class Conn:
    """One line-protocol client connection with logged traffic."""

    def __init__(self, port: int, tag: str):
        self.sock = socket.create_connection(("127.0.0.1", port), timeout=60)
        self.f = self.sock.makefile("rw", encoding="utf-8", newline="\n")
        self.tag = tag

    def send(self, line: str) -> None:
        self.f.write(line + "\n")
        self.f.flush()

    def recv(self) -> str:
        reply = self.f.readline().rstrip("\n")
        print(f"[{self.tag}] {reply}")
        return reply

    def drain_gen(self, seed=None):
        """Read a streamed generation: `tok` lines then the summary line.
        `seed` carries token ids already consumed off this stream (the
        index check continues from them). Returns (ids, summary)."""
        tok_ids = list(seed or [])
        while True:
            reply = self.recv()
            if reply.startswith("tok "):
                idx, tid = reply.split()[1:3]
                if int(idx) != len(tok_ids):
                    fail(f"{self.tag}: tok indices out of order: {reply!r}")
                tok_ids.append(int(tid))
            else:
                return tok_ids, reply

    def close(self) -> None:
        self.sock.close()


def check_gen_summary(tag: str, tok_ids, summary: str, want_n: int) -> None:
    if not summary.startswith("tokens="):
        fail(f"{tag}: gen summary reply: {summary!r}")
    summary_ids = [int(t) for t in summary.split()[0][len("tokens="):].split(",") if t]
    if len(tok_ids) != want_n or tok_ids != summary_ids:
        fail(f"{tag}: streamed ids {tok_ids} != summary ids {summary_ids} (want {want_n})")


def phase_protocol() -> None:
    """Classify, streamed gen, model info, and the stable error replies."""
    proc, port = spawn_server(["--seq-len", "32", "--max-sessions", "4"])
    try:
        c = Conn(port, "client")

        # classify: one stable label= line
        c.send("4 8 15 16 23 42")
        reply = c.recv()
        if not reply.startswith("label="):
            fail(f"classify reply: {reply!r}")

        # streamed generation: exactly 4 `tok <i> <id>` lines (indices in
        # order), then the `tokens=` summary whose ids match the stream
        c.send("gen 4 1 2 3")
        tok_ids, reply = c.drain_gen()
        check_gen_summary("client", tok_ids, reply, 4)

        # model info: the served configuration as one key=value line
        c.send("model")
        reply = c.recv()
        if "backend=fallback" not in reply or "seq_len=32" not in reply:
            fail(f"model reply: {reply!r}")

        # stable errors: unknown verb, zero-budget gen
        c.send("frobnicate 1 2")
        if c.recv() != "error=unknown verb 'frobnicate'":
            fail("unknown-verb reply drifted")
        c.send("gen 0 1")
        if c.recv() != "error=gen count must be positive":
            fail("zero-count reply drifted")

        c.close()
        print("serve-smoke phase 1: OK (classify, streamed gen, model, stable errors)")
    finally:
        stop_server(proc)


def phase_over_admission() -> None:
    """Drive the server past its admission bound: a second generation
    must get the stable busy= line while the single slot is held, and the
    identical retry must succeed once the slot retires."""
    # capacity one, no wait queue; the long seq_len gives conn A a
    # generation that outlives the busy-probe round trip by a wide margin
    proc, port = spawn_server(["--seq-len", "512", "--max-sessions", "1", "--queue-depth", "0"])
    try:
        a = Conn(port, "conn A")
        b = Conn(port, "conn B")

        # conn A takes the only slot; its first tok line proves it was
        # admitted and is streaming
        a.send("gen 400 1 2 3")
        first = a.recv()
        if not first.startswith("tok 0 "):
            fail(f"over-admission: conn A first reply {first!r}, want 'tok 0 <id>'")

        # conn B overflows `slots + queue_depth` and must get the stable
        # busy line — and nothing else — without losing its connection
        b.send("gen 4 9 8 7")
        reply = b.recv()
        if reply != BUSY_LINE:
            fail(f"over-admission: want {BUSY_LINE!r}, got {reply!r}")

        # drain A to its summary; retiring frees the slot
        tok_ids, reply = a.drain_gen(seed=[int(first.split()[2])])
        check_gen_summary("conn A", tok_ids, reply, 400)

        # same request, same connection, after retirement: admitted
        b.send("gen 4 9 8 7")
        tok_ids, reply = b.drain_gen()
        check_gen_summary("conn B", tok_ids, reply, 4)

        a.close()
        b.close()
        print("serve-smoke phase 2: OK (busy= under over-admission, retry after retirement)")
    finally:
        stop_server(proc)


def phase_chaos() -> None:
    """Kill a client mid-stream, then drive a graceful drain shutdown —
    the fault-tolerance contract (DESIGN.md §Faults) from outside the
    process: survivors keep serving, every connection resolves with a
    stable line, and the drained `--wait` process exits 0 by itself."""
    # the long seq_len keeps chaos-victim generations in flight while we
    # act; a small drain window keeps the final wait fast either way
    proc, port = spawn_server(
        ["--seq-len", "512", "--max-sessions", "4", "--drain-ms", "500"]
    )
    try:
        # conn A: stream a long generation, read a few tokens, vanish.
        # The server's next write fails, the session is cancelled, and —
        # the actual assertion — nobody else notices.
        a = Conn(port, "conn A")
        a.send("gen 400 1 2 3")
        for _ in range(3):
            reply = a.recv()
            if not reply.startswith("tok "):
                fail(f"chaos: conn A expected tok lines, got {reply!r}")
        a.close()
        print("[chaos] conn A killed mid-stream")

        # conn B: a full request right through the wreckage
        b = Conn(port, "conn B")
        b.send("gen 4 9 8 7")
        tok_ids, reply = b.drain_gen()
        check_gen_summary("conn B", tok_ids, reply, 4)
        b.close()

        # conn C: still streaming when the drain begins
        c = Conn(port, "conn C")
        c.send("gen 400 5 5 5")
        first = c.recv()
        if not first.startswith("tok 0 "):
            fail(f"chaos: conn C first reply {first!r}, want 'tok 0 <id>'")

        # conn D: begin the graceful drain, then probe the intake refusal
        d = Conn(port, "conn D")
        d.send("shutdown")
        reply = d.recv()
        if reply != "ok=draining":
            fail(f"chaos: shutdown reply {reply!r}, want 'ok=draining'")
        d.send("gen 4 1 2 3")
        reply = d.recv()
        if not (reply == "error=server shutting down" or reply.startswith("error=server ")):
            fail(f"chaos: post-drain request got {reply!r}, want a stable error")
        d.close()

        # conn C resolves either way: finished inside the drain window
        # (tokens= summary) or aborted with the stable shutdown error
        tok_ids, reply = c.drain_gen(seed=[int(first.split()[2])])
        if reply.startswith("tokens="):
            check_gen_summary("conn C", tok_ids, reply, 400)
        elif reply != "error=server shutting down":
            fail(f"chaos: conn C resolution {reply!r}")
        c.close()

        # the drained --wait process exits cleanly on its own
        try:
            rc = proc.wait(timeout=60)
        except subprocess.TimeoutExpired:
            fail("chaos: drained server never exited")
        for line in proc.stdout:
            sys.stdout.write(f"[server] {line}")
        if rc != 0:
            fail(f"chaos: drained server exited rc={rc}")
        print("serve-smoke phase 3: OK (mid-stream kill isolated, drain shutdown clean)")
    finally:
        stop_server(proc)


def main() -> int:
    if "--chaos" in sys.argv[1:]:
        phase_chaos()
    else:
        phase_protocol()
        phase_over_admission()
    print("serve-smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
