//! Parallel, allocation-free blocked execution engine for Sparse Sinkhorn
//! Attention (DESIGN.md §Engine).
//!
//! The naive reference path in [`super::attention`] exists to be obviously
//! correct: it materializes every block, clones and rescales `(b, d)`
//! tiles per permutation weight, and runs on one thread. This module is
//! the production path over the *same* algorithm:
//!
//! * **Zero-copy blocking** — [`BlockedView`] carves `nb` blocks out of a
//!   contiguous `(ell, d)` buffer without copying (the strided-view
//!   conventions shared with `runtime::tensor`).
//! * **Fused gather-matmul sort** — the balanced matrix `r` is nearly a
//!   permutation, so block mixing skips zero weights and accumulates
//!   `w * block` directly into a preallocated workspace tile
//!   ([`gather_block_into`]): no clone, no scale pass, no temporaries.
//! * **SortCut** (paper §3.3) — the truncated path gathers only the first
//!   `n_cut` sorted blocks and attends all queries to them.
//! * **Worker pool** — output blocks are embarrassingly parallel; they are
//!   split via `chunks_mut` and fanned out over [`WorkerPool`], one
//!   private `Workspace` per worker. Inner loops allocate nothing.
//!
//! **Bit-exactness:** every kernel mirrors the reference path's
//! floating-point operation order (see `matrix.rs`), and blocks never
//! share accumulators, so fused and parallel outputs equal the naive
//! path's bit for bit — for any thread count. The property tests in
//! `tests/engine_props.rs` pin this contract (edge cases are covered
//! below); `bench engine` re-checks it before every timing run.

use super::balance::NEG_INF;
use super::matrix::{
    add_assign, matmul_into, matmul_t_scaled_into, softmax_rows_inplace, Mat, MatView, MatViewMut,
};
use super::pool::WorkerPool;

/// Zero-copy view of an `(ell, d)` matrix as `nb` contiguous `(b, d)`
/// blocks sharing one buffer.
#[derive(Debug, Clone, Copy)]
pub struct BlockedView<'a> {
    pub nb: usize,
    /// rows per block
    pub b: usize,
    /// model dim
    pub d: usize,
    data: &'a [f32],
}

impl<'a> BlockedView<'a> {
    pub fn from_seq(x: &'a Mat, nb: usize) -> Self {
        assert!(nb > 0, "nb must be positive");
        assert_eq!(x.rows % nb, 0, "nb must divide ell");
        BlockedView { nb, b: x.rows / nb, d: x.cols, data: &x.data }
    }

    /// Block `i` as a strided matrix view.
    pub fn block(&self, i: usize) -> MatView<'a> {
        MatView::contiguous(self.block_slice(i), self.b, self.d)
    }

    /// Block `i`'s raw contiguous storage.
    pub fn block_slice(&self, i: usize) -> &'a [f32] {
        let n = self.b * self.d;
        &self.data[i * n..(i + 1) * n]
    }
}

/// Fused gather-matmul over the near-permutation sort weights: write
/// `sum_j weights[j] * block_j` into `out`, skipping zero entries. This is
/// the reference `Blocked::sort` inner loop with the clone-scale-add
/// temporaries fused away (same accumulation order, bit-identical).
pub fn gather_block_into(weights: &[f32], src: &BlockedView, out: &mut [f32]) {
    debug_assert_eq!(weights.len(), src.nb);
    debug_assert_eq!(out.len(), src.b * src.d);
    out.fill(0.0);
    for (j, &w) in weights.iter().enumerate() {
        if w != 0.0 {
            for (o, x) in out.iter_mut().zip(src.block_slice(j)) {
                *o += w * *x;
            }
        }
    }
}

/// Per-worker scratch tiles; sized once, reused for every block the worker
/// processes (the engine's per-block loop is allocation-free).
struct Workspace {
    /// gathered (sorted) keys, `(b, d)`
    ks: Vec<f32>,
    /// gathered (sorted) values, `(b, d)`
    vs: Vec<f32>,
    /// joint `[sorted | local]` logits, `(b, 2b)`
    logits: Vec<f32>,
    /// local-term combine scratch, `(b, d)`
    tmp: Vec<f32>,
}

impl Workspace {
    fn new(b: usize, d: usize) -> Self {
        Workspace {
            ks: vec![0.0; b * d],
            vs: vec![0.0; b * d],
            logits: vec![0.0; 2 * b * b],
            tmp: vec![0.0; b * d],
        }
    }
}

/// The parallel blocked engine. Construction is free; `threads == 0`
/// auto-detects (see [`super::pool::auto_threads`]).
#[derive(Debug, Clone, Copy)]
pub struct SinkhornEngine {
    pool: WorkerPool,
}

impl SinkhornEngine {
    pub fn new(threads: usize) -> Self {
        SinkhornEngine { pool: WorkerPool::new(threads) }
    }

    /// Single-threaded fused engine (the "fused" row of `bench engine`).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// One worker per available core (the "parallel" row).
    pub fn auto() -> Self {
        Self::new(0)
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Sparse Sinkhorn attention over `(ell, d)` q/k/v with balanced sort
    /// matrix `r` — semantics identical to
    /// [`super::attention::sinkhorn_attention`], output bit-identical.
    pub fn attention(&self, q: &Mat, k: &Mat, v: &Mat, r: &Mat, nb: usize, causal: bool) -> Mat {
        let mut out = Mat::zeros(q.rows, q.cols);
        self.attention_into(q, k, v, r, nb, causal, &mut out);
        out
    }

    /// [`Self::attention`] into a caller-provided output (serving hot
    /// path: reuse the buffer across requests). `out` need not be zeroed.
    #[allow(clippy::too_many_arguments)]
    pub fn attention_into(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        r: &Mat,
        nb: usize,
        causal: bool,
        out: &mut Mat,
    ) {
        check_qkv(q, k, v);
        assert_eq!((r.rows, r.cols), (nb, nb), "sort matrix must be (nb, nb)");
        assert_eq!((out.rows, out.cols), (q.rows, q.cols), "output shape");
        let qb = BlockedView::from_seq(q, nb);
        let kb = BlockedView::from_seq(k, nb);
        let vb = BlockedView::from_seq(v, nb);
        let (b, d) = (qb.b, qb.d);
        let scale = 1.0 / (d as f32).sqrt();

        let tasks: Vec<(usize, &mut [f32])> = out.data.chunks_mut(b * d).enumerate().collect();
        self.pool.run(
            tasks,
            || Workspace::new(b, d),
            |ws, (i, chunk)| block_attention(ws, i, chunk, &qb, &kb, &vb, r, causal, scale),
        );
    }

    /// SortCut truncated attention (paper §3.3): every query attends to
    /// the first `n_cut` *sorted* blocks. Semantics identical to
    /// [`super::attention::sortcut_attention`], output bit-identical, but
    /// only `n_cut` of the `nb` gather rows are ever computed.
    pub fn sortcut_attention(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        r: &Mat,
        nb: usize,
        n_cut: usize,
    ) -> Mat {
        let mut out = Mat::zeros(q.rows, q.cols);
        self.sortcut_attention_into(q, k, v, r, nb, n_cut, &mut out);
        out
    }

    #[allow(clippy::too_many_arguments)]
    pub fn sortcut_attention_into(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        r: &Mat,
        nb: usize,
        n_cut: usize,
        out: &mut Mat,
    ) {
        check_qkv(q, k, v);
        assert_eq!((r.rows, r.cols), (nb, nb), "sort matrix must be (nb, nb)");
        assert!((1..=nb).contains(&n_cut), "n_cut must be in 1..=nb, got {n_cut}");
        assert_eq!((out.rows, out.cols), (q.rows, q.cols), "output shape");
        let qb = BlockedView::from_seq(q, nb);
        let kb = BlockedView::from_seq(k, nb);
        let vb = BlockedView::from_seq(v, nb);
        let (b, d) = (qb.b, qb.d);
        let scale = 1.0 / (d as f32).sqrt();

        // gather the truncated sorted K/V once (n_cut blocks, not nb)
        let mut kcut = vec![0.0f32; n_cut * b * d];
        let mut vcut = vec![0.0f32; n_cut * b * d];
        for i in 0..n_cut {
            gather_block_into(r.row(i), &kb, &mut kcut[i * b * d..(i + 1) * b * d]);
            gather_block_into(r.row(i), &vb, &mut vcut[i * b * d..(i + 1) * b * d]);
        }
        let kcutv = MatView::contiguous(&kcut, n_cut * b, d);
        let vcutv = MatView::contiguous(&vcut, n_cut * b, d);

        // all row operations (logits, softmax, combine) are row-local, so
        // query blocks parallelize bit-exactly
        let tasks: Vec<(usize, &mut [f32])> = out.data.chunks_mut(b * d).enumerate().collect();
        self.pool.run(
            tasks,
            || vec![0.0f32; b * n_cut * b],
            |scratch, (i, chunk)| {
                let qi = qb.block(i);
                let mut lg = MatViewMut::contiguous(scratch, b, n_cut * b);
                matmul_t_scaled_into(&qi, &kcutv, scale, &mut lg);
                softmax_rows_inplace(&mut lg);
                let mut y = MatViewMut::contiguous(chunk, b, d);
                matmul_into(&lg.as_view(), &vcutv, &mut y);
            },
        );
    }
}

fn check_qkv(q: &Mat, k: &Mat, v: &Mat) {
    assert_eq!(q.rows, k.rows, "q/k rows");
    assert_eq!(q.rows, v.rows, "q/v rows");
    assert_eq!(q.cols, k.cols, "q/k cols");
    assert_eq!(k.cols, v.cols, "k/v cols");
}

/// One output block of the fused sorted+local attention. Mirrors the loop
/// body of the reference `sinkhorn_attention` exactly (see module docs for
/// the bit-exactness contract).
#[allow(clippy::too_many_arguments)]
fn block_attention(
    ws: &mut Workspace,
    i: usize,
    out_chunk: &mut [f32],
    qb: &BlockedView,
    kb: &BlockedView,
    vb: &BlockedView,
    r: &Mat,
    causal: bool,
    scale: f32,
) {
    let (b, d) = (qb.b, qb.d);
    let rrow = r.row(i);
    let row_support: f32 = rrow.iter().sum();
    let valid = row_support > 1e-6;

    // 1. fused gather of this block's sorted keys/values
    gather_block_into(rrow, kb, &mut ws.ks);
    gather_block_into(rrow, vb, &mut ws.vs);

    let qi = qb.block(i);
    // 2. sorted-term logits into the left (b, b) band of the (b, 2b) tile
    {
        let mut ls = MatViewMut::new(&mut ws.logits, b, b, 2 * b);
        if valid {
            let ksv = MatView::contiguous(&ws.ks, b, d);
            matmul_t_scaled_into(&qi, &ksv, scale, &mut ls);
        } else {
            // no sort support for this block: mask the whole sorted term
            ls.fill(NEG_INF);
        }
    }
    // 3. local-term logits into the right band, causally masked if asked
    {
        let mut ll = MatViewMut::new(&mut ws.logits[b..], b, b, 2 * b);
        matmul_t_scaled_into(&qi, &kb.block(i), scale, &mut ll);
        if causal {
            for t in 0..b {
                for u in (t + 1)..b {
                    ll.set(t, u, NEG_INF);
                }
            }
        }
    }
    // 4. joint softmax over [sorted | local]
    {
        let mut lg = MatViewMut::contiguous(&mut ws.logits, b, 2 * b);
        softmax_rows_inplace(&mut lg);
    }
    // 5. combine: y = P_s @ V_sorted + P_l @ V_local, written in place
    let mut y = MatViewMut::contiguous(out_chunk, b, d);
    {
        let ps = MatView::new(&ws.logits, b, b, 2 * b);
        let vsv = MatView::contiguous(&ws.vs, b, d);
        matmul_into(&ps, &vsv, &mut y);
    }
    {
        let pl = MatView::new(&ws.logits[b..], b, b, 2 * b);
        let mut t = MatViewMut::contiguous(&mut ws.tmp, b, d);
        matmul_into(&pl, &vb.block(i), &mut t);
        add_assign(&mut y, &t.as_view());
    }
}

#[cfg(test)]
mod tests {
    // The heavy bit-exactness property suites (fused == naive, parallel
    // == fused for any thread count, sortcut == naive, sortcut k = nb)
    // live in tests/engine_props.rs — only edge cases are covered here.
    use super::*;
    use crate::sinkhorn::balance::sinkhorn;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
    }

    #[test]
    fn attention_into_reuses_dirty_buffer() {
        let mut rng = Rng::new(0xE5);
        let (nb, b, d) = (3, 4, 6);
        let ell = nb * b;
        let q = rand_mat(&mut rng, ell, d);
        let k = rand_mat(&mut rng, ell, d);
        let v = rand_mat(&mut rng, ell, d);
        let r = sinkhorn(&rand_mat(&mut rng, nb, nb), 8);
        let eng = SinkhornEngine::serial();
        let want = eng.attention(&q, &k, &v, &r, nb, false);
        let mut out = Mat::from_fn(ell, d, |_, _| f32::NAN); // dirty
        eng.attention_into(&q, &k, &v, &r, nb, false, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    #[should_panic(expected = "nb must divide ell")]
    fn rejects_indivisible_block_count() {
        let q = Mat::zeros(10, 4);
        SinkhornEngine::serial().attention(&q, &q, &q, &Mat::zeros(3, 3), 3, false);
    }

    #[test]
    #[should_panic(expected = "n_cut must be in 1..=nb")]
    fn rejects_zero_cut() {
        let q = Mat::zeros(8, 4);
        SinkhornEngine::serial().sortcut_attention(&q, &q, &q, &Mat::eye(4), 4, 0);
    }
}
