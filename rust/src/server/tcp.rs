//! TCP line-protocol frontend for the inference service.
//!
//! Protocol (one request per line, UTF-8):
//!   client: `<id> <id> <id> ...\n`   (space-separated token ids)
//!   server: `label=<k> batch=<n> queue_us=<q> total_us=<t>\n`
//!           or `error=<message>\n`
//!
//! Each accepted connection gets its own thread that forwards requests to
//! the shared [`ServerHandle`] (the dynamic batcher merges concurrent
//! streams into executor batches).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::service::ServerHandle;

/// A listening TCP frontend. The acceptor runs as a detached daemon
/// thread for the lifetime of the process: `TcpListener::incoming` has no
/// portable cancellation, so `drop` does NOT join it (joining would
/// deadlock — the loop blocks in accept). Connection handlers exit when
/// clients disconnect; requests after the backing [`ServerHandle`]'s
/// server shuts down get `error=` replies.
pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    _accept_join: JoinHandle<()>,
}

/// Parse one request line into token ids.
pub fn parse_request(line: &str) -> Result<Vec<i32>> {
    line.split_whitespace()
        .map(|t| t.parse::<i32>().with_context(|| format!("bad token '{t}'")))
        .collect()
}

/// Render a response line.
pub fn format_response(label: i32, batch: usize, queue_us: u128, total_us: u128) -> String {
    format!("label={label} batch={batch} queue_us={queue_us} total_us={total_us}\n")
}

impl TcpFrontend {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve.
    pub fn start(addr: &str, handle: ServerHandle) -> Result<TcpFrontend> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let accept_join = std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { break };
                let h = handle.clone();
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, h);
                });
            }
        });
        Ok(TcpFrontend { addr: local, _accept_join: accept_join })
    }
}

fn serve_conn(stream: TcpStream, handle: ServerHandle) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let reply = match parse_request(&line) {
            Err(e) => format!("error={e}\n"),
            Ok(tokens) if tokens.is_empty() => "error=empty request\n".to_string(),
            Ok(tokens) => match handle.classify(tokens) {
                Ok(r) => format_response(
                    r.label,
                    r.batch_size,
                    r.queue.as_micros(),
                    r.total.as_micros(),
                ),
                Err(e) => format!("error={e}\n"),
            },
        };
        writer.write_all(reply.as_bytes())?;
        writer.flush()?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_valid() {
        assert_eq!(parse_request("1 2 3\n").unwrap(), vec![1, 2, 3]);
        assert_eq!(parse_request("  7  \n").unwrap(), vec![7]);
        assert!(parse_request("1 x 3").is_err());
    }

    #[test]
    fn response_format() {
        let s = format_response(1, 8, 120, 4500);
        assert_eq!(s, "label=1 batch=8 queue_us=120 total_us=4500\n");
    }

    #[test]
    fn parse_empty_gives_empty_vec() {
        assert_eq!(parse_request("\n").unwrap(), Vec::<i32>::new());
    }
}
