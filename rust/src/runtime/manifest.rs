//! Typed views over the AOT artifact manifests written by
//! `python/compile/aot.py` (`<exp>.manifest.json`) and the global
//! `registry.json`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor crossing the Rust <-> XLA boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }

    pub fn primitive(self) -> xla::PrimitiveType {
        match self {
            Dtype::F32 => xla::PrimitiveType::F32,
            Dtype::I32 => xla::PrimitiveType::S32,
        }
    }
}

/// One named tensor slot (a parameter leaf or a batch input).
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl LeafSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("shape not an array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(LeafSpec {
            name: j.str_of("name")?,
            shape,
            dtype: Dtype::parse(&j.str_of("dtype")?)?,
        })
    }
}

/// Which model family an experiment belongs to (decides batch layout and
/// eval-output interpretation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Lm,
    Cls,
    Seq2seq,
}

impl Family {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "lm" => Ok(Family::Lm),
            "cls" => Ok(Family::Cls),
            "seq2seq" => Ok(Family::Seq2seq),
            other => bail!("unknown family '{other}'"),
        }
    }
}

/// Parsed `<exp>.manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub family: Family,
    pub table: String,
    pub params: Vec<LeafSpec>,
    pub train_batch_inputs: Vec<LeafSpec>,
    pub eval_batch_inputs: Vec<LeafSpec>,
    pub eval_outputs: Vec<String>,
    pub init_hlo: PathBuf,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    /// Raw config (vocab, ell, nb, variant, ...) for typed lookups.
    pub cfg: Json,
    pub train_cfg: Json,
    pub eval_cfg: Json,
}

impl Manifest {
    pub fn load(dir: &Path, name: &str) -> Result<Self> {
        let path = dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Self> {
        let leafs = |key: &str| -> Result<Vec<LeafSpec>> {
            j.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow!("{key} not an array"))?
                .iter()
                .map(LeafSpec::from_json)
                .collect()
        };
        let arts = j.req("artifacts")?;
        Ok(Manifest {
            name: j.str_of("name")?,
            family: Family::parse(&j.str_of("family")?)?,
            table: j.str_of("table")?,
            params: leafs("params")?,
            train_batch_inputs: leafs("train_batch_inputs")?,
            eval_batch_inputs: leafs("eval_batch_inputs")?,
            eval_outputs: arts_names(j.req("eval_outputs")?)?,
            init_hlo: dir.join(arts.str_of("init")?),
            train_hlo: dir.join(arts.str_of("train")?),
            eval_hlo: dir.join(arts.str_of("eval")?),
            cfg: j.req("cfg")?.clone(),
            train_cfg: j.req("train_cfg")?.clone(),
            eval_cfg: j.get("eval_cfg").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn n_leaves(&self) -> usize {
        self.params.len()
    }

    /// Total parameter count (for the paper-style "# Params" column).
    pub fn n_params(&self) -> usize {
        self.params.iter().map(|l| l.elements()).sum()
    }

    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.cfg.usize_of(key)
    }

    pub fn variant(&self) -> String {
        self.cfg.str_of("variant").unwrap_or_else(|_| "?".into())
    }
}

fn arts_names(j: &Json) -> Result<Vec<String>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("eval_outputs not an array"))?
        .iter()
        .map(|o| o.str_of("name"))
        .collect()
}

/// One entry of `registry.json`.
#[derive(Debug, Clone)]
pub struct RegistryEntry {
    pub name: String,
    pub family: Family,
    pub table: String,
    pub cfg: Json,
    pub train_cfg: Json,
}

/// The global experiment registry.
#[derive(Debug, Clone)]
pub struct Registry {
    pub dir: PathBuf,
    pub entries: Vec<RegistryEntry>,
}

impl Registry {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("registry.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text)?;
        let entries = j
            .req("experiments")?
            .as_arr()
            .ok_or_else(|| anyhow!("experiments not an array"))?
            .iter()
            .map(|e| {
                Ok(RegistryEntry {
                    name: e.str_of("name")?,
                    family: Family::parse(&e.str_of("family")?)?,
                    table: e.str_of("table")?,
                    cfg: e.req("cfg")?.clone(),
                    train_cfg: e.req("train_cfg")?.clone(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Registry { dir: dir.to_path_buf(), entries })
    }

    pub fn by_table(&self, table: &str) -> Vec<&RegistryEntry> {
        self.entries.iter().filter(|e| e.table == table).collect()
    }

    pub fn find(&self, name: &str) -> Result<&RegistryEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("experiment '{name}' not in registry"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn manifest_from_json() {
        let j = Json::parse(
            r#"{
              "name": "t", "family": "lm", "table": "table2",
              "params": [{"name": "w", "shape": [2, 3], "dtype": "f32"}],
              "train_batch_inputs": [{"name": "tokens", "shape": [4, 9], "dtype": "i32"}],
              "eval_batch_inputs": [{"name": "tokens", "shape": [4, 9], "dtype": "i32"}],
              "eval_outputs": [{"name": "loss"}],
              "cfg": {"ell": 8, "variant": "sinkhorn"}, "train_cfg": {"batch": 4},
              "artifacts": {"init": "t.init.hlo.txt", "train": "t.train.hlo.txt",
                            "eval": "t.eval.hlo.txt", "manifest": "t.manifest.json"}
            }"#,
        )
        .unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert_eq!(m.n_leaves(), 1);
        assert_eq!(m.n_params(), 6);
        assert_eq!(m.family, Family::Lm);
        assert_eq!(m.cfg_usize("ell").unwrap(), 8);
        assert_eq!(m.variant(), "sinkhorn");
        assert!(m.train_hlo.ends_with("t.train.hlo.txt"));
    }
}
