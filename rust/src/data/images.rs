//! Synthetic pixel-generation dataset (stand-in for CIFAR-10, §5.3).
//!
//! Images are structured, not noise: a 2-D color gradient background, a
//! solid rectangle, and mild pixel noise. Flattened RGB subpixels form the
//! autoregressive sequence (paper: 32x32x3 = 3072; here 8x8x3 = 192),
//! giving real long-range structure — the same column re-appears every
//! `3*width` steps, which a block-local window cannot capture.

use crate::util::rng::Rng;

pub struct ImageTask {
    pub width: usize,
    pub height: usize,
    rng: Rng,
}

impl ImageTask {
    /// `seq_len` must equal width*height*3; we fix a square image.
    pub fn for_seq_len(seq_len: usize, seed: u64) -> Self {
        let pixels = seq_len / 3;
        let side = (pixels as f64).sqrt() as usize;
        assert_eq!(side * side * 3, seq_len, "seq_len must be 3*s^2");
        ImageTask { width: side, height: side, rng: Rng::new(seed) }
    }

    /// One image as a flat sequence of `width*height*3` subpixel values
    /// in [0, 256).
    pub fn image(&mut self) -> Vec<i32> {
        let (w, h) = (self.width, self.height);
        // random gradient + rectangle parameters
        let base = [
            self.rng.usize_below(200) as i32,
            self.rng.usize_below(200) as i32,
            self.rng.usize_below(200) as i32,
        ];
        let gx = self.rng.range_i64(-12, 13) as i32;
        let gy = self.rng.range_i64(-12, 13) as i32;
        let rx0 = self.rng.usize_below(w / 2);
        let ry0 = self.rng.usize_below(h / 2);
        let rx1 = rx0 + 1 + self.rng.usize_below(w - rx0 - 1);
        let ry1 = ry0 + 1 + self.rng.usize_below(h - ry0 - 1);
        let rect = [
            self.rng.usize_below(256) as i32,
            self.rng.usize_below(256) as i32,
            self.rng.usize_below(256) as i32,
        ];

        let mut out = Vec::with_capacity(w * h * 3);
        for y in 0..h {
            for x in 0..w {
                let in_rect = x >= rx0 && x < rx1 && y >= ry0 && y < ry1;
                for c in 0..3 {
                    let mut val = if in_rect {
                        rect[c]
                    } else {
                        base[c] + gx * x as i32 + gy * y as i32
                    };
                    val += self.rng.range_i64(-4, 5) as i32; // sensor noise
                    out.push(val.clamp(0, 255));
                }
            }
        }
        out
    }

    /// Batch of flattened images, row-major (bsz, seq_len).
    pub fn batch(&mut self, bsz: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(bsz * self.width * self.height * 3);
        for _ in 0..bsz {
            out.extend(self.image());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_in_byte_range() {
        let mut t = ImageTask::for_seq_len(192, 1);
        for _ in 0..4 {
            let img = t.image();
            assert_eq!(img.len(), 192);
            assert!(img.iter().all(|&v| (0..256).contains(&v)));
        }
    }

    #[test]
    #[should_panic(expected = "3*s^2")]
    fn rejects_bad_seq_len() {
        ImageTask::for_seq_len(200, 1);
    }

    #[test]
    fn images_are_structured_not_noise() {
        // neighboring pixels should correlate far above random bytes
        let mut t = ImageTask::for_seq_len(192, 5);
        let img = t.image();
        let mut adj_diff = 0.0;
        let mut rand_diff = 0.0;
        let n = img.len() - 3;
        for i in 0..n {
            adj_diff += (img[i] - img[i + 3]).abs() as f64; // same channel, next pixel
            rand_diff += (img[i] - img[(i * 37 + 91) % img.len()]).abs() as f64;
        }
        assert!(adj_diff * 1.5 < rand_diff, "adj {adj_diff} rand {rand_diff}");
    }

    #[test]
    fn deterministic() {
        let mut a = ImageTask::for_seq_len(192, 4);
        let mut b = ImageTask::for_seq_len(192, 4);
        assert_eq!(a.image(), b.image());
    }
}
