//! Small dense row-major f32 matrices for the pure-Rust reference
//! implementation of Sparse Sinkhorn Attention (no BLAS offline; sizes
//! here are tiny — nb x nb sort matrices and b x d tiles).

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// C = A @ B.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// C = A @ B^T.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t dims");
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            for j in 0..other.rows {
                let mut acc = 0.0;
                for k in 0..self.cols {
                    acc += self[(i, k)] * other[(j, k)];
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row-wise softmax in place.
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let r = self.row_mut(i);
            let m = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in r.iter_mut() {
                *x = (*x - m).exp();
                sum += *x;
            }
            for x in r.iter_mut() {
                *x /= sum;
            }
        }
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(a.matmul(&Mat::eye(3)), a);
        assert_eq!(Mat::eye(3).matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches() {
        let a = Mat::from_fn(2, 4, |i, j| (i + j) as f32);
        let b = Mat::from_fn(3, 4, |i, j| (i * j) as f32 + 1.0);
        let bt = Mat::from_fn(4, 3, |i, j| b[(j, i)]);
        assert_eq!(a.matmul_t(&b), a.matmul(&bt));
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut a = Mat::from_fn(4, 5, |i, j| (i as f32) - (j as f32) * 0.3);
        a.softmax_rows();
        for i in 0..4 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }
}
