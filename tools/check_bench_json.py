#!/usr/bin/env python3
"""Validate the machine-readable bench outputs (`BENCH_<name>.json` at the
repo root) against their schema and against the registered bench targets.

The BENCH_*.json files are the repo's perf trajectory: successive PRs
regenerate them and diff. This gate keeps them honest:

* every `BENCH_<name>.json` must correspond to a registered
  `bench --target <name>` arm (rust/src/bench/tables.rs ALL_TARGETS), or
  the file claims a provenance nothing can regenerate;
* the document must parse and carry `{target, unit, cells}` with
  `target == <name>` and a non-empty cell list;
* every cell must carry the target's required keys with sane types
  (positive shape integers, a non-empty path/mode string, a positive
  metric).

Needs no Rust toolchain — `make doc-refs` runs it in every environment
(both CI jobs, via `check-docs`, and the offline container). Zero
committed files is a pass: smoke benches deliberately emit no JSON, so
the gate only ever sees files produced by a real `make bench` run.

Usage: python3 tools/check_bench_json.py [FILE...]
Exit code 0 when every file validates, 1 otherwise.
"""
import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# Per-target cell schema: key -> "int" (positive integer), "num" (positive
# number), "str" (non-empty string), "uint" (integer >= 0).
CELL_SCHEMAS = {
    "engine": {
        "ell": "int",
        "nb": "int",
        "b": "int",
        "d": "int",
        "path": "str",
        "threads": "int",
        "ns_per_iter": "num",
    },
    "decode": {
        "ell": "int",
        "nb": "int",
        "b": "int",
        "d": "int",
        "n_cut": "uint",
        "path": "str",
        "threads": "int",
        "tokens_per_sec": "num",
    },
    "model": {
        "depth": "int",
        "heads": "int",
        "ell": "int",
        "nb": "int",
        "b": "int",
        "d": "int",
        "d_ff": "uint",
        "mode": "str",
        "batch": "int",
        "threads": "int",
        "ns_per_iter": "num",
    },
    # "prefill" is the prompt-ingestion axis (DESIGN.md §Prefill):
    # "step" = one decode step per tick, "chunked" = block-parallel
    # chunks between ticks; ttft_* are submit -> first-token percentiles
    "serve": {
        "transport": "str",
        "mode": "str",
        "prefill": "str",
        "sessions": "int",
        "prompt_len": "int",
        "gen_len": "int",
        "slots": "int",
        "tokens_per_sec": "num",
        "p50_tok_ms": "num",
        "p95_tok_ms": "num",
        "ttft_p50_ms": "num",
        "ttft_p95_ms": "num",
        "occupancy": "num",
    },
    "pages": {
        "mode": "str",
        "sessions": "int",
        "overlap_pct": "uint",
        "prompt_len": "int",
        "gen_len": "int",
        "resident_bytes": "num",
        "bytes_per_session": "num",
        "admitted": "int",
    },
    # sort backends head-to-head (DESIGN.md §Backends): one row per
    # (backend, shape) with the mix+attention median and the quality
    # proxy vs dense attention (every sparse backend deviates from dense,
    # so the "num" > 0 check is sound)
    "backends": {
        "backend": "str",
        "ell": "int",
        "nb": "int",
        "b": "int",
        "d": "int",
        "threads": "int",
        "ns_per_iter": "num",
        "dense_max_abs": "num",
    },
}


def registered_targets() -> set:
    tables = ROOT / "rust" / "src" / "bench" / "tables.rs"
    if not tables.exists():
        return set()
    m = re.search(r"ALL_TARGETS[^=]*=\s*&\[(.*?)\]", tables.read_text(encoding="utf-8"), re.DOTALL)
    if not m:
        return set()
    return set(re.findall(r'"([A-Za-z0-9_]+)"', m.group(1)))


def check_value(kind: str, v) -> bool:
    if kind == "int":
        return isinstance(v, (int, float)) and not isinstance(v, bool) and v == int(v) and v > 0
    if kind == "uint":
        return isinstance(v, (int, float)) and not isinstance(v, bool) and v == int(v) and v >= 0
    if kind == "num":
        return isinstance(v, (int, float)) and not isinstance(v, bool) and v > 0
    if kind == "str":
        return isinstance(v, str) and len(v) > 0
    raise AssertionError(f"unknown schema kind {kind}")


def check_file(path: Path, targets: set) -> list:
    errors = []
    name = re.fullmatch(r"BENCH_([A-Za-z0-9_]+)\.json", path.name)
    if not name:
        return [f"{path.name}: not a BENCH_<name>.json file"]
    target = name.group(1)
    if targets and target not in targets:
        errors.append(
            f"{path.name}: '{target}' is not a registered bench target "
            f"(tables.rs ALL_TARGETS: {sorted(targets)})"
        )
    schema = CELL_SCHEMAS.get(target)
    if schema is None:
        errors.append(
            f"{path.name}: no cell schema registered for '{target}' — add one to "
            f"tools/check_bench_json.py when adding a JSON-emitting bench target"
        )
        return errors
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        return errors + [f"{path.name}: does not parse as JSON ({e})"]
    if not isinstance(doc, dict):
        return errors + [f"{path.name}: top level must be an object"]
    if doc.get("target") != target:
        errors.append(f"{path.name}: top-level target={doc.get('target')!r}, want {target!r}")
    if not isinstance(doc.get("unit"), str) or not doc.get("unit"):
        errors.append(f"{path.name}: missing/empty 'unit' string")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        errors.append(f"{path.name}: 'cells' must be a non-empty array")
        return errors
    for i, cell in enumerate(cells):
        if not isinstance(cell, dict):
            errors.append(f"{path.name}: cells[{i}] must be an object")
            continue
        for key, kind in schema.items():
            if key not in cell:
                errors.append(f"{path.name}: cells[{i}] missing '{key}'")
            elif not check_value(kind, cell[key]):
                errors.append(
                    f"{path.name}: cells[{i}].{key}={cell[key]!r} fails the '{kind}' check"
                )
        extra = set(cell) - set(schema)
        if extra:
            errors.append(
                f"{path.name}: cells[{i}] has unknown keys {sorted(extra)} — extend the "
                f"schema in tools/check_bench_json.py alongside the emitter"
            )
    return errors


def main() -> int:
    args = [Path(a) for a in sys.argv[1:]]
    files = args if args else sorted(ROOT.glob("BENCH_*.json"))
    targets = registered_targets()
    if not targets:
        print("FAIL: could not read ALL_TARGETS from rust/src/bench/tables.rs")
        return 1
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: no such file")
            continue
        errors.extend(check_file(f, targets))
    for msg in errors:
        print(f"FAIL: {msg}")
    if not files:
        print(
            "checked 0 BENCH_*.json files (none committed — the offline container "
            "has no toolchain to generate them): OK"
        )
        return 0
    print(
        f"checked {len(files)} BENCH_*.json file(s) against {len(targets)} registered "
        f"targets: " + ("FAIL" if errors else "OK")
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
