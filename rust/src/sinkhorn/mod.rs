//! Pure-Rust reference implementation of Sparse Sinkhorn Attention.
//!
//! This is *not* on the training hot path (that's the AOT-compiled XLA
//! graphs); it exists to (1) property-test the algorithm's invariants from
//! the coordinator side, (2) cross-check artifact numerics end-to-end,
//! (3) back the §4 memory-complexity analysis with an executable model,
//! and — since the [`engine`] rework — (4) serve inference on machines
//! with no compiled HLO artifacts at all, through the streaming blocked
//! execution engine (DESIGN.md §Engine, §Streaming) that
//! `server::fallback` runs on, including (5) token-by-token autoregressive
//! generation through the incremental [`decode`] path (DESIGN.md §Decode),
//! and (6) the full multi-layer, multi-head Sinkhorn Transformer stack
//! ([`model`], DESIGN.md §Model) that composes all of the above into the
//! depth-L architecture the paper's results come from. Since PR 9 the
//! block-mixing decision itself is pluggable ([`strategy`], DESIGN.md
//! §Backends): Sinkhorn balancing is the reference [`SortStrategy`], with
//! `routing` (online k-means, per Routing Transformers) and `local`
//! (the paper's local-window baseline) selectable per stack.

pub mod attention;
pub mod balance;
pub mod decode;
pub mod engine;
pub mod matrix;
pub mod memory;
pub mod model;
pub mod pages;
pub mod pool;
pub mod strategy;

pub use attention::{
    causal_decode_attention, decode_attention_with, dense_attention, local_attention,
    reference_stack_decode, reference_stack_decode_with, reference_stack_forward,
    reference_stack_forward_with, routing_mixing, sinkhorn_attention, sortcut_attention,
};
pub use balance::{causal_sinkhorn, ds_residual, sinkhorn};
pub use decode::{DecodeScratch, DecodeState, LayerDecodeState};
pub use engine::{
    AttentionReq, BlockedView, DecodeReq, EngineWorkspaces, PrefillReq, SinkhornEngine,
    SortLayout,
};
pub use matrix::{Mat, MatView, MatViewMut};
pub use model::{
    SinkhornStack, StackBatchScratch, StackConfig, StackDecodeScratch, StackDecodeState,
    StackPrefillReq, StackPrefillScratch, StackScratch, StackStepReq, TransformerLayer,
};
pub use pages::{Page, PagePool, PageTable, PoolStats};
pub use pool::WorkerPool;
pub use strategy::{
    routing_assignments, Backend, LocalSort, RoutingSort, SinkhornSort, SortStrategy,
    ALL_BACKENDS,
};
