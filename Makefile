# Sparse Sinkhorn Attention — repo-level targets.
# `make ci` aggregates every gate (.github/workflows/ci.yml runs it);
# `doc-refs` is the toolchain-free subset that must pass anywhere.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test check-docs doc-refs fmt-check clippy ci bench bench-engine bench-decode bench-model bench-serve bench-pages bench-backends bench-smoke serve-smoke chaos-smoke serve-fallback artifacts all

all: build

## The full CI gate set (.github/workflows/ci.yml `rust` job): build,
## tests, format, lint, docs + reference checks, a smoke pass of the
## runtime-free bench targets (tiny shapes, correctness gates on, no
## BENCH_*.json pollution), the TCP serve smoke (scripted classify +
## streamed gen against a live fallback server), and the chaos smoke
## (mid-stream client kill + graceful drain, DESIGN.md §Faults).
ci: build test fmt-check clippy check-docs bench-smoke serve-smoke chaos-smoke

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

## CI documentation gate: rustdoc must be warning-free and every
## `DESIGN.md §` citation in rust/src/ must resolve to a real section.
check-docs: doc-refs
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

## The reference checks alone need no Rust toolchain (plain python3):
## DESIGN.md anchors + every committed BENCH_*.json against the schema and
## the registered bench targets (the CI `docs` job runs exactly this).
doc-refs:
	python3 tools/check_design_refs.py --all
	python3 tools/check_bench_json.py

## Formatting gate. Loudly skipped when no Rust toolchain is on PATH (the
## offline build container), like the toolchain half of check-docs.
fmt-check:
	@if command -v $(CARGO) >/dev/null 2>&1; then \
		$(CARGO) fmt --all --manifest-path $(MANIFEST) -- --check; \
	else \
		echo "WARNING: fmt-check SKIPPED — no '$(CARGO)' toolchain on PATH"; \
	fi

## Lint gate, same toolchain guard as fmt-check.
clippy:
	@if command -v $(CARGO) >/dev/null 2>&1; then \
		$(CARGO) clippy --all-targets --manifest-path $(MANIFEST) -- -D warnings; \
	else \
		echo "WARNING: clippy SKIPPED — no '$(CARGO)' toolchain on PATH"; \
	fi

## Regenerate the perf numbers: the engine naive/fused/parallel table, the
## decode tokens/sec table, the model depth-sweep table, the serve
## offered-load sweep (request-batch vs continuous scheduler), the
## paged-vs-monolithic residency/admission sweep and the sort-backend
## head-to-head (DESIGN.md §Backends), plus machine-readable medians in
## BENCH_engine.json, BENCH_decode.json, BENCH_model.json,
## BENCH_serve.json, BENCH_pages.json and BENCH_backends.json at the
## repo root.
bench: bench-engine bench-decode bench-model bench-serve bench-pages bench-backends

bench-engine:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target engine

bench-decode:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target decode

bench-model:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target model

bench-serve:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target serve

bench-pages:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target pages

bench-backends:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target backends

## CI smoke benches: every runtime-free target (engine, decode, model,
## serve, pages and backends at tiny shapes with one rep; memory is
## analytic and already instant) — the correctness gates (engine vs naive
## oracle, decode vs full-prefix oracle, stack vs per-layer oracle,
## scheduler vs single-request generate, paged cohorts vs monolithic
## generate, every sort backend vs its naive reference) still run, but
## the real BENCH_*.json files are left untouched.
bench-smoke:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target engine --smoke
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target decode --smoke
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target model --smoke
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target serve --smoke
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target pages --smoke
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target backends --smoke
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target memory --smoke

## End-to-end TCP smoke (wired into `make ci`): spawn the fallback server
## on an ephemeral port, run scripted classify + *streamed* gen + model +
## stable-error traffic through the real socket path, then drive a
## capacity-one server over admission (stable busy= line, successful
## retry after retirement) and assert every reply (tools/serve_smoke.py).
## Loudly skipped without a Rust toolchain, like fmt-check — the script
## runs the built `sinkhorn serve` binary.
serve-smoke:
	@if command -v $(CARGO) >/dev/null 2>&1; then \
		CARGO=$(CARGO) python3 tools/serve_smoke.py; \
	else \
		echo "WARNING: serve-smoke SKIPPED — no '$(CARGO)' toolchain on PATH"; \
	fi

## Chaos smoke (wired into `make ci`): the fault-tolerance contract of
## DESIGN.md §Faults driven from outside the process — a client killed
## mid-stream must not disturb a concurrent session, the `shutdown` verb
## must drain gracefully (ok=draining, stable refusal of new work, every
## open stream resolved), and the --wait process must then exit 0 on its
## own. Same toolchain guard as serve-smoke.
chaos-smoke:
	@if command -v $(CARGO) >/dev/null 2>&1; then \
		CARGO=$(CARGO) python3 tools/serve_smoke.py --chaos; \
	else \
		echo "WARNING: chaos-smoke SKIPPED — no '$(CARGO)' toolchain on PATH"; \
	fi

## Serve the pure-Rust fallback engine over TCP (no artifacts needed):
##   echo "4 8 15 16 23 42" | nc 127.0.0.1 7878     # classify
##   echo "gen 8 4 8 15 16" | nc 127.0.0.1 7878     # generate 8 tokens
serve-fallback:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- serve --fallback --port 7878 --wait

## AOT-compile the XLA artifacts (needs the python env + real xla crate).
artifacts:
	cd python && python -m compile.aot
