//! Deterministic fault injection for the serving stack (DESIGN.md
//! §Faults).
//!
//! A [`FaultPlan`] is a replayable schedule of failures keyed by *event
//! ordinal*, not wall clock: each injection class keeps its own atomic
//! event counter, and an event fails iff its ordinal is in the plan's
//! precomputed set. Two plans built from the same [`FaultSpec`] (or the
//! same [`FaultPlan::seeded`] seed) therefore fire at exactly the same
//! points of any deterministic execution — the property the chaos
//! battery in `tests/faults_props.rs` leans on to compare a faulted run
//! against its fault-free twin bitwise.
//!
//! Three seams consume a plan:
//!
//! * **page allocation** — the plan implements
//!   [`AllocFault`](crate::sinkhorn::pages::AllocFault); a scheduled
//!   ordinal makes [`PagePool::alloc`](crate::sinkhorn::pages::PagePool)
//!   panic with the stable [`ALLOC_FAIL_MSG`] payload *before* touching
//!   the ledger, modeling transient arena exhaustion;
//! * **session step** — [`FaultPlan::step_point`] panics with
//!   [`STEP_PANIC_MSG`] at scheduled ordinals (one event per session per
//!   tick), modeling a poisoned session;
//! * **socket writes** — [`FaultPlan::sock_point`] (one event per
//!   streamed `tok` line) returns [`SockFault::Drop`] (hard-close the
//!   connection mid-stream) or [`SockFault::Stall`] (a slow client that
//!   stops reading for a while).
//!
//! Cloning a plan shares its counters (the clone is a *handle*): the
//! model, pool and frontend all tick the same schedule. To replay a
//! schedule, build a fresh plan from the same spec.

use std::any::Any;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::sinkhorn::pages::{AllocFault, ALLOC_FAIL_MSG};
use crate::util::rng::Rng;

/// Stable panic payload of an injected session-step fault — surfaces to
/// clients as `error=injected step panic` (rust/README.md failure modes).
pub const STEP_PANIC_MSG: &str = "injected step panic";

/// Stable reply for a panic whose payload the containment layer does not
/// recognize — a *genuine* bug caught by `catch_unwind`, converted to a
/// per-session error instead of a dead scheduler (DESIGN.md §Faults).
pub const SESSION_PANIC_MSG: &str = "session panicked";

/// Map a caught panic payload to its stable client-facing message:
/// injected faults keep their exact payload, anything else collapses to
/// [`SESSION_PANIC_MSG`] so internal panic text never leaks to clients.
pub fn panic_msg(payload: &(dyn Any + Send)) -> &'static str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        for known in [ALLOC_FAIL_MSG, STEP_PANIC_MSG] {
            if *s == known {
                return known;
            }
        }
    }
    SESSION_PANIC_MSG
}

/// What an injected socket fault does to the connection, consulted once
/// per streamed `tok` line ([`FaultPlan::sock_point`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SockFault {
    /// Hard-close the connection mid-stream (a vanished client).
    Drop,
    /// Sleep before the write (a client that stopped reading).
    Stall(Duration),
}

/// One injection class: the scheduled ordinals and the live event
/// counter. `fire` is lock-free — injection points sit on the decode
/// hot path.
struct FaultSet {
    ordinals: BTreeSet<usize>,
    ctr: AtomicUsize,
}

impl FaultSet {
    fn new(ordinals: impl IntoIterator<Item = usize>) -> FaultSet {
        FaultSet { ordinals: ordinals.into_iter().collect(), ctr: AtomicUsize::new(0) }
    }

    /// Count one event; true iff its ordinal is scheduled to fail.
    fn fire(&self) -> bool {
        let n = self.ctr.fetch_add(1, Ordering::Relaxed);
        !self.ordinals.is_empty() && self.ordinals.contains(&n)
    }

    fn seen(&self) -> usize {
        self.ctr.load(Ordering::Relaxed)
    }
}

/// The declarative fault schedule a [`FaultPlan`] is built from. Each
/// field lists the failing event ordinals of one injection class
/// (0-based, counted independently per class).
#[derive(Debug, Clone, Default)]
pub struct FaultSpec {
    /// page-pool allocations that fail ([`ALLOC_FAIL_MSG`] panic)
    pub alloc_fail: Vec<usize>,
    /// per-session step points that panic ([`STEP_PANIC_MSG`])
    pub step_panic: Vec<usize>,
    /// streamed `tok` writes that hard-close the connection
    pub sock_drop: Vec<usize>,
    /// streamed `tok` writes that stall for [`FaultSpec::stall_for`]
    pub sock_stall: Vec<usize>,
    /// how long a [`SockFault::Stall`] sleeps (default 50ms)
    pub stall_for: Duration,
}

struct PlanInner {
    alloc: FaultSet,
    step: FaultSet,
    sock_drop: FaultSet,
    sock_stall: FaultSet,
    stall_for: Duration,
}

/// A replayable, shareable fault schedule (module docs). `Clone` shares
/// the event counters — every holder ticks the same schedule.
#[derive(Clone)]
pub struct FaultPlan {
    inner: Arc<PlanInner>,
}

impl FaultPlan {
    /// The empty plan: every injection point is a no-op (the production
    /// default — one relaxed atomic increment per event).
    pub fn none() -> FaultPlan {
        FaultPlan::from_spec(&FaultSpec::default())
    }

    /// Build a plan firing exactly the ordinals `spec` lists.
    pub fn from_spec(spec: &FaultSpec) -> FaultPlan {
        let stall_for = if spec.stall_for.is_zero() {
            Duration::from_millis(50)
        } else {
            spec.stall_for
        };
        FaultPlan {
            inner: Arc::new(PlanInner {
                alloc: FaultSet::new(spec.alloc_fail.iter().copied()),
                step: FaultSet::new(spec.step_panic.iter().copied()),
                sock_drop: FaultSet::new(spec.sock_drop.iter().copied()),
                sock_stall: FaultSet::new(spec.sock_stall.iter().copied()),
                stall_for,
            }),
        }
    }

    /// A randomized but fully reproducible schedule: `per_class` fault
    /// ordinals per injection class, drawn uniformly from `[0, horizon)`
    /// with the repo RNG. Same `(seed, per_class, horizon)` → the same
    /// plan, so a chaos run can be replayed exactly.
    pub fn seeded(seed: u64, per_class: usize, horizon: usize) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_017);
        let mut draw = |salt: u64| -> Vec<usize> {
            let mut r = rng.fork(salt);
            (0..per_class).map(|_| r.range_i64(0, horizon.max(1) as i64) as usize).collect()
        };
        FaultPlan::from_spec(&FaultSpec {
            alloc_fail: draw(1),
            step_panic: draw(2),
            sock_drop: draw(3),
            sock_stall: draw(4),
            stall_for: Duration::ZERO,
        })
    }

    /// Session-step injection point: counts one event, panicking with
    /// the stable [`STEP_PANIC_MSG`] payload at scheduled ordinals. The
    /// scheduler's per-session `catch_unwind` converts it to a stable
    /// `error=` retirement (DESIGN.md §Faults).
    pub fn step_point(&self) {
        if self.inner.step.fire() {
            std::panic::panic_any(STEP_PANIC_MSG);
        }
    }

    /// Socket-write injection point (one event per streamed `tok` line):
    /// `None` = write normally. Both class counters observe every event
    /// (so their ordinals stay aligned); drop wins over stall when both
    /// fire on the same ordinal.
    pub fn sock_point(&self) -> Option<SockFault> {
        let drop_hit = self.inner.sock_drop.fire();
        let stall_hit = self.inner.sock_stall.fire();
        if drop_hit {
            return Some(SockFault::Drop);
        }
        if stall_hit {
            return Some(SockFault::Stall(self.inner.stall_for));
        }
        None
    }

    /// Events counted so far per class `(alloc, step, sock_drop,
    /// sock_stall)` — lets tests assert a schedule actually exercised
    /// its seams.
    pub fn seen(&self) -> (usize, usize, usize, usize) {
        let i = &self.inner;
        (i.alloc.seen(), i.step.seen(), i.sock_drop.seen(), i.sock_stall.seen())
    }
}

impl AllocFault for FaultPlan {
    fn on_alloc(&self) -> bool {
        self.inner.alloc.fire()
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let i = &self.inner;
        f.debug_struct("FaultPlan")
            .field("alloc_fail", &i.alloc.ordinals)
            .field("step_panic", &i.step.ordinals)
            .field("sock_drop", &i.sock_drop.ordinals)
            .field("sock_stall", &i.sock_stall.ordinals)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_exactly_the_scheduled_ordinals() {
        let plan = FaultPlan::from_spec(&FaultSpec {
            step_panic: vec![1, 3],
            ..Default::default()
        });
        let mut fired = Vec::new();
        for i in 0..6 {
            if std::panic::catch_unwind(|| plan.step_point()).is_err() {
                fired.push(i);
            }
        }
        assert_eq!(fired, vec![1, 3]);
        assert_eq!(plan.seen().1, 6);
    }

    #[test]
    fn clones_share_one_schedule() {
        let a = FaultPlan::from_spec(&FaultSpec { alloc_fail: vec![1], ..Default::default() });
        let b = a.clone();
        assert!(!a.on_alloc(), "ordinal 0 passes");
        assert!(b.on_alloc(), "the clone's event is ordinal 1 — counters are shared");
        assert!(!a.on_alloc());
    }

    #[test]
    fn seeded_plans_replay_identically() {
        let a = format!("{:?}", FaultPlan::seeded(42, 5, 100));
        let b = format!("{:?}", FaultPlan::seeded(42, 5, 100));
        let c = format!("{:?}", FaultPlan::seeded(43, 5, 100));
        assert_eq!(a, b, "same seed must rebuild the same schedule");
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn panic_payloads_map_to_stable_messages() {
        let p = std::panic::catch_unwind(|| std::panic::panic_any(ALLOC_FAIL_MSG)).unwrap_err();
        assert_eq!(panic_msg(&*p), ALLOC_FAIL_MSG);
        let p = std::panic::catch_unwind(|| std::panic::panic_any(STEP_PANIC_MSG)).unwrap_err();
        assert_eq!(panic_msg(&*p), STEP_PANIC_MSG);
        // arbitrary payloads (including Strings from panic!("{..}"))
        // collapse to the generic stable line — no internal text leaks
        let p = std::panic::catch_unwind(|| panic!("index out of bounds: 7")).unwrap_err();
        assert_eq!(panic_msg(&*p), SESSION_PANIC_MSG);
        let p = std::panic::catch_unwind(|| std::panic::panic_any(17u32)).unwrap_err();
        assert_eq!(panic_msg(&*p), SESSION_PANIC_MSG);
    }

    #[test]
    fn sock_faults_drop_beats_stall_and_stall_has_a_floor() {
        let plan = FaultPlan::from_spec(&FaultSpec {
            sock_drop: vec![0],
            sock_stall: vec![1],
            ..Default::default()
        });
        assert_eq!(plan.sock_point(), Some(SockFault::Drop));
        // both class counters saw event 0, so the stall scheduled at
        // ordinal 1 fires on the next event
        match plan.sock_point() {
            Some(SockFault::Stall(d)) => assert!(d > Duration::ZERO, "stall floor"),
            other => panic!("want stall, got {other:?}"),
        }
        assert_eq!(plan.sock_point(), None);
    }
}
