//! Host-side tensors and conversions to/from `xla::Literal`.
//!
//! The coordinator's data pipeline produces `HostTensor`s; the runtime
//! uploads them as literals. Downloads go the other way for metrics,
//! checkpoints and predictions. Rank-2 f32 tensors also bridge zero-copy
//! into the blocked engine's strided views ([`HostTensor::mat_view`]) and
//! owning matrices ([`HostTensor::from_mat`]/[`HostTensor::into_mat`]),
//! so engine results and runtime tensors share one layout convention
//! (row-major, shape + stride) instead of copying at the boundary.

use anyhow::{bail, Result};

use crate::sinkhorn::matrix::{Mat, MatView};

use super::manifest::{Dtype, LeafSpec};

/// A dense host tensor (row-major), f32 or i32.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn zeros(dtype: Dtype, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        match dtype {
            Dtype::F32 => HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] },
            Dtype::I32 => HostTensor::I32 { shape: shape.to_vec(), data: vec![0; n] },
        }
    }

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Upload: convert to an XLA literal with this shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        if dims.is_empty() {
            // rank-0: reshape from [1] to []
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Download: read back from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Zero-copy view of a rank-2 f32 tensor as a blocked-engine matrix
    /// view (shared row-major layout — no data movement).
    pub fn mat_view(&self) -> Result<MatView<'_>> {
        match self {
            HostTensor::F32 { shape, data } if shape.len() == 2 => {
                Ok(MatView::contiguous(data, shape[0], shape[1]))
            }
            HostTensor::F32 { shape, .. } => bail!("mat_view: rank {} != 2", shape.len()),
            HostTensor::I32 { .. } => bail!("mat_view: tensor is not f32"),
        }
    }

    /// Wrap an engine matrix as a rank-2 tensor (copies the buffer).
    pub fn from_mat(m: &Mat) -> HostTensor {
        HostTensor::F32 { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    /// Take a rank-2 f32 tensor's buffer as an engine matrix (no copy).
    pub fn into_mat(self) -> Result<Mat> {
        match self {
            HostTensor::F32 { shape, data } if shape.len() == 2 => {
                Ok(Mat::from_vec(shape[0], shape[1], data))
            }
            other => bail!("into_mat: need a rank-2 f32 tensor, got {:?} {:?}", other.dtype(), other.shape()),
        }
    }

    /// Validate against a manifest slot (shape + dtype).
    pub fn check_spec(&self, spec: &LeafSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "tensor '{}': shape {:?} != manifest {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        if self.dtype() != spec.dtype {
            bail!("tensor '{}': dtype mismatch", spec.name);
        }
        Ok(())
    }
}

/// Zero-initialized literal matching a manifest leaf (Adam m/v slots).
pub fn zero_literal(spec: &LeafSpec) -> xla::Literal {
    xla::Literal::create_from_shape(spec.dtype.primitive(), &spec.shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros(Dtype::F32, &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(&[3], vec![-1, 0, 7]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = HostTensor::scalar_f32(2.5);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_f32().unwrap(), &[2.5]);
        assert!(back.shape().is_empty());
    }

    #[test]
    fn spec_check() {
        let spec = LeafSpec { name: "w".into(), shape: vec![2, 2], dtype: Dtype::F32 };
        assert!(HostTensor::zeros(Dtype::F32, &[2, 2]).check_spec(&spec).is_ok());
        assert!(HostTensor::zeros(Dtype::F32, &[4]).check_spec(&spec).is_err());
        assert!(HostTensor::zeros(Dtype::I32, &[2, 2]).check_spec(&spec).is_err());
    }

    #[test]
    fn mat_bridge_roundtrip() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let t = HostTensor::from_mat(&m);
        assert_eq!(t.shape(), &[3, 4]);
        // zero-copy view shares layout with the matrix
        let v = t.mat_view().unwrap();
        assert_eq!(v.to_mat(), m);
        assert_eq!(t.into_mat().unwrap(), m);
        // rank / dtype guards
        assert!(HostTensor::f32(&[4], vec![0.0; 4]).mat_view().is_err());
        assert!(HostTensor::i32(&[2, 2], vec![0; 4]).mat_view().is_err());
    }

    #[test]
    fn zero_literal_matches() {
        let spec = LeafSpec { name: "m".into(), shape: vec![3, 4], dtype: Dtype::F32 };
        let lit = zero_literal(&spec);
        let t = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t.shape(), &[3, 4]);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
