//! Offline stand-in for the `anyhow` crate, API-compatible with the subset
//! this repository uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]
//! macros and the [`Context`] extension trait.
//!
//! The build container has no crates.io access, so `rust/Cargo.toml` points
//! the `anyhow` dependency at this path crate. The semantics match real
//! `anyhow` where it matters here:
//!
//! * `{}` formats the outermost message only; `{:#}` walks the whole
//!   context chain (`outer: inner: root`), which is what `main.rs` prints.
//! * Any `std::error::Error` converts via `?` (so `io::Error`,
//!   `FromUtf8Error`, parse errors, ... all work unchanged).
//! * Like real `anyhow`, [`Error`] deliberately does **not** implement
//!   `std::error::Error` — that is what keeps the blanket `From` legal.

use std::fmt;

/// A chain of error messages, outermost context first, root cause last.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { frames: vec![m.to_string()] }
    }

    /// Wrap with an outer context frame (used by [`Context`]).
    pub fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.frames.insert(0, ctx.to_string());
        self
    }

    /// The root cause message (innermost frame).
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate frames from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, exactly how real anyhow renders it
            let mut first = true;
            for frame in &self.frames {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(frame)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does not implement `std::error::Error`; this
// blanket impl would otherwise collide with `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // preserve the source chain as context frames
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// `anyhow::Result<T>` — alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format args: `anyhow!("bad dim {d}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`, mirroring real `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        assert_eq!(format!("{e:#}"), "bad value 7");
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn ensure_guards() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x % 2 == 0, "odd: {x}");
            Ok(x / 2)
        }
        assert_eq!(f(4).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "odd: 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_fail().context("loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest");
        let alt = format!("{e:#}");
        assert!(alt.starts_with("loading manifest: "), "{alt}");
        assert!(alt.len() > "loading manifest: ".len());
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u8> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(5u8).context("x").unwrap(), 5);
    }

    #[test]
    fn debug_lists_causes() {
        let e = io_fail().context("outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.starts_with("outer"), "{d}");
        assert!(d.contains("Caused by:"), "{d}");
    }
}
