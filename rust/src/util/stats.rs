//! Statistics + timing helpers for metrics and the bench harness
//! (`criterion` is not in the offline crate set).

use std::time::Instant;

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a sample (interpolated); `q` in [0, 100].
pub fn percentile(xs: &mut [f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let w = rank - lo as f64;
    xs[lo] * (1.0 - w) + xs[hi] * w
}

/// Exponential moving average (for smoothed loss curves).
#[derive(Debug, Clone)]
pub struct Ema {
    pub alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Wall-clock timer that records laps in seconds.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Time a closure `n` times and return per-iteration seconds (after `warmup`
/// extra untimed runs). The bench harness's core primitive.
pub fn time_iters<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..n)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect()
}

/// Fixed-width ASCII table writer used by every `bench table*` target.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for i in 0..ncol {
                s.push_str(&format!("{:<w$} | ", cells[i], w = widths[i]));
            }
            s.trim_end().to_string() + "\n"
        };
        out.push_str(&line(&self.headers, &widths));
        let sep: usize = widths.iter().sum::<usize>() + 3 * ncol + 1;
        out.push_str(&"-".repeat(sep));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((r.var() - var).abs() < 1e-12);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 10.0);
    }

    #[test]
    fn percentile_interp() {
        let mut xs = vec![0.0, 10.0];
        assert!((percentile(&mut xs, 50.0) - 5.0).abs() < 1e-12);
        let mut xs = vec![3.0, 1.0, 2.0];
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 100.0), 3.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.push(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["model", "ppl"]);
        t.row(&["vanilla".into(), "41.57".into()]);
        let s = t.render();
        assert!(s.contains("demo") && s.contains("vanilla") && s.contains("41.57"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
