"""Multi-head attention variants (paper §3.2 + baselines from §5).

Variants (the strings used throughout configs, benches and manifests):

  ``vanilla``  — dense O(ell^2) attention (Vaswani et al., 2017)
  ``local``    — block-local attention baseline (window = block)
  ``sparse``   — Sparse Transformer, *fixed* scheme (Child et al., 2019),
                 simulated with masking exactly as the paper's own baseline
                 implementation (§5.2: "manually simulated masking")
  ``sinkhorn`` — Sparse Sinkhorn Attention (sorted + local terms, L1 kernel)
  ``mixture``  — sinkhorn + vanilla summed (paper §3.2.3)
  ``sortcut``  — SortCut truncated attention (paper §3.4, encoder-only)

All heads of the sinkhorn family learn their own sorting network (the paper
does not share R across heads); K and V share one sort matrix (§3.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers, sortnet
from .kernels import attention_kernel, sortcut_kernel, ref

SINKHORN_FAMILY = ("sinkhorn", "mixture", "sortcut")


def attention_init(key, cfg):
    """Parameters for one multi-head attention layer."""
    d, nh = cfg["d_model"], cfg["n_heads"]
    keys = jax.random.split(key, 5)
    p = {
        "q": layers.dense_init(keys[0], d, d),
        "k": layers.dense_init(keys[1], d, d),
        "v": layers.dense_init(keys[2], d, d),
        "o": layers.dense_init(keys[3], d, d),
    }
    if cfg["variant"] in SINKHORN_FAMILY:
        p["sort"] = sortnet.sortnet_init(
            keys[4], d, cfg["nb"], nh, p_variant=cfg.get("p_variant", 4)
        )
    return p


def _split_heads(x, nh):
    b, ell, d = x.shape
    dh = d // nh
    return x.reshape(b, ell, nh, dh).transpose(0, 2, 1, 3)  # (B, H, ell, dh)


def _merge_heads(x):
    b, nh, ell, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, ell, nh * dh)


def _block(x, nb):
    g, ell, dh = x.shape
    return x.reshape(g, nb, ell // nb, dh)


def _sparse_fixed_mask(ell: int, b: int, c: int, causal: bool) -> jnp.ndarray:
    """Child et al. (2019) 'fixed' factorized pattern as a dense mask.

    Head pattern A1 (local): same block. Pattern A2 (fixed columns): the
    last ``c`` positions of every block act as summary positions visible to
    all. We merge both into one mask per head-group; the layer splits heads
    between the two patterns.
    Returns (2, ell, ell) bool — [0] local pattern, [1] fixed pattern.
    """
    i = jnp.arange(ell)[:, None]
    j = jnp.arange(ell)[None, :]
    same_block = (i // b) == (j // b)
    summary = (j % b) >= (b - c)
    m_local = same_block
    m_fixed = summary | same_block
    if causal:
        caus = j <= i
        m_local = m_local & caus
        m_fixed = m_fixed & caus
    return jnp.stack([m_local, m_fixed])


def _dense_heads(q, k, v, mask=None, causal=False):
    """(B,H,ell,dh) dense attention with optional (H-broadcastable) mask."""
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    logits = jnp.einsum("bhid,bhjd->bhij", q, k) * scale
    ell = q.shape[2]
    if causal:
        tri = jnp.tril(jnp.ones((ell, ell), bool))
        logits = jnp.where(tri, logits, ref.NEG_INF)
    if mask is not None:
        logits = jnp.where(mask, logits, ref.NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhij,bhjd->bhid", p, v)


def multihead_attention(params, x, cfg, *, causal: bool, key=None):
    """Apply one multi-head attention layer of the configured variant.

    Args:
      params: dict from ``attention_init``.
      x: (B, ell, d_model).
      cfg: model config dict (d_model, n_heads, nb, variant, sinkhorn_iters,
           tau, p_variant, n_cut, share_kv, sparse_c).
      causal: decoder-style masking.
      key: PRNG key for Gumbel noise (None => deterministic, no noise).

    Returns (B, ell, d_model).
    """
    variant = cfg["variant"]
    nh = cfg["n_heads"]
    bsz, ell, d = x.shape

    q = _split_heads(layers.dense(params["q"], x), nh)
    k = _split_heads(layers.dense(params["k"], x), nh)
    if cfg.get("share_kv", False):
        v = k  # Table 8 row (5): tie K and V
    else:
        v = _split_heads(layers.dense(params["v"], x), nh)

    if variant == "vanilla":
        y = _dense_heads(q, k, v, causal=causal)
        return layers.dense(params["o"], _merge_heads(y))

    if variant == "sparse":
        b = ell // cfg["nb"]
        masks = _sparse_fixed_mask(ell, b, cfg.get("sparse_c", max(1, b // 4)), causal)
        half = nh // 2 or 1
        head_mask = jnp.concatenate(
            [jnp.broadcast_to(masks[0], (half, ell, ell)),
             jnp.broadcast_to(masks[1], (nh - half, ell, ell))]
        )[None]
        y = _dense_heads(q, k, v, mask=head_mask)
        return layers.dense(params["o"], _merge_heads(y))

    nb = cfg["nb"]
    dh = d // nh
    qf = q.reshape(bsz * nh, ell, dh)
    kf = k.reshape(bsz * nh, ell, dh)
    vf = v.reshape(bsz * nh, ell, dh)

    if variant == "local":
        y = attention_kernel.local_block_attention(
            _block(qf, nb), _block(kf, nb), _block(vf, nb), causal=causal
        )
        y = y.reshape(bsz, nh, ell, dh)
        return layers.dense(params["o"], _merge_heads(y))

    # --- sinkhorn family: build per-head sort matrices ---
    s = sortnet.sort_matrix(
        params["sort"], x,
        nb=nb, n_iters=cfg["sinkhorn_iters"], tau=cfg.get("tau", 0.75),
        p_variant=cfg.get("p_variant", 4), causal=causal, key=key,
    )  # (B, H, nb, nb)
    s_flat = s.reshape(bsz * nh, nb, nb)
    k_blk, v_blk, q_blk = _block(kf, nb), _block(vf, nb), _block(qf, nb)
    k_sorted = jnp.einsum("gij,gjbd->gibd", s_flat, k_blk)
    v_sorted = jnp.einsum("gij,gjbd->gibd", s_flat, v_blk)
    # a sorted block is valid iff its R row has support (§3.3.3 sparsity)
    valid = (s_flat.sum(axis=-1) > 1e-6).astype(qf.dtype)  # (G, nb)

    if variant == "sortcut":
        n_cut = cfg["n_cut"]
        k_cut = k_sorted[:, :n_cut].reshape(bsz * nh, n_cut * (ell // nb), dh)
        v_cut = v_sorted[:, :n_cut].reshape(bsz * nh, n_cut * (ell // nb), dh)
        y = sortcut_kernel.sortcut_attention(qf, k_cut, v_cut)
        y = y.reshape(bsz, nh, ell, dh)
        return layers.dense(params["o"], _merge_heads(y))

    y = attention_kernel.sinkhorn_block_attention(
        q_blk, k_blk, v_blk, k_sorted, v_sorted, valid, causal=causal
    )
    y = y.reshape(bsz, nh, ell, dh)

    if variant == "mixture":  # §3.2.3: + vanilla dense view
        y = y + _dense_heads(q, k, v, causal=causal)

    return layers.dense(params["o"], _merge_heads(y))
