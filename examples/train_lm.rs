//! End-to-end driver (DESIGN.md "end-to-end validation"): train a Sinkhorn
//! Transformer language model for a few hundred steps on the synthetic
//! corpus, log the loss curve, evaluate perplexity against the local- and
//! vanilla-attention baselines, checkpoint, and verify resume-exactness.
//!
//! Run: `cargo run --release --example train_lm -- [--steps N] [--exp NAME]`
//! The output block is recorded in EXPERIMENTS.md.

use std::path::PathBuf;

use anyhow::Result;
use sinkhorn::coordinator::{self, Checkpoint, TrainOptions};
use sinkhorn::data::TaskData;
use sinkhorn::runtime::{artifacts_dir, Experiment, HostTensor, Runtime};
use sinkhorn::util::cli::Args;

fn train_and_eval(
    rt: &Runtime,
    artifacts: &PathBuf,
    name: &str,
    steps: usize,
    ckpt: Option<PathBuf>,
) -> Result<(f64, f64)> {
    let exp = Experiment::load(artifacts, name)?;
    let mut data = TaskData::for_experiment(&exp.manifest)?;
    println!("\n=== {name} ({} params) ===", exp.manifest.n_params());
    let opts = TrainOptions {
        steps,
        seed: 17,
        log_every: (steps / 20).max(1),
        verbose: false,
        checkpoint: ckpt,
    };
    let (state, report) = coordinator::train_from_scratch(rt, &exp, &mut data, &opts)?;
    for (s, l) in &report.curve.points {
        println!("  step {s:>5}  loss {l:.4}");
    }
    println!("  curve: {}", report.curve.sparkline(50));
    println!("  {:.2} steps/s over {:.1}s", report.steps_per_sec, report.secs);
    let TaskData::Lm(mut d) = data else { anyhow::bail!("not an LM task") };
    let loss = coordinator::eval_lm(rt, &exp, &state, &mut d, 4)?;
    let ppl = coordinator::perplexity(loss);
    println!("  held-out: loss {loss:.4} nats, ppl {ppl:.3}");
    Ok((loss, ppl))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = artifacts_dir();
    let steps = args.usize("steps", 300)?;
    let exp_name = args.str("exp", "lmw_tiny__sinkhorn_b16");
    let rt = Runtime::cpu()?;

    let ckpt_path = std::env::temp_dir().join("sinkhorn_train_lm.ckpt");
    let (_, sink_ppl) =
        train_and_eval(&rt, &artifacts, &exp_name, steps, Some(ckpt_path.clone()))?;
    let (_, local_ppl) = train_and_eval(&rt, &artifacts, "lmw_tiny__local_b16", steps, None)?;
    let (_, dense_ppl) = train_and_eval(&rt, &artifacts, "lmw_tiny__vanilla", steps, None)?;

    println!("\n=== summary (steps={steps}) ===");
    println!("  sinkhorn ppl {sink_ppl:.3} | local ppl {local_ppl:.3} | vanilla ppl {dense_ppl:.3}");
    println!(
        "  paper shape holds? sinkhorn < local: {}",
        if sink_ppl < local_ppl { "YES" } else { "no (more steps needed)" }
    );

    // checkpoint resume-exactness: restore and take one more eval
    let exp = Experiment::load(&artifacts, &exp_name)?;
    let restored = Checkpoint::load(&ckpt_path)?.restore(&exp.manifest)?;
    println!(
        "  checkpoint restored at step {} ({} leaves)",
        restored.step,
        restored.params.len()
    );
    // verify a param leaf roundtrips exactly
    let orig = Checkpoint::load(&ckpt_path)?;
    let t0 = &orig.tensors[0].1;
    let t1 = HostTensor::from_literal(&restored.params[0])?;
    assert_eq!(t0, &t1, "checkpoint roundtrip must be bit-exact");
    println!("  checkpoint roundtrip: bit-exact OK");
    println!("\ntrain_lm end-to-end OK");
    Ok(())
}
