//! TCP line-protocol frontend for the inference service.
//!
//! One request per UTF-8 line; the full protocol (every request form and
//! every reply, with a scripted example) is documented in
//! `rust/README.md`. Summary:
//!
//!   classify:  `<id> <id> <id> ...`            (bare space-separated ids)
//!   generate:  `gen <max_new> <id> <id> ...`   (prompt ids may be empty)
//!   info:      `model`                          (served model description)
//!
//!   replies:   `label=<k> batch=<n> queue_us=<q> total_us=<t>`
//!              `tok <i> <id>` (zero or more, streamed per generated token)
//!              `tokens=<id>,<id>,... batch=<n> queue_us=<q> total_us=<t>`
//!              `backend=<fallback|artifact> <key>=<value> ...`
//!              `busy=generation queue full`
//!              `error=<one stable line>`
//!
//! A `gen` request is the protocol's one multi-line reply (DESIGN.md
//! §Scheduler): under the continuous scheduler the frontend writes one
//! `tok <i> <id>` line the moment token `i` is produced, then the
//! historical `tokens=...` summary line — kept for compatibility, so a
//! client that only reads the summary still works by skipping `tok `
//! lines (the request-batch executor and the artifact backend emit no
//! `tok ` lines at all). Admission overflow gets the stable one-line
//! `busy=` reply ([`busy_line`]).
//!
//! Error replies are deliberately boring: one line, outermost message
//! only, length-capped ([`error_line`]) — internal context chains and
//! hostile request bytes never echo back to clients.
//!
//! Each accepted connection gets its own thread that forwards requests to
//! the shared [`ServerHandle`] (the dynamic batcher merges concurrent
//! streams into executor batches, classify and generate alike).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::service::{ServerHandle, BUSY_MSG};

/// A listening TCP frontend. The acceptor runs as a detached daemon
/// thread for the lifetime of the process: `TcpListener::incoming` has no
/// portable cancellation, so `drop` does NOT join it (joining would
/// deadlock — the loop blocks in accept). Connection handlers exit when
/// clients disconnect; requests after the backing [`ServerHandle`]'s
/// server shuts down get `error=` replies.
pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    _accept_join: JoinHandle<()>,
}

/// A parsed protocol line.
#[derive(Debug, Clone, PartialEq)]
pub enum ParsedRequest {
    /// The original bare-ids form: classify the sequence.
    Classify(Vec<i32>),
    /// `gen <max_new> <ids...>`: greedily decode up to `max_new` tokens.
    Generate { max_new: usize, tokens: Vec<i32> },
    /// `model`: describe the served model (backend, depth, heads, config).
    ModelInfo,
}

/// Longest slice of client input echoed back inside an error message.
const ECHO_CAP: usize = 24;

/// Clip a client token for inclusion in an error reply: at most
/// [`ECHO_CAP`] characters, so an overflowing or garbage line cannot
/// inflate the response.
fn clip(t: &str) -> String {
    if t.chars().count() <= ECHO_CAP {
        t.to_string()
    } else {
        let head: String = t.chars().take(ECHO_CAP).collect();
        format!("{head}...")
    }
}

fn parse_id(t: &str) -> Result<i32> {
    t.parse::<i32>().map_err(|_| anyhow!("bad token '{}'", clip(t)))
}

/// Parse one request line. Rejections are stable one-line messages:
/// `empty request`, `bad token '...'` (non-numeric or overflowing ids),
/// `unknown verb '...'`, `gen needs a token count`, `bad count '...'`,
/// `model takes no arguments`.
pub fn parse_request(line: &str) -> Result<ParsedRequest> {
    let mut toks = line.split_whitespace();
    let Some(first) = toks.next() else {
        bail!("empty request");
    };
    if first == "model" {
        if toks.next().is_some() {
            bail!("model takes no arguments");
        }
        return Ok(ParsedRequest::ModelInfo);
    }
    if first == "gen" {
        let n = toks.next().context("gen needs a token count")?;
        let max_new: usize = n.parse().map_err(|_| anyhow!("bad count '{}'", clip(n)))?;
        if max_new == 0 {
            bail!("gen count must be positive");
        }
        let tokens = toks.map(parse_id).collect::<Result<Vec<i32>>>()?;
        return Ok(ParsedRequest::Generate { max_new, tokens });
    }
    // bare ids = classify. A leading token that does not even look like a
    // number is a verb we don't know, not a bad id.
    if first.parse::<i32>().is_err()
        && !first.starts_with(|c: char| c.is_ascii_digit() || c == '-' || c == '+')
    {
        bail!("unknown verb '{}'", clip(first));
    }
    let tokens =
        std::iter::once(first).chain(toks).map(parse_id).collect::<Result<Vec<i32>>>()?;
    Ok(ParsedRequest::Classify(tokens))
}

/// Render a classify response line.
pub fn format_response(label: i32, batch: usize, queue_us: u128, total_us: u128) -> String {
    format!("label={label} batch={batch} queue_us={queue_us} total_us={total_us}\n")
}

/// Render a generate response line (`tokens=` stays empty when the
/// capacity-clamped budget produced nothing).
pub fn format_gen_response(
    tokens: &[i32],
    batch: usize,
    queue_us: u128,
    total_us: u128,
) -> String {
    let ids =
        tokens.iter().map(|t| t.to_string()).collect::<Vec<String>>().join(",");
    format!("tokens={ids} batch={batch} queue_us={queue_us} total_us={total_us}\n")
}

/// Render an error reply: exactly one line, the *outermost* error message
/// only (never the `{:#}` context chain, which names internal modules and
/// file paths), capped at 120 characters. Every `error=` the frontend
/// emits goes through here.
pub fn error_line(e: &anyhow::Error) -> String {
    let msg = e.to_string();
    let first = msg.lines().next().unwrap_or("internal error");
    let capped: String = first.chars().take(120).collect();
    format!("error={capped}\n")
}

/// The stable admission-overflow reply (DESIGN.md §Scheduler): scripts
/// match on this exact line to implement backoff.
pub fn busy_line() -> String {
    format!("busy={BUSY_MSG}\n")
}

/// Render a generate-path failure: admission overflow gets the stable
/// [`busy_line`]; everything else the ordinary [`error_line`].
pub fn gen_error_line(e: &anyhow::Error) -> String {
    if e.to_string() == BUSY_MSG {
        busy_line()
    } else {
        error_line(e)
    }
}

/// One streamed token line: `tok <index> <id>` (DESIGN.md §Scheduler).
pub fn format_tok_line(index: usize, id: i32) -> String {
    format!("tok {index} {id}\n")
}

impl TcpFrontend {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve.
    pub fn start(addr: &str, handle: ServerHandle) -> Result<TcpFrontend> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let accept_join = std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { break };
                let h = handle.clone();
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, h);
                });
            }
        });
        Ok(TcpFrontend { addr: local, _accept_join: accept_join })
    }
}

fn serve_conn(stream: TcpStream, handle: ServerHandle) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let reply = match parse_request(&line) {
            Err(e) => error_line(&e),
            Ok(ParsedRequest::Classify(tokens)) => match handle.classify(tokens) {
                Ok(r) => format_response(
                    r.label,
                    r.batch_size,
                    r.queue.as_micros(),
                    r.total.as_micros(),
                ),
                Err(e) => error_line(&e),
            },
            Ok(ParsedRequest::Generate { max_new, tokens }) => {
                // the streamed reply: one `tok <i> <id>` line per produced
                // token (flushed immediately — the continuous scheduler
                // emits them as its ticks complete), then the historical
                // `tokens=` summary line for compatibility
                match handle.generate_streaming(tokens, max_new) {
                    Err(e) => gen_error_line(&e),
                    Ok((toks, resp)) => {
                        for (i, id) in toks.iter() {
                            writer.write_all(format_tok_line(i, id).as_bytes())?;
                            writer.flush()?;
                        }
                        // the token channel closed: the summary reply is due
                        match resp.recv() {
                            Ok(Ok(r)) => format_gen_response(
                                r.gen.as_deref().unwrap_or(&[]),
                                r.batch_size,
                                r.queue.as_micros(),
                                r.total.as_micros(),
                            ),
                            Ok(Err(e)) => gen_error_line(&e),
                            Err(_) => gen_error_line(&anyhow!("server dropped request")),
                        }
                    }
                }
            }
            Ok(ParsedRequest::ModelInfo) => match handle.model_info() {
                // the payload is already one `key=value ...` line
                Ok(r) => format!("{}\n", r.info.as_deref().unwrap_or("backend=unknown")),
                Err(e) => error_line(&e),
            },
        };
        writer.write_all(reply.as_bytes())?;
        writer.flush()?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_classify_valid() {
        assert_eq!(
            parse_request("1 2 3\n").unwrap(),
            ParsedRequest::Classify(vec![1, 2, 3])
        );
        assert_eq!(parse_request("  7  \n").unwrap(), ParsedRequest::Classify(vec![7]));
        assert_eq!(parse_request("-4 +2\n").unwrap(), ParsedRequest::Classify(vec![-4, 2]));
    }

    #[test]
    fn parse_gen_valid() {
        assert_eq!(
            parse_request("gen 5 1 2 3\n").unwrap(),
            ParsedRequest::Generate { max_new: 5, tokens: vec![1, 2, 3] }
        );
        // empty prompt is allowed: the model decodes from PAD
        assert_eq!(
            parse_request("gen 2\n").unwrap(),
            ParsedRequest::Generate { max_new: 2, tokens: vec![] }
        );
    }

    #[test]
    fn parse_model_info_valid_and_strict() {
        assert_eq!(parse_request("model\n").unwrap(), ParsedRequest::ModelInfo);
        assert_eq!(parse_request("  model  \n").unwrap(), ParsedRequest::ModelInfo);
        let e = parse_request("model 1 2\n").unwrap_err();
        assert_eq!(e.to_string(), "model takes no arguments");
    }

    #[test]
    fn parse_rejects_empty_lines() {
        for line in ["", "\n", "   \n", " \t \n"] {
            let e = parse_request(line).unwrap_err();
            assert_eq!(e.to_string(), "empty request", "line {line:?}");
        }
    }

    #[test]
    fn parse_rejects_overflowing_ids() {
        // i32 overflow in classify and gen positions, usize overflow in count
        let e = parse_request("1 99999999999999999999 3\n").unwrap_err();
        assert_eq!(e.to_string(), "bad token '99999999999999999999'");
        let e = parse_request("gen 3 99999999999999999999\n").unwrap_err();
        assert_eq!(e.to_string(), "bad token '99999999999999999999'");
        let e = parse_request("gen 99999999999999999999999999 1\n").unwrap_err();
        assert!(e.to_string().starts_with("bad count '"), "{e}");
    }

    #[test]
    fn parse_rejects_unknown_verbs_and_bad_counts() {
        let e = parse_request("frobnicate 1 2\n").unwrap_err();
        assert_eq!(e.to_string(), "unknown verb 'frobnicate'");
        // numeric-looking garbage stays a token error, not a verb error
        let e = parse_request("12x 3\n").unwrap_err();
        assert_eq!(e.to_string(), "bad token '12x'");
        let e = parse_request("gen x 1\n").unwrap_err();
        assert_eq!(e.to_string(), "bad count 'x'");
        let e = parse_request("gen 0 1\n").unwrap_err();
        assert_eq!(e.to_string(), "gen count must be positive");
        let e = parse_request("gen\n").unwrap_err();
        assert_eq!(e.to_string(), "gen needs a token count");
    }

    #[test]
    fn error_replies_are_one_stable_line() {
        // hostile input is clipped before it reaches the reply
        let long = "z".repeat(500);
        let e = parse_request(&format!("{long} 1\n")).unwrap_err();
        let reply = error_line(&e);
        assert!(reply.len() < 60, "echoed too much: {reply}");
        assert_eq!(reply.matches('\n').count(), 1);
        assert!(reply.starts_with("error=unknown verb 'zzzz"));
        // context chains never leak: only the outermost frame is rendered
        let chained = anyhow::Error::msg("root cause with /internal/path")
            .context("middle frame")
            .context("request failed");
        let reply = error_line(&chained);
        assert_eq!(reply, "error=request failed\n");
    }

    #[test]
    fn response_formats() {
        assert_eq!(
            format_response(1, 8, 120, 4500),
            "label=1 batch=8 queue_us=120 total_us=4500\n"
        );
        assert_eq!(
            format_gen_response(&[4, 8, 15], 2, 10, 99),
            "tokens=4,8,15 batch=2 queue_us=10 total_us=99\n"
        );
        assert_eq!(format_gen_response(&[], 1, 0, 1), "tokens= batch=1 queue_us=0 total_us=1\n");
        assert_eq!(format_tok_line(0, 42), "tok 0 42\n");
        assert_eq!(format_tok_line(7, -3), "tok 7 -3\n");
    }

    #[test]
    fn busy_maps_to_its_own_stable_line() {
        assert_eq!(busy_line(), "busy=generation queue full\n");
        // the scheduler's admission error maps to busy=, nothing else does
        assert_eq!(gen_error_line(&anyhow!("{}", BUSY_MSG)), busy_line());
        let other = anyhow!("exec failed: boom");
        assert_eq!(gen_error_line(&other), error_line(&other));
        assert_eq!(busy_line().matches('\n').count(), 1);
    }

    /// End to end over a real socket: a `gen` request streams `tok` lines
    /// (indices in order, ids matching the summary), then the `tokens=`
    /// summary; classify stays single-line on the same connection.
    #[test]
    fn tcp_gen_streams_tok_lines_then_summary() {
        use crate::server::{BatchPolicy, FallbackConfig, Server};
        use std::io::{BufRead, BufReader, Write};
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        let fe = TcpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();
        let mut conn = std::net::TcpStream::connect(fe.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"gen 4 1 2 3\n").unwrap();
        let mut tok_ids = Vec::new();
        let summary = loop {
            let mut l = String::new();
            reader.read_line(&mut l).unwrap();
            if let Some(rest) = l.strip_prefix("tok ") {
                let mut parts = rest.split_whitespace();
                let idx: usize = parts.next().unwrap().parse().unwrap();
                let id: i32 = parts.next().unwrap().parse().unwrap();
                assert_eq!(idx, tok_ids.len(), "tok indices must stream in order");
                tok_ids.push(id);
            } else {
                break l;
            }
        };
        assert!(summary.starts_with("tokens="), "got: {summary}");
        assert_eq!(tok_ids.len(), 4);
        let summary_ids: Vec<i32> = summary
            .split_whitespace()
            .next()
            .unwrap()
            .trim_start_matches("tokens=")
            .split(',')
            .map(|t| t.parse().unwrap())
            .collect();
        assert_eq!(tok_ids, summary_ids, "streamed ids must match the summary line");
        // the connection stays usable for single-line verbs
        conn.write_all(b"5 6 7\n").unwrap();
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        assert!(l.starts_with("label="), "got: {l}");
        drop(conn);
        drop(fe);
        server.shutdown().unwrap();
    }
}
