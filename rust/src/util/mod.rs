//! Hand-rolled substrates: the offline crate set has no serde/clap/rand/
//! criterion/proptest, so the coordinator carries its own JSON codec,
//! argument parser, PRNGs, stats/bench helpers and property-test harness.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Levenshtein edit distance between two sequences (used by the Table 1
/// sorting metric, normalized by target length as in Tensor2Tensor).
pub fn edit_distance<T: PartialEq>(a: &[T], b: &[T]) -> usize {
    let (n, m) = (a.len(), b.len());
    if n == 0 {
        return m;
    }
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![0usize; m + 1];
    for i in 1..=n {
        cur[0] = i;
        for j in 1..=m {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance(b"kitten", b"sitting"), 3);
        assert_eq!(edit_distance::<u8>(b"", b"abc"), 3);
        assert_eq!(edit_distance(b"abc", b"abc"), 0);
        assert_eq!(edit_distance(b"abc", b""), 3);
    }

    #[test]
    fn edit_distance_symmetric() {
        let a = [1, 2, 3, 4, 5];
        let b = [1, 3, 2, 5];
        assert_eq!(edit_distance(&a, &b), edit_distance(&b, &a));
    }
}
