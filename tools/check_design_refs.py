#!/usr/bin/env python3
"""Verify that every `DESIGN.md §<anchor>` citation in rust/src/ names a
section that actually exists in DESIGN.md (the repo's docs used to cite
seven sections that didn't exist — this check keeps them resolvable),
and that every `BENCH_<name>.json` EXPERIMENTS.md promises can actually
be regenerated — i.e. `<name>` is a registered `bench --target` arm in
rust/src/bench/tables.rs::ALL_TARGETS.

Usage: python3 tools/check_design_refs.py [--all]
  --all also scans python/, examples/, rust/tests/ and rust/benches/
Exit code 0 when every reference resolves, 1 otherwise.
"""
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# A citation may continue with comma-separated anchors ("DESIGN.md
# §Engine, §Streaming") — capture the whole run, then pull every anchor
# out of it, so secondary anchors are verified too.
REF_RE = re.compile(r"DESIGN\.md ((?:§[A-Za-z0-9_-]+(?:,\s*)?)+)")
ANCHOR_RE = re.compile(r"§([A-Za-z0-9_-]+)")
HEADING_RE = re.compile(r"^#{1,6}\s+.*§([A-Za-z0-9_-]+)", re.MULTILINE)

# Anchors the codebase is built around — DESIGN.md must keep these
# headings even before any citation goes stale (a refactor that drops a
# section should fail here, not when someone later cites it).
REQUIRED_ANCHORS = {
    "1", "2", "4",
    "Engine", "Perf", "Hardware-Adaptation",
    # streaming-kernel PR: flash-style softmax + tiled microkernel docs
    "Streaming", "Microkernels",
    # incremental-decode PR: cached causal Sinkhorn state + SortCut decode
    "Decode",
    # model-stack PR: multi-layer multi-head transformer stack + CI
    "Model",
    # scheduler PR: continuous-batching decode scheduler + admission
    "Scheduler",
    # paged-KV PR: page-pool decode caches + COW prefix sharing
    "Pages",
    # fault-tolerance PR: deadlines/cancellation, panic isolation, drain
    # shutdown, deterministic fault injection
    "Faults",
    # HTTP gateway PR: typed JSON routes + SSE streaming over the
    # scheduler, status mapping for every stable error
    "Gateway",
    # pluggable-backends PR: SortStrategy trait contract + the
    # backend-comparison matrix
    "Backends",
    # chunked-prefill PR: block-parallel prompt ingestion, the bitwise
    # step-path contract, and the scheduler's chunk budget
    "Prefill",
}

BENCH_JSON_RE = re.compile(r"BENCH_([A-Za-z0-9_]+)\.json")

# The CLI's `--backend a|b|c` help string (rust/src/main.rs) and the
# backtick-quoted first column of the DESIGN.md §Backends comparison
# matrix (`| `name` | ...`).
BACKEND_FLAG_RE = re.compile(r"--backend\s+([a-z][a-z0-9_-]*(?:\|[a-z][a-z0-9_-]*)+)")
BACKEND_ROW_RE = re.compile(r"^\|\s*`([a-z][a-z0-9_-]*)`\s*\|", re.MULTILINE)


def check_backend_names() -> list:
    """Every backend named in the DESIGN.md §Backends comparison matrix
    must appear in the CLI `--backend sinkhorn|routing|local` help string
    (rust/src/main.rs) and vice versa — the docs may not promise a
    backend the CLI can't select, and the CLI may not grow one the
    design doc doesn't cover."""
    design = ROOT / "DESIGN.md"
    main_rs = ROOT / "rust" / "src" / "main.rs"
    if not main_rs.exists():
        return ["rust/src/main.rs does not exist"]
    m = BACKEND_FLAG_RE.search(main_rs.read_text(encoding="utf-8"))
    if not m:
        return ["rust/src/main.rs has no '--backend a|b|c' help string"]
    cli = set(m.group(1).split("|"))
    text = design.read_text(encoding="utf-8")
    sec = re.search(r"^(#{1,6})\s+.*§Backends.*$", text, re.MULTILINE)
    if not sec:
        return ["DESIGN.md has no §Backends heading (required anchor)"]
    level = len(sec.group(1))
    rest = text[sec.end():]
    nxt = re.search(rf"^#{{1,{level}}}\s", rest, re.MULTILINE)
    body = rest[: nxt.start()] if nxt else rest
    doc = set(BACKEND_ROW_RE.findall(body))
    errors = []
    if not doc:
        errors.append(
            "DESIGN.md §Backends has no comparison-matrix rows (| `name` | ...) to "
            "cross-check against the CLI --backend help"
        )
    for name in sorted(doc - cli):
        errors.append(
            f"DESIGN.md §Backends documents backend `{name}` but the CLI --backend "
            f"help string in rust/src/main.rs does not offer it (offers: {sorted(cli)})"
        )
    for name in sorted(cli - doc):
        errors.append(
            f"CLI --backend offers '{name}' but the DESIGN.md §Backends comparison "
            f"matrix has no `{name}` row (documents: {sorted(doc)})"
        )
    return errors


def check_bench_targets() -> list:
    """Every BENCH_<name>.json named in EXPERIMENTS.md must have a
    matching `bench --target <name>` arm (tables.rs ALL_TARGETS), or the
    doc promises a file nothing can regenerate."""
    experiments = ROOT / "EXPERIMENTS.md"
    tables = ROOT / "rust" / "src" / "bench" / "tables.rs"
    errors = []
    if not experiments.exists():
        return ["EXPERIMENTS.md does not exist"]
    if not tables.exists():
        return ["rust/src/bench/tables.rs does not exist"]
    names = set(BENCH_JSON_RE.findall(experiments.read_text(encoding="utf-8")))
    src = tables.read_text(encoding="utf-8")
    m = re.search(r"ALL_TARGETS[^=]*=\s*&\[(.*?)\]", src, re.DOTALL)
    if not m:
        return ["tables.rs has no ALL_TARGETS list"]
    targets = set(re.findall(r'"([A-Za-z0-9_]+)"', m.group(1)))
    for name in sorted(names):
        if name not in targets:
            errors.append(
                f"EXPERIMENTS.md names BENCH_{name}.json but 'bench --target {name}' "
                f"is not a registered target (tables.rs ALL_TARGETS: {sorted(targets)})"
            )
    if not names:
        errors.append("EXPERIMENTS.md names no BENCH_*.json files — scan regex wrong?")
    return errors


def main() -> int:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist")
        return 1
    anchors = set(HEADING_RE.findall(design.read_text(encoding="utf-8")))

    scan_dirs = [ROOT / "rust" / "src"]
    if "--all" in sys.argv[1:]:
        scan_dirs += [
            ROOT / "python",
            ROOT / "examples",
            ROOT / "rust" / "tests",
            ROOT / "rust" / "benches",
        ]

    refs = []  # (file, line_no, anchor)
    for d in scan_dirs:
        for path in sorted(d.rglob("*")):
            if path.suffix not in {".rs", ".py", ".md"} or not path.is_file():
                continue
            for i, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
                for run in REF_RE.findall(line):
                    for anchor in ANCHOR_RE.findall(run):
                        refs.append((path.relative_to(ROOT), i, anchor))

    if not refs:
        print("FAIL: found no DESIGN.md § references — scan paths wrong?")
        return 1

    bad = [(f, i, a) for (f, i, a) in refs if a not in anchors]
    for f, i, a in bad:
        print(f"FAIL: {f}:{i} cites DESIGN.md §{a}, but DESIGN.md has no such section")
    missing = REQUIRED_ANCHORS - anchors
    for a in sorted(missing):
        print(f"FAIL: DESIGN.md lost the required section anchor §{a}")
    bench_errors = check_bench_targets()
    for msg in bench_errors:
        print(f"FAIL: {msg}")
    backend_errors = check_backend_names()
    for msg in backend_errors:
        print(f"FAIL: {msg}")
    failed = bad or missing or bench_errors or backend_errors
    print(
        f"checked {len(refs)} references to {len(set(a for _, _, a in refs))} anchors "
        f"({', '.join(sorted(set(a for _, _, a in refs)))}) "
        f"against {len(anchors)} headings "
        f"({len(REQUIRED_ANCHORS)} required) "
        f"+ EXPERIMENTS.md BENCH_*.json targets "
        f"+ DESIGN.md §Backends vs CLI --backend: "
        + ("FAIL" if failed else "OK")
    )
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
