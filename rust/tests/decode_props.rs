//! Property tests for the incremental autoregressive decode path against
//! the naive full-prefix oracle — run with no artifacts and no XLA, in
//! every build. The contract under test (DESIGN.md §Decode):
//!
//! 1. for every step `t` of a decoded sequence, the incremental
//!    `decode_step_into` output matches `attention::causal_decode_attention`
//!    (which recomputes the whole prefix from scratch per position) within
//!    1e-5 max-abs — including steps that cross a block boundary, partial
//!    final blocks, and every SortCut width;
//! 2. a batch of sequences decoded through the engine is bit-identical for
//!    any thread count, and the engine entry is bit-identical to the
//!    serial `DecodeState::step_into` scratch entry;
//! 3. the per-sequence state's real allocation matches the analytic model
//!    `memory::decode_state_bytes` — the KV cache plus a constant-size
//!    sorted cache, never a score matrix;
//! 4. the continuous-batching scheduler's building blocks (DESIGN.md
//!    §Scheduler): the stack's fused batched step is bit-identical to
//!    serial `decode_step`s for staggered cohorts, and randomized
//!    arrival/length schedules driven through the session machinery —
//!    with sessions retiring mid-wave while survivors keep ticking —
//!    reproduce single-request `generate` exactly for slot counts
//!    {1, 2, 8} and engine thread counts {1, 3}.

use sinkhorn::sinkhorn::engine::ENGINE_TOL as TOL;
use sinkhorn::sinkhorn::memory::decode_state_bytes;
use sinkhorn::sinkhorn::{
    causal_decode_attention, DecodeReq, DecodeScratch, DecodeState, Mat, SinkhornEngine,
};
use sinkhorn::util::prop::{forall, Gen};
use sinkhorn::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
}

struct Case {
    q: Mat,
    k: Mat,
    v: Mat,
    logits: Mat,
    b: usize,
    nb: usize,
    /// decoded length; may end mid-block
    total: usize,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case(b={}, nb={}, d={}, total={})",
            self.b,
            self.nb,
            self.q.cols,
            self.total
        )
    }
}

fn case_with(rng: &mut Rng, nb: usize, b: usize, d: usize, total: usize) -> Case {
    let ell = nb * b;
    Case {
        q: rand_mat(rng, ell, d),
        k: rand_mat(rng, ell, d),
        v: rand_mat(rng, ell, d),
        logits: rand_mat(rng, nb, nb),
        b,
        nb,
        total,
    }
}

fn gen_case(g: &mut Gen) -> Case {
    let nb = 2 + g.usize(0, 4);
    let b = 2 + g.usize(0, 5);
    let d = 4 + g.usize(0, 8);
    let ell = nb * b;
    // half the cases stop mid-block to cover partial tails
    let total = if g.usize(0, 2) == 0 { ell } else { ell - g.usize(1, b) };
    let mut rng = Rng::new(g.rng.next_u64());
    case_with(&mut rng, nb, b, d, total)
}

/// Decode `c` step by step through the engine entry; return the stacked
/// per-step outputs.
fn decode_all(c: &Case, eng: &SinkhornEngine, n_cut: Option<usize>) -> Mat {
    let d = c.q.cols;
    let mut st = DecodeState::new(c.b, d, c.nb, 5, n_cut);
    let mut out = Mat::zeros(c.total, d);
    for t in 0..c.total {
        let mut row = vec![0.0f32; d];
        eng.decode_step_into(vec![DecodeReq {
            state: &mut st,
            q: c.q.row(t),
            k: c.k.row(t),
            v: c.v.row(t),
            sort_logits: &c.logits,
            out: &mut row,
        }]);
        out.row_mut(t).copy_from_slice(&row);
    }
    out
}

#[test]
fn incremental_matches_full_prefix_oracle() {
    // every step, every block boundary, full-causal and a random SortCut
    forall(20, 0xDEC2, gen_case, |c| {
        let oracle_full = causal_decode_attention(&c.q, &c.k, &c.v, &c.logits, c.b, 5, None);
        let got = decode_all(c, &SinkhornEngine::serial(), None);
        for t in 0..c.total {
            for e in 0..c.q.cols {
                let d = (got[(t, e)] - oracle_full[(t, e)]).abs();
                if d > TOL {
                    return Err(format!("full-causal step {t} diverged by {d}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn incremental_matches_oracle_for_every_sortcut_width() {
    let mut rng = Rng::new(0xDEC3);
    for (nb, b, d) in [(3usize, 4usize, 8usize), (4, 3, 5), (2, 34, 9), (5, 2, 16)] {
        let total = nb * b - b / 2; // always end mid-block
        let c = case_with(&mut rng, nb, b, d, total.max(1));
        for cut in 1..=nb {
            let oracle = causal_decode_attention(&c.q, &c.k, &c.v, &c.logits, b, 5, Some(cut));
            let got = decode_all(&c, &SinkhornEngine::serial(), Some(cut));
            for t in 0..c.total {
                for e in 0..d {
                    let dv = (got[(t, e)] - oracle[(t, e)]).abs();
                    assert!(
                        dv <= TOL,
                        "nb={nb} b={b} cut={cut} step {t}: diverged by {dv}"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_decode_is_thread_invariant_bitwise() {
    // a batch of sequences stepped in lockstep must produce identical
    // bytes for every thread count (the SINKHORN_THREADS guarantee)
    let mut rng = Rng::new(0xDEC4);
    let cases: Vec<Case> = (0..5)
        .map(|i| {
            let (nb, b, d) = (2 + i % 3, 2 + i, 4 + 2 * i);
            let total = nb * b - i.min(b - 1);
            case_with(&mut rng, nb, b, d, total)
        })
        .collect();
    let cuts: Vec<Option<usize>> = (0..cases.len())
        .map(|i| if i % 2 == 0 { None } else { Some(1 + i % 2) })
        .collect();
    let run = |threads: usize| -> Vec<Mat> {
        let eng = SinkhornEngine::new(threads);
        let mut states: Vec<DecodeState> = cases
            .iter()
            .zip(&cuts)
            .map(|(c, cut)| DecodeState::new(c.b, c.q.cols, c.nb, 5, *cut))
            .collect();
        let mut outs: Vec<Mat> = cases.iter().map(|c| Mat::zeros(c.total, c.q.cols)).collect();
        let max_t = cases.iter().map(|c| c.total).max().unwrap();
        for t in 0..max_t {
            let mut reqs = Vec::new();
            for ((c, st), out) in cases.iter().zip(states.iter_mut()).zip(outs.iter_mut()) {
                if t < c.total {
                    let d = c.q.cols;
                    reqs.push(DecodeReq {
                        state: st,
                        q: c.q.row(t),
                        k: c.k.row(t),
                        v: c.v.row(t),
                        sort_logits: &c.logits,
                        out: &mut out.data[t * d..(t + 1) * d],
                    });
                }
            }
            eng.decode_step_into(reqs);
        }
        outs
    };
    let serial = run(1);
    for threads in [2usize, 3, 7] {
        assert_eq!(run(threads), serial, "threads={threads} diverged bitwise");
    }
}

#[test]
fn engine_entry_matches_serial_scratch_entry_bitwise() {
    let mut rng = Rng::new(0xDEC5);
    let c = case_with(&mut rng, 3, 4, 6, 11);
    let via_engine = decode_all(&c, &SinkhornEngine::serial(), Some(2));
    let d = c.q.cols;
    let mut st = DecodeState::new(c.b, d, c.nb, 5, Some(2));
    let mut scratch = DecodeScratch::new();
    let mut via_scratch = Mat::zeros(c.total, d);
    for t in 0..c.total {
        let mut row = vec![0.0f32; d];
        st.step_into(c.q.row(t), c.k.row(t), c.v.row(t), &c.logits, &mut scratch, &mut row);
        via_scratch.row_mut(t).copy_from_slice(&row);
    }
    assert_eq!(via_engine, via_scratch);
}

#[test]
fn state_allocation_matches_memory_model() {
    for (b, d, nb, cut) in [
        (8usize, 8usize, 4usize, None),
        (64, 64, 16, None),
        (64, 64, 16, Some(2)),
        (16, 32, 8, Some(8)),
    ] {
        let st = DecodeState::new(b, d, nb, 5, cut);
        assert_eq!(
            st.f32_elems() * 4,
            decode_state_bytes(b, d, nb, cut),
            "accounting drifted at b={b} d={d} nb={nb} cut={cut:?}"
        );
        assert_eq!(st.capacity(), nb * b);
        assert!(st.is_empty());
    }
}

/// The scheduler's model-layer primitive: `decode_step_batch` over
/// staggered cohorts (sessions joining at different ticks, leaving at
/// different lengths) is bit-identical to stepping each sequence alone
/// through `decode_step` — bare, full, and SortCut stacks.
#[test]
fn stack_batched_step_is_bitwise_equal_to_serial_steps() {
    use sinkhorn::sinkhorn::{SinkhornStack, StackConfig, StackStepReq};
    let mut rng = Rng::new(0x5BA7);
    for (depth, heads, d_ff, n_cut) in
        [(1usize, 1usize, 0usize, None), (2, 2, 16, None), (2, 2, 16, Some(2))]
    {
        let cfg = StackConfig {
            seq_len: 12,
            d_model: 8,
            n_heads: heads,
            depth,
            d_ff,
            nb: 3,
            sinkhorn_iters: 4,
            causal: false,
            n_cut,
        };
        let stack = SinkhornStack::seeded(cfg, 0xBEE5, SinkhornEngine::new(3)).unwrap();
        let totals = [12usize, 7, 10]; // mixed lengths, some mid-block
        let starts = [0usize, 3, 1]; // staggered arrivals
        let rows: Vec<Mat> = totals.iter().map(|&n| rand_mat(&mut rng, n, 8)).collect();
        // serial oracle: each sequence stepped alone
        let serial: Vec<Mat> = rows
            .iter()
            .map(|x| {
                let mut st = stack.decode_state();
                let mut scratch = stack.new_decode_scratch();
                let mut out = Mat::zeros(x.rows, x.cols);
                for t in 0..x.rows {
                    stack.decode_step(&mut st, x.row(t), &mut scratch, out.row_mut(t));
                }
                out
            })
            .collect();
        // batched: whoever is live at a tick steps together
        let mut states: Vec<_> = rows.iter().map(|_| stack.decode_state()).collect();
        let mut outs: Vec<Mat> = rows.iter().map(|x| Mat::zeros(x.rows, x.cols)).collect();
        let mut scratch = stack.new_batch_scratch();
        let last_tick = starts.iter().zip(&totals).map(|(s, t)| s + t).max().unwrap();
        for tick in 0..last_tick {
            let mut reqs: Vec<StackStepReq> = Vec::new();
            for (i, (st, out)) in states.iter_mut().zip(outs.iter_mut()).enumerate() {
                if tick >= starts[i] && tick - starts[i] < totals[i] {
                    let t = tick - starts[i];
                    reqs.push(StackStepReq { st, x: rows[i].row(t), out: out.row_mut(t) });
                }
            }
            stack.decode_step_batch(reqs, &mut scratch);
        }
        for (i, (got, want)) in outs.iter().zip(&serial).enumerate() {
            assert_eq!(
                got, want,
                "depth={depth} heads={heads} cut={n_cut:?}: cohort-stepped sequence {i} \
                 drifted from serial decode_step"
            );
        }
    }
}

/// The scheduler interleaving suite (DESIGN.md §Scheduler): randomized
/// arrival/length schedules driven through the session machinery must
/// reproduce the single-request oracle bit-exactly — every emitted token
/// extends the oracle stream (checked per tick), retiring a session
/// mid-wave never perturbs survivors, and the result is invariant to the
/// slot count and the engine thread count.
#[test]
fn scheduler_interleavings_match_single_request_generate() {
    use sinkhorn::server::{FallbackConfig, FallbackModel, GenSession};
    let mut rng = Rng::new(0x5EED5);
    for trial in 0..3u64 {
        let n_req = 6 + (trial as usize % 3);
        let schedule: Vec<(Vec<i32>, usize, usize)> = (0..n_req)
            .map(|_| {
                let plen = 1 + (rng.next_u64() % 10) as usize;
                let prompt: Vec<i32> =
                    (0..plen).map(|_| (rng.next_u64() % 64) as i32).collect();
                let max_new = 1 + (rng.next_u64() % 6) as usize;
                let arrive = (rng.next_u64() % 8) as usize;
                (prompt, max_new, arrive)
            })
            .collect();
        let mut baseline: Option<Vec<Vec<i32>>> = None;
        for threads in [1usize, 3] {
            let model = FallbackModel::new(FallbackConfig {
                seq_len: 32,
                d_model: 16,
                nb: 4,
                vocab: 64,
                depth: 2,
                n_heads: 2,
                d_ff: 32,
                threads,
                ..Default::default()
            })
            .unwrap();
            let oracle: Vec<Vec<i32>> =
                schedule.iter().map(|(p, n, _)| model.generate(p, *n)).collect();
            match &baseline {
                None => baseline = Some(oracle.clone()),
                Some(b) => {
                    assert_eq!(&oracle, b, "threads={threads} changed single-request generate")
                }
            }
            for slots in [1usize, 2, 8] {
                let mut sessions: Vec<Option<GenSession>> =
                    schedule.iter().map(|_| None).collect();
                let mut finished: Vec<Option<Vec<i32>>> =
                    schedule.iter().map(|_| None).collect();
                let mut emitted: Vec<Vec<i32>> = schedule.iter().map(|_| Vec::new()).collect();
                let mut scratch = model.new_batch_scratch();
                let mut tick = 0usize;
                loop {
                    assert!(tick < 10_000, "scheduler simulation failed to converge");
                    // admission in arrival order as slots free up
                    let active_n = sessions.iter().filter(|s| s.is_some()).count();
                    let mut free = slots.saturating_sub(active_n);
                    for (i, (p, n, arrive)) in schedule.iter().enumerate() {
                        if free == 0 {
                            break;
                        }
                        if *arrive <= tick && sessions[i].is_none() && finished[i].is_none() {
                            let s = model.open_session(p, *n);
                            if s.done() {
                                finished[i] = Some(s.into_generated());
                            } else {
                                sessions[i] = Some(s);
                                free -= 1;
                            }
                        }
                    }
                    // one tick over the live cohort
                    let mut idx: Vec<usize> = Vec::new();
                    let mut live: Vec<&mut GenSession> = Vec::new();
                    for (i, s) in sessions.iter_mut().enumerate() {
                        if let Some(sess) = s.as_mut() {
                            idx.push(i);
                            live.push(sess);
                        }
                    }
                    if live.is_empty() {
                        if finished.iter().all(|f| f.is_some()) {
                            break;
                        }
                        tick += 1; // idle tick: waiting on a later arrival
                        continue;
                    }
                    let toks = model.step_sessions(&mut live, &mut scratch);
                    drop(live);
                    for (&i, e) in idx.iter().zip(&toks) {
                        if let Some(id) = e {
                            emitted[i].push(*id);
                            // per-tick stream check: every emitted token
                            // extends the single-request stream exactly
                            assert_eq!(
                                &emitted[i][..],
                                &oracle[i][..emitted[i].len()],
                                "trial {trial} threads {threads} slots {slots}: session {i}'s \
                                 stream diverged at token {}",
                                emitted[i].len() - 1
                            );
                        }
                    }
                    // retire finished sessions mid-wave; survivors keep
                    // their slots and their state untouched
                    for &i in &idx {
                        if sessions[i].as_ref().is_some_and(GenSession::done) {
                            finished[i] = Some(sessions[i].take().unwrap().into_generated());
                        }
                    }
                    tick += 1;
                }
                for (i, f) in finished.iter().enumerate() {
                    assert_eq!(
                        f.as_ref().unwrap(),
                        &oracle[i],
                        "trial {trial} threads {threads} slots {slots}: session {i} final \
                         stream diverged from single-request generate"
                    );
                }
            }
        }
    }
}

/// Reservation-based admission (DESIGN.md §Pages): a byte budget that
/// worst-case slot budgeting divides into ONE monolithic session admits
/// a cohort of short paged sessions *concurrently* — observable as a
/// retiring tick shared by more than one session — while the same budget
/// on a monolithic model serializes them. Outputs stay bit-equal to
/// single-request generate either way.
#[test]
fn paged_reservations_admit_where_worst_case_budgeting_serializes() {
    use sinkhorn::server::{BatchPolicy, FallbackConfig, FallbackModel, Server};
    let base = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, vocab: 64, ..Default::default() };
    let model = FallbackModel::new(base.clone()).unwrap();
    // budget: > one short paged session x2, < two worst-case sessions
    let budget = model.session_state_bytes() + model.session_state_bytes() / 3;
    let policy = BatchPolicy {
        mem_budget: budget,
        // wide intake window: both requests land in one gather, so the
        // concurrency observation below does not race the first tick
        max_wait: std::time::Duration::from_millis(50),
        ..Default::default()
    };
    let reqs: Vec<(Vec<i32>, usize)> = vec![(vec![3, 5], 12), (vec![7, 9], 12)];
    let run = |cfg: FallbackConfig| -> Vec<(Vec<i32>, usize)> {
        let server = Server::start_fallback(cfg, policy).unwrap();
        let handles: Vec<_> = reqs
            .iter()
            .map(|(p, n)| server.handle.generate_streaming(p.clone(), *n).unwrap())
            .collect();
        let out = handles
            .into_iter()
            .map(|(_toks, resp)| {
                let r = resp.recv().unwrap().unwrap();
                (r.gen.unwrap(), r.batch_size)
            })
            .collect();
        server.shutdown().unwrap();
        out
    };
    let paged = run(base.clone());
    let mono = run(FallbackConfig { paged: false, ..base.clone() });
    for ((p, n), ((got_p, _), (got_m, _))) in reqs.iter().zip(paged.iter().zip(&mono)) {
        let want = model.generate(p, *n);
        assert_eq!(got_p, &want, "paged reservation path diverged from generate");
        assert_eq!(got_m, &want, "monolithic path diverged from generate");
    }
    assert!(
        paged.iter().any(|(_, bs)| *bs >= 2),
        "paged reservations must run the cohort concurrently (batch sizes {:?})",
        paged.iter().map(|(_, bs)| *bs).collect::<Vec<_>>()
    );
    assert!(
        mono.iter().all(|(_, bs)| *bs == 1),
        "worst-case budgeting should serialize this cohort (batch sizes {:?})",
        mono.iter().map(|(_, bs)| *bs).collect::<Vec<_>>()
    );
}

/// The floor-1 progress guarantee survives the paged admission path: a
/// 1-byte budget (no session ever "fits") still serves a whole cohort,
/// one session at a time, each bit-equal to single-request generate.
#[test]
fn paged_one_byte_budget_floor_still_serves_a_cohort() {
    use sinkhorn::server::{BatchPolicy, FallbackConfig, FallbackModel, Server};
    let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, vocab: 64, ..Default::default() };
    let model = FallbackModel::new(cfg.clone()).unwrap();
    let policy = BatchPolicy {
        mem_budget: 1,
        max_wait: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start_fallback(cfg, policy).unwrap();
    let mut joins = Vec::new();
    for t in 0..4i32 {
        let h = server.handle.clone();
        joins.push(std::thread::spawn(move || {
            let prompt: Vec<i32> = (0..(2 + t % 3)).map(|i| i * 5 + t).collect();
            let max_new = 2 + (t as usize % 3);
            (prompt.clone(), max_new, h.generate(prompt, max_new).unwrap().gen.unwrap())
        }));
    }
    for j in joins {
        let (prompt, max_new, got) = j.join().unwrap();
        assert_eq!(got, model.generate(&prompt, max_new), "floor-1 session diverged");
    }
    server.shutdown().unwrap();
}

/// Page-pressure-aware retirement: a budget with room for ~2 reserved
/// sessions takes a 6-deep wave; the wait queue must drain as retiring
/// sessions hand their reservations back mid-wave — every request
/// completes and matches single-request generate, none ever sees the
/// busy error (the queue is deep enough to hold the overflow).
#[test]
fn wait_queue_drains_as_retiring_sessions_free_pages() {
    use sinkhorn::server::{BatchPolicy, FallbackConfig, FallbackModel, Server};
    let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, vocab: 64, ..Default::default() };
    let model = FallbackModel::new(cfg.clone()).unwrap();
    // two short paged sessions fit; the other four must wait for pages
    let budget = 2 * model.session_admission_bytes(&[1, 2, 3], 6);
    let policy = BatchPolicy {
        mem_budget: budget,
        queue_depth: 16,
        max_wait: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start_fallback(cfg, policy).unwrap();
    let reqs: Vec<(Vec<i32>, usize)> =
        (0..6).map(|t| ((0..3).map(|i| i * 7 + t).collect(), 4 + (t as usize % 3))).collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|(p, n)| server.handle.generate_streaming(p.clone(), *n).unwrap())
        .collect();
    for ((p, n), (_toks, resp)) in reqs.iter().zip(handles) {
        let r = resp.recv().unwrap().expect("queued request must drain, not go busy");
        assert_eq!(r.gen.unwrap(), model.generate(p, *n), "drained session diverged");
    }
    server.shutdown().unwrap();
}

#[test]
fn decode_state_never_allocates_scores() {
    // the state is the KV cache + constant-size sorted cache: growing the
    // capacity grows it linearly, growing the block count quadratically
    // only through the tiny (nb, nb) sort matrix
    let base = decode_state_bytes(64, 64, 16, None);
    let double_cap = decode_state_bytes(64, 64, 32, None);
    assert!(double_cap < 2 * base + 32 * 32 * 4 + 4);
    // and it undercuts one materialized (ell, ell) causal score matrix
    let ell = 16 * 64;
    assert!(base < ell * ell * 4 / 4, "state must stay far below O(ell^2) scores");
}
