//! Tiny argument parser (no `clap` in the offline crate set).
//!
//! Grammar: `sinkhorn <subcommand> [--key value]... [--flag]... [positional]...`
//! Values parse lazily and typed getters report the offending flag on error.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                    out.present.push(name.to_string());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                    out.present.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected number, got '{v}'")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        self.flags.get(key).is_some_and(|v| v != "false" && v != "0")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --steps 100 --exp lmw_tiny__vanilla --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
        assert_eq!(a.str("exp", ""), "lmw_tiny__vanilla");
        assert!(a.bool("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --table=table1 --scale=0.5");
        assert_eq!(a.str("table", ""), "table1");
        assert_eq!(a.f64("scale", 1.0).unwrap(), 0.5);
    }

    #[test]
    fn positional() {
        let a = parse("eval ckpt.bin extra");
        assert_eq!(a.positional, vec!["ckpt.bin", "extra"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x --n abc");
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
        assert!(a.usize("n", 0).is_err());
    }

    #[test]
    fn bool_false_values() {
        let a = parse("x --flag false");
        assert!(!a.bool("flag"));
        assert!(a.has("flag"));
    }
}
