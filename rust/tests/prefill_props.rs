//! Property tests for chunked block-parallel prefill (DESIGN.md §Prefill)
//! — run with no artifacts and no XLA, in every build. The contract under
//! test: ingesting a prompt through the chunked prefill path is **bitwise
//! identical** to feeding the same rows one `decode_step` at a time,
//! because the chunk entry replays the exact per-token op order of the
//! step path. Concretely:
//!
//! 1. `DecodeState::append_chunk` equals a serial `step_into` loop bit
//!    for bit — for randomized chunk schedules (size-1 chunks, block-
//!    aligned chunks, chunks crossing block boundaries, partial tails)
//!    and exhaustively for every two-chunk split point of a sequence,
//!    full-causal and every SortCut width, outputs *and* the sorted
//!    gather cache;
//! 2. a paged state fed the same chunks is bitwise identical to its
//!    monolithic twin after every chunk (DESIGN.md §Pages);
//! 3. the depth-L `SinkhornStack::prefill` matches token-by-token
//!    `decode_step` bitwise, and decode steps *continued after* a chunked
//!    prefill still match — the handed-over state is indistinguishable;
//! 4. chunked prefill is bit-identical across engine thread counts, and
//!    the batched entry equals per-sequence calls;
//! 5. SortCut freezes the same cut through both paths: the cut caches
//!    match bitwise after ingestion and never diverge afterwards;
//! 6. the serving layer: two concurrent `open_session`s on disjoint
//!    prompts both make progress (the prefix-cache lock is no longer held
//!    across prefill), and a long-prompt session admitted mid-stream is
//!    absorbed in budgeted chunks without stalling an active session's
//!    token cadence — one token per tick, streams equal to `generate`
//!    (DESIGN.md §Scheduler, §Prefill).

use sinkhorn::server::{BatchPolicy, FallbackConfig, FallbackModel, GenSession, Server};
use sinkhorn::sinkhorn::{
    DecodeScratch, DecodeState, Mat, PagePool, SinkhornEngine, SinkhornStack, StackConfig,
};
use sinkhorn::util::prop::{forall, Gen};
use sinkhorn::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
}

/// Split `total` tokens into a randomized chunk schedule that mixes the
/// interesting shapes: single tokens, exactly one block, block-crossing
/// chunks, and whatever ragged tail is left.
fn chunk_schedule(g: &mut Gen, total: usize, b: usize) -> Vec<usize> {
    let mut left = total;
    let mut out = Vec::new();
    while left > 0 {
        let n = match g.usize(0, 4) {
            0 => 1,
            1 => b,
            2 => b + 1,
            _ => 1 + g.usize(0, (2 * b).min(left)),
        };
        let n = n.min(left).max(1);
        out.push(n);
        left -= n;
    }
    out
}

// ---------------------------------------------------------------------------
// DecodeState level: append_chunk vs the serial step loop
// ---------------------------------------------------------------------------

struct Case {
    q: Mat,
    k: Mat,
    v: Mat,
    logits: Mat,
    b: usize,
    nb: usize,
    /// ingested length; may end mid-block
    total: usize,
    chunks: Vec<usize>,
    n_cut: Option<usize>,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case(b={}, nb={}, d={}, total={}, chunks={:?}, cut={:?})",
            self.b, self.nb, self.q.cols, self.total, self.chunks, self.n_cut
        )
    }
}

fn gen_case(g: &mut Gen) -> Case {
    let nb = 2 + g.usize(0, 4);
    let b = 2 + g.usize(0, 5);
    let d = 4 + g.usize(0, 8);
    let ell = nb * b;
    // half the cases stop mid-block to cover partial tails
    let total = if g.usize(0, 2) == 0 { ell } else { ell - g.usize(1, b) };
    let chunks = chunk_schedule(g, total, b);
    let n_cut = if g.usize(0, 3) == 0 { Some(1 + g.usize(0, nb - 1)) } else { None };
    let mut rng = Rng::new(g.rng.next_u64());
    Case {
        q: rand_mat(&mut rng, ell, d),
        k: rand_mat(&mut rng, ell, d),
        v: rand_mat(&mut rng, ell, d),
        logits: rand_mat(&mut rng, nb, nb),
        b,
        nb,
        total,
        chunks,
        n_cut,
    }
}

/// Serial oracle: one `step_into` per token; returns the stacked per-step
/// outputs and leaves `st` at `total` tokens.
fn step_all(c: &Case, st: &mut DecodeState) -> Mat {
    let d = c.q.cols;
    let mut scratch = DecodeScratch::new();
    let mut out = Mat::zeros(c.total, d);
    for t in 0..c.total {
        let mut row = vec![0.0f32; d];
        st.step_into(c.q.row(t), c.k.row(t), c.v.row(t), &c.logits, &mut scratch, &mut row);
        out.row_mut(t).copy_from_slice(&row);
    }
    out
}

/// Chunked path: drive `st` through `append_chunk` following `chunks`;
/// returns the stacked outputs.
fn chunk_all(c: &Case, st: &mut DecodeState) -> Mat {
    let d = c.q.cols;
    let mut scratch = DecodeScratch::new();
    let mut out = Mat::zeros(c.total, d);
    let mut t = 0usize;
    for &n in &c.chunks {
        let rows = t * d..(t + n) * d;
        let mut rows_out = vec![0.0f32; n * d];
        st.append_chunk(
            &c.q.data[rows.clone()],
            &c.k.data[rows.clone()],
            &c.v.data[rows],
            &c.logits,
            &mut scratch,
            &mut rows_out,
        );
        out.data[t * d..(t + n) * d].copy_from_slice(&rows_out);
        t += n;
    }
    assert_eq!(t, c.total);
    out
}

#[test]
fn append_chunk_matches_serial_steps_bitwise() {
    forall(24, 0x9F11, gen_case, |c| {
        let d = c.q.cols;
        let mut st_serial = DecodeState::new(c.b, d, c.nb, 5, c.n_cut);
        let want = step_all(c, &mut st_serial);
        let mut st_chunk = DecodeState::new(c.b, d, c.nb, 5, c.n_cut);
        let got = chunk_all(c, &mut st_chunk);
        for t in 0..c.total {
            if got.row(t) != want.row(t) {
                return Err(format!("chunked output diverged at token {t}"));
            }
        }
        // the states themselves must be indistinguishable: the sorted
        // gather cache (which pins the SortCut cut) matches bitwise...
        if st_chunk.sorted_cache() != st_serial.sorted_cache() {
            return Err("sorted-gather caches diverged after ingestion".into());
        }
        // ...and further serial steps from either state stay bit-equal
        if c.total < c.nb * c.b {
            let mut scratch = DecodeScratch::new();
            let (mut a, mut b) = (vec![0.0f32; d], vec![0.0f32; d]);
            let t = c.total;
            st_serial.step_into(c.q.row(t), c.k.row(t), c.v.row(t), &c.logits, &mut scratch, &mut a);
            st_chunk.step_into(c.q.row(t), c.k.row(t), c.v.row(t), &c.logits, &mut scratch, &mut b);
            if a != b {
                return Err("post-prefill decode step diverged".into());
            }
        }
        Ok(())
    });
}

/// Exhaustive two-chunk splits: every split point of a fixed sequence —
/// every block boundary and every mid-block tail — through one
/// `append_chunk` pair, against the serial oracle, full-causal and cut.
#[test]
fn append_chunk_bitwise_at_every_split_point() {
    let (nb, b, d) = (3usize, 4usize, 6usize);
    let total = nb * b;
    let mut rng = Rng::new(0x9F22);
    for n_cut in [None, Some(1), Some(2)] {
        let base = Case {
            q: rand_mat(&mut rng, total, d),
            k: rand_mat(&mut rng, total, d),
            v: rand_mat(&mut rng, total, d),
            logits: rand_mat(&mut rng, nb, nb),
            b,
            nb,
            total,
            chunks: vec![],
            n_cut,
        };
        let mut st = DecodeState::new(b, d, nb, 5, n_cut);
        let want = step_all(&base, &mut st);
        let want_cache = st.sorted_cache();
        let (wsk, wsv) = (want_cache.0.to_vec(), want_cache.1.to_vec());
        for split in 1..total {
            let c = Case { chunks: vec![split, total - split], ..clone_case(&base) };
            let mut st = DecodeState::new(b, d, nb, 5, n_cut);
            let got = chunk_all(&c, &mut st);
            assert_eq!(
                got.data, want.data,
                "split at {split} (cut={n_cut:?}) diverged from the serial oracle"
            );
            let (sk, sv) = st.sorted_cache();
            assert_eq!((sk, sv), (&wsk[..], &wsv[..]), "cache diverged at split {split}");
        }
    }
}

fn clone_case(c: &Case) -> Case {
    Case {
        q: c.q.clone(),
        k: c.k.clone(),
        v: c.v.clone(),
        logits: c.logits.clone(),
        b: c.b,
        nb: c.nb,
        total: c.total,
        chunks: c.chunks.clone(),
        n_cut: c.n_cut,
    }
}

/// Paged == mono per chunk: after every `append_chunk`, the paged state's
/// outputs and sorted cache are bitwise equal to the monolithic twin's.
#[test]
fn paged_equals_mono_per_chunk() {
    forall(20, 0x9F33, gen_case, |c| {
        let d = c.q.cols;
        for bpp in [1usize, 2] {
            let pool = PagePool::new();
            let mut mono = DecodeState::new(c.b, d, c.nb, 5, c.n_cut);
            let mut paged = DecodeState::new_paged(c.b, d, c.nb, 5, c.n_cut, &pool, bpp);
            let mut scratch = DecodeScratch::new();
            let mut t = 0usize;
            for &n in &c.chunks {
                let rows = t * d..(t + n) * d;
                let mut out_m = vec![0.0f32; n * d];
                let mut out_p = vec![0.0f32; n * d];
                mono.append_chunk(
                    &c.q.data[rows.clone()],
                    &c.k.data[rows.clone()],
                    &c.v.data[rows.clone()],
                    &c.logits,
                    &mut scratch,
                    &mut out_m,
                );
                paged.append_chunk(
                    &c.q.data[rows.clone()],
                    &c.k.data[rows.clone()],
                    &c.v.data[rows],
                    &c.logits,
                    &mut scratch,
                    &mut out_p,
                );
                if out_m != out_p {
                    return Err(format!("paged chunk at t={t} (bpp={bpp}) diverged"));
                }
                if mono.sorted_cache() != paged.sorted_cache() {
                    return Err(format!("paged cache at t={t} (bpp={bpp}) diverged"));
                }
                t += n;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Stack level: SinkhornStack::prefill vs token-by-token decode_step
// ---------------------------------------------------------------------------

fn stack_cfg(nb: usize, b: usize, heads: usize, d_head: usize, depth: usize, d_ff: usize) -> StackConfig {
    StackConfig {
        seq_len: nb * b,
        d_model: heads * d_head,
        n_heads: heads,
        depth,
        d_ff,
        nb,
        sinkhorn_iters: 5,
        causal: false,
        n_cut: None,
    }
}

struct StackCase {
    cfg: StackConfig,
    x: Mat,
    total: usize,
    chunks: Vec<usize>,
    seed: u64,
}

impl std::fmt::Debug for StackCase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let c = &self.cfg;
        write!(
            f,
            "StackCase(nb={}, b={}, d={}, heads={}, depth={}, d_ff={}, cut={:?}, total={}, chunks={:?})",
            c.nb,
            c.block_rows(),
            c.d_model,
            c.n_heads,
            c.depth,
            c.d_ff,
            c.n_cut,
            self.total,
            self.chunks
        )
    }
}

fn gen_stack_case(g: &mut Gen) -> StackCase {
    let nb = 2 + g.usize(0, 3);
    let b = 2 + g.usize(0, 4);
    let heads = 1 + g.usize(0, 2);
    let d_head = 2 + g.usize(0, 5);
    let depth = 1 + g.usize(0, 2);
    let d_ff = if g.usize(0, 2) == 0 { 0 } else { heads * d_head * 2 + 1 };
    let mut cfg = stack_cfg(nb, b, heads, d_head, depth, d_ff);
    if g.usize(0, 3) == 0 {
        cfg.n_cut = Some(1 + g.usize(0, nb - 1));
    }
    let ell = cfg.seq_len;
    // leave headroom so decode can continue after the prefill
    let total = ell - 1 - g.usize(0, b.min(ell - 1));
    let chunks = chunk_schedule(g, total, b);
    let mut rng = Rng::new(g.rng.next_u64());
    let x = rand_mat(&mut rng, ell, cfg.d_model);
    StackCase { cfg, x, total, chunks, seed: rng.next_u64() }
}

#[test]
fn stack_prefill_matches_token_by_token_decode() {
    forall(20, 0x9F44, gen_stack_case, |c| {
        let stack =
            SinkhornStack::seeded(c.cfg.clone(), c.seed, SinkhornEngine::serial()).unwrap();
        let d = c.cfg.d_model;
        // oracle: one decode_step per token
        let mut st_step = stack.decode_state();
        let mut dsc = stack.new_decode_scratch();
        let mut want = Mat::zeros(c.total, d);
        for t in 0..c.total {
            let mut row = vec![0.0f32; d];
            stack.decode_step(&mut st_step, c.x.row(t), &mut dsc, &mut row);
            want.row_mut(t).copy_from_slice(&row);
        }
        // chunked prefill over the same rows
        let mut st_pre = stack.decode_state();
        let mut psc = stack.new_prefill_scratch();
        let mut got = Mat::zeros(c.total, d);
        let mut t = 0usize;
        for &n in &c.chunks {
            let mut rows_out = vec![0.0f32; n * d];
            stack.prefill(&mut st_pre, &c.x.data[t * d..(t + n) * d], &mut psc, Some(&mut rows_out[..]));
            got.data[t * d..(t + n) * d].copy_from_slice(&rows_out);
            t += n;
        }
        if got.data != want.data {
            let t = (0..c.total).find(|&t| got.row(t) != want.row(t)).unwrap();
            return Err(format!("prefill diverged from decode_step at token {t}"));
        }
        // the handed-over state is indistinguishable: continued decode
        // steps from both states stay bitwise equal (this also pins the
        // SortCut cut — a differently-frozen cut would diverge here)
        for t in c.total..c.cfg.seq_len {
            let (mut a, mut b) = (vec![0.0f32; d], vec![0.0f32; d]);
            stack.decode_step(&mut st_step, c.x.row(t), &mut dsc, &mut a);
            stack.decode_step(&mut st_pre, c.x.row(t), &mut dsc, &mut b);
            if a != b {
                return Err(format!("post-prefill decode diverged at token {t}"));
            }
        }
        Ok(())
    });
}

/// Chunked prefill is bitwise invariant to engine thread count, and the
/// batched entry (several sessions per call) equals per-sequence calls.
#[test]
fn stack_prefill_thread_count_and_batch_invariance() {
    forall(12, 0x9F55, gen_stack_case, |c| {
        let d = c.cfg.d_model;
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for threads in [1usize, 4] {
            let stack =
                SinkhornStack::seeded(c.cfg.clone(), c.seed, SinkhornEngine::new(threads)).unwrap();
            let mut st = stack.decode_state();
            let mut psc = stack.new_prefill_scratch();
            let mut got = vec![0.0f32; c.total * d];
            let mut t = 0usize;
            for &n in &c.chunks {
                let mut rows_out = vec![0.0f32; n * d];
                stack.prefill(&mut st, &c.x.data[t * d..(t + n) * d], &mut psc, Some(&mut rows_out[..]));
                got[t * d..(t + n) * d].copy_from_slice(&rows_out);
                t += n;
            }
            outs.push(got);
        }
        if outs[0] != outs[1] {
            return Err("prefill is not bit-identical across thread counts".into());
        }
        // batched: two independent sessions prefilled in one call must
        // equal the single-session path for each
        let stack =
            SinkhornStack::seeded(c.cfg.clone(), c.seed, SinkhornEngine::new(2)).unwrap();
        let mut psc = stack.new_prefill_scratch();
        let (mut st_a, mut st_b) = (stack.decode_state(), stack.decode_state());
        let (mut out_a, mut out_b) =
            (vec![0.0f32; c.total * d], vec![0.0f32; c.total * d]);
        let mut t = 0usize;
        for &n in &c.chunks {
            use sinkhorn::sinkhorn::StackPrefillReq;
            let xs = &c.x.data[t * d..(t + n) * d];
            let (a, b) = (&mut out_a[t * d..(t + n) * d], &mut out_b[t * d..(t + n) * d]);
            stack.prefill_batch(
                vec![
                    StackPrefillReq { st: &mut st_a, xs, out: Some(a) },
                    StackPrefillReq { st: &mut st_b, xs, out: Some(b) },
                ],
                &mut psc,
            );
            t += n;
        }
        if out_a != outs[0] || out_b != outs[0] {
            return Err("batched prefill diverged from the single-session path".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Serving layer: concurrent opens, scheduler interleaving
// ---------------------------------------------------------------------------

fn serve_cfg() -> FallbackConfig {
    FallbackConfig {
        seq_len: 32,
        d_model: 16,
        nb: 4,
        vocab: 64,
        depth: 2,
        n_heads: 2,
        d_ff: 32,
        ..Default::default()
    }
}

/// Two concurrent `open_session`s on *disjoint* prompts both make
/// progress: the prefix-cache lock is held only for the match and the
/// insert, never across the chunked prefill itself
/// (`fallback.rs::session_state_for`). Each stream still equals the
/// single-request oracle.
#[test]
fn concurrent_opens_of_disjoint_prompts_both_progress() {
    let m = FallbackModel::new(serve_cfg()).unwrap();
    let max_new = 4;
    // disjoint prompts long enough that the prefix-cache fill runs the
    // chunked path across block boundaries (b = 8 here)
    let prompts: Vec<Vec<i32>> = vec![
        (0..20).map(|i| (i * 3 + 1) % 64).collect(),
        (0..20).map(|i| (i * 5 + 2) % 64).collect(),
    ];
    let want: Vec<Vec<i32>> = prompts.iter().map(|p| m.generate(p, max_new)).collect();
    let barrier = std::sync::Barrier::new(prompts.len());
    std::thread::scope(|s| {
        let handles: Vec<_> = prompts
            .iter()
            .zip(&want)
            .map(|(p, w)| {
                let (m, barrier) = (&m, &barrier);
                s.spawn(move || {
                    barrier.wait();
                    let mut sess = m.open_session(p, max_new);
                    let mut scratch = m.new_batch_scratch();
                    while !sess.done() {
                        m.step_sessions(&mut [&mut sess], &mut scratch);
                    }
                    assert_eq!(sess.generated(), &w[..], "concurrent open changed the stream");
                })
            })
            .collect();
        for h in handles {
            h.join().expect("an open_session thread failed to make progress");
        }
    });
}

/// Deterministic scheduler interleave at the model level: while a
/// long-prompt session is absorbed chunk by chunk, an already-active
/// session emits exactly one token per tick — its cadence never stalls —
/// and both final streams equal the single-request oracle. Prefill takes
/// exactly `ceil(remaining / budget)` chunks of at most `budget` tokens.
#[test]
fn prefill_interleave_preserves_active_cadence() {
    let cfg = FallbackConfig { prefix_share: false, ..serve_cfg() };
    let m = FallbackModel::new(cfg).unwrap();
    let budget = 5usize;
    let short: Vec<i32> = (0..4).map(|i| i * 7 + 3).collect();
    let long: Vec<i32> = (0..24).map(|i| (i * 11 + 1) % 64).collect();
    let (want_short, want_long) = (m.generate(&short, 8), m.generate(&long, 3));

    let mut a = m.open_session(&short, 8);
    let mut scratch = m.new_batch_scratch();
    let mut psc = m.new_prefill_scratch();
    // A is mid-stream when B arrives: tick it past its own prompt
    while a.generated().is_empty() {
        m.step_sessions(&mut [&mut a], &mut scratch);
    }

    let mut b = m.open_session(&long, 3);
    let remaining = b.prefill_remaining();
    assert!(remaining > 2 * budget, "long prompt must need several chunks (got {remaining})");
    let mut chunks = 0usize;
    while b.prefill_remaining() > 0 {
        let n = m.prefill_session(&mut b, budget, &mut psc);
        assert!(0 < n && n <= budget, "chunk of {n} tokens exceeds the budget {budget}");
        chunks += 1;
        // the active session ticks between chunks and never misses a beat
        let before = a.generated().len();
        m.step_sessions(&mut [&mut a], &mut scratch);
        assert_eq!(a.generated().len(), before + 1, "active cadence stalled during prefill");
    }
    assert_eq!(chunks, remaining.div_ceil(budget), "prefill chunk count off");
    assert_eq!(b.committed(), long.len() - 1, "prefill must stop one short of the prompt");
    assert!(b.generated().is_empty(), "prefill must not emit tokens");
    while !a.done() || !b.done() {
        let mut live: Vec<&mut GenSession> =
            [&mut a, &mut b].into_iter().filter(|s| !s.done()).collect();
        m.step_sessions(&mut live, &mut scratch);
    }
    assert_eq!(a.generated(), &want_short[..], "active session's stream changed");
    assert_eq!(b.generated(), &want_long[..], "prefilled session's stream changed");
}

/// End to end through the continuous scheduler: with a chunk budget set,
/// a long-prompt generation admitted while another streams is absorbed in
/// chunks (`service.rs` phase 6) and both replies are bit-equal to the
/// single-request oracle; token events stay in order.
#[test]
fn scheduler_chunked_prefill_streams_bit_identical() {
    let cfg = serve_cfg();
    let model = FallbackModel::new(cfg.clone()).unwrap();
    let short: Vec<i32> = (0..4).map(|i| i * 7 + 3).collect();
    let long: Vec<i32> = (0..24).map(|i| (i * 11 + 1) % 64).collect();
    let (want_short, want_long) = (model.generate(&short, 8), model.generate(&long, 4));
    let policy = BatchPolicy { prefill_chunk_tokens: 5, ..Default::default() };
    let server = Server::start_fallback(cfg, policy).unwrap();
    let (toks_a, reply_a) = server.handle.generate_streaming(short, 8).unwrap();
    // first token read: A is active before B is admitted
    let first = toks_a.recv().expect("active session must stream");
    assert_eq!(first.0, 0);
    let (toks_b, reply_b) = server.handle.generate_streaming(long, 4).unwrap();
    let mut got_a = vec![first.1];
    for (i, id) in toks_a.iter() {
        assert_eq!(i, got_a.len(), "tok indices must stream in order");
        got_a.push(id);
    }
    let got_b: Vec<i32> = toks_b.iter().map(|(_, id)| id).collect();
    assert_eq!(got_a, want_short, "chunked-prefill stream diverged from the oracle");
    assert_eq!(got_b, want_long, "long-prompt stream diverged from the oracle");
    assert_eq!(reply_a.recv().unwrap().unwrap().gen.unwrap(), want_short);
    assert_eq!(reply_b.recv().unwrap().unwrap().gen.unwrap(), want_long);
    server.shutdown().unwrap();
}
