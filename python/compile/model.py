"""L2 models: decoder-only LM, encoder classifier, seq2seq transducer.

Each model is a pure function ``(params, batch..., key) -> logits`` built
from ``attention.multihead_attention`` with the variant chosen in the
config, mirroring the paper's tasks:

  - ``lm_logits``        : language modeling / pixel generation (§5.2, §5.3)
  - ``classifier_logits``: document classification / NLI (§5.4, SortCut)
  - ``seq2seq_logits``   : algorithmic sorting (§5.1)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention, layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn": attention.attention_init(k1, cfg),
        "ffn": layers.ffn_init(k2, cfg["d_model"], cfg["d_ff"]),
        "ln1": layers.layernorm_init(cfg["d_model"]),
        "ln2": layers.layernorm_init(cfg["d_model"]),
    }


def _xlayer_init(key, cfg):
    """Decoder layer with cross attention (seq2seq)."""
    k1, k2, k3 = jax.random.split(key, 3)
    vcfg = dict(cfg, variant="vanilla")  # cross-attention stays dense
    return {
        "attn": attention.attention_init(k1, cfg),
        "xattn": attention.attention_init(k2, vcfg),
        "ffn": layers.ffn_init(k3, cfg["d_model"], cfg["d_ff"]),
        "ln1": layers.layernorm_init(cfg["d_model"]),
        "lnx": layers.layernorm_init(cfg["d_model"]),
        "ln2": layers.layernorm_init(cfg["d_model"]),
    }


def lm_init(key, cfg):
    keys = jax.random.split(key, cfg["n_layers"] + 2)
    return {
        "embed": layers.embedding_init(keys[0], cfg["vocab"], cfg["d_model"]),
        "layers": [_layer_init(keys[i + 1], cfg) for i in range(cfg["n_layers"])],
        "ln_f": layers.layernorm_init(cfg["d_model"]),
        "head": layers.dense_init(keys[-1], cfg["d_model"], cfg["vocab"]),
    }


def classifier_init(key, cfg):
    keys = jax.random.split(key, cfg["n_layers"] + 2)
    return {
        "embed": layers.embedding_init(keys[0], cfg["vocab"], cfg["d_model"]),
        "layers": [_layer_init(keys[i + 1], cfg) for i in range(cfg["n_layers"])],
        "ln_f": layers.layernorm_init(cfg["d_model"]),
        "head": layers.dense_init(keys[-1], cfg["d_model"], cfg["n_classes"]),
    }


def seq2seq_init(key, cfg):
    n = cfg["n_layers"]
    keys = jax.random.split(key, 2 * n + 3)
    return {
        "embed": layers.embedding_init(keys[0], cfg["vocab"], cfg["d_model"]),
        "enc": [_layer_init(keys[1 + i], cfg) for i in range(n)],
        "dec": [_xlayer_init(keys[1 + n + i], cfg) for i in range(n)],
        "ln_e": layers.layernorm_init(cfg["d_model"]),
        "ln_d": layers.layernorm_init(cfg["d_model"]),
        "head": layers.dense_init(keys[-1], cfg["d_model"], cfg["vocab"]),
    }


# ---------------------------------------------------------------------------
# forward passes (pre-norm residual blocks)
# ---------------------------------------------------------------------------


def _run_layer(p, x, cfg, *, causal, key):
    x = x + attention.multihead_attention(p["attn"], layers.layernorm(p["ln1"], x), cfg, causal=causal, key=key)
    x = x + layers.ffn(p["ffn"], layers.layernorm(p["ln2"], x))
    return x


def _embed_seq(params, tokens, cfg):
    ell = tokens.shape[1]
    x = layers.embed(params["embed"], tokens)
    return x + layers.sinusoid_positions(ell, cfg["d_model"])[None]


def lm_logits(params, tokens, cfg, key=None):
    """Causal LM: tokens (B, ell) int32 -> logits (B, ell, vocab)."""
    x = _embed_seq(params, tokens, cfg)
    for i, p in enumerate(params["layers"]):
        k = None if key is None else jax.random.fold_in(key, i)
        x = _run_layer(p, x, cfg, causal=True, key=k)
    return layers.dense(params["head"], layers.layernorm(params["ln_f"], x))


def classifier_logits(params, tokens, cfg, key=None):
    """Encoder classifier: tokens (B, ell) -> class logits (B, n_classes)."""
    x = _embed_seq(params, tokens, cfg)
    for i, p in enumerate(params["layers"]):
        k = None if key is None else jax.random.fold_in(key, i)
        x = _run_layer(p, x, cfg, causal=False, key=k)
    x = layers.layernorm(params["ln_f"], x).mean(axis=1)
    return layers.dense(params["head"], x)


def _cross_attend(p, x, mem, cfg):
    """Standard dense cross-attention (queries x, keys/values mem)."""
    nh = cfg["n_heads"]
    q = attention._split_heads(layers.dense(p["q"], x), nh)
    k = attention._split_heads(layers.dense(p["k"], mem), nh)
    v = attention._split_heads(layers.dense(p["v"], mem), nh)
    y = attention._dense_heads(q, k, v)
    return layers.dense(p["o"], attention._merge_heads(y))


def seq2seq_logits(params, src, tgt_in, cfg, key=None):
    """Encoder-decoder: src (B, ls), tgt_in (B, lt) -> logits (B, lt, vocab)."""
    mem = _embed_seq(params, src, cfg)
    for i, p in enumerate(params["enc"]):
        k = None if key is None else jax.random.fold_in(key, i)
        mem = _run_layer(p, mem, cfg, causal=False, key=k)
    mem = layers.layernorm(params["ln_e"], mem)

    x = _embed_seq(params, tgt_in, cfg)
    for i, p in enumerate(params["dec"]):
        k = None if key is None else jax.random.fold_in(key, 100 + i)
        x = x + attention.multihead_attention(p["attn"], layers.layernorm(p["ln1"], x), cfg, causal=True, key=k)
        x = x + _cross_attend(p["xattn"], layers.layernorm(p["lnx"], x), mem, cfg)
        x = x + layers.ffn(p["ffn"], layers.layernorm(p["ln2"], x))
    return layers.dense(params["head"], layers.layernorm(params["ln_d"], x))
