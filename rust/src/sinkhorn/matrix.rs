//! Small dense row-major f32 matrices for the pure-Rust reference
//! implementation of Sparse Sinkhorn Attention (no BLAS offline; sizes
//! here are tiny — nb x nb sort matrices and b x d tiles), plus the
//! zero-copy strided views ([`MatView`]/[`MatViewMut`]) and register-tiled
//! write-into microkernels that back the allocation-free blocked engine
//! (`sinkhorn::engine`, DESIGN.md §Engine, §Microkernels). The views
//! follow the same row-major shape+stride conventions as
//! `runtime::tensor::HostTensor` (which bridges into them via
//! `HostTensor::mat_view`).
//!
//! **Numerics contract:** the owning `Mat` methods (`matmul`, `matmul_t`,
//! `softmax_rows`) are the naive oracle — single accumulator, obvious
//! order. The `*_into` microkernels split the contraction over
//! [`LANES`]-wide partial accumulators so LLVM autovectorizes them on
//! stable Rust, which reorders float summation: their results are
//! *epsilon-equal* (a few ULPs) to the oracle, not bit-identical. The
//! engine's property tests (`tests/engine_props.rs`) bound the end-to-end
//! divergence at 1e-5 max-abs; the tests below bound each kernel.

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// C = A @ B.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// C = A @ B^T.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t dims");
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            for j in 0..other.rows {
                let mut acc = 0.0;
                for k in 0..self.cols {
                    acc += self[(i, k)] * other[(j, k)];
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row-wise softmax in place.
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let r = self.row_mut(i);
            let m = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in r.iter_mut() {
                *x = (*x - m).exp();
                sum += *x;
            }
            for x in r.iter_mut() {
                *x /= sum;
            }
        }
    }

    /// Largest element-wise |a - b|. NaN anywhere poisons the result to
    /// NaN (instead of being silently dropped by `f32::max`), so
    /// tolerance gates like `diff <= TOL` fail on NaN outputs — the
    /// engine's epsilon gates rely on this.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, |acc, d| if d > acc || d.is_nan() { d } else { acc })
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

// --- zero-copy strided views ------------------------------------------------

/// Immutable view of a row-major `(rows, cols)` region inside a shared
/// buffer; `row_stride >= cols` lets a view select a column band (e.g. the
/// sorted half of a `(b, 2b)` logits tile).
#[derive(Debug, Clone, Copy)]
pub struct MatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub row_stride: usize,
    data: &'a [f32],
}

impl<'a> MatView<'a> {
    pub fn new(data: &'a [f32], rows: usize, cols: usize, row_stride: usize) -> Self {
        assert!(cols <= row_stride, "cols {cols} > row_stride {row_stride}");
        assert!(
            rows == 0 || (rows - 1) * row_stride + cols <= data.len(),
            "view {rows}x{cols} (stride {row_stride}) exceeds buffer of {}",
            data.len()
        );
        MatView { rows, cols, row_stride, data }
    }

    /// Contiguous view over a whole buffer.
    pub fn contiguous(data: &'a [f32], rows: usize, cols: usize) -> Self {
        Self::new(data, rows, cols, cols)
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.row_stride + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }

    /// Sub-view of rows `[r0, r0 + n)` — how the streaming engine carves
    /// key/value tiles out of a segment. Contiguous views only.
    pub fn row_range(&self, r0: usize, n: usize) -> MatView<'a> {
        assert_eq!(self.row_stride, self.cols, "row_range needs a contiguous view");
        assert!(r0 + n <= self.rows, "row range {r0}+{n} > {}", self.rows);
        MatView::contiguous(&self.data[r0 * self.cols..(r0 + n) * self.cols], n, self.cols)
    }

    /// Materialize into an owning `Mat` (test/debug helper).
    pub fn to_mat(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self.at(i, j))
    }
}

/// Mutable strided view (same layout rules as [`MatView`]).
#[derive(Debug)]
pub struct MatViewMut<'a> {
    pub rows: usize,
    pub cols: usize,
    pub row_stride: usize,
    data: &'a mut [f32],
}

impl<'a> MatViewMut<'a> {
    pub fn new(data: &'a mut [f32], rows: usize, cols: usize, row_stride: usize) -> Self {
        assert!(cols <= row_stride, "cols {cols} > row_stride {row_stride}");
        assert!(
            rows == 0 || (rows - 1) * row_stride + cols <= data.len(),
            "view {rows}x{cols} (stride {row_stride}) exceeds buffer of {}",
            data.len()
        );
        MatViewMut { rows, cols, row_stride, data }
    }

    pub fn contiguous(data: &'a mut [f32], rows: usize, cols: usize) -> Self {
        Self::new(data, rows, cols, cols)
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.row_stride + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, x: f32) {
        self.data[i * self.row_stride + j] = x;
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }

    /// Reborrow as an immutable view.
    pub fn as_view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, row_stride: self.row_stride, data: &*self.data }
    }

    pub fn fill(&mut self, x: f32) {
        for i in 0..self.rows {
            self.row_mut(i).fill(x);
        }
    }
}

impl Mat {
    pub fn view(&self) -> MatView<'_> {
        MatView::contiguous(&self.data, self.rows, self.cols)
    }

    pub fn view_mut(&mut self) -> MatViewMut<'_> {
        MatViewMut::contiguous(&mut self.data, self.rows, self.cols)
    }

    /// Zero-copy view of a contiguous row range `[r0, r0 + rows)`.
    pub fn row_block(&self, r0: usize, rows: usize) -> MatView<'_> {
        assert!(r0 + rows <= self.rows, "row block {r0}+{rows} > {}", self.rows);
        MatView::contiguous(&self.data[r0 * self.cols..(r0 + rows) * self.cols], rows, self.cols)
    }
}

// --- register-tiled write-into microkernels (DESIGN.md §Microkernels) -------
//
// A plain `acc += a * b` reduction loop is a serial FP dependency chain:
// LLVM must preserve the summation order and leaves it scalar. The kernels
// below keep LANES independent partial accumulators (one SIMD register's
// worth of f32) and unroll rows so each loaded operand is reused from
// registers; a scalar tail handles shapes not divisible by the tile
// widths. Stable Rust only — no `std::simd`.

/// Rows of `a` processed per [`matmul_t_scaled_into`] microkernel tile
/// (each loaded `b` row is reused `MT_TILE_I` times from registers).
const MT_TILE_I: usize = 4;
/// Contraction unroll width: 8 f32 lanes = one 256-bit vector register.
pub const LANES: usize = 8;

/// Fold `LANES` partial accumulators into one sum (fixed lane order).
#[inline]
fn hsum(acc: &[f32; LANES]) -> f32 {
    acc.iter().sum()
}

/// Dot product with `LANES` independent accumulators + scalar tail.
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xv, yv) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            acc[l] += xv[l] * yv[l];
        }
    }
    let mut s = hsum(&acc);
    for (a, b) in xc.remainder().iter().zip(yc.remainder()) {
        s += a * b;
    }
    s
}

/// Four simultaneous dot products against one shared `y` row — the
/// [`matmul_t_scaled_into`] microkernel body. The 4 x `LANES` f32
/// accumulators stay resident in registers.
#[inline]
fn dot4(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], y: &[f32]) -> [f32; 4] {
    let n = y.len();
    let mut acc = [[0.0f32; LANES]; 4];
    let mut k = 0;
    while k + LANES <= n {
        let yv = &y[k..k + LANES];
        let (v0, v1) = (&x0[k..k + LANES], &x1[k..k + LANES]);
        let (v2, v3) = (&x2[k..k + LANES], &x3[k..k + LANES]);
        for l in 0..LANES {
            acc[0][l] += v0[l] * yv[l];
            acc[1][l] += v1[l] * yv[l];
            acc[2][l] += v2[l] * yv[l];
            acc[3][l] += v3[l] * yv[l];
        }
        k += LANES;
    }
    let mut s = [hsum(&acc[0]), hsum(&acc[1]), hsum(&acc[2]), hsum(&acc[3])];
    while k < n {
        s[0] += x0[k] * y[k];
        s[1] += x1[k] * y[k];
        s[2] += x2[k] * y[k];
        s[3] += x3[k] * y[k];
        k += 1;
    }
    s
}

/// `out = (a @ b^T) * scale`, written into a preallocated view.
///
/// Register-tiled: `MT_TILE_I` (4) rows of `a` against each row of `b`,
/// the contraction unrolled [`LANES`] wide, with scalar tails for leftover
/// rows and the non-multiple k remainder — any shape is accepted.
/// Epsilon-, not bit-equal to `a.matmul_t(b)` + `scale()` (split
/// accumulators reorder the summation).
pub fn matmul_t_scaled_into(a: &MatView, b: &MatView, scale: f32, out: &mut MatViewMut) {
    assert_eq!(a.cols, b.cols, "matmul_t dims");
    assert_eq!((out.rows, out.cols), (a.rows, b.rows), "out dims");
    let mut i = 0;
    while i + MT_TILE_I <= a.rows {
        let (x0, x1) = (a.row(i), a.row(i + 1));
        let (x2, x3) = (a.row(i + 2), a.row(i + 3));
        for j in 0..b.rows {
            let s = dot4(x0, x1, x2, x3, b.row(j));
            for (ti, sv) in s.iter().enumerate() {
                out.set(i + ti, j, sv * scale);
            }
        }
        i += MT_TILE_I;
    }
    while i < a.rows {
        let xr = a.row(i);
        for j in 0..b.rows {
            out.set(i, j, dot(xr, b.row(j)) * scale);
        }
        i += 1;
    }
}

/// `out += probs @ v` without clearing — the streaming-softmax combine
/// primitive (`engine::stream_segment`). Tiled 4 wide over the
/// contraction so each pass over an output row folds in four `v` rows,
/// keeping the all-zero skip from the naive kernel (sort weights are
/// near-permutation sparse, and masked streaming probabilities are
/// exactly zero).
pub fn matmul_acc_into(probs: &MatView, v: &MatView, out: &mut MatViewMut) {
    assert_eq!(probs.cols, v.rows, "matmul dims");
    assert_eq!((out.rows, out.cols), (probs.rows, v.cols), "out dims");
    for i in 0..probs.rows {
        let or = out.row_mut(i);
        let mut k = 0;
        while k + 4 <= probs.cols {
            let w = [probs.at(i, k), probs.at(i, k + 1), probs.at(i, k + 2), probs.at(i, k + 3)];
            if w != [0.0; 4] {
                let (v0, v1) = (v.row(k), v.row(k + 1));
                let (v2, v3) = (v.row(k + 2), v.row(k + 3));
                for ((((o, a), b), c), e) in
                    or.iter_mut().zip(v0).zip(v1).zip(v2).zip(v3)
                {
                    *o += w[0] * a + w[1] * b + w[2] * c + w[3] * e;
                }
            }
            k += 4;
        }
        while k < probs.cols {
            let wk = probs.at(i, k);
            if wk != 0.0 {
                for (o, x) in or.iter_mut().zip(v.row(k)) {
                    *o += wk * x;
                }
            }
            k += 1;
        }
    }
}

/// `out += a @ b` in the *naive oracle's* accumulation order — row `i`,
/// then the contraction index `k` (skipping zero `a` entries), then `j` —
/// i.e. exactly the loop of [`Mat::matmul`], written into a preallocated
/// view. Chained from a zeroed `out` this is bit-identical to
/// `Mat::matmul`, and summing several products into one `out` is
/// bit-identical to `matmul` + [`Mat::add`] per term. The layer stack
/// (`sinkhorn::model`) uses it for the q/k/v and output projections so a
/// depth-1 stack reproduces the historical single-layer fallback bitwise;
/// the FFN path, which has no bitwise heritage, uses the faster tiled
/// [`matmul_acc_into`] instead.
pub fn matmul_acc_ordered_into(a: &MatView, b: &MatView, out: &mut MatViewMut) {
    assert_eq!(a.cols, b.rows, "matmul dims");
    assert_eq!((out.rows, out.cols), (a.rows, b.cols), "out dims");
    for i in 0..a.rows {
        let ar = a.row(i);
        let or = out.row_mut(i);
        for (k, &av) in ar.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            for (o, &bv) in or.iter_mut().zip(b.row(k)) {
                *o += av * bv;
            }
        }
    }
}

/// Row-vector times matrix: `out[j] = Σ_c x[c] * w[c, j]`, skipping zero
/// `x` entries — the decode loop's per-token projection. Same accumulation
/// order as [`Mat::matmul`] on a 1-row left operand, so the single-row and
/// batched projection paths agree bitwise.
pub fn row_times(x: &[f32], w: &Mat) -> Vec<f32> {
    debug_assert_eq!(x.len(), w.rows);
    let mut out = vec![0.0f32; w.cols];
    row_times_into(x, w, &mut out);
    out
}

/// [`row_times`] into a preallocated output (the stack's decode hot path).
pub fn row_times_into(x: &[f32], w: &Mat, out: &mut [f32]) {
    out.fill(0.0);
    row_times_acc_into(x, w, out);
}

/// `out += x * w` without clearing — the accumulating form of
/// [`row_times_into`] (same order), which the decode loop's multi-head
/// output projection folds one head at a time into a shared row.
pub fn row_times_acc_into(x: &[f32], w: &Mat, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.rows);
    debug_assert_eq!(out.len(), w.cols);
    for (c, &a) in x.iter().enumerate() {
        if a == 0.0 {
            continue;
        }
        for (o, &wv) in out.iter_mut().zip(w.row(c)) {
            *o += a * wv;
        }
    }
}

// --- fused layer kernels (DESIGN.md §Model) ---------------------------------
//
// The transformer stack's non-matmul per-row work, written in the same
// register-tiled style as the microkernels above: LANES-wide split
// accumulators for the LayerNorm reductions (so LLVM autovectorizes the
// mean/variance passes), element-wise GELU, and the broadcast bias init
// that turns `matmul_acc_into` into a fused matmul+bias. Like the tiled
// matmuls, the split-accumulator LayerNorm reorders float summation and is
// epsilon-, not bit-equal to a single-accumulator reference.

/// LayerNorm variance floor (shared by the kernel and the naive oracle in
/// `attention::reference_stack_forward`, so the two paths differ only in
/// summation order).
pub const LN_EPS: f32 = 1e-5;

/// Sum a slice with `LANES` independent partial accumulators + scalar
/// tail — the vectorizable reduction both LayerNorm passes use.
#[inline]
fn sum_lanes(xs: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut c = xs.chunks_exact(LANES);
    for v in &mut c {
        for l in 0..LANES {
            acc[l] += v[l];
        }
    }
    let mut s = hsum(&acc);
    for x in c.remainder() {
        s += x;
    }
    s
}

/// Sum of squared deviations from `mean`, `LANES`-split like [`sum_lanes`].
#[inline]
fn sumsq_dev_lanes(xs: &[f32], mean: f32) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut c = xs.chunks_exact(LANES);
    for v in &mut c {
        for l in 0..LANES {
            let d = v[l] - mean;
            acc[l] += d * d;
        }
    }
    let mut s = hsum(&acc);
    for x in c.remainder() {
        let d = x - mean;
        s += d * d;
    }
    s
}

/// Row-wise LayerNorm with affine parameters, written into a preallocated
/// view: `out[i, j] = (x[i, j] - mean_i) / sqrt(var_i + LN_EPS) * gamma[j]
/// + beta[j]`. One fused pass per row computes mean, variance and the
/// normalized affine output; the reductions use `LANES`-split accumulators
/// (register-tiled style), so results are epsilon-equal to a
/// single-accumulator reference.
pub fn layernorm_into(x: &MatView, gamma: &[f32], beta: &[f32], out: &mut MatViewMut) {
    assert_eq!(gamma.len(), x.cols, "gamma len");
    assert_eq!(beta.len(), x.cols, "beta len");
    assert_eq!((out.rows, out.cols), (x.rows, x.cols), "out dims");
    let n = x.cols as f32;
    for i in 0..x.rows {
        let xr = x.row(i);
        let mean = sum_lanes(xr) / n;
        let var = sumsq_dev_lanes(xr, mean) / n;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let or = out.row_mut(i);
        for ((o, &xv), (&g, &bt)) in or.iter_mut().zip(xr).zip(gamma.iter().zip(beta)) {
            *o = (xv - mean) * inv * g + bt;
        }
    }
}

/// [`layernorm_into`] for a single row (the decode loop's per-token form —
/// same kernel, same op order, so decode and batch prefill agree).
pub fn layernorm_row_into(x: &[f32], gamma: &[f32], beta: &[f32], out: &mut [f32]) {
    let xv = MatView::contiguous(x, 1, x.len());
    let mut ov = MatViewMut::contiguous(out, 1, x.len());
    layernorm_into(&xv, gamma, beta, &mut ov);
}

/// GELU, tanh approximation (the transformer-standard form):
/// `0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3)))`. Element-wise, so the
/// kernel and any reference implementation agree bitwise.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Element-wise [`gelu`] written into a preallocated view (the FFN
/// activation pass).
pub fn gelu_into(x: &MatView, out: &mut MatViewMut) {
    assert_eq!((out.rows, out.cols), (x.rows, x.cols), "out dims");
    for i in 0..x.rows {
        for (o, &xv) in out.row_mut(i).iter_mut().zip(x.row(i)) {
            *o = gelu(xv);
        }
    }
}

/// Broadcast `bias` into every row of `out` — the accumulator init that
/// fuses the bias add into the matmul: `bias_rows_into(b, out)` followed by
/// [`matmul_acc_into`]`(x, w, out)` computes `x @ w + b` with no separate
/// bias pass over the output.
pub fn bias_rows_into(bias: &[f32], out: &mut MatViewMut) {
    assert_eq!(bias.len(), out.cols, "bias len");
    for i in 0..out.rows {
        out.row_mut(i).copy_from_slice(bias);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(a.matmul(&Mat::eye(3)), a);
        assert_eq!(Mat::eye(3).matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches() {
        let a = Mat::from_fn(2, 4, |i, j| (i + j) as f32);
        let b = Mat::from_fn(3, 4, |i, j| (i * j) as f32 + 1.0);
        let bt = Mat::from_fn(4, 3, |i, j| b[(j, i)]);
        assert_eq!(a.matmul_t(&b), a.matmul(&bt));
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut a = Mat::from_fn(4, 5, |i, j| (i as f32) - (j as f32) * 0.3);
        a.softmax_rows();
        for i in 0..4 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    fn demo(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = crate::util::rng::Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32)
    }

    #[test]
    fn views_select_blocks_and_bands() {
        let m = demo(6, 4, 1);
        // contiguous row block
        let blk = m.row_block(2, 2);
        assert_eq!(blk.to_mat(), Mat::from_fn(2, 4, |i, j| m[(i + 2, j)]));
        // strided column band: right half of each row
        let band = MatView::new(&m.data[2..], 6, 2, 4);
        assert_eq!(band.to_mat(), Mat::from_fn(6, 2, |i, j| m[(i, j + 2)]));
        assert_eq!(m.view().to_mat(), m);
        // contiguous sub-range of a view's rows (streaming key tiles)
        let rr = m.view().row_range(1, 3);
        assert_eq!(rr.to_mat(), Mat::from_fn(3, 4, |i, j| m[(i + 1, j)]));
    }

    /// Kernel tolerance: the microkernels reorder float summation, so a
    /// few ULPs of divergence from the naive `Mat` oracle are expected —
    /// bounded by the engine-wide contract constant.
    const TOL: f32 = crate::sinkhorn::engine::ENGINE_TOL;

    fn assert_close(got: &Mat, want: &Mat, what: &str) {
        let d = got.max_abs_diff(want);
        assert!(d <= TOL, "{what}: max abs diff {d}");
    }

    #[test]
    fn matmul_t_scaled_into_matches_reference() {
        // sweep shapes around the tile widths: row tails (rows % 4 != 0)
        // and contraction tails (k % LANES != 0), both tiny and multi-tile
        for (rows, cols, k) in
            [(3usize, 5usize, 5usize), (4, 4, 8), (7, 9, 13), (8, 3, 16), (1, 1, 1), (12, 6, 23)]
        {
            let a = demo(rows, k, 2 + rows as u64);
            let b = demo(cols, k, 3 + cols as u64);
            let mut want = a.matmul_t(&b);
            want.scale(0.25);
            let mut out = Mat::zeros(rows, cols);
            matmul_t_scaled_into(&a.view(), &b.view(), 0.25, &mut out.view_mut());
            assert_close(&out, &want, &format!("matmul_t ({rows},{cols},{k})"));
        }
    }

    #[test]
    fn matmul_acc_into_from_zero_matches_reference() {
        for (rows, k, cols) in [(3usize, 4usize, 6usize), (5, 7, 9), (4, 8, 16), (2, 1, 3)] {
            let a = demo(rows, k, 4 + rows as u64);
            let b = demo(k, cols, 5 + cols as u64);
            let want = a.matmul(&b);
            let mut out = Mat::zeros(rows, cols);
            matmul_acc_into(&a.view(), &b.view(), &mut out.view_mut());
            assert_close(&out, &want, &format!("matmul ({rows},{k},{cols})"));
        }
    }

    #[test]
    fn matmul_acc_into_accumulates() {
        let a = demo(5, 6, 11);
        let b = demo(6, 7, 12);
        let base = demo(5, 7, 13);
        let mut want = base.clone();
        want.add(&a.matmul(&b));
        let mut out = base.clone();
        matmul_acc_into(&a.view(), &b.view(), &mut out.view_mut());
        assert_close(&out, &want, "matmul_acc");
    }

    #[test]
    fn max_abs_diff_poisons_on_nan() {
        let a = demo(2, 3, 20);
        let mut b = a.clone();
        b.data[1] = f32::NAN;
        let d = a.max_abs_diff(&b);
        assert!(d.is_nan(), "NaN must not be dropped by the diff gate: {d}");
        assert!(!(d <= 1e-5), "tolerance gates must fail on NaN");
        // NaN early in the buffer must survive later larger diffs
        b.data[5] = 100.0;
        assert!(a.max_abs_diff(&b).is_nan());
    }

    #[test]
    fn matmul_acc_ordered_is_bitwise_matmul() {
        // the oracle-order kernel must be *bit*-identical to Mat::matmul
        // from a zeroed output, and to matmul + add when accumulating —
        // the depth-1 stack-vs-legacy-fallback equivalence rides on this
        let a = demo(5, 7, 31);
        let b = demo(7, 4, 32);
        let want = a.matmul(&b);
        let mut out = Mat::zeros(5, 4);
        matmul_acc_ordered_into(&a.view(), &b.view(), &mut out.view_mut());
        assert_eq!(out, want);
        let a2 = demo(5, 6, 33);
        let b2 = demo(6, 4, 34);
        let mut want2 = want.clone();
        want2.add(&a2.matmul(&b2));
        matmul_acc_ordered_into(&a2.view(), &b2.view(), &mut out.view_mut());
        assert_eq!(out, want2);
    }

    #[test]
    fn row_times_matches_one_row_matmul_bitwise() {
        let w = demo(6, 9, 35);
        let x = demo(1, 6, 36);
        let want = x.matmul(&w);
        assert_eq!(row_times(x.row(0), &w), want.row(0));
        let mut out = vec![f32::NAN; 9]; // dirty buffer must be overwritten
        row_times_into(x.row(0), &w, &mut out);
        assert_eq!(&out, want.row(0));
    }

    #[test]
    fn layernorm_rows_are_normalized_and_affine() {
        let x = demo(5, 11, 40); // 11: off the 8-lane tile
        let gamma = vec![1.0f32; 11];
        let beta = vec![0.0f32; 11];
        let mut out = Mat::zeros(5, 11);
        layernorm_into(&x.view(), &gamma, &beta, &mut out.view_mut());
        for i in 0..5 {
            let m: f32 = out.row(i).iter().sum::<f32>() / 11.0;
            let v: f32 = out.row(i).iter().map(|&y| (y - m) * (y - m)).sum::<f32>() / 11.0;
            assert!(m.abs() < 1e-5, "row {i} mean {m}");
            assert!((v - 1.0).abs() < 1e-3, "row {i} var {v}");
        }
        // affine params shift and scale
        let gamma2 = vec![2.0f32; 11];
        let beta2 = vec![0.5f32; 11];
        let mut out2 = Mat::zeros(5, 11);
        layernorm_into(&x.view(), &gamma2, &beta2, &mut out2.view_mut());
        for (a, b) in out.data.iter().zip(&out2.data) {
            assert!((2.0 * a + 0.5 - b).abs() <= 1e-5);
        }
        // the single-row decode form is the same kernel
        let mut row = vec![0.0f32; 11];
        layernorm_row_into(x.row(2), &gamma, &beta, &mut row);
        assert_eq!(&row, out.row(2));
    }

    #[test]
    fn layernorm_within_epsilon_of_naive_reduction() {
        // split-accumulator mean/variance vs the single-accumulator
        // reference — tail lengths straddle the LANES tile
        for cols in [5usize, 8, 17, 64] {
            let x = demo(3, cols, 41 + cols as u64);
            let gamma: Vec<f32> = (0..cols).map(|j| 0.5 + j as f32 * 0.01).collect();
            let beta: Vec<f32> = (0..cols).map(|j| j as f32 * 0.02 - 0.1).collect();
            let mut got = Mat::zeros(3, cols);
            layernorm_into(&x.view(), &gamma, &beta, &mut got.view_mut());
            let mut want = Mat::zeros(3, cols);
            for i in 0..3 {
                let mut mean = 0.0f32;
                for &v in x.row(i) {
                    mean += v;
                }
                mean /= cols as f32;
                let mut var = 0.0f32;
                for &v in x.row(i) {
                    var += (v - mean) * (v - mean);
                }
                var /= cols as f32;
                let inv = 1.0 / (var + LN_EPS).sqrt();
                for j in 0..cols {
                    want[(i, j)] = (x[(i, j)] - mean) * inv * gamma[j] + beta[j];
                }
            }
            assert_close(&got, &want, &format!("layernorm cols={cols}"));
        }
    }

    #[test]
    fn gelu_known_values_and_odd_shape() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
        assert!(gelu(10.0) - 10.0 < 1e-3 && gelu(10.0) <= 10.0);
        assert!(gelu(-10.0).abs() < 1e-3);
        let x = demo(3, 7, 50);
        let mut out = Mat::zeros(3, 7);
        gelu_into(&x.view(), &mut out.view_mut());
        for (o, &xv) in out.data.iter().zip(&x.data) {
            assert_eq!(*o, gelu(xv));
        }
    }

    #[test]
    fn bias_rows_then_matmul_acc_is_fused_bias_matmul() {
        let x = demo(4, 6, 51);
        let w = demo(6, 9, 52);
        let bias: Vec<f32> = (0..9).map(|j| j as f32 * 0.1 - 0.3).collect();
        let mut out = Mat::from_fn(4, 9, |_, _| f32::NAN); // dirty
        bias_rows_into(&bias, &mut out.view_mut());
        matmul_acc_into(&x.view(), &w.view(), &mut out.view_mut());
        let mut want = x.matmul(&w);
        for i in 0..4 {
            for (o, &b) in want.row_mut(i).iter_mut().zip(&bias) {
                *o += b;
            }
        }
        assert_close(&out, &want, "fused matmul+bias");
    }

    #[test]
    fn strided_write_only_touches_band() {
        // write a (2,3) product into the left band of a (2,5)-strided buffer
        let a = Mat::eye(2);
        let b = demo(2, 3, 9);
        let mut buf = vec![7.0f32; 2 * 5];
        {
            let mut out = MatViewMut::new(&mut buf, 2, 3, 5);
            out.fill(0.0);
            matmul_acc_into(&a.view(), &b.view(), &mut out);
        }
        for i in 0..2 {
            assert_eq!(&buf[i * 5..i * 5 + 3], b.row(i));
            assert_eq!(&buf[i * 5 + 3..i * 5 + 5], &[7.0, 7.0]); // untouched
        }
    }
}
