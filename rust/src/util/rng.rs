//! Deterministic PRNGs for data generation, shuffling and property tests.
//!
//! The offline crate set has no `rand`, so this module provides a
//! SplitMix64 seeder and a PCG32 generator (O'Neill 2014) with the handful
//! of distributions the coordinator needs. Everything is reproducible from
//! a single `u64` seed — data pipelines record their seed in results files.

/// SplitMix64 — used to expand one seed into stream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): small, fast, statistically solid.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Construct from a seed; distinct `stream`s give independent sequences.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let init = sm.next_u64();
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(init);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::with_stream(self.next_u64() ^ tag, tag.wrapping_mul(2) | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-like power-law index in [0, n): p(i) proportional to 1/(i+1)^alpha.
    /// Used for the synthetic LM corpus vocabulary distribution.
    pub fn zipf(&mut self, n: usize, alpha: f64, cdf_cache: &mut Vec<f64>) -> usize {
        if cdf_cache.len() != n {
            cdf_cache.clear();
            let mut acc = 0.0;
            for i in 0..n {
                acc += 1.0 / ((i + 1) as f64).powf(alpha);
                cdf_cache.push(acc);
            }
            let total = acc;
            for c in cdf_cache.iter_mut() {
                *c /= total;
            }
        }
        let u = self.f64();
        match cdf_cache.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(n - 1),
        }
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[inline]
fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let m = (a as u128) * (b as u128);
    ((m >> 64) as u64, m as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_monotone_head() {
        let mut r = Rng::new(9);
        let mut cache = Vec::new();
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            counts[r.zipf(50, 1.2, &mut cache)] += 1;
        }
        assert!(counts[0] > counts[5] && counts[5] > counts[30]);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(13);
        let mut c = [0usize; 3];
        for _ in 0..3000 {
            c[r.weighted(&[1.0, 0.0, 9.0])] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }

    #[test]
    fn fork_independent() {
        let mut r = Rng::new(21);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
