//! Per-table bench targets: each regenerates one table/figure of the paper
//! with paper-vs-measured columns and records it under artifacts/results/.
//!
//! Seven targets are *runtime-free* — `engine` (pure-Rust blocked engine:
//! naive vs fused vs parallel), `decode` (incremental autoregressive
//! decoding: full-recompute vs cached vs SortCut, DESIGN.md §Decode),
//! `model` (the depth-L stack forward, DESIGN.md §Model), `serve` (the
//! serving executor under offered load: request-batch waves vs the
//! continuous-batching scheduler, DESIGN.md §Scheduler), `pages`
//! (decode-cache residency and admission under prefix overlap, DESIGN.md
//! §Pages), `backends` (the sort backends head-to-head: sinkhorn vs
//! routing vs local, DESIGN.md §Backends) and `memory` (the §4 analytic
//! model) — and run on any machine; the rest train AOT artifacts and need
//! a PJRT runtime plus `make artifacts` (DESIGN.md §2).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::runtime::{Registry, Runtime};
use crate::sinkhorn::engine::ENGINE_TOL;
use crate::sinkhorn::{
    causal_decode_attention, memory, reference_stack_forward, sinkhorn, sinkhorn_attention,
    DecodeReq, DecodeScratch, DecodeState, Mat, PrefillReq, SinkhornEngine, SinkhornStack,
    StackConfig, WorkerPool,
};
use crate::util::rng::Rng;
use crate::util::stats::{percentile, time_iters, Table};

use super::{paper, run_table_experiments, save_result, BenchOptions, ExpResult};

fn fmt(x: f64) -> String {
    format!("{x:.3}")
}

fn by_variant(results: &[ExpResult]) -> HashMap<String, &ExpResult> {
    results.iter().map(|r| (r.name.clone(), r)).collect()
}

fn lookup<'a>(
    map: &'a HashMap<String, &'a ExpResult>,
    prefix: &str,
    variant: &str,
) -> Option<&'a ExpResult> {
    map.get(&format!("{prefix}__{variant}")).copied()
}

/// Table 1 — algorithmic sorting: EM + edit distance, eval at 2x length.
pub fn table1(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "table1", None)?;
    let map = by_variant(&results);
    let mut t = Table::new(
        "Table 1 — seq2seq sorting (paper: ell=256 eval 512 | ours: ell=64 eval 128)",
        &["Model", "paper EdDist", "paper EM%", "ours EdDist", "ours EM%"],
    );
    for (variant, p_ed, p_em) in paper::table1_paper() {
        let (ed, em) = lookup(&map, "sort", variant)
            .map(|r| (fmt(r.metric2.unwrap_or(f64::NAN)), fmt(r.metric)))
            .unwrap_or(("-".into(), "-".into()));
        t.row(&[variant.to_string(), fmt(p_ed), fmt(p_em), ed, em]);
    }
    finish(opts, "table1", t)
}

/// Table 2 — word-level LM perplexity, two model sizes.
pub fn table2(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "table2", None)?;
    let map = by_variant(&results);
    let mut t = Table::new(
        "Table 2 — word LM ppl (paper: LM1B Base/Big | ours: synthetic, tiny/small)",
        &["Model", "paper Base", "paper Big", "ours tiny", "ours small"],
    );
    for (variant, p_base, p_big) in paper::table2_paper() {
        let tiny = lookup(&map, "lmw_tiny", variant).map(|r| fmt(r.metric)).unwrap_or("-".into());
        let small =
            lookup(&map, "lmw_small", variant).map(|r| fmt(r.metric)).unwrap_or("-".into());
        t.row(&[variant.to_string(), fmt(p_base), fmt(p_big), tiny, small]);
    }
    finish(opts, "table2", t)
}

/// Table 3 — SOTA comparison: quoted rows + our measured best variants.
pub fn table3(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    // reuse table2's best sinkhorn + mixture runs (paper reports its best)
    let results = run_table_experiments(rt, reg, opts, "table2", Some("sinkhorn_b32"))?;
    let mix = run_table_experiments(rt, reg, opts, "table2", Some("mixture"))?;
    let mut t = Table::new(
        "Table 3 — published LM1B comparison (quoted) + ours (measured, synthetic corpus)",
        &["Model", "# Params", "Perplexity", "source"],
    );
    for (model, params, ppl) in paper::table3_paper() {
        t.row(&[model.to_string(), params.to_string(), fmt(ppl), "paper".into()]);
    }
    for r in results.iter().chain(mix.iter()) {
        t.row(&[
            format!("ours {}", r.name),
            format!("{:.2}M", r.n_params as f64 / 1e6),
            fmt(r.metric),
            "measured".into(),
        ]);
    }
    finish(opts, "table3", t)
}

/// Table 4 — char-level LM bpc.
pub fn table4(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "table4", None)?;
    let map = by_variant(&results);
    let mut t = Table::new(
        "Table 4 — char LM bpc (paper: LM1B 1024 chars | ours: synthetic, 256 chars)",
        &["Model", "paper Base", "paper Big", "ours"],
    );
    for (variant, p_base, p_big) in paper::table4_paper() {
        let ours = lookup(&map, "lmc", variant).map(|r| fmt(r.metric)).unwrap_or("-".into());
        t.row(&[variant.to_string(), fmt(p_base), fmt(p_big), ours]);
    }
    finish(opts, "table4", t)
}

/// Table 5 — pixel-wise image generation bpd.
pub fn table5(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "table5", None)?;
    let map = by_variant(&results);
    let mut t = Table::new(
        "Table 5 — image generation bpd (paper: CIFAR-10 3072 px | ours: synthetic 192 px)",
        &["Model", "paper Bpd", "ours Bpd"],
    );
    for (variant, p_bpd) in paper::table5_paper() {
        let ours = lookup(&map, "img", variant).map(|r| fmt(r.metric)).unwrap_or("-".into());
        t.row(&[variant.to_string(), fmt(p_bpd), ours]);
    }
    finish(opts, "table5", t)
}

/// Table 6 — sentiment classification accuracy (word + char).
pub fn table6(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "table6", None)?;
    let map = by_variant(&results);
    let mut t = Table::new(
        "Table 6 — sentiment accuracy (paper: IMDb/SST | ours: synthetic planted-signal)",
        &["Model", "IMDb w", "IMDb c", "SST w", "SST c", "(ours)"],
    );
    for (variant, p) in paper::table6_paper() {
        t.row(&[
            format!("paper {variant}"),
            fmt(p[0]),
            fmt(p[1]),
            fmt(p[2]),
            fmt(p[3]),
            String::new(),
        ]);
    }
    // our grid: the three block sizes per family
    let ours_variants = variant_grid(&map, "imdbw");
    for v in ours_variants {
        let cell = |ds: &str| -> String {
            // block sizes differ by dataset (ell-dependent); match by family+rank
            match_variant(&map, ds, &v).map(|r| fmt(r.metric)).unwrap_or("-".into())
        };
        t.row(&[
            format!("ours {v}"),
            cell("imdbw"),
            cell("imdbc"),
            cell("sstw"),
            cell("sstc"),
            String::new(),
        ]);
    }
    finish(opts, "table6", t)
}

/// Table 7 — NLI accuracy.
pub fn table7(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "table7", None)?;
    let map = by_variant(&results);
    let mut t = Table::new(
        "Table 7 — NLI accuracy (paper: SNLI/MNLI | ours: synthetic entity-attribute NLI)",
        &["Model", "SNLI", "MNLI", "(ours)"],
    );
    for (variant, p_snli, p_mnli) in paper::table7_paper() {
        t.row(&[format!("paper {variant}"), fmt(p_snli), fmt(p_mnli), String::new()]);
    }
    for v in variant_grid(&map, "snli") {
        let snli = match_variant(&map, "snli", &v).map(|r| fmt(r.metric)).unwrap_or("-".into());
        let mnli = match_variant(&map, "mnli", &v).map(|r| fmt(r.metric)).unwrap_or("-".into());
        t.row(&[format!("ours {v}"), snli, mnli, String::new()]);
    }
    finish(opts, "table7", t)
}

/// Table 8 — SortNet ablations.
pub fn table8(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "table8", None)?;
    // the p4 default row comes from table2's lmw_tiny__sinkhorn_b16
    let default_row = super::run_experiment(rt, opts, "lmw_tiny__sinkhorn_b16")?;
    let map = by_variant(&results);
    let mut t = Table::new(
        "Table 8 — SortNet ablations at b=16 (paper b=32 on LM1B)",
        &["Modeling choice", "paper ppl", "ours ppl"],
    );
    let ours = |abl: &str| -> String {
        map.get(&format!("abl_{abl}__sinkhorn_b16")).map(|r| fmt(r.metric)).unwrap_or("-".into())
    };
    for (variant, p_ppl) in paper::table8_paper() {
        let val = match variant {
            "p4 (default)" => fmt(default_row.metric),
            "p1" => ours("p1"),
            "p2" => ours("p2"),
            "p3" => ours("p3"),
            "sharekv" => ours("sharekv"),
            "noiters" => ours("noiters"),
            _ => "-".into(),
        };
        t.row(&[variant.to_string(), fmt(p_ppl), val]);
    }
    finish(opts, "table8", t)
}

/// Figure 3 — Gumbel temperature sweep.
pub fn fig3(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "fig3", None)?;
    let default_row = super::run_experiment(rt, opts, "lmw_tiny__sinkhorn_b16")?; // tau=0.75
    let mut t = Table::new(
        "Figure 3 — temperature tau vs ppl (paper optimum: tau=0.75)",
        &["tau", "ours ppl"],
    );
    let mut rows: Vec<(f64, f64)> = results
        .iter()
        .map(|r| {
            let tau = r
                .name
                .split("tau")
                .nth(1)
                .and_then(|s| s.split("__").next())
                .map(|s| s.replace('p', ".").parse().unwrap_or(f64::NAN))
                .unwrap_or(f64::NAN);
            (tau, r.metric)
        })
        .collect();
    rows.push((0.75, default_row.metric));
    rows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (tau, ppl) in rows {
        t.row(&[format!("{tau:.2}"), fmt(ppl)]);
    }
    finish(opts, "fig3", t)
}

/// Figure 4 — sinkhorn iterations sweep.
pub fn fig4(rt: &Runtime, reg: &Registry, opts: &BenchOptions) -> Result<String> {
    let results = run_table_experiments(rt, reg, opts, "fig4", None)?;
    let k0 = super::run_experiment(rt, opts, "abl_noiters__sinkhorn_b16")?;
    let k5 = super::run_experiment(rt, opts, "lmw_tiny__sinkhorn_b16")?;
    let mut t = Table::new(
        "Figure 4 — sinkhorn iterations k vs ppl (paper optimum: k=5-10, k=0 catastrophic)",
        &["k", "ours ppl"],
    );
    let mut rows: Vec<(usize, f64)> = results
        .iter()
        .map(|r| {
            let k = r
                .name
                .split("_k")
                .nth(1)
                .and_then(|s| s.split("__").next())
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            (k, r.metric)
        })
        .collect();
    rows.push((0, k0.metric));
    rows.push((5, k5.metric));
    rows.sort_by_key(|&(k, _)| k);
    for (k, ppl) in rows {
        t.row(&[k.to_string(), fmt(ppl)]);
    }
    finish(opts, "fig4", t)
}

/// §4 memory-complexity analysis: analytic model across sequence lengths.
pub fn memory_table(opts: &BenchOptions) -> Result<String> {
    let d = 64;
    let mut t = Table::new(
        "§4 memory complexity — attention score + aux f32 elements per head",
        &["ell", "dense", "local(nb=16)", "sparse", "sinkhorn(nb=16)", "sortcut(n=2)", "saving"],
    );
    for ell in [256usize, 512, 1024, 2048, 4096, 8192] {
        let nb = 16;
        let dense = memory::dense(ell, d);
        let local = memory::local(ell, nb, d);
        let sparse = memory::sparse_fixed(ell, nb, (ell / nb / 4).max(1), d);
        let sink = memory::sinkhorn(ell, nb, d);
        let cut = memory::sortcut(ell, nb, 2, d);
        t.row(&[
            ell.to_string(),
            dense.total_elems().to_string(),
            local.total_elems().to_string(),
            sparse.total_elems().to_string(),
            sink.total_elems().to_string(),
            cut.total_elems().to_string(),
            format!("{:.0}x", memory::saving_factor(ell, nb)),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!(
        "\nL1 kernel VMEM/program: b=64,d=64 -> {} KiB (TPU VMEM ~16 MiB); MXU-shaped: {}\n\
         engine Workspace/worker: b=64,d=64 -> {} KiB (DESIGN.md §Perf)\n",
        memory::kernel_vmem_bytes(64, 64) / 1024,
        memory::mxu_mac_fraction(64, 64) == 1.0,
        memory::engine_workspace_bytes(64, 64) / 1024,
    ));
    save_result(&opts.artifacts, "memory", &s)?;
    println!("{s}");
    Ok(s)
}

/// One measured `(ell, nb)` cell of the engine bench (medians in ms).
struct EngineCell {
    ell: usize,
    nb: usize,
    naive_ms: f64,
    fused_ms: f64,
    parallel_ms: f64,
}

/// `bench engine` — wall-clock of the pure-Rust paths across sequence
/// lengths and block counts: the seed's naive reference (`attention.rs`)
/// vs the streaming single-thread engine vs the parallel engine
/// (DESIGN.md §Engine, §Streaming). Before timing, the engine is asserted
/// within [`ENGINE_TOL`] of the naive oracle and the parallel run is
/// asserted bit-equal to the serial engine, so the table can't quietly
/// compare different computations. Besides the text table, the medians
/// are emitted machine-readably to `BENCH_engine.json` at the repo root —
/// the perf trajectory the ROADMAP asks for.
pub fn engine_table(opts: &BenchOptions) -> Result<String> {
    let d = 64;
    let par = SinkhornEngine::auto();
    let fused = SinkhornEngine::serial();
    // smoke mode (CI): one tiny shape, one rep — the correctness gates
    // still run, the timing columns are non-representative by design
    let (ells, nbs): (&[usize], &[usize]) =
        if opts.smoke { (&[128], &[4]) } else { (&[512, 1024, 4096], &[4, 8, 16]) };
    let mut t = Table::new(
        &format!(
            "engine — sorted+local attention wall-clock, d={d} (parallel: {} threads){}",
            par.threads(),
            if opts.smoke { " [SMOKE]" } else { "" }
        ),
        &["ell", "nb", "naive ms", "fused ms", "parallel ms", "fused x", "parallel x"],
    );
    let mut cells = Vec::new();
    for &ell in ells {
        for &nb in nbs {
            let mut rng = Rng::new(0xB0 ^ (ell * 31 + nb) as u64);
            let mk = |rng: &mut Rng| Mat::from_fn(ell, d, |_, _| rng.normal() as f32 * 0.5);
            let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            let r = sinkhorn(&Mat::from_fn(nb, nb, |_, _| rng.normal() as f32), 8);

            // correctness gate: one run of each path before timing
            let want = sinkhorn_attention(&q, &k, &v, &r, nb, false);
            let got = fused.attention(&q, &k, &v, &r, nb, false);
            let diff = want.max_abs_diff(&got);
            anyhow::ensure!(
                diff <= ENGINE_TOL,
                "streaming engine diverged from naive at ell={ell} nb={nb}: max-abs {diff}"
            );
            anyhow::ensure!(
                par.attention(&q, &k, &v, &r, nb, false) == got,
                "parallel engine must equal the serial engine bit for bit at ell={ell} nb={nb}"
            );

            // timing: fewer iters at the large end (naive is slow there —
            // that's the point)
            let iters = if opts.smoke {
                1
            } else if ell >= 4096 {
                3
            } else {
                5
            };
            let mut out = Mat::zeros(ell, d);
            let mut t_naive =
                time_iters(1, iters, || drop(sinkhorn_attention(&q, &k, &v, &r, nb, false)));
            let mut t_fused =
                time_iters(1, iters, || fused.attention_into(&q, &k, &v, &r, nb, false, &mut out));
            let mut t_par =
                time_iters(1, iters, || par.attention_into(&q, &k, &v, &r, nb, false, &mut out));
            let (naive, fus, parl) = (
                percentile(&mut t_naive, 50.0) * 1e3,
                percentile(&mut t_fused, 50.0) * 1e3,
                percentile(&mut t_par, 50.0) * 1e3,
            );
            t.row(&[
                ell.to_string(),
                nb.to_string(),
                format!("{naive:.2}"),
                format!("{fus:.2}"),
                format!("{parl:.2}"),
                format!("{:.2}x", naive / fus),
                format!("{:.2}x", naive / parl),
            ]);
            cells.push(EngineCell { ell, nb, naive_ms: naive, fused_ms: fus, parallel_ms: parl });
        }
    }
    let mut s = t.render();
    s.push_str(
        "naive = single-thread reference path (attention.rs: materializes every block,\n\
         the (b, 2b) joint logits and both probability matrices);\n\
         fused = streaming-softmax engine with tiled microkernels, 1 thread;\n\
         parallel = same engine + worker pool over (request, head, block) tasks.\n\
         Gate: engine within 1e-5 max-abs of naive; parallel == fused bit for bit.\n",
    );
    save_result(&opts.artifacts, "engine", &s)?;
    if opts.smoke {
        s.push_str("smoke run: BENCH_engine.json left untouched\n");
    } else {
        let json_path = write_engine_json(d, par.threads(), &cells)?;
        s.push_str(&format!("machine-readable medians: {}\n", json_path.display()));
    }
    println!("{s}");
    Ok(s)
}

/// Emit the engine bench machine-readably: one row per `(shape, path)`
/// with the median ns/iter and the thread count that produced it, written
/// to `BENCH_engine.json` at the repo root. This file seeds the perf
/// trajectory — successive PRs regenerate it and diff.
fn write_engine_json(
    d: usize,
    par_threads: usize,
    cells: &[EngineCell],
) -> Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let mut rows = Vec::new();
    for c in cells {
        let paths: [(&str, f64, usize); 3] = [
            ("naive", c.naive_ms, 1),
            ("fused", c.fused_ms, 1),
            ("parallel", c.parallel_ms, par_threads),
        ];
        for (path, ms, threads) in paths {
            rows.push(Json::Obj(vec![
                ("ell".into(), Json::from(c.ell)),
                ("nb".into(), Json::from(c.nb)),
                ("b".into(), Json::from(c.ell / c.nb)),
                ("d".into(), Json::from(d)),
                ("path".into(), Json::from(path)),
                ("threads".into(), Json::from(threads)),
                ("ns_per_iter".into(), Json::from((ms * 1e6).round())),
            ]));
        }
    }
    let doc = Json::Obj(vec![
        ("target".into(), Json::from("engine")),
        ("unit".into(), Json::from("ns_per_iter_p50")),
        ("cells".into(), Json::Arr(rows)),
    ]);
    let path = repo_root().join("BENCH_engine.json");
    std::fs::write(&path, doc.to_string_pretty() + "\n")?;
    Ok(path)
}

/// One measured decode cell: tokens/sec for one `(ell, path)` pair.
struct DecodeCell {
    ell: usize,
    nb: usize,
    path: &'static str,
    /// engine worker threads the cell ran on (1 for the serial
    /// generation paths; the pool width for the prefill paths)
    threads: usize,
    toks_per_sec: f64,
}

/// Decode a whole sequence token by token through the incremental path
/// (the serving per-request loop: one `DecodeState`, one reused scratch).
fn decode_run(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    logits: &Mat,
    b: usize,
    nb: usize,
    n_cut: Option<usize>,
) -> Mat {
    let mut st = DecodeState::new(b, q.cols, nb, 5, n_cut);
    let mut scratch = DecodeScratch::new();
    let mut out = Mat::zeros(q.rows, q.cols);
    for t in 0..q.rows {
        st.step_into(q.row(t), k.row(t), v.row(t), logits, &mut scratch, out.row_mut(t));
    }
    out
}

/// Ingest an `ell`-token prompt into `n_seqs` independent decode states —
/// one token per engine pass through the batched step entry (the legacy
/// prefill: what the scheduler's tick loop costs per prompt token), or
/// one block-aligned chunk per engine pass through the block-parallel
/// prefill entry (DESIGN.md §Prefill). Returns every sequence's stacked
/// outputs so the caller can gate the two paths bitwise against each
/// other before timing them.
fn prefill_run(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    logits: &Mat,
    b: usize,
    nb: usize,
    n_seqs: usize,
    eng: &SinkhornEngine,
    chunked: bool,
) -> Vec<Mat> {
    let d = q.cols;
    let mut states: Vec<DecodeState> =
        (0..n_seqs).map(|_| DecodeState::new(b, d, nb, 5, None)).collect();
    let mut outs: Vec<Mat> = (0..n_seqs).map(|_| Mat::zeros(q.rows, d)).collect();
    if chunked {
        let mut t = 0usize;
        while t < q.rows {
            let n = b.min(q.rows - t);
            let rows = t * d..(t + n) * d;
            let reqs: Vec<PrefillReq> = states
                .iter_mut()
                .zip(outs.iter_mut())
                .map(|(state, out)| PrefillReq {
                    state,
                    q: &q.data[rows.clone()],
                    k: &k.data[rows.clone()],
                    v: &v.data[rows.clone()],
                    sort_logits: logits,
                    out: &mut out.data[rows.clone()],
                })
                .collect();
            eng.prefill_chunks_into(reqs);
            t += n;
        }
    } else {
        for t in 0..q.rows {
            let reqs: Vec<DecodeReq> = states
                .iter_mut()
                .zip(outs.iter_mut())
                .map(|(state, out)| DecodeReq {
                    state,
                    q: q.row(t),
                    k: k.row(t),
                    v: v.row(t),
                    sort_logits: logits,
                    out: out.row_mut(t),
                })
                .collect();
            eng.decode_step_into(reqs);
        }
    }
    outs
}

/// `bench decode` — tokens/sec of autoregressive decoding across sequence
/// lengths (DESIGN.md §Decode): the full-recompute baseline
/// (`attention::causal_decode_attention`, which rebalances and regathers
/// the whole prefix for every token — what serving without caches costs)
/// vs the incremental `DecodeState` path vs incremental + SortCut
/// truncation — plus prompt-ingestion (prefill) throughput for a small
/// cohort: one engine pass per token vs one block-parallel pass per
/// block-aligned chunk (DESIGN.md §Prefill). Before timing, the
/// incremental path is asserted within [`ENGINE_TOL`] of the oracle at
/// the smallest shape and the chunked prefill is asserted *bitwise*
/// equal to the step prefill, so the table can't quietly compare
/// different computations. Medians also land machine-readably in
/// `BENCH_decode.json` at the repo root, next to `BENCH_engine.json`.
pub fn decode_table(opts: &BenchOptions) -> Result<String> {
    let (b, d, cut) = (64usize, 64usize, 2usize);
    let ells: &[usize] = if opts.smoke { &[256] } else { &[512, 1024, 4096] };
    let mut t = Table::new(
        &format!(
            "decode — autoregressive tokens/sec, b=64 d=64, cut=2 (DESIGN.md §Decode){}",
            if opts.smoke { " [SMOKE]" } else { "" }
        ),
        &[
            "ell",
            "nb",
            "full tok/s",
            "incr tok/s",
            "incr+cut tok/s",
            "incr x",
            "cut x",
            "pf step tok/s",
            "pf chunk tok/s",
            "pf x",
        ],
    );
    // prefill throughput cells: a small cohort of prompts ingested
    // together, the way the scheduler batches them (DESIGN.md §Prefill)
    let (eng, n_seqs) = (SinkhornEngine::new(0), 4usize);
    let mut cells = Vec::new();
    for &ell in ells {
        let nb = ell / b;
        let mut rng = Rng::new(0xDE ^ (ell * 17) as u64);
        let mk = |rng: &mut Rng| Mat::from_fn(ell, d, |_, _| rng.normal() as f32 * 0.5);
        let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let logits = Mat::from_fn(nb, nb, |_, _| rng.normal() as f32);

        // correctness gate (cheapest shape): every incremental step within
        // epsilon of the full-prefix oracle, full-causal and SortCut
        if ell == 512 || opts.smoke {
            for cutv in [None, Some(cut)] {
                let oracle = causal_decode_attention(&q, &k, &v, &logits, b, 5, cutv);
                let got = decode_run(&q, &k, &v, &logits, b, nb, cutv);
                let diff = got.max_abs_diff(&oracle);
                anyhow::ensure!(
                    diff <= ENGINE_TOL,
                    "incremental decode diverged from the oracle at ell={ell} cut={cutv:?}: \
                     max-abs {diff}"
                );
            }
            // prefill gate: the chunked path is *bitwise* equal to the
            // token-by-token path, per sequence (DESIGN.md §Prefill)
            let step = prefill_run(&q, &k, &v, &logits, b, nb, n_seqs, &eng, false);
            let chunked = prefill_run(&q, &k, &v, &logits, b, nb, n_seqs, &eng, true);
            for (s, (a, c)) in step.iter().zip(chunked.iter()).enumerate() {
                anyhow::ensure!(
                    a.data == c.data,
                    "chunked prefill is not bit-identical to step prefill at ell={ell} seq={s}"
                );
            }
        }

        // timing: the full-recompute baseline is O(ell^2), so fewer iters
        // at the large end (its slowness is the measurement). All three
        // paths get the same warmup so the ratios don't ride on cold
        // caches.
        let iters = if ell >= 4096 || opts.smoke { 1 } else { 3 };
        let mut t_full = time_iters(
            1,
            iters,
            || drop(causal_decode_attention(&q, &k, &v, &logits, b, 5, None)),
        );
        let mut t_incr =
            time_iters(1, iters, || drop(decode_run(&q, &k, &v, &logits, b, nb, None)));
        let mut t_cut =
            time_iters(1, iters, || drop(decode_run(&q, &k, &v, &logits, b, nb, Some(cut))));
        let mut t_pf_step = time_iters(1, iters, || {
            drop(prefill_run(&q, &k, &v, &logits, b, nb, n_seqs, &eng, false))
        });
        let mut t_pf_chunk = time_iters(1, iters, || {
            drop(prefill_run(&q, &k, &v, &logits, b, nb, n_seqs, &eng, true))
        });
        let full = ell as f64 / percentile(&mut t_full, 50.0);
        let incr = ell as f64 / percentile(&mut t_incr, 50.0);
        let cutc = ell as f64 / percentile(&mut t_cut, 50.0);
        let ingested = (n_seqs * ell) as f64;
        let pf_step = ingested / percentile(&mut t_pf_step, 50.0);
        let pf_chunk = ingested / percentile(&mut t_pf_chunk, 50.0);
        t.row(&[
            ell.to_string(),
            nb.to_string(),
            format!("{full:.0}"),
            format!("{incr:.0}"),
            format!("{cutc:.0}"),
            format!("{:.2}x", incr / full),
            format!("{:.2}x", cutc / full),
            format!("{pf_step:.0}"),
            format!("{pf_chunk:.0}"),
            format!("{:.2}x", pf_chunk / pf_step),
        ]);
        cells.push(DecodeCell { ell, nb, path: "full_recompute", threads: 1, toks_per_sec: full });
        cells.push(DecodeCell { ell, nb, path: "incremental", threads: 1, toks_per_sec: incr });
        cells.push(DecodeCell {
            ell,
            nb,
            path: "incremental_sortcut",
            threads: 1,
            toks_per_sec: cutc,
        });
        cells.push(DecodeCell {
            ell,
            nb,
            path: "prefill_step",
            threads: eng.threads(),
            toks_per_sec: pf_step,
        });
        cells.push(DecodeCell {
            ell,
            nb,
            path: "prefill_chunked",
            threads: eng.threads(),
            toks_per_sec: pf_chunk,
        });
    }
    let mut s = t.render();
    s.push_str(
        "full = no-cache baseline (attention.rs::causal_decode_attention: per token,\n\
         rebalance the causal sort matrix over the whole prefix and regather from scratch);\n\
         incr = incremental DecodeState (cached causal Sinkhorn state, rebalance only at\n\
         block boundaries, cached sorted K/V, streaming-softmax carry — O(b*d) per step);\n\
         incr+cut = same with SortCut truncation (cut=2 sorted blocks, append-only cache).\n\
         pf step / pf chunk = prompt-ingestion throughput for a 4-sequence cohort: one\n\
         engine pass per token vs one block-parallel pass per block-aligned chunk\n\
         (DESIGN.md §Prefill); both paths produce bit-identical states and outputs.\n\
         Gates: incremental within 1e-5 max-abs of the oracle at every step, and\n\
         chunked prefill bitwise equal to step prefill per sequence (ell=512).\n",
    );
    save_result(&opts.artifacts, "decode", &s)?;
    if opts.smoke {
        s.push_str("smoke run: BENCH_decode.json left untouched\n");
    } else {
        let json_path = write_decode_json(b, d, cut, &cells)?;
        s.push_str(&format!("machine-readable medians: {}\n", json_path.display()));
    }
    println!("{s}");
    Ok(s)
}

/// Emit the decode bench machine-readably: one row per `(ell, path)` with
/// the median tokens/sec, written to `BENCH_decode.json` at the repo root
/// (the decode-side companion of `BENCH_engine.json`).
fn write_decode_json(
    b: usize,
    d: usize,
    cut: usize,
    cells: &[DecodeCell],
) -> Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let mut rows = Vec::new();
    for c in cells {
        rows.push(Json::Obj(vec![
            ("ell".into(), Json::from(c.ell)),
            ("nb".into(), Json::from(c.nb)),
            ("b".into(), Json::from(b)),
            ("d".into(), Json::from(d)),
            ("n_cut".into(), Json::from(if c.path == "incremental_sortcut" { cut } else { 0 })),
            ("path".into(), Json::from(c.path)),
            ("threads".into(), Json::from(c.threads)),
            ("tokens_per_sec".into(), Json::from(c.toks_per_sec.round())),
        ]));
    }
    let doc = Json::Obj(vec![
        ("target".into(), Json::from("decode")),
        ("unit".into(), Json::from("tokens_per_sec_p50")),
        ("cells".into(), Json::Arr(rows)),
    ]);
    let path = repo_root().join("BENCH_decode.json");
    std::fs::write(&path, doc.to_string_pretty() + "\n")?;
    Ok(path)
}

/// One measured model cell: wall-clock for one `(depth, mode)` pair.
struct ModelCell {
    depth: usize,
    mode: &'static str,
    threads: usize,
    ms: f64,
}

/// `bench model` — wall-clock of the full multi-layer Sinkhorn Transformer
/// stack (DESIGN.md §Model) across depths, single-sequence vs batched
/// serving. Before timing, every depth's engine stack is asserted within
/// [`ENGINE_TOL`] of the naive per-layer oracle
/// (`attention::reference_stack_forward`) and the batch path bit-equal to
/// the single path, so the table can't quietly compare different
/// computations. Medians land machine-readably in `BENCH_model.json` at
/// the repo root, next to the engine and decode trajectories.
pub fn model_table(opts: &BenchOptions) -> Result<String> {
    // full transformer layers (pre-LN + GELU FFN), multi-head; smoke mode
    // shrinks every dimension and runs one rep
    let (ell, depths, heads, d, d_ff, batch_n): (usize, &[usize], usize, usize, usize, usize) =
        if opts.smoke { (128, &[1, 2], 2, 32, 64, 2) } else { (512, &[1, 2, 4], 4, 64, 128, 8) };
    let nb = 8;
    let pool = WorkerPool::new(0);
    let mut t = Table::new(
        &format!(
            "model — depth-L stack forward wall-clock, ell={ell} d={d} heads={heads} \
             d_ff={d_ff} nb={nb} (batch={batch_n}, pool: {} threads){}",
            pool.threads(),
            if opts.smoke { " [SMOKE]" } else { "" }
        ),
        &["depth", "params", "single ms", "batch ms", "batch ms/seq", "batch x"],
    );
    let mut cells = Vec::new();
    for &depth in depths {
        let cfg = StackConfig {
            seq_len: ell,
            d_model: d,
            n_heads: heads,
            depth,
            d_ff,
            nb,
            sinkhorn_iters: 5,
            causal: false,
            n_cut: None,
        };
        let mut stack =
            SinkhornStack::seeded(cfg.clone(), 0x40DE1 ^ depth as u64, SinkhornEngine::auto())?;
        let mut rng = Rng::new(0x40 ^ (depth * 13) as u64);
        let x0 = Mat::from_fn(ell, d, |_, _| rng.normal() as f32 * 0.5);

        // correctness gates: engine stack within epsilon of the naive
        // per-layer oracle; batch path bit-equal to the single path
        let want = reference_stack_forward(&x0, &stack.cfg, &stack.layers);
        let mut got = x0.clone();
        stack.forward(&mut got);
        let diff = got.max_abs_diff(&want);
        anyhow::ensure!(
            diff <= ENGINE_TOL,
            "stack diverged from the per-layer oracle at depth={depth}: max-abs {diff}"
        );
        let mut xs: Vec<Mat> = (0..batch_n).map(|_| x0.clone()).collect();
        stack.forward_batch(&mut xs, &pool);
        for (i, xb) in xs.iter().enumerate() {
            anyhow::ensure!(
                xb == &got,
                "batch forward must equal the single forward bit for bit (depth={depth}, seq {i})"
            );
        }

        let iters = if opts.smoke { 1 } else { 5 };
        let mut x = x0.clone();
        let mut t_single = time_iters(1, iters, || {
            x.data.copy_from_slice(&x0.data);
            stack.forward(&mut x);
        });
        let mut t_batch = time_iters(1, iters, || {
            for xb in xs.iter_mut() {
                xb.data.copy_from_slice(&x0.data);
            }
            stack.forward_batch(&mut xs, &pool);
        });
        let single = percentile(&mut t_single, 50.0) * 1e3;
        let batch = percentile(&mut t_batch, 50.0) * 1e3;
        t.row(&[
            depth.to_string(),
            stack.n_params().to_string(),
            format!("{single:.2}"),
            format!("{batch:.2}"),
            format!("{:.2}", batch / batch_n as f64),
            format!("{:.2}x", single * batch_n as f64 / batch),
        ]);
        let single_threads = stack.engine().threads();
        cells.push(ModelCell { depth, mode: "single", threads: single_threads, ms: single });
        cells.push(ModelCell { depth, mode: "batch", threads: pool.threads(), ms: batch });
    }
    let mut s = t.render();
    s.push_str(&format!(
        "single = one sequence through SinkhornStack::forward (parallel engine over\n\
         (head, block) tasks, pooled per-worker workspaces reused across layers);\n\
         batch = {batch_n} sequences through forward_batch (request-parallel workers when\n\
         the batch fills the pool, sequential block-parallel otherwise);\n\
         batch x = throughput gain vs {batch_n} single passes.\n\
         Gate: stack within 1e-5 max-abs of the naive per-layer oracle at every depth;\n\
         batch bit-equal to single.\n",
    ));
    save_result(&opts.artifacts, "model", &s)?;
    if opts.smoke {
        s.push_str("smoke run: BENCH_model.json left untouched\n");
    } else {
        let json_path = write_model_json(ell, nb, d, d_ff, heads, batch_n, &cells)?;
        s.push_str(&format!("machine-readable medians: {}\n", json_path.display()));
    }
    println!("{s}");
    Ok(s)
}

/// Emit the model bench machine-readably: one row per `(depth, mode)` with
/// the median ns/iter, written to `BENCH_model.json` at the repo root (the
/// stack-side companion of `BENCH_engine.json`/`BENCH_decode.json`).
#[allow(clippy::too_many_arguments)]
fn write_model_json(
    ell: usize,
    nb: usize,
    d: usize,
    d_ff: usize,
    heads: usize,
    batch_n: usize,
    cells: &[ModelCell],
) -> Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let mut rows = Vec::new();
    for c in cells {
        rows.push(Json::Obj(vec![
            ("depth".into(), Json::from(c.depth)),
            ("heads".into(), Json::from(heads)),
            ("ell".into(), Json::from(ell)),
            ("nb".into(), Json::from(nb)),
            ("b".into(), Json::from(ell / nb)),
            ("d".into(), Json::from(d)),
            ("d_ff".into(), Json::from(d_ff)),
            ("mode".into(), Json::from(c.mode)),
            ("batch".into(), Json::from(if c.mode == "batch" { batch_n } else { 1 })),
            ("threads".into(), Json::from(c.threads)),
            ("ns_per_iter".into(), Json::from((c.ms * 1e6).round())),
        ]));
    }
    let doc = Json::Obj(vec![
        ("target".into(), Json::from("model")),
        ("unit".into(), Json::from("ns_per_iter_p50")),
        ("cells".into(), Json::Arr(rows)),
    ]);
    let path = repo_root().join("BENCH_model.json");
    std::fs::write(&path, doc.to_string_pretty() + "\n")?;
    Ok(path)
}

/// One measured serve cell: one `(transport, offered load, executor
/// mode)` triple. `transport` is how the clients reached the scheduler:
/// `channel` = in-process `ServerHandle` (the executor-only number),
/// `tcp` = the line protocol over a real socket, `http` = the JSON/SSE
/// gateway over a real socket (DESIGN.md §Gateway).
struct ServeCell {
    transport: &'static str,
    mode: &'static str,
    /// prompt-ingestion axis: `step` = one decode step per tick (chunk
    /// budget 0), `chunked` = block-parallel prefill between ticks
    /// (DESIGN.md §Prefill) — streams are bit-identical either way
    prefill: &'static str,
    sessions: usize,
    prompt_len: usize,
    gen_len: usize,
    slots: usize,
    toks_per_sec: f64,
    p50_tok_ms: f64,
    p95_tok_ms: f64,
    /// time to first token, submit → first streamed event (wave
    /// executors stream nothing: their whole reply is the first token)
    ttft_p50_ms: f64,
    ttft_p95_ms: f64,
    occupancy: f64,
}

/// Parse the TCP `tokens=... batch=... queue_us=... total_us=...`
/// summary line into (ids, queue_us, total_us).
fn parse_tcp_summary(line: &str) -> Result<(Vec<i32>, u64, u64)> {
    let (mut toks, mut queue_us, mut total_us) = (Vec::new(), 0u64, 0u64);
    anyhow::ensure!(line.starts_with("tokens="), "serve bench tcp client got {line:?}");
    for part in line.split_whitespace() {
        if let Some(v) = part.strip_prefix("tokens=") {
            toks = v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse::<i32>())
                .collect::<std::result::Result<_, _>>()?;
        } else if let Some(v) = part.strip_prefix("queue_us=") {
            queue_us = v.parse()?;
        } else if let Some(v) = part.strip_prefix("total_us=") {
            total_us = v.parse()?;
        }
    }
    Ok((toks, queue_us, total_us))
}

/// Split one SSE payload (`event: <name>\ndata: <json>\n\n`) into the
/// event name and its data line.
fn parse_sse_event(text: &str) -> Result<(&str, &str)> {
    let mut event = None;
    let mut data = None;
    for line in text.lines() {
        if let Some(v) = line.strip_prefix("event: ") {
            event = Some(v);
        } else if let Some(v) = line.strip_prefix("data: ") {
            data = Some(v);
        }
    }
    match (event, data) {
        (Some(e), Some(d)) => Ok((e, d)),
        _ => anyhow::bail!("malformed SSE event: {text:?}"),
    }
}

/// One bench client over the TCP line protocol: fire `plan` requests
/// back to back on one connection, gate every reply against the oracle,
/// and return `(n_tokens, per-token latencies ms, per-request TTFTs ms,
/// service seconds)` — the same tuple the in-process clients report.
fn drive_serve_tcp(
    addr: std::net::SocketAddr,
    plan: &[(Vec<i32>, usize, Vec<i32>)],
) -> Result<(usize, Vec<f64>, Vec<f64>, f64)> {
    use std::io::{BufRead, BufReader, Write};
    use std::time::Instant;
    let mut conn = std::net::TcpStream::connect(addr)?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let (mut lat_ms, mut ttft_ms) = (Vec::new(), Vec::new());
    let (mut n_tokens, mut service_s) = (0usize, 0.0f64);
    for (p, want_n, want) in plan {
        let ids = p.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ");
        conn.write_all(format!("gen {want_n} {ids}\n").as_bytes())?;
        conn.flush()?;
        let submit = Instant::now();
        let mut prev = submit;
        let mut streamed: Vec<i32> = Vec::new();
        loop {
            let mut l = String::new();
            anyhow::ensure!(reader.read_line(&mut l)? > 0, "tcp stream closed mid-reply");
            if let Some(rest) = l.strip_prefix("tok ") {
                let now = Instant::now();
                if streamed.is_empty() {
                    ttft_ms.push((now - submit).as_secs_f64() * 1e3);
                }
                lat_ms.push((now - prev).as_secs_f64() * 1e3);
                prev = now;
                let id = rest
                    .split_whitespace()
                    .nth(1)
                    .ok_or_else(|| anyhow::anyhow!("bad tok line {l:?}"))?;
                streamed.push(id.parse()?);
            } else {
                let (full, queue_us, total_us) = parse_tcp_summary(l.trim_end())?;
                anyhow::ensure!(
                    &full == want,
                    "serve bench oracle gate: tcp transport diverged from single-request generate"
                );
                anyhow::ensure!(streamed == full, "streamed ids must match the summary");
                if streamed.is_empty() {
                    // nothing streamed: the summary is the first arrival
                    ttft_ms.push((Instant::now() - submit).as_secs_f64() * 1e3);
                }
                n_tokens += full.len();
                service_s += total_us.saturating_sub(queue_us) as f64 / 1e6;
                break;
            }
        }
    }
    Ok((n_tokens, lat_ms, ttft_ms, service_s))
}

/// One bench client over the HTTP/SSE gateway: POST `/v1/generate` per
/// request on one keep-alive connection, stream the `tok` events, gate
/// the `done` summary against the oracle; same return tuple as
/// [`drive_serve_tcp`].
fn drive_serve_http(
    addr: std::net::SocketAddr,
    plan: &[(Vec<i32>, usize, Vec<i32>)],
) -> Result<(usize, Vec<f64>, Vec<f64>, f64)> {
    use crate::server::json::{FromJson, GenerateRequest, GenerateSummary, ToJson, TokEvent};
    use std::io::{BufRead, BufReader, Read, Write};
    use std::time::Instant;
    let mut conn = std::net::TcpStream::connect(addr)?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let (mut lat_ms, mut ttft_ms) = (Vec::new(), Vec::new());
    let (mut n_tokens, mut service_s) = (0usize, 0.0f64);
    for (p, want_n, want) in plan {
        let body = GenerateRequest { max_new: *want_n, tokens: p.clone(), deadline_ms: None }
            .to_json();
        conn.write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )?;
        conn.flush()?;
        let submit = Instant::now();
        let mut status = String::new();
        reader.read_line(&mut status)?;
        anyhow::ensure!(
            status.starts_with("HTTP/1.1 200"),
            "serve bench http client got {status:?}"
        );
        let (mut chunked, mut content_length) = (false, 0usize);
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let line = h.trim_end().to_ascii_lowercase();
            if line.is_empty() {
                break;
            }
            if line.starts_with("transfer-encoding:") && line.contains("chunked") {
                chunked = true;
            } else if let Some(v) = line.strip_prefix("content-length:") {
                content_length = v.trim().parse()?;
            }
        }
        let mut prev = submit;
        let mut streamed: Vec<i32> = Vec::new();
        let summary: GenerateSummary = if chunked {
            let done = loop {
                let mut sz = String::new();
                reader.read_line(&mut sz)?;
                let n = usize::from_str_radix(sz.trim(), 16)?;
                anyhow::ensure!(n > 0, "sse stream ended without a done event");
                let mut payload = vec![0u8; n];
                reader.read_exact(&mut payload)?;
                let mut crlf = [0u8; 2];
                reader.read_exact(&mut crlf)?;
                let text = String::from_utf8(payload)?;
                let (event, data) = parse_sse_event(&text)?;
                match event {
                    "tok" => {
                        let now = Instant::now();
                        if streamed.is_empty() {
                            ttft_ms.push((now - submit).as_secs_f64() * 1e3);
                        }
                        lat_ms.push((now - prev).as_secs_f64() * 1e3);
                        prev = now;
                        streamed.push(TokEvent::from_json(data)?.id);
                    }
                    "done" => break GenerateSummary::from_json(data)?,
                    other => anyhow::bail!("unexpected SSE event '{other}'"),
                }
            };
            // the terminal 0-chunk and its trailing blank line
            let mut z = String::new();
            reader.read_line(&mut z)?;
            anyhow::ensure!(z.trim() == "0", "bad SSE terminator {z:?}");
            let mut blank = String::new();
            reader.read_line(&mut blank)?;
            done
        } else {
            // token-free reply (request-batch executors stream nothing):
            // plain JSON summary, tokens accounted at total/n each
            let mut body = vec![0u8; content_length];
            reader.read_exact(&mut body)?;
            GenerateSummary::from_json(std::str::from_utf8(&body)?)?
        };
        anyhow::ensure!(
            &summary.tokens == want,
            "serve bench oracle gate: http transport diverged from single-request generate"
        );
        if streamed.is_empty() {
            // token-free reply: the whole summary is the first arrival
            ttft_ms.push((Instant::now() - submit).as_secs_f64() * 1e3);
            let per = summary.total_us as f64 / 1e3 / summary.tokens.len().max(1) as f64;
            lat_ms.extend(std::iter::repeat(per).take(summary.tokens.len()));
        } else {
            anyhow::ensure!(streamed == summary.tokens, "streamed ids must match the summary");
        }
        n_tokens += summary.tokens.len();
        service_s += summary.total_us.saturating_sub(summary.queue_us) as f64 / 1e6;
    }
    Ok((n_tokens, lat_ms, ttft_ms, service_s))
}

/// `bench serve` — the serving executor under offered load (DESIGN.md
/// §Scheduler): N concurrent clients fire mixed-length generate requests
/// at a fallback server running either the legacy **request-batch** wave
/// executor or the **continuous-batching** scheduler — the latter with
/// prompts ingested one decode step per tick (`prefill=step`) or through
/// the budgeted block-parallel chunks of DESIGN.md §Prefill
/// (`prefill=chunked`) — and the sweep reports aggregate tokens/s,
/// p50/p95 per-token latency, p50/p95 time-to-first-token, and slot
/// occupancy per `(sessions × prompt/gen length, mode, prefill)` cell.
///
/// Per-token latency is the inter-arrival gap of streamed tokens (first
/// token: submit → arrival); TTFT is that first gap, collected per
/// request. The request-batch executor streams nothing, so its tokens
/// are accounted at `total / n_tokens` each and its TTFT is the whole
/// reply time — which is the honest number: every token of a wave
/// arrives when the whole wave does. Occupancy is
/// `Σ per-request service time / (wall · slots)`.
///
/// Before timing anything, every reply is gated against the
/// single-request oracle: the scheduler's output must equal
/// `FallbackModel::generate` exactly, per request, regardless of what
/// shared its ticks — the bench cannot quietly compare different
/// computations. Medians land machine-readably in `BENCH_serve.json` at
/// the repo root, next to the engine/decode/model trajectories.
pub fn serve_table(opts: &BenchOptions) -> Result<String> {
    use crate::server::{BatchPolicy, ExecMode, FallbackConfig, FallbackModel, Server};
    use std::time::{Duration, Instant};
    let (seq_len, d_model, nb, depth, heads, d_ff): (usize, usize, usize, usize, usize, usize) =
        if opts.smoke { (32, 16, 4, 1, 1, 0) } else { (128, 32, 8, 2, 2, 64) };
    let slots = 8usize;
    let cfg = FallbackConfig {
        seq_len,
        d_model,
        nb,
        depth,
        n_heads: heads,
        d_ff,
        vocab: 64,
        ..Default::default()
    };
    let oracle = FallbackModel::new(cfg.clone())?;
    // offered-load grid: (concurrent clients, base prompt len, base gen len)
    let loads: &[(usize, usize, usize)] =
        if opts.smoke { &[(3, 4, 3)] } else { &[(4, 8, 8), (8, 8, 16), (16, 16, 24)] };
    let reqs_per_client = if opts.smoke { 1 } else { 3 };
    let mut t = Table::new(
        &format!(
            "serve — offered-load sweep, depth={depth} heads={heads} d={d_model} \
             seq_len={seq_len} ({slots} slots){}",
            if opts.smoke { " [SMOKE]" } else { "" }
        ),
        &[
            "transport",
            "mode",
            "prefill",
            "sessions",
            "prompt",
            "gen",
            "tok/s",
            "p50 tok ms",
            "p95 tok ms",
            "ttft p50",
            "ttft p95",
            "occupancy",
        ],
    );
    // chunked-prefill budget: one Sinkhorn block per chunk (the natural
    // unit of the block-parallel path — DESIGN.md §Prefill)
    let chunk = seq_len / nb;
    let mut cells = Vec::new();
    for &(n_clients, plen, glen) in loads {
        for (mode, mode_name, prefill) in [
            (ExecMode::RequestBatch, "request_batch", "step"),
            (ExecMode::Continuous, "continuous", "step"),
            (ExecMode::Continuous, "continuous", "chunked"),
        ] {
            let policy = BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(1),
                mode,
                max_sessions: slots,
                queue_depth: 4096,
                mem_budget: 0,
                prefill_chunk_tokens: if prefill == "chunked" { chunk } else { 0 },
                ..Default::default()
            };
            let server = Server::start_fallback(cfg.clone(), policy)?;
            // precompute every client's prompts, budgets and the oracle
            // generations *before* the timed window — inside it the gate
            // is a pure comparison, so oracle CPU never contends with the
            // load being measured
            let expected: Vec<Vec<(Vec<i32>, usize, Vec<i32>)>> = (0..n_clients)
                .map(|c| {
                    (0..reqs_per_client)
                        .map(|r| {
                            let p: Vec<i32> = (0..plen + (c % 3))
                                .map(|i| ((i * 7 + c + r) % 64) as i32)
                                .collect();
                            let want_n = match (c + r) % 3 {
                                0 => (glen / 2).max(1),
                                1 => glen,
                                _ => glen * 2,
                            };
                            let want = oracle.generate(&p, want_n);
                            (p, want_n, want)
                        })
                        .collect()
                })
                .collect();
            let t0 = Instant::now();
            // each client fires mixed-length requests back to back: every
            // third asks for a 2x generation, so wave executors
            // head-of-line block on it while the scheduler backfills
            let results: Vec<(usize, Vec<f64>, Vec<f64>, f64)> = std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for (c, plan) in expected.iter().enumerate() {
                    let h = server.handle.clone();
                    handles.push(scope.spawn(move || {
                        let mut token_lat_ms: Vec<f64> = Vec::new();
                        let mut req_ttft_ms: Vec<f64> = Vec::new();
                        let mut n_tokens = 0usize;
                        let mut service_s = 0.0f64;
                        for (r, (p, want_n, want)) in plan.iter().enumerate() {
                            let submit = Instant::now();
                            let (toks, resp) = h.generate_streaming(p.clone(), *want_n).unwrap();
                            let mut prev = submit;
                            let mut ids = Vec::new();
                            for (_i, id) in toks.iter() {
                                let now = Instant::now();
                                if ids.is_empty() {
                                    req_ttft_ms.push((now - submit).as_secs_f64() * 1e3);
                                }
                                token_lat_ms.push((now - prev).as_secs_f64() * 1e3);
                                prev = now;
                                ids.push(id);
                            }
                            let rsp = resp.recv().unwrap().unwrap();
                            let full = rsp.gen.clone().unwrap_or_default();
                            // oracle gate: identical to single-request decode
                            assert_eq!(
                                &full, want,
                                "serve bench oracle gate: scheduler output diverged \
                                 from single-request generate (client {c}, req {r})"
                            );
                            if ids.is_empty() {
                                // request-batch: no token events — every token
                                // of the wave arrives with the summary, which
                                // is also the honest first-token time
                                req_ttft_ms.push(rsp.total.as_secs_f64() * 1e3);
                                let per =
                                    rsp.total.as_secs_f64() * 1e3 / full.len().max(1) as f64;
                                token_lat_ms.extend(std::iter::repeat(per).take(full.len()));
                            } else {
                                assert_eq!(ids, full, "streamed ids must match the summary");
                            }
                            n_tokens += full.len();
                            service_s += (rsp.total - rsp.queue).as_secs_f64();
                        }
                        (n_tokens, token_lat_ms, req_ttft_ms, service_s)
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            server.shutdown()?;
            let total_tokens: usize = results.iter().map(|r| r.0).sum();
            let mut lat: Vec<f64> = results.iter().flat_map(|r| r.1.iter().copied()).collect();
            let mut ttft: Vec<f64> = results.iter().flat_map(|r| r.2.iter().copied()).collect();
            let service_total: f64 = results.iter().map(|r| r.3).sum();
            anyhow::ensure!(total_tokens > 0, "serve bench produced no tokens");
            let toks_per_sec = total_tokens as f64 / wall;
            let p50 = percentile(&mut lat, 50.0).max(1e-6);
            let p95 = percentile(&mut lat, 95.0).max(1e-6);
            let ttft_p50 = percentile(&mut ttft, 50.0).max(1e-6);
            let ttft_p95 = percentile(&mut ttft, 95.0).max(1e-6);
            let occupancy = (service_total / (wall * slots as f64)).max(1e-6);
            t.row(&[
                "channel".to_string(),
                mode_name.to_string(),
                prefill.to_string(),
                n_clients.to_string(),
                plen.to_string(),
                glen.to_string(),
                format!("{toks_per_sec:.0}"),
                format!("{p50:.3}"),
                format!("{p95:.3}"),
                format!("{ttft_p50:.3}"),
                format!("{ttft_p95:.3}"),
                format!("{occupancy:.3}"),
            ]);
            cells.push(ServeCell {
                transport: "channel",
                mode: mode_name,
                prefill,
                sessions: n_clients,
                prompt_len: plen,
                gen_len: glen,
                slots,
                toks_per_sec,
                p50_tok_ms: p50,
                p95_tok_ms: p95,
                ttft_p50_ms: ttft_p50,
                ttft_p95_ms: ttft_p95,
                occupancy,
            });
        }
    }
    // socket-transport sweep: the same loads pushed through the real
    // frontends under the continuous scheduler, so the bench captures
    // gateway overhead (framing, JSON/SSE codec, outbox relay) rather
    // than executor throughput alone (DESIGN.md §Gateway). Same oracle
    // gate: every streamed reply must be bit-equal to single-request
    // generate regardless of which wire carried it.
    for transport in ["tcp", "http"] {
        for &(n_clients, plen, glen) in loads {
            let policy = BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(1),
                mode: ExecMode::Continuous,
                max_sessions: slots,
                queue_depth: 4096,
                mem_budget: 0,
                ..Default::default()
            };
            let server = Server::start_fallback(cfg.clone(), policy)?;
            let (addr, _tcp_fe, _http_fe) = if transport == "tcp" {
                let fe = crate::server::TcpFrontend::start("127.0.0.1:0", server.handle.clone())?;
                (fe.addr, Some(fe), None)
            } else {
                let fe = crate::server::HttpFrontend::start("127.0.0.1:0", server.handle.clone())?;
                (fe.addr, None, Some(fe))
            };
            let expected: Vec<Vec<(Vec<i32>, usize, Vec<i32>)>> = (0..n_clients)
                .map(|c| {
                    (0..reqs_per_client)
                        .map(|r| {
                            let p: Vec<i32> = (0..plen + (c % 3))
                                .map(|i| ((i * 7 + c + r) % 64) as i32)
                                .collect();
                            let want_n = match (c + r) % 3 {
                                0 => (glen / 2).max(1),
                                1 => glen,
                                _ => glen * 2,
                            };
                            let want = oracle.generate(&p, want_n);
                            (p, want_n, want)
                        })
                        .collect()
                })
                .collect();
            let t0 = Instant::now();
            let results: Vec<(usize, Vec<f64>, Vec<f64>, f64)> = std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for plan in expected.iter() {
                    handles.push(scope.spawn(move || {
                        if transport == "tcp" {
                            drive_serve_tcp(addr, plan).unwrap()
                        } else {
                            drive_serve_http(addr, plan).unwrap()
                        }
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            drop(_tcp_fe);
            drop(_http_fe);
            server.shutdown()?;
            let total_tokens: usize = results.iter().map(|r| r.0).sum();
            let mut lat: Vec<f64> = results.iter().flat_map(|r| r.1.iter().copied()).collect();
            let mut ttft: Vec<f64> = results.iter().flat_map(|r| r.2.iter().copied()).collect();
            let service_total: f64 = results.iter().map(|r| r.3).sum();
            anyhow::ensure!(total_tokens > 0, "serve bench produced no tokens ({transport})");
            let toks_per_sec = total_tokens as f64 / wall;
            let p50 = percentile(&mut lat, 50.0).max(1e-6);
            let p95 = percentile(&mut lat, 95.0).max(1e-6);
            let ttft_p50 = percentile(&mut ttft, 50.0).max(1e-6);
            let ttft_p95 = percentile(&mut ttft, 95.0).max(1e-6);
            let occupancy = (service_total / (wall * slots as f64)).max(1e-6);
            t.row(&[
                transport.to_string(),
                "continuous".to_string(),
                "step".to_string(),
                n_clients.to_string(),
                plen.to_string(),
                glen.to_string(),
                format!("{toks_per_sec:.0}"),
                format!("{p50:.3}"),
                format!("{p95:.3}"),
                format!("{ttft_p50:.3}"),
                format!("{ttft_p95:.3}"),
                format!("{occupancy:.3}"),
            ]);
            cells.push(ServeCell {
                transport,
                mode: "continuous",
                prefill: "step",
                sessions: n_clients,
                prompt_len: plen,
                gen_len: glen,
                slots,
                toks_per_sec,
                p50_tok_ms: p50,
                p95_tok_ms: p95,
                ttft_p50_ms: ttft_p50,
                ttft_p95_ms: ttft_p95,
                occupancy,
            });
        }
    }
    let mut s = t.render();
    s.push_str(
        "request_batch = legacy wave executor (each gathered batch of generations runs\n\
         to completion; arrivals mid-flight wait for the whole wave);\n\
         continuous = token-level scheduler (session table, one fused (session, layer,\n\
         head) engine pass per tick, admission between ticks, slots freed immediately).\n\
         gen column = base budget; each client mixes 0.5x/1x/2x of it per request.\n\
         prefill: step = prompts ride the tick loop one decode step per tick;\n\
         chunked = block-parallel prefill between ticks (--prefill-chunk-tokens, one\n\
         Sinkhorn block per chunk here — DESIGN.md §Prefill; bit-identical streams).\n\
         ttft = submit -> first streamed token (wave replies land whole: ttft = total).\n\
         transport: channel = in-process ServerHandle (executor-only); tcp / http =\n\
         the same continuous loads over real sockets through the line protocol and\n\
         the JSON/SSE gateway respectively, so the delta vs channel is frontend cost.\n\
         Gate: every reply bit-equal to single-request generate (the scheduler oracle).\n",
    );
    save_result(&opts.artifacts, "serve", &s)?;
    if opts.smoke {
        s.push_str("smoke run: BENCH_serve.json left untouched\n");
    } else {
        let json_path = write_serve_json(&cells)?;
        s.push_str(&format!("machine-readable medians: {}\n", json_path.display()));
    }
    println!("{s}");
    Ok(s)
}

/// Emit the serve bench machine-readably: one row per `(transport, load,
/// mode)` with
/// throughput, per-token latency percentiles and occupancy, written to
/// `BENCH_serve.json` at the repo root (the serving-side companion of the
/// engine/decode/model trajectories).
fn write_serve_json(cells: &[ServeCell]) -> Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let mut rows = Vec::new();
    for c in cells {
        rows.push(Json::Obj(vec![
            ("transport".into(), Json::from(c.transport)),
            ("mode".into(), Json::from(c.mode)),
            ("prefill".into(), Json::from(c.prefill)),
            ("sessions".into(), Json::from(c.sessions)),
            ("prompt_len".into(), Json::from(c.prompt_len)),
            ("gen_len".into(), Json::from(c.gen_len)),
            ("slots".into(), Json::from(c.slots)),
            ("tokens_per_sec".into(), Json::from(c.toks_per_sec)),
            ("p50_tok_ms".into(), Json::from(c.p50_tok_ms)),
            ("p95_tok_ms".into(), Json::from(c.p95_tok_ms)),
            ("ttft_p50_ms".into(), Json::from(c.ttft_p50_ms)),
            ("ttft_p95_ms".into(), Json::from(c.ttft_p95_ms)),
            ("occupancy".into(), Json::from(c.occupancy)),
        ]));
    }
    let doc = Json::Obj(vec![
        ("target".into(), Json::from("serve")),
        ("unit".into(), Json::from("tokens_per_sec")),
        ("cells".into(), Json::Arr(rows)),
    ]);
    let path = repo_root().join("BENCH_serve.json");
    std::fs::write(&path, doc.to_string_pretty() + "\n")?;
    Ok(path)
}

/// One measured pages cell: one `(cohort, storage mode)` pair.
struct PagesCell {
    mode: &'static str,
    sessions: usize,
    overlap_pct: usize,
    prompt_len: usize,
    gen_len: usize,
    resident_bytes: f64,
    bytes_per_session: f64,
    admitted: usize,
}

/// `bench pages` — decode-cache residency and admission under prefix
/// overlap (DESIGN.md §Pages): cohorts of sessions whose prompts share a
/// 0/50/90% common prefix run to completion on the paged KV-cache, and
/// each cohort reports actual resident bytes (page-pool ledger + the
/// fixed per-session R/descriptor footprint) against the monolithic
/// worst-case allocation, plus how many cohort members a fixed
/// 4-worst-case-session memory budget admits under per-session page
/// reservations versus worst-case slot division.
///
/// Gates (the bench aborts rather than reporting a broken cache):
/// every paged session must reproduce the monolithic single-request
/// `generate` oracle bit for bit; overlapping cohorts must pin strictly
/// fewer resident bytes than monolithic states; reservation admission
/// must never admit fewer sessions than worst-case budgeting and must
/// admit strictly more at the highest overlap. Full runs land in
/// `BENCH_pages.json` at the repo root.
pub fn pages_table(opts: &BenchOptions) -> Result<String> {
    use crate::server::{FallbackConfig, FallbackModel, GenSession};
    let (seq_len, d_model, nb, depth, heads, d_ff): (usize, usize, usize, usize, usize, usize) =
        if opts.smoke { (32, 16, 4, 1, 1, 0) } else { (128, 32, 8, 2, 2, 64) };
    let (n, plen, glen) = if opts.smoke { (8, 17, 2) } else { (16, 65, 8) };
    let cfg = FallbackConfig {
        seq_len,
        d_model,
        nb,
        depth,
        n_heads: heads,
        d_ff,
        vocab: 64,
        ..Default::default()
    };
    let b = seq_len / nb;
    let d_head = d_model / heads;
    let bpp = cfg.blocks_per_page();
    let mut t = Table::new(
        &format!(
            "pages — resident bytes and admission vs prefix overlap, depth={depth} \
             heads={heads} d={d_model} seq_len={seq_len} ({n} sessions){}",
            if opts.smoke { " [SMOKE]" } else { "" }
        ),
        &["mode", "sessions", "overlap%", "prompt", "gen", "resident KB", "KB/session", "admitted"],
    );
    let mut cells = Vec::new();
    // fixed budget: exactly four worst-case monolithic sessions
    let probe = FallbackModel::new(cfg.clone())?;
    let mono_session = probe.session_state_bytes();
    let budget = 4 * mono_session;
    let mono_admitted = memory::admitted_sessions(budget, mono_session, n);
    // non-page footprint a paged session keeps outside the pool (R,
    // per-layer descriptors): the analytic resident model at length 0
    let fixed = memory::stack_paged_resident_bytes(depth, heads, b, d_head, nb, None, bpp, 0);
    let overlaps: &[usize] = &[0, 50, 90];
    let mut admitted_by_overlap = Vec::new();
    for &pct in overlaps {
        let shared_toks = plen * pct / 100;
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|s| {
                (0..plen)
                    .map(|i| {
                        let salt = if i < shared_toks { 0 } else { 17 * (s + 1) };
                        ((i * 7 + 3 + salt) % 64) as i32
                    })
                    .collect()
            })
            .collect();
        // fresh model per cohort: the prefix cache starts cold
        let m = FallbackModel::new(cfg.clone())?;
        let want: Vec<Vec<i32>> = prompts.iter().map(|p| m.generate(p, glen)).collect();
        let mut sessions: Vec<GenSession> =
            prompts.iter().map(|p| m.open_session(p, glen)).collect();
        let mut scratch = m.new_batch_scratch();
        loop {
            let mut live: Vec<&mut GenSession> =
                sessions.iter_mut().filter(|s| !s.done()).collect();
            if live.is_empty() {
                break;
            }
            m.step_sessions(&mut live, &mut scratch);
        }
        for (s, w) in sessions.iter().zip(&want) {
            anyhow::ensure!(
                s.generated() == &w[..],
                "pages bench oracle gate: paged session diverged from \
                 single-request generate (overlap {pct}%)"
            );
        }
        // residency at completion, sessions still resident (pool ledger
        // counts shared pages once; the prefix cache's snapshots ride on
        // the same pages plus their pre-divergence sort caches)
        let paged_resident = m.pool_stats().bytes_in_use() as f64 + (n * fixed) as f64;
        let mono_resident = (n * mono_session) as f64;
        anyhow::ensure!(
            pct == 0 || paged_resident < mono_resident,
            "pages bench gate: overlap {pct}% cohort must pin fewer resident bytes \
             paged ({paged_resident}) than monolithic ({mono_resident})"
        );
        // admission replay on a cold model, exactly the scheduler's rule:
        // charge each session's reservation in FIFO order, floor one
        let gk = FallbackModel::new(cfg.clone())?;
        let mut reserved = 0usize;
        let mut admitted = 0usize;
        let mut keep_alive = Vec::new();
        for p in &prompts {
            let need = gk.session_admission_bytes(p, glen);
            if admitted > 0 && reserved + need > budget {
                break;
            }
            keep_alive.push(gk.open_session(p, glen));
            reserved += need;
            admitted += 1;
        }
        anyhow::ensure!(
            admitted >= mono_admitted,
            "pages bench gate: reservation admission ({admitted}) fell below \
             worst-case budgeting ({mono_admitted}) at overlap {pct}%"
        );
        admitted_by_overlap.push(admitted);
        for (mode, resident, adm) in
            [("paged", paged_resident, admitted), ("mono", mono_resident, mono_admitted)]
        {
            t.row(&[
                mode.to_string(),
                n.to_string(),
                pct.to_string(),
                plen.to_string(),
                glen.to_string(),
                format!("{:.1}", resident / 1024.0),
                format!("{:.1}", resident / n as f64 / 1024.0),
                adm.to_string(),
            ]);
            cells.push(PagesCell {
                mode,
                sessions: n,
                overlap_pct: pct,
                prompt_len: plen,
                gen_len: glen,
                resident_bytes: resident,
                bytes_per_session: resident / n as f64,
                admitted: adm,
            });
        }
    }
    anyhow::ensure!(
        admitted_by_overlap.last().copied().unwrap_or(0) > mono_admitted,
        "pages bench gate: the highest-overlap cohort must admit strictly more \
         sessions than worst-case budgeting ({admitted_by_overlap:?} vs {mono_admitted})"
    );
    let mut s = t.render();
    s.push_str(
        "paged = shared PagePool arena (resident = pool ledger + per-session R/desc);\n\
         mono = worst-case monolithic decode states (O(seq_len) per session up front).\n\
         admitted = sessions a 4-worst-case-session budget takes: per-session page\n\
         reservations net of cached prefix pages (paged) vs budget / worst-case (mono).\n\
         Gate: paged sessions bit-equal to single-request generate; overlap cohorts\n\
         strictly cheaper than mono; reservations never admit fewer, more at 90%.\n",
    );
    save_result(&opts.artifacts, "pages", &s)?;
    if opts.smoke {
        s.push_str("smoke run: BENCH_pages.json left untouched\n");
    } else {
        let json_path = write_pages_json(&cells)?;
        s.push_str(&format!("machine-readable medians: {}\n", json_path.display()));
    }
    println!("{s}");
    Ok(s)
}

/// Emit the pages bench machine-readably: one row per `(cohort, storage
/// mode)` with resident bytes and admitted sessions, written to
/// `BENCH_pages.json` at the repo root (the memory-side companion of
/// `BENCH_serve.json`).
fn write_pages_json(cells: &[PagesCell]) -> Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let mut rows = Vec::new();
    for c in cells {
        rows.push(Json::Obj(vec![
            ("mode".into(), Json::from(c.mode)),
            ("sessions".into(), Json::from(c.sessions)),
            ("overlap_pct".into(), Json::from(c.overlap_pct)),
            ("prompt_len".into(), Json::from(c.prompt_len)),
            ("gen_len".into(), Json::from(c.gen_len)),
            ("resident_bytes".into(), Json::from(c.resident_bytes)),
            ("bytes_per_session".into(), Json::from(c.bytes_per_session)),
            ("admitted".into(), Json::from(c.admitted)),
        ]));
    }
    let doc = Json::Obj(vec![
        ("target".into(), Json::from("pages")),
        ("unit".into(), Json::from("bytes")),
        ("cells".into(), Json::Arr(rows)),
    ]);
    let path = repo_root().join("BENCH_pages.json");
    std::fs::write(&path, doc.to_string_pretty() + "\n")?;
    Ok(path)
}

/// One measured backends cell: one `(backend, shape)` pair (median ms for
/// mix + attention, plus the quality proxy vs dense attention).
struct BackendCell {
    backend: &'static str,
    ell: usize,
    nb: usize,
    ms: f64,
    dense_max_abs: f64,
}

/// `bench backends` — the sort backends head-to-head behind the
/// `SortStrategy` trait (DESIGN.md §Backends): `sinkhorn` (the paper's
/// balanced SortNet mixing), `routing` (online k-means block clustering,
/// per Routing Transformers) and `local` (the window-only baseline, an
/// all-zero mixing matrix). Every backend is oracle-gated before timing:
/// the engine output must sit within [`ENGINE_TOL`] of the naive
/// per-backend reference in `attention.rs` (the backend's own mixing
/// matrix fed to the seed `sinkhorn_attention`), the routing strategy's
/// mixing matrix must equal the from-scratch `routing_mixing` oracle bit
/// for bit, and the parallel engine must equal the serial engine bit for
/// bit — so the head-to-head can't quietly compare different
/// computations. The quality-proxy column is the max-abs gap to *dense*
/// softmax attention over the same inputs (the paper's Table 1 framing:
/// what each sparse variant gives up vs full attention); the wall-clock
/// column times mix + attention together — the full per-layer cost a
/// backend controls. Medians land in `BENCH_backends.json` at the repo
/// root next to the other machine-readable bench files.
pub fn backends_table(opts: &BenchOptions) -> Result<String> {
    use crate::sinkhorn::{dense_attention, routing_mixing, RoutingSort, SortStrategy, ALL_BACKENDS};
    let d = 64;
    let n_iters = 8;
    let par = SinkhornEngine::auto();
    let fused = SinkhornEngine::serial();
    // smoke mode (CI): one tiny shape, one rep — the correctness gates
    // still run, the timing columns are non-representative by design
    let shapes: &[(usize, usize)] = if opts.smoke { &[(128, 4)] } else { &[(512, 8), (1024, 16)] };
    let mut t = Table::new(
        &format!(
            "backends — sort backends head-to-head, d={d} (parallel: {} threads){}",
            par.threads(),
            if opts.smoke { " [SMOKE]" } else { "" }
        ),
        &["backend", "ell", "nb", "mix+attn ms", "vs dense max-abs"],
    );
    let mut cells = Vec::new();
    for &(ell, nb) in shapes {
        let mut rng = Rng::new(0xBAC ^ (ell * 31 + nb) as u64);
        let mk = |rng: &mut Rng| Mat::from_fn(ell, d, |_, _| rng.normal() as f32 * 0.5);
        let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let feats = Mat::from_fn(nb, nb, |_, _| rng.normal() as f32);
        let dense = dense_attention(&q, &k, &v, false);
        for backend in ALL_BACKENDS {
            let strat = backend.strategy(nb);
            let r = strat.mix(&feats, n_iters, false);

            // correctness gates: one run of each path before timing
            if backend == crate::sinkhorn::Backend::Routing {
                let k_clusters = RoutingSort::for_blocks(nb).k;
                anyhow::ensure!(
                    r == routing_mixing(&feats, nb, k_clusters, false),
                    "routing strategy must equal the routing_mixing oracle bit for bit at nb={nb}"
                );
            }
            let want = sinkhorn_attention(&q, &k, &v, &r, nb, false);
            let got = par.attention(&q, &k, &v, &r, nb, false);
            let diff = want.max_abs_diff(&got);
            anyhow::ensure!(
                diff <= ENGINE_TOL,
                "{} backend diverged from its naive reference at ell={ell} nb={nb}: max-abs {diff}",
                backend.name()
            );
            anyhow::ensure!(
                fused.attention(&q, &k, &v, &r, nb, false) == got,
                "parallel engine must equal the serial engine bit for bit for backend {} at \
                 ell={ell} nb={nb}",
                backend.name()
            );
            let dense_max_abs = got.max_abs_diff(&dense) as f64;

            let iters = if opts.smoke { 1 } else { 5 };
            let mut out = Mat::zeros(ell, d);
            let mut t_mix = time_iters(1, iters, || {
                let r = strat.mix(&feats, n_iters, false);
                par.attention_into(&q, &k, &v, &r, nb, false, &mut out);
            });
            let ms = percentile(&mut t_mix, 50.0) * 1e3;
            t.row(&[
                backend.name().to_string(),
                ell.to_string(),
                nb.to_string(),
                format!("{ms:.2}"),
                format!("{dense_max_abs:.4}"),
            ]);
            cells.push(BackendCell { backend: backend.name(), ell, nb, ms, dense_max_abs });
        }
    }
    let mut s = t.render();
    s.push_str(
        "sinkhorn = balanced SortNet mixing (the paper); routing = online k-means over\n\
         block descriptors (Routing Transformers); local = window-only baseline (zero\n\
         mixing matrix -> sorted term masked, block-diagonal attention).\n\
         vs dense max-abs = quality proxy: max-abs gap to full softmax attention over\n\
         the same inputs (paper Table 1 framing). Gates: each backend within 1e-5\n\
         max-abs of its naive attention.rs reference; routing mixing bit-equal to the\n\
         routing_mixing oracle; parallel == serial engine bit for bit.\n",
    );
    save_result(&opts.artifacts, "backends", &s)?;
    if opts.smoke {
        s.push_str("smoke run: BENCH_backends.json left untouched\n");
    } else {
        let json_path = write_backends_json(d, par.threads(), &cells)?;
        s.push_str(&format!("machine-readable medians: {}\n", json_path.display()));
    }
    println!("{s}");
    Ok(s)
}

/// Emit the backends bench machine-readably: one row per `(backend,
/// shape)` with the median ns/iter for mix + attention and the quality
/// proxy vs dense attention, written to `BENCH_backends.json` at the repo
/// root — the comparative-serving-lab record (DESIGN.md §Backends).
fn write_backends_json(
    d: usize,
    threads: usize,
    cells: &[BackendCell],
) -> Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let mut rows = Vec::new();
    for c in cells {
        rows.push(Json::Obj(vec![
            ("backend".into(), Json::from(c.backend)),
            ("ell".into(), Json::from(c.ell)),
            ("nb".into(), Json::from(c.nb)),
            ("b".into(), Json::from(c.ell / c.nb)),
            ("d".into(), Json::from(d)),
            ("threads".into(), Json::from(threads)),
            ("ns_per_iter".into(), Json::from((c.ms * 1e6).round())),
            ("dense_max_abs".into(), Json::from(c.dense_max_abs)),
        ]));
    }
    let doc = Json::Obj(vec![
        ("target".into(), Json::from("backends")),
        ("unit".into(), Json::from("ns_per_iter_p50")),
        ("cells".into(), Json::Arr(rows)),
    ]);
    let path = repo_root().join("BENCH_backends.json");
    std::fs::write(&path, doc.to_string_pretty() + "\n")?;
    Ok(path)
}

/// Locate the repo root at runtime: the working directory when it (or an
/// ancestor, for `cargo run` from `rust/`) contains `rust/Cargo.toml`.
/// Falls back to the build-time manifest location only when the process
/// runs outside any checkout — a moved/renamed repo still resolves
/// correctly as long as the bench runs from inside it.
fn repo_root() -> std::path::PathBuf {
    if let Ok(cwd) = std::env::current_dir() {
        let mut dir = cwd.as_path();
        loop {
            if dir.join("rust").join("Cargo.toml").is_file() {
                return dir.to_path_buf();
            }
            match dir.parent() {
                Some(p) => dir = p,
                None => break,
            }
        }
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("."))
}

// --- helpers ---------------------------------------------------------------

fn finish(opts: &BenchOptions, tag: &str, t: Table) -> Result<String> {
    let s = t.render();
    save_result(&opts.artifacts, tag, &s)?;
    println!("{s}");
    Ok(s)
}

/// The measured variant suffixes available for a dataset prefix, sorted.
fn variant_grid(map: &HashMap<String, &ExpResult>, ds: &str) -> Vec<String> {
    let mut v: Vec<String> = map
        .keys()
        .filter(|k| k.starts_with(&format!("{ds}__")))
        .map(|k| k.split("__").nth(1).unwrap().to_string())
        .collect();
    v.sort_by_key(|s| (variant_family_rank(s), variant_block(s)));
    v
}

fn variant_family_rank(v: &str) -> usize {
    if v.starts_with("vanilla") {
        0
    } else if v.starts_with("sinkhorn") {
        1
    } else if v.starts_with("sortcut") {
        2
    } else {
        3
    }
}

fn variant_block(v: &str) -> usize {
    v.rsplit(|c: char| !c.is_ascii_digit())
        .next()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// Match "same family + same size-rank" across datasets whose block sizes
/// differ (ell-dependent): e.g. imdbw sinkhorn_b8 <-> sstc sinkhorn_b16.
fn match_variant<'a>(
    map: &'a HashMap<String, &'a ExpResult>,
    ds: &str,
    variant: &str,
) -> Option<&'a ExpResult> {
    if let Some(r) = map.get(&format!("{ds}__{variant}")) {
        return Some(r);
    }
    let grid = variant_grid(map, ds);
    // rank within family in the *source* grid
    let fam = variant_family_rank(variant);
    let same_fam: Vec<&String> = grid.iter().filter(|v| variant_family_rank(v) == fam).collect();
    let src_rank = same_fam
        .iter()
        .position(|v| variant_block(v) == variant_block(variant))
        .or_else(|| {
            // fall back to ordering of the requested variant among typical blocks
            let blocks = [4usize, 8, 16, 32, 64];
            blocks.iter().position(|&b| b == variant_block(variant))
        })?;
    same_fam
        .get(src_rank.min(same_fam.len().saturating_sub(1)))
        .and_then(|v| map.get(&format!("{ds}__{v}")))
        .copied()
}

/// Does a target train AOT artifacts (and therefore need a PJRT runtime
/// and registry), or is it runtime-free (`engine`, `decode`, `model`,
/// `serve`, `pages`, `backends`, `memory`)?
pub fn target_needs_runtime(target: &str) -> bool {
    !matches!(
        target,
        "engine" | "decode" | "model" | "serve" | "pages" | "backends" | "memory"
    )
}

/// Optional runtime + registry bootstrap shared by the CLI and the bench
/// harness: skipped entirely when `needed` is false (runtime-free
/// targets), and the root cause is printed once when a component is
/// unavailable — the downstream skip messages only say "unavailable".
pub fn load_backend(
    artifacts: &std::path::Path,
    needed: bool,
) -> (Option<Runtime>, Option<Registry>) {
    if !needed {
        return (None, None);
    }
    let rt = Runtime::cpu().map_err(|e| eprintln!("[bench] PJRT runtime unavailable: {e:#}")).ok();
    let reg = Registry::load(artifacts)
        .map_err(|e| eprintln!("[bench] registry unavailable: {e:#}"))
        .ok();
    (rt, reg)
}

/// Dispatch by target name ("table1".."table8", "fig3", "fig4", "memory",
/// "engine", "decode"). `rt`/`reg` may be `None` for runtime-free targets;
/// targets that train error out cleanly when they are missing.
pub fn run_target(
    rt: Option<&Runtime>,
    reg: Option<&Registry>,
    opts: &BenchOptions,
    target: &str,
) -> Result<()> {
    // validate the name first: a typo'd target must say "unknown", not
    // "needs a PJRT runtime"
    if !ALL_TARGETS.contains(&target) {
        anyhow::bail!(
            "unknown bench target '{target}' (expected one of {ALL_TARGETS:?}, or 'all')"
        );
    }
    if !target_needs_runtime(target) {
        match target {
            "engine" => engine_table(opts)?,
            "decode" => decode_table(opts)?,
            "model" => model_table(opts)?,
            "serve" => serve_table(opts)?,
            "pages" => pages_table(opts)?,
            "backends" => backends_table(opts)?,
            "memory" => memory_table(opts)?,
            _ => unreachable!(),
        };
        return Ok(());
    }
    let rt = rt.ok_or_else(|| {
        anyhow!("target '{target}' trains AOT artifacts and needs a PJRT runtime (DESIGN.md §2)")
    })?;
    let reg = reg.ok_or_else(|| {
        anyhow!("target '{target}' needs the experiment registry (run `make artifacts`)")
    })?;
    match target {
        "table1" => table1(rt, reg, opts)?,
        "table2" => table2(rt, reg, opts)?,
        "table3" => table3(rt, reg, opts)?,
        "table4" => table4(rt, reg, opts)?,
        "table5" => table5(rt, reg, opts)?,
        "table6" => table6(rt, reg, opts)?,
        "table7" => table7(rt, reg, opts)?,
        "table8" => table8(rt, reg, opts)?,
        "fig3" => fig3(rt, reg, opts)?,
        "fig4" => fig4(rt, reg, opts)?,
        _ => unreachable!("target validated against ALL_TARGETS above"),
    };
    Ok(())
}

/// Run every target, skipping (with a message) the training targets when
/// no runtime/registry is available — shared by the CLI and the bench
/// harness so the skip semantics live in one place.
pub fn run_all(rt: Option<&Runtime>, reg: Option<&Registry>, opts: &BenchOptions) -> Result<()> {
    for t in ALL_TARGETS {
        if target_needs_runtime(t) && (rt.is_none() || reg.is_none()) {
            eprintln!("[bench] skipping {t}: no PJRT runtime/registry (run `make artifacts`)");
            continue;
        }
        run_target(rt, reg, opts, t)?;
    }
    Ok(())
}

pub const ALL_TARGETS: &[&str] = &[
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "fig3",
    "fig4", "memory", "engine", "decode", "model", "serve", "pages", "backends",
];
