# Sparse Sinkhorn Attention — repo-level targets.
# `check-docs` is the CI documentation gate; the rest are conveniences.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test check-docs doc-refs bench-engine serve-fallback artifacts all

all: build

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

## CI documentation gate: rustdoc must be warning-free and every
## `DESIGN.md §` citation in rust/src/ must resolve to a real section.
check-docs: doc-refs
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

## The reference check alone needs no Rust toolchain (plain python3).
doc-refs:
	python3 tools/check_design_refs.py --all

## Regenerate the naive/fused/parallel engine table (no artifacts needed).
bench-engine:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target engine

## Serve the pure-Rust fallback engine over TCP (no artifacts needed):
##   echo "4 8 15 16 23 42" | nc 127.0.0.1 7878
serve-fallback:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- serve --fallback --port 7878 --wait

## AOT-compile the XLA artifacts (needs the python env + real xla crate).
artifacts:
	cd python && python -m compile.aot
