//! Pure-Rust Sinkhorn balancing — mirrors `kernels/ref.py` exactly and is
//! the oracle for the coordinator-side property tests (doubly-stochastic
//! invariants, causal support, convergence).

use super::matrix::Mat;

pub const NEG_INF: f32 = -1e9;

/// Slice logsumexp: one max pass + one sum pass, no iterator clone, no
/// allocation. Same fold order as the historical cloned-iterator version,
/// so results are unchanged bit for bit.
fn logsumexp(xs: &[f32]) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for &x in xs {
        m = m.max(x);
    }
    let m = m.max(NEG_INF);
    let mut s = 0.0f32;
    for &x in xs {
        s += (x - m).exp();
    }
    s.ln() + m
}

/// Log-domain Sinkhorn normalization: `n_iters` alternating row/column
/// normalizations of `exp(logits)`. `n_iters == 0` => row softmax only
/// (paper Table 8 row 6 ablation).
pub fn sinkhorn(logits: &Mat, n_iters: usize) -> Mat {
    let mut x = logits.clone();
    if n_iters == 0 {
        x.softmax_rows();
        return x;
    }
    let (n, m) = (x.rows, x.cols);
    let mut col = vec![0.0f32; n]; // reused column staging for the slice lse
    for _ in 0..n_iters {
        for i in 0..n {
            let lse = logsumexp(x.row(i));
            for v in x.row_mut(i) {
                *v -= lse;
            }
        }
        for j in 0..m {
            for (i, c) in col.iter_mut().enumerate() {
                *c = x[(i, j)];
            }
            let lse = logsumexp(&col);
            for i in 0..n {
                x[(i, j)] -= lse;
            }
        }
    }
    for v in &mut x.data {
        *v = v.exp();
    }
    x
}

/// Causal masked variant (§3.3.2): entries with src block j after dest
/// block i (j > i; `strict` also j == i) are pinned to zero, and — the
/// crucial part — the *column* normalizer at entry (i, j) only sums rows
/// j..=i. A full column sum would include rows i' > i whose logits encode
/// future block content, leaking the future through the normalizer
/// (mirrors `ref.causal_sinkhorn_log`; pinned by tests on both sides).
pub fn causal_sinkhorn(logits: &Mat, n_iters: usize, strict: bool) -> Mat {
    let n = logits.rows;
    let keep = |i: usize, j: usize| if strict { j < i } else { j <= i };
    let mut x = Mat::from_fn(n, n, |i, j| if keep(i, j) { logits[(i, j)] } else { NEG_INF });
    if n_iters == 0 {
        x.softmax_rows();
        return Mat::from_fn(n, n, |i, j| if keep(i, j) { x[(i, j)] } else { 0.0 });
    }
    for _ in 0..n_iters {
        for i in 0..n {
            let lse = logsumexp(x.row(i)).max(NEG_INF);
            for (j, v) in x.row_mut(i).iter_mut().enumerate() {
                *v = if keep(i, j) { *v - lse } else { NEG_INF };
            }
        }
        for j in 0..n {
            // cumulative (causal) column logsumexp, stabilized by the
            // column max (cancels exactly — see ref.py)
            let cmax = (0..n).map(|i| x[(i, j)]).fold(f32::NEG_INFINITY, f32::max).max(NEG_INF);
            let mut csum = 0.0f32;
            for i in 0..n {
                if keep(i, j) {
                    csum += (x[(i, j)] - cmax).exp();
                    let ncol = ((csum + 1e-30).ln() + cmax).max(NEG_INF);
                    x[(i, j)] -= ncol;
                } else {
                    x[(i, j)] = NEG_INF;
                }
            }
        }
    }
    Mat::from_fn(n, n, |i, j| if keep(i, j) { x[(i, j)].exp() } else { 0.0 })
}

/// How far a matrix is from doubly stochastic: max |row/col sum - 1|.
pub fn ds_residual(s: &Mat) -> f32 {
    let mut worst: f32 = 0.0;
    for i in 0..s.rows {
        let r: f32 = s.row(i).iter().sum();
        worst = worst.max((r - 1.0).abs());
    }
    for j in 0..s.cols {
        let c: f32 = (0..s.rows).map(|i| s[(i, j)]).sum();
        worst = worst.max((c - 1.0).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    fn rand_logits(g: &mut Gen, n: usize) -> Mat {
        Mat::from_vec(n, n, g.vec_f32(n * n, -3.0, 3.0))
    }

    #[test]
    fn converges_to_doubly_stochastic() {
        forall(
            48,
            0xD5,
            |g| {
                let n = 2 + g.usize(0, 7);
                rand_logits(g, n)
            },
            |logits| {
                let s = sinkhorn(logits, 30);
                let r = ds_residual(&s);
                if r < 5e-3 {
                    Ok(())
                } else {
                    Err(format!("residual {r}"))
                }
            },
        );
    }

    #[test]
    fn residual_decreases_with_iters() {
        let mut g_ = crate::util::rng::Rng::new(7);
        let logits = Mat::from_fn(8, 8, |_, _| g_.normal() as f32);
        let r1 = ds_residual(&sinkhorn(&logits, 1));
        let r5 = ds_residual(&sinkhorn(&logits, 5));
        let r20 = ds_residual(&sinkhorn(&logits, 20));
        assert!(r5 <= r1 + 1e-6 && r20 <= r5 + 1e-6, "{r1} {r5} {r20}");
    }

    #[test]
    fn nonnegative_entries() {
        forall(
            32,
            0xA1,
            |g| {
                let n = 2 + g.usize(0, 6);
                rand_logits(g, n)
            },
            |l| {
                let s = sinkhorn(l, 5);
                if s.data.iter().all(|&x| x >= 0.0) {
                    Ok(())
                } else {
                    Err("negative entry".into())
                }
            },
        );
    }

    #[test]
    fn causal_support_respected() {
        forall(
            32,
            0xC2,
            |g| {
                let n = 3 + g.usize(0, 5);
                rand_logits(g, n)
            },
            |l| {
                for strict in [false, true] {
                    let s = causal_sinkhorn(l, 8, strict);
                    for i in 0..s.rows {
                        for j in 0..s.cols {
                            let banned = if strict { j >= i } else { j > i };
                            if banned && s[(i, j)] != 0.0 {
                                return Err(format!("leak at ({i},{j}) strict={strict}"));
                            }
                        }
                    }
                    // all entries must be valid probabilities-ish weights
                    for v in &s.data {
                        if !v.is_finite() || *v < 0.0 {
                            return Err(format!("bad entry {v}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn causal_normalizers_never_see_future() {
        // THE causal invariant: perturbing row i' of the logits must not
        // change any output row i < i' (this is what full-column Sinkhorn
        // normalization violates — see §3.3.2 and the kernel docstring)
        forall(
            32,
            0xF1,
            |g| {
                let n = 3 + g.usize(0, 5);
                rand_logits(g, n)
            },
            |l| {
                for strict in [false, true] {
                    let n = l.rows;
                    let base = causal_sinkhorn(l, 7, strict);
                    for tgt in 1..n {
                        let mut l2 = l.clone();
                        for j in 0..n {
                            l2[(tgt, j)] += 2.5;
                        }
                        let pert = causal_sinkhorn(&l2, 7, strict);
                        for i in 0..tgt {
                            for j in 0..n {
                                let d = (base[(i, j)] - pert[(i, j)]).abs();
                                if d > 1e-5 {
                                    return Err(format!(
                                        "row {i} changed by {d} when row {tgt} perturbed (strict={strict})"
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Max |row sum - 1| over rows that have causal support (row 0 has
    /// none in strict mode and is excluded — its sum is pinned to 0).
    fn causal_row_residual(s: &Mat, strict: bool) -> f32 {
        let mut worst = 0.0f32;
        for i in 0..s.rows {
            if strict && i == 0 {
                continue;
            }
            let r: f32 = s.row(i).iter().sum();
            worst = worst.max((r - 1.0).abs());
        }
        worst
    }

    #[test]
    fn causal_supported_rows_approach_stochastic() {
        // the decoder's rebalance primitive: after enough iterations every
        // row with causal support must be (approximately) a probability
        // distribution over its visible source blocks
        forall(
            24,
            0xC5,
            |g| {
                let n = 2 + g.usize(0, 5);
                rand_logits(g, n)
            },
            |l| {
                for strict in [false, true] {
                    let s = causal_sinkhorn(l, 30, strict);
                    let r = causal_row_residual(&s, strict);
                    if r > 0.1 {
                        return Err(format!("row residual {r} (strict={strict})"));
                    }
                    if strict {
                        let r0: f32 = s.row(0).iter().sum();
                        if r0 != 0.0 {
                            return Err(format!("strict row 0 must be empty, sums to {r0}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn causal_row_residual_monotone_over_iters() {
        // more balancing never moves the supported rows further from
        // stochastic — the ds_residual_decreases_with_iters analogue under
        // the causal mask
        forall(
            20,
            0xC6,
            |g| {
                let n = 3 + g.usize(0, 4);
                rand_logits(g, n)
            },
            |l| {
                for strict in [false, true] {
                    let r1 = causal_row_residual(&causal_sinkhorn(l, 1, strict), strict);
                    let r5 = causal_row_residual(&causal_sinkhorn(l, 5, strict), strict);
                    let r20 = causal_row_residual(&causal_sinkhorn(l, 20, strict), strict);
                    if !(r5 <= r1 + 1e-4 && r20 <= r5 + 1e-4) {
                        return Err(format!("not monotone (strict={strict}): {r1} {r5} {r20}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn causal_prefix_consistent() {
        // THE decode-enabling property (DESIGN.md §Decode): balancing the
        // top-left (m, m) corner of the logits agrees with the top-left of
        // the full balance — entry (i, j) only ever depends on logits rows
        // <= i — so the incremental decoder may cache balanced rows across
        // block boundaries instead of rebalancing the whole history
        forall(
            24,
            0xC7,
            |g| {
                let n = 2 + g.usize(0, 5);
                rand_logits(g, n)
            },
            |l| {
                for strict in [false, true] {
                    let full = causal_sinkhorn(l, 6, strict);
                    for m in 1..=l.rows {
                        let sub_logits = Mat::from_fn(m, m, |i, j| l[(i, j)]);
                        let sub = causal_sinkhorn(&sub_logits, 6, strict);
                        for i in 0..m {
                            for j in 0..m {
                                let d = (sub[(i, j)] - full[(i, j)]).abs();
                                if d > 1e-5 {
                                    return Err(format!(
                                        "prefix m={m} diverges at ({i},{j}) by {d} (strict={strict})"
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_iters_is_row_softmax() {
        let l = Mat::from_vec(2, 2, vec![0.0, 0.0, 1.0, 3.0]);
        let s = sinkhorn(&l, 0);
        assert!((s[(0, 0)] - 0.5).abs() < 1e-6);
        let e = ((1.0f32).exp(), (3.0f32).exp());
        assert!((s[(1, 1)] - e.1 / (e.0 + e.1)).abs() < 1e-6);
    }

    #[test]
    fn permutation_fixed_point() {
        // a matrix already near a hard permutation stays put
        let mut l = Mat::zeros(4, 4);
        let perm = [2usize, 0, 3, 1];
        for (i, &p) in perm.iter().enumerate() {
            l[(i, p)] = 20.0; // huge logit
        }
        let s = sinkhorn(&l, 10);
        for (i, &p) in perm.iter().enumerate() {
            assert!(s[(i, p)] > 0.99, "({i},{p}) = {}", s[(i, p)]);
        }
    }
}
