//! Batched inference serving (the L3 "router" role): client threads submit
//! token sequences; a dynamic batcher groups them; a single executor thread
//! owning the PJRT runtime classifies whole batches at once.

pub mod batch;
pub mod service;
pub mod tcp;

pub use batch::{gather, BatchPolicy};
pub use service::{Response, Server, ServerHandle};
pub use tcp::TcpFrontend;
