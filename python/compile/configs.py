"""Experiment registry — the single source of truth shared by the AOT
exporter (this package) and the Rust coordinator (via artifacts/registry.json).

Every entry maps to one (task, attention-variant) pair from the paper's
evaluation section and produces two HLO artifacts (train + eval) plus a
manifest. Scales are shrunk to a 1-core CPU testbed (see DESIGN.md §4 —
we reproduce the *shape* of each table, not absolute numbers).

Naming: ``<task>__<variant>``, where variant encodes the paper's column,
e.g. ``sinkhorn_b16`` = Sinkhorn Transformer with block length 16.
"""

from __future__ import annotations

# --------------------------------------------------------------------------
# model-size presets (paper: Base 50M / Big 430M -> here: tiny / small)
# --------------------------------------------------------------------------

TINY = dict(d_model=64, n_heads=4, d_ff=128, n_layers=2)
SMALL = dict(d_model=128, n_heads=4, d_ff=256, n_layers=3)


def _cfg(size, *, vocab, ell, block, variant, **kw):
    cfg = dict(size)
    cfg.update(vocab=vocab, ell=ell, variant=variant)
    assert ell % block == 0, (ell, block)
    cfg["block"] = block
    cfg["nb"] = ell // block
    cfg.setdefault("sinkhorn_iters", 5)
    cfg.setdefault("tau", 0.75)
    cfg.setdefault("p_variant", 4)
    cfg.setdefault("share_kv", False)
    cfg.update(kw)
    return cfg


def _variants(ell, blocks, *, sortcut=False, include_big_local=True):
    """The standard comparison set used by most tables."""
    out = [("vanilla", dict(variant="vanilla", block=blocks[-1]))]
    for b in blocks if include_big_local else blocks[-1:]:
        out.append((f"local_b{b}", dict(variant="local", block=b)))
    out.append((f"sparse_b{blocks[-1]}", dict(variant="sparse", block=blocks[-1])))
    for b in blocks:
        out.append((f"sinkhorn_b{b}", dict(variant="sinkhorn", block=b)))
    out.append(("mixture", dict(variant="mixture", block=blocks[-1])))
    if sortcut:
        for b in blocks:
            out.append((f"sortcut_2x{b}", dict(variant="sortcut", block=b, n_cut=2)))
    return out


EXPERIMENTS: list[dict] = []


def _add(name, family, size, *, vocab, ell, variant_kw, train, table, **extra):
    kw = dict(variant_kw)
    block = kw.pop("block")
    variant = kw.pop("variant")
    cfg = _cfg(size, vocab=vocab, ell=ell, block=block, variant=variant, **kw, **extra)
    EXPERIMENTS.append(
        dict(name=name, family=family, cfg=cfg, train=train, table=table)
    )


# --------------------------------------------------------------------------
# Table 1 — algorithmic sorting, seq2seq, eval at 2x length
# --------------------------------------------------------------------------
SORT_TRAIN = dict(batch=8, warmup=200, default_steps=400, eval_batch=8)
for vname, vkw in [
    ("vanilla", dict(variant="vanilla", block=16)),
    ("local_b16", dict(variant="local", block=16)),
    ("sparse_b16", dict(variant="sparse", block=16)),
    ("sinkhorn_b4", dict(variant="sinkhorn", block=4)),
    ("sinkhorn_b8", dict(variant="sinkhorn", block=8)),
    ("sinkhorn_b16", dict(variant="sinkhorn", block=16)),
]:
    _add(
        f"sort__{vname}", "seq2seq", TINY, vocab=20, ell=64,
        variant_kw=vkw, train=SORT_TRAIN, table="table1",
        ell_tgt=64, ell_eval=128, ell_tgt_eval=128,
    )

# --------------------------------------------------------------------------
# Table 2 — word-level LM, tiny ("Base") and small ("Big") columns
# --------------------------------------------------------------------------
LM_TRAIN = dict(batch=8, warmup=400, default_steps=400, eval_batch=8)
for size_name, size in [("tiny", TINY), ("small", SMALL)]:
    for vname, vkw in _variants(128, [8, 16, 32]):
        _add(
            f"lmw_{size_name}__{vname}", "lm", size, vocab=512, ell=128,
            variant_kw=vkw, train=LM_TRAIN, table="table2",
        )

# --------------------------------------------------------------------------
# Table 4 — char-level LM (longer sequences, fixed block)
# --------------------------------------------------------------------------
for vname, vkw in _variants(256, [32], include_big_local=True):
    _add(
        f"lmc__{vname}", "lm", TINY, vocab=96, ell=256,
        variant_kw=vkw, train=dict(LM_TRAIN, batch=4), table="table4",
    )

# --------------------------------------------------------------------------
# Table 5 — pixel-wise image generation (flattened RGB, ell = 8x8x3)
# --------------------------------------------------------------------------
for vname, vkw in _variants(192, [16], include_big_local=True):
    _add(
        f"img__{vname}", "lm", TINY, vocab=256, ell=192,
        variant_kw=vkw, train=dict(LM_TRAIN, batch=4), table="table5",
    )

# --------------------------------------------------------------------------
# Tables 6/7 — classification: sentiment (word+char) and NLI
# --------------------------------------------------------------------------
CLS_TRAIN = dict(batch=16, warmup=200, default_steps=300, eval_batch=32)
CLS_SETS = [
    ("imdbw", 512, 128, 2, "table6"),  # (name, vocab, ell, classes, table)
    ("imdbc", 64, 256, 2, "table6"),
    ("sstw", 512, 64, 2, "table6"),
    ("sstc", 64, 256, 2, "table6"),
    ("snli", 512, 128, 3, "table7"),
    ("mnli", 512, 128, 3, "table7"),
]
for dsname, vocab, ell, ncls, table in CLS_SETS:
    blocks = [max(4, ell // 32), max(8, ell // 16), max(16, ell // 8)]
    variants = [("vanilla", dict(variant="vanilla", block=blocks[-1]))]
    for b in blocks:
        variants.append((f"sinkhorn_b{b}", dict(variant="sinkhorn", block=b)))
    for b in blocks:
        variants.append((f"sortcut_2x{b}", dict(variant="sortcut", block=b, n_cut=2)))
    for vname, vkw in variants:
        _add(
            f"{dsname}__{vname}", "cls", TINY, vocab=vocab, ell=ell,
            variant_kw=vkw, train=CLS_TRAIN, table=table, n_classes=ncls,
        )

# --------------------------------------------------------------------------
# Table 8 — SortNet ablations (on word LM, block 16)
# --------------------------------------------------------------------------
ABL = [
    ("p1", dict(p_variant=1)),
    ("p2", dict(p_variant=2)),
    ("p3", dict(p_variant=3)),
    # p4 == lmw_tiny__sinkhorn_b16 (the default)
    ("sharekv", dict(share_kv=True)),
    ("noiters", dict(sinkhorn_iters=0)),
]
for aname, akw in ABL:
    _add(
        f"abl_{aname}__sinkhorn_b16", "lm", TINY, vocab=512, ell=128,
        variant_kw=dict(variant="sinkhorn", block=16), train=LM_TRAIN,
        table="table8", **akw,
    )

# --------------------------------------------------------------------------
# Figure 3 — Gumbel temperature sweep; Figure 4 — sinkhorn iteration sweep
# --------------------------------------------------------------------------
for tau in (0.25, 0.5, 1.0):  # 0.75 is the default above
    _add(
        f"fig3_tau{str(tau).replace('.', 'p')}__sinkhorn_b16", "lm", TINY,
        vocab=512, ell=128, variant_kw=dict(variant="sinkhorn", block=16),
        train=LM_TRAIN, table="fig3", tau=tau,
    )
for k in (1, 2, 10, 20):  # 5 is the default; 0 is abl_noiters
    _add(
        f"fig4_k{k}__sinkhorn_b16", "lm", TINY, vocab=512, ell=128,
        variant_kw=dict(variant="sinkhorn", block=16), train=LM_TRAIN,
        table="fig4", sinkhorn_iters=k,
    )


BY_NAME = {e["name"]: e for e in EXPERIMENTS}


def eval_cfg(exp: dict) -> dict:
    """Config used to lower the eval graph (seq2seq evals at 2x length)."""
    cfg = dict(exp["cfg"])
    if "ell_eval" in cfg:
        cfg["ell"] = cfg["ell_eval"]
        cfg["ell_tgt"] = cfg["ell_tgt_eval"]
        # nb is kept fixed; the block length doubles with the sequence
    return cfg


if __name__ == "__main__":
    from collections import Counter

    print(len(EXPERIMENTS), "experiments")
    print(Counter(e["table"] for e in EXPERIMENTS))
