//! Synthetic classification datasets (stand-ins for IMDb/SST — §5.4 — and
//! SNLI/MNLI — Table 7), with *planted* signals that reward global context:
//!
//! **Sentiment**: documents mix neutral filler with lexicon words. The
//! label is determined by which sentiment lexicon dominates, but lexicon
//! words are *spread across the whole document* (and a fraction of
//! documents put all their evidence in the final quarter), so a model
//! restricted to an early local window underperforms.
//!
//! **NLI**: premise = entity-attribute assignments ("e3 a7 v2"), the
//! hypothesis re-states one (entailment), contradicts a value
//! (contradiction), or mentions an unseen entity (neutral). Premise and
//! hypothesis are concatenated with a SEP, as in the paper's T2T setup.

use crate::util::rng::Rng;

use super::tokenizer::{pad_to, CharVocab, N_SPECIALS, SEP};

/// Word-level sentiment task.
pub struct SentimentTask {
    pub vocab: usize,
    rng: Rng,
    zipf_cache: Vec<f64>,
    lex_size: usize,
}

#[derive(Debug, Clone)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32,
}

impl SentimentTask {
    pub fn new(vocab: usize, seed: u64) -> Self {
        SentimentTask { vocab, rng: Rng::new(seed), zipf_cache: Vec::new(), lex_size: 24 }
    }

    /// token-id layout: [specials | pos lexicon | neg lexicon | filler]
    fn pos_word(&mut self) -> i32 {
        N_SPECIALS + self.rng.usize_below(self.lex_size) as i32
    }

    fn neg_word(&mut self) -> i32 {
        N_SPECIALS + (self.lex_size + self.rng.usize_below(self.lex_size)) as i32
    }

    fn filler(&mut self) -> i32 {
        let base = N_SPECIALS as usize + 2 * self.lex_size;
        let n = self.vocab - base;
        (base + self.rng.zipf(n, 1.1, &mut self.zipf_cache)) as i32
    }

    pub fn example(&mut self, len: usize) -> Example {
        let label = self.rng.usize_below(2) as i32;
        // evidence budget: 8-14% of tokens are sentiment-bearing, with a
        // 60/40 majority for the true label
        let n_evidence = (len as f64 * (0.08 + self.rng.f64() * 0.06)) as usize;
        let n_major = (n_evidence as f64 * 0.8) as usize;
        let late_only = self.rng.bool(0.3); // sometimes all signal is late
        let mut tokens: Vec<i32> = (0..len).map(|_| self.filler()).collect();
        for e in 0..n_evidence {
            let major = e < n_major;
            let w = match (label, major) {
                (1, true) | (0, false) => self.pos_word(),
                _ => self.neg_word(),
            };
            let pos = if late_only {
                len - 1 - self.rng.usize_below(len / 4)
            } else {
                self.rng.usize_below(len)
            };
            tokens[pos] = w;
        }
        Example { tokens, label }
    }

    pub fn dataset(&mut self, n: usize, len: usize) -> Vec<Example> {
        (0..n).map(|_| self.example(len)).collect()
    }
}

/// Char-level sentiment: word examples rendered to characters.
pub struct CharSentimentTask {
    inner: SentimentTask,
    cv: CharVocab,
}

impl CharSentimentTask {
    pub fn new(seed: u64) -> Self {
        CharSentimentTask { inner: SentimentTask::new(512, seed), cv: CharVocab::ascii() }
    }

    pub fn example(&mut self, char_len: usize) -> Example {
        let w = self.inner.example(char_len / 4);
        let mut chars = Vec::with_capacity(char_len);
        for tok in w.tokens {
            let word = super::corpus::CharCorpus::render_word(tok);
            chars.extend(self.cv.encode_str(&word));
            chars.push(self.cv.encode(' '));
            if chars.len() >= char_len {
                break;
            }
        }
        Example { tokens: pad_to(chars, char_len), label: w.label }
    }

    pub fn dataset(&mut self, n: usize, char_len: usize) -> Vec<Example> {
        (0..n).map(|_| self.example(char_len)).collect()
    }
}

/// NLI task: 3-way entailment over synthetic entity-attribute worlds.
pub struct NliTask {
    pub vocab: usize,
    rng: Rng,
    n_entities: usize,
    n_attrs: usize,
    n_values: usize,
}

impl NliTask {
    pub fn new(vocab: usize, seed: u64, hard: bool) -> Self {
        // `hard` (MNLI-like) uses a bigger world => lower accuracy ceiling
        let scale = if hard { 2 } else { 1 };
        NliTask {
            vocab,
            rng: Rng::new(seed),
            n_entities: 40 * scale,
            n_attrs: 12 * scale,
            n_values: 20 * scale,
        }
    }

    fn ent(&self, i: usize) -> i32 {
        N_SPECIALS + (i % self.n_entities) as i32
    }

    fn attr(&self, i: usize) -> i32 {
        N_SPECIALS + (self.n_entities + i % self.n_attrs) as i32
    }

    fn val(&self, i: usize) -> i32 {
        N_SPECIALS + (self.n_entities + self.n_attrs + i % self.n_values) as i32
    }

    /// labels: 0 = entailment, 1 = contradiction, 2 = neutral.
    pub fn example(&mut self, len: usize) -> Example {
        let n_facts = 3 + self.rng.usize_below(4);
        let mut facts: Vec<(usize, usize, usize)> = Vec::with_capacity(n_facts);
        while facts.len() < n_facts {
            let e = self.rng.usize_below(self.n_entities);
            let a = self.rng.usize_below(self.n_attrs);
            // unique (entity, attribute) pairs keep the world consistent —
            // otherwise a "contradiction" could restate another fact
            if facts.iter().any(|f| f.0 == e && f.1 == a) {
                continue;
            }
            facts.push((e, a, self.rng.usize_below(self.n_values)));
        }
        let label = self.rng.usize_below(3) as i32;
        let probe = facts[self.rng.usize_below(facts.len())];
        let hyp = match label {
            0 => probe, // restated fact
            1 => {
                // same entity+attr, different value
                let mut v = self.rng.usize_below(self.n_values);
                if v == probe.2 {
                    v = (v + 1) % self.n_values;
                }
                (probe.0, probe.1, v)
            }
            _ => {
                // unseen entity => neutral
                let mut e = self.rng.usize_below(self.n_entities);
                while facts.iter().any(|f| f.0 == e) {
                    e = (e + 1) % self.n_entities;
                }
                (e, self.rng.usize_below(self.n_attrs), self.rng.usize_below(self.n_values))
            }
        };

        let mut tokens = Vec::with_capacity(len);
        for &(e, a, v) in &facts {
            tokens.extend_from_slice(&[self.ent(e), self.attr(a), self.val(v)]);
        }
        tokens.push(SEP);
        tokens.extend_from_slice(&[self.ent(hyp.0), self.attr(hyp.1), self.val(hyp.2)]);
        Example { tokens: pad_to(tokens, len), label }
    }

    pub fn dataset(&mut self, n: usize, len: usize) -> Vec<Example> {
        (0..n).map(|_| self.example(len)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentiment_labels_balanced_and_in_range() {
        let mut t = SentimentTask::new(512, 1);
        let ds = t.dataset(200, 64);
        let ones: usize = ds.iter().filter(|e| e.label == 1).count();
        assert!((60..140).contains(&ones), "unbalanced: {ones}");
        for e in &ds {
            assert_eq!(e.tokens.len(), 64);
            assert!(e.tokens.iter().all(|&t| t >= 0 && (t as usize) < 512));
        }
    }

    #[test]
    fn sentiment_signal_learnable_by_lexicon_count() {
        // a bag-of-lexicon classifier should beat chance comfortably —
        // sanity that the planted signal exists
        let mut t = SentimentTask::new(512, 2);
        let ds = t.dataset(400, 128);
        let lex = 24usize;
        let mut correct = 0;
        for e in &ds {
            let pos = e
                .tokens
                .iter()
                .filter(|&&w| (N_SPECIALS..N_SPECIALS + lex as i32).contains(&w))
                .count();
            let neg = e
                .tokens
                .iter()
                .filter(|&&w| {
                    (N_SPECIALS + lex as i32..N_SPECIALS + 2 * lex as i32).contains(&w)
                })
                .count();
            let pred = i32::from(pos >= neg);
            if pred == e.label {
                correct += 1;
            }
        }
        assert!(correct > 300, "signal too weak: {correct}/400");
    }

    #[test]
    fn char_sentiment_shapes() {
        let mut t = CharSentimentTask::new(3);
        let e = t.example(256);
        assert_eq!(e.tokens.len(), 256);
    }

    #[test]
    fn nli_label_consistency() {
        let mut t = NliTask::new(512, 7, false);
        for _ in 0..100 {
            let e = t.example(128);
            assert!((0..3).contains(&e.label));
            let sep_pos = e.tokens.iter().position(|&x| x == SEP).unwrap();
            // hypothesis triple follows SEP
            let h = &e.tokens[sep_pos + 1..sep_pos + 4];
            let facts: Vec<&[i32]> = e.tokens[..sep_pos].chunks(3).collect();
            let restated = facts.iter().any(|f| f == &h);
            match e.label {
                0 => assert!(restated, "entailment must restate a fact"),
                1 => {
                    assert!(!restated);
                    assert!(
                        facts.iter().any(|f| f[0] == h[0] && f[1] == h[1] && f[2] != h[2]),
                        "contradiction must conflict on a value"
                    );
                }
                _ => assert!(
                    !facts.iter().any(|f| f[0] == h[0]),
                    "neutral entity must be unseen"
                ),
            }
        }
    }

    #[test]
    fn nli_tokens_in_vocab() {
        let mut t = NliTask::new(512, 9, true);
        let ds = t.dataset(50, 128);
        for e in ds {
            assert!(e.tokens.iter().all(|&x| x >= 0 && (x as usize) < 512));
        }
    }
}
