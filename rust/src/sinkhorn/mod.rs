//! Pure-Rust reference implementation of Sparse Sinkhorn Attention.
//!
//! This is *not* on the training hot path (that's the AOT-compiled XLA
//! graphs); it exists to (1) property-test the algorithm's invariants from
//! the coordinator side, (2) cross-check artifact numerics end-to-end,
//! (3) back the §4 memory-complexity analysis with an executable model,
//! and — since the [`engine`] rework — (4) serve inference on machines
//! with no compiled HLO artifacts at all, through the streaming blocked
//! execution engine (DESIGN.md §Engine, §Streaming) that
//! `server::fallback` runs on, including (5) token-by-token autoregressive
//! generation through the incremental [`decode`] path (DESIGN.md §Decode).

pub mod attention;
pub mod balance;
pub mod decode;
pub mod engine;
pub mod matrix;
pub mod memory;
pub mod pool;

pub use attention::{
    causal_decode_attention, dense_attention, local_attention, sinkhorn_attention,
    sortcut_attention,
};
pub use balance::{causal_sinkhorn, ds_residual, sinkhorn};
pub use decode::{DecodeScratch, DecodeState};
pub use engine::{AttentionReq, BlockedView, DecodeReq, SinkhornEngine};
pub use matrix::{Mat, MatView, MatViewMut};
pub use pool::WorkerPool;
