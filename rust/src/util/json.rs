//! Minimal JSON codec (parser + serializer).
//!
//! `serde`/`serde_json` are not available in the offline crate set, so the
//! artifact manifests, experiment registry, configs and result files are
//! handled by this module. Supports the full JSON grammar (objects, arrays,
//! strings with escapes incl. `\uXXXX`, numbers, bools, null). Object key
//! order is preserved (insertion order) so round-trips are stable.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

// hand-rolled (no `thiserror` in the offline crate set)
impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Like [`get`] but errors with the missing key name — for manifests.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Typed convenience: `m.str_of("name")?`.
    pub fn str_of(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a string"))?
            .to_string())
    }

    pub fn f64_of(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a number"))
    }

    pub fn usize_of(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a non-negative number"))
    }

    /// Map of object entries for iteration with stable order.
    pub fn entries(&self) -> BTreeMap<&str, &Json> {
        match self {
            Json::Obj(kv) => kv.iter().map(|(k, v)| (k.as_str(), v)).collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-print with 1-space indent (matches python `json.dumps(indent=1)`).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl fmt::Display) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| self.err(e))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|e| self.err(e))?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| self.err(e))?;
                            // (surrogate pairs not needed for our manifests;
                            // lone surrogates map to the replacement char)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|e| self.err(e))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut o = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            o.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// Builder helpers for writing result files.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"n":1.25,"s":"he\"llo","a":[true,false,null],"o":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }

    #[test]
    fn int_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn fuzz_roundtrip_random_values() {
        use crate::util::prop::{forall, Gen};

        fn gen_value(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize(0, 4) } else { g.usize(0, 6) } {
                0 => Json::Null,
                1 => Json::Bool(g.usize(0, 2) == 1),
                2 => Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => {
                    let n = g.usize(0, 8);
                    let chars: Vec<char> =
                        "ab\"\\\n\té ".chars().collect();
                    Json::Str((0..n).map(|_| *g.rng.choice(&chars)).collect())
                }
                4 => Json::Arr((0..g.usize(0, 4)).map(|_| gen_value(g, depth.saturating_sub(1))).collect()),
                _ => Json::Obj(
                    (0..g.usize(0, 4))
                        .map(|i| (format!("k{i}"), gen_value(g, depth.saturating_sub(1))))
                        .collect(),
                ),
            }
        }

        forall(
            128,
            0x15,
            |g| gen_value(g, 3),
            |v| {
                let compact = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
                let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
                if &compact != v {
                    return Err(format!("compact mismatch: {compact:?}"));
                }
                if &pretty != v {
                    return Err(format!("pretty mismatch: {pretty:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pretty_matches_python_json_dumps_indent1() {
        let v = Json::parse(r#"{"a": [1, 2], "b": {"c": true}}"#).unwrap();
        assert_eq!(
            v.to_string_pretty(),
            "{\n \"a\": [\n  1,\n  2\n ],\n \"b\": {\n  \"c\": true\n }\n}"
        );
    }
}
