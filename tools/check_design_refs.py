#!/usr/bin/env python3
"""Verify that every `DESIGN.md §<anchor>` citation in rust/src/ names a
section that actually exists in DESIGN.md (the repo's docs used to cite
seven sections that didn't exist — this check keeps them resolvable).

Usage: python3 tools/check_design_refs.py [--all]
  --all also scans python/, examples/, rust/tests/ and rust/benches/
Exit code 0 when every reference resolves, 1 otherwise.
"""
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# A citation may continue with comma-separated anchors ("DESIGN.md
# §Engine, §Streaming") — capture the whole run, then pull every anchor
# out of it, so secondary anchors are verified too.
REF_RE = re.compile(r"DESIGN\.md ((?:§[A-Za-z0-9_-]+(?:,\s*)?)+)")
ANCHOR_RE = re.compile(r"§([A-Za-z0-9_-]+)")
HEADING_RE = re.compile(r"^#{1,6}\s+.*§([A-Za-z0-9_-]+)", re.MULTILINE)

# Anchors the codebase is built around — DESIGN.md must keep these
# headings even before any citation goes stale (a refactor that drops a
# section should fail here, not when someone later cites it).
REQUIRED_ANCHORS = {
    "1", "2", "4",
    "Engine", "Perf", "Hardware-Adaptation",
    # streaming-kernel PR: flash-style softmax + tiled microkernel docs
    "Streaming", "Microkernels",
}


def main() -> int:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist")
        return 1
    anchors = set(HEADING_RE.findall(design.read_text(encoding="utf-8")))

    scan_dirs = [ROOT / "rust" / "src"]
    if "--all" in sys.argv[1:]:
        scan_dirs += [
            ROOT / "python",
            ROOT / "examples",
            ROOT / "rust" / "tests",
            ROOT / "rust" / "benches",
        ]

    refs = []  # (file, line_no, anchor)
    for d in scan_dirs:
        for path in sorted(d.rglob("*")):
            if path.suffix not in {".rs", ".py", ".md"} or not path.is_file():
                continue
            for i, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
                for run in REF_RE.findall(line):
                    for anchor in ANCHOR_RE.findall(run):
                        refs.append((path.relative_to(ROOT), i, anchor))

    if not refs:
        print("FAIL: found no DESIGN.md § references — scan paths wrong?")
        return 1

    bad = [(f, i, a) for (f, i, a) in refs if a not in anchors]
    for f, i, a in bad:
        print(f"FAIL: {f}:{i} cites DESIGN.md §{a}, but DESIGN.md has no such section")
    missing = REQUIRED_ANCHORS - anchors
    for a in sorted(missing):
        print(f"FAIL: DESIGN.md lost the required section anchor §{a}")
    print(
        f"checked {len(refs)} references to {len(set(a for _, _, a in refs))} anchors "
        f"({', '.join(sorted(set(a for _, _, a in refs)))}) "
        f"against {len(anchors)} headings "
        f"({len(REQUIRED_ANCHORS)} required): "
        + ("FAIL" if bad or missing else "OK")
    )
    return 1 if bad or missing else 0


if __name__ == "__main__":
    sys.exit(main())
