//! Incremental autoregressive decoding for Sparse Sinkhorn Attention
//! (DESIGN.md §Decode).
//!
//! The batch paths ([`super::attention`], [`super::engine`]) recompute the
//! whole sequence's attention on every call — O(ℓ·b·d) per token if a
//! server replayed them once per generated token. This module is the
//! serving decode path: a per-sequence [`DecodeState`] caches everything
//! that survives from step to step, so producing one more token costs
//! O(b·d):
//!
//! * **K/V cache** — the new token's projected key/value rows are appended
//!   into block-aligned storage; nothing earlier is touched.
//! * **Cached sort state, owned by the strategy** — the block-mixing
//!   matrix `R` is recomputed through the state's [`SortStrategy`]
//!   ([`SortStrategy::mix_prefix`], DESIGN.md §Backends) only when a
//!   block boundary fills. For the default [`SinkhornSort`] that is
//!   Causal Sinkhorn Balancing ([`causal_sinkhorn`] with `strict =
//!   true`), and the caching rule is sound because strict-causal
//!   balancing is *prefix-consistent*: `R[i, j]` depends only on logits
//!   rows `<= i`, so the `(m, m)` balance of the first `m` blocks agrees
//!   with the top-left of any larger balance (pinned by
//!   `balance.rs::causal_prefix_consistent` and the float32 simulation
//!   in EXPERIMENTS.md). Every other backend must state the same
//!   property through [`SortStrategy::prefix_stable`] — `routing` holds
//!   it by construction (online assignments never revisit), `local`
//!   trivially (zero matrix) — and a cut-configured state refuses a
//!   strategy that doesn't. Between boundaries the cached rows are
//!   reused as-is.
//! * **Cached sorted K/V** — the gathered sorted blocks the current token
//!   attends to are materialized once per boundary ([`gather_block_into`]
//!   over the complete blocks) and then reused for every token of the
//!   block. Strictness guarantees the gather never reads the in-progress
//!   block (its weight is exactly zero).
//! * **Streaming-softmax carry** — each step runs the engine's
//!   `stream_segment` twice (sorted segment, then the local causal
//!   window), carrying the running max/denominator between them in a
//!   caller-provided `StreamState`; the `(1, keys)` logits are never
//!   materialized.
//!
//! **SortCut decoding** (paper §3.3): with `n_cut = Some(c)` every token
//! attends to `[first c sorted blocks | local causal window]` instead of
//! its own block's sorted row. Prefix-consistency makes the cut cache
//! *append-only*: once row `j < c` of `R` exists it never changes, so each
//! boundary only gathers the newly live rows — and once the cut is
//! complete, later boundaries skip rebalancing altogether (no balanced
//! row would ever be read again).
//!
//! **Storage** (DESIGN.md §Pages): a state's caches live in one of two
//! [`Store`]s. *Monolithic* ([`DecodeState::new`]) owns worst-case
//! `Vec` buffers — simple, and the differential oracle. *Paged*
//! ([`DecodeState::new_paged`]) holds [`PageTable`] views over a shared
//! [`PagePool`] arena: K/V pages appear lazily as blocks are written
//! (resident bytes follow the actual length, not the capacity) and
//! [`DecodeState::fork`] shares every existing page by refcount, so
//! sessions opened on a common prompt prefix share cached K/V and
//! sorted-gather state until a write copy-on-writes them apart. Because
//! the local window and the gather only ever touch whole blocks, and
//! pages hold whole blocks, the paged step reads *exactly* the slices
//! the monolithic step reads — the two paths are bit-identical per step
//! (`tests/pages_props.rs`). A frozen SortCut cut cache is the fast
//! path: once `cut_rows == c` no rebalance ever writes it again, so its
//! pages stay shared forever with zero copies.
//!
//! **Contract** (`tests/decode_props.rs`): every step's output matches the
//! naive full-prefix oracle [`causal_decode_attention`] within
//! [`ENGINE_TOL`](super::engine::ENGINE_TOL) — including steps that cross
//! a block boundary and every `n_cut` — and a batch of sequences decoded
//! through [`SinkhornEngine::decode_step_into`] is bit-identical for any
//! thread count. Memory is accounted analytically by
//! [`memory::decode_state_bytes`] and asserted against
//! [`DecodeState::f32_elems`].
//!
//! [`causal_sinkhorn`]: super::balance::causal_sinkhorn
//! [`causal_decode_attention`]: super::attention::causal_decode_attention
//! [`SinkhornEngine::decode_step_into`]: super::engine::SinkhornEngine::decode_step_into
//! [`memory::decode_state_bytes`]: super::memory::decode_state_bytes
//! [`gather_block_into`]: super::engine::gather_block_into

use std::sync::Arc;

use super::engine::{
    gather_block_into, gather_pages_into, normalize_rows, BlockedView, StreamState,
};
use super::matrix::{Mat, MatView, MatViewMut};
use super::pages::{Page, PagePool, PageTable};
use super::strategy::{SinkhornSort, SortStrategy};

/// Row-support threshold below which a balanced sort row is treated as
/// empty and its sorted term masked — the same cutoff the batch paths use.
const SUPPORT_EPS: f32 = 1e-6;

/// Where a [`DecodeState`]'s caches live (DESIGN.md §Pages): owned
/// worst-case buffers, or page-table views over a shared [`PagePool`].
/// Every step reads/writes the same block-shaped slices either way — the
/// variants are bit-identical per step.
enum Store {
    /// Worst-case preallocated buffers (`nb_cap * b * d` per K/V side,
    /// `cache_blocks * b * d` per sorted side) — the original layout and
    /// the differential oracle for the paged one.
    Mono {
        /// appended keys, block-aligned: token `t`'s row lives at `t * d`
        k: Vec<f32>,
        /// appended values, same layout
        v: Vec<f32>,
        /// gathered sorted keys the current tokens attend to: `(b, d)` in
        /// full mode, up to `(n_cut * b, d)` in SortCut mode
        sk: Vec<f32>,
        /// gathered sorted values, same layout
        sv: Vec<f32>,
    },
    /// Arena-backed views: K/V pages allocated lazily on append, the
    /// sorted cache as one page per side allocated at the first
    /// rebalance. [`DecodeState::fork`] bumps refcounts; writes
    /// copy-on-write through [`Page::make_mut`].
    Paged {
        k: PageTable,
        v: PageTable,
        sk: Option<Page>,
        sv: Option<Page>,
        pool: PagePool,
    },
}

/// Per-sequence incremental decode state (DESIGN.md §Decode): the
/// block-aligned K/V cache, the cached strict-causal balanced sort matrix,
/// and the gathered sorted K/V the current tokens attend to. Monolithic
/// states preallocate everything at construction; paged states allocate
/// pages as the sequence actually grows (DESIGN.md §Pages).
pub struct DecodeState {
    /// rows per block
    b: usize,
    /// model dim
    d: usize,
    /// capacity in blocks (sequence capacity = `nb_cap * b` tokens)
    nb_cap: usize,
    /// balance iterations per rebalance (forwarded to the strategy;
    /// ignored by backends that don't iterate)
    n_iters: usize,
    /// `Some(c)`: SortCut decoding over the first `c` sorted blocks;
    /// `None`: full causal decoding over the token's own sorted row
    n_cut: Option<usize>,
    /// the sort backend that owns the cached-mixing recompute rule
    /// (DESIGN.md §Backends); [`SinkhornSort`] by default, which keeps
    /// this path bitwise identical to the pre-trait decoder
    strategy: Arc<dyn SortStrategy>,
    /// K/V + sorted-gather storage (monolithic or paged)
    store: Store,
    /// tokens appended so far
    len: usize,
    /// cached balanced sort matrix: top-left `(balanced, balanced)` of this
    /// preallocated `(nb_cap, nb_cap)` buffer holds
    /// `causal_sinkhorn(logits[..balanced, ..balanced], n_iters, strict)`
    r: Mat,
    /// blocks covered by the cached balance (0 before the first step)
    balanced: usize,
    /// valid key rows in the sorted cache
    sorted_rows: usize,
    /// SortCut: balanced rows already consumed into the cut cache
    /// (append-only — prefix-consistency keeps earlier rows stable)
    cut_rows: usize,
}

fn check_shape(b: usize, d: usize, nb_cap: usize, n_cut: Option<usize>) {
    assert!(b > 0 && d > 0 && nb_cap > 0, "b, d, nb_cap must be positive");
    if let Some(c) = n_cut {
        assert!((1..=nb_cap).contains(&c), "n_cut must be in 1..=nb_cap, got {c}");
    }
}

impl DecodeState {
    /// Fresh monolithic state for a sequence of up to `nb_cap * b` tokens.
    pub fn new(b: usize, d: usize, nb_cap: usize, n_iters: usize, n_cut: Option<usize>) -> Self {
        check_shape(b, d, nb_cap, n_cut);
        let cache_blocks = n_cut.unwrap_or(1);
        DecodeState {
            b,
            d,
            nb_cap,
            n_iters,
            n_cut,
            strategy: Arc::new(SinkhornSort),
            store: Store::Mono {
                k: vec![0.0; nb_cap * b * d],
                v: vec![0.0; nb_cap * b * d],
                sk: vec![0.0; cache_blocks * b * d],
                sv: vec![0.0; cache_blocks * b * d],
            },
            len: 0,
            r: Mat::zeros(nb_cap, nb_cap),
            balanced: 0,
            sorted_rows: 0,
            cut_rows: 0,
        }
    }

    /// Fresh paged state over `pool` (DESIGN.md §Pages): same capacity and
    /// semantics as [`DecodeState::new`], but nothing is resident until
    /// steps write it — a page holds `blocks_per_page` blocks of one
    /// cached tensor.
    pub fn new_paged(
        b: usize,
        d: usize,
        nb_cap: usize,
        n_iters: usize,
        n_cut: Option<usize>,
        pool: &PagePool,
        blocks_per_page: usize,
    ) -> Self {
        check_shape(b, d, nb_cap, n_cut);
        assert!(blocks_per_page > 0, "blocks_per_page must be positive");
        DecodeState {
            b,
            d,
            nb_cap,
            n_iters,
            n_cut,
            strategy: Arc::new(SinkhornSort),
            store: Store::Paged {
                k: PageTable::new(pool, b * d, blocks_per_page),
                v: PageTable::new(pool, b * d, blocks_per_page),
                sk: None,
                sv: None,
                pool: pool.clone(),
            },
            len: 0,
            r: Mat::zeros(nb_cap, nb_cap),
            balanced: 0,
            sorted_rows: 0,
            cut_rows: 0,
        }
    }

    /// Share this state's caches with a new one (DESIGN.md §Pages). Paged
    /// states fork by refcount — no float moves until one side writes and
    /// copy-on-write splits the touched page. Monolithic states deep-copy
    /// (they are the semantics oracle: fork-then-diverge must behave
    /// exactly like two independent copies, `tests/pages_props.rs`).
    pub fn fork(&self) -> Self {
        DecodeState {
            b: self.b,
            d: self.d,
            nb_cap: self.nb_cap,
            n_iters: self.n_iters,
            n_cut: self.n_cut,
            strategy: self.strategy.clone(),
            store: match &self.store {
                Store::Mono { k, v, sk, sv } => Store::Mono {
                    k: k.clone(),
                    v: v.clone(),
                    sk: sk.clone(),
                    sv: sv.clone(),
                },
                Store::Paged { k, v, sk, sv, pool } => Store::Paged {
                    k: k.fork(),
                    v: v.fork(),
                    sk: sk.clone(),
                    sv: sv.clone(),
                    pool: pool.clone(),
                },
            },
            len: self.len,
            r: self.r.clone(),
            balanced: self.balanced,
            sorted_rows: self.sorted_rows,
            cut_rows: self.cut_rows,
        }
    }

    /// Rebuild this (fresh) state around a different sort backend
    /// (DESIGN.md §Backends). Must be called before the first step — the
    /// cached mixing rows belong to the strategy that computed them — and
    /// a SortCut state refuses a strategy whose prefix mixing is not
    /// prefix-stable, because the frozen append-only cut cache is unsound
    /// without it (module docs).
    pub fn with_strategy(mut self, strategy: Arc<dyn SortStrategy>) -> Self {
        assert_eq!(self.len, 0, "strategy must be set before the first decode step");
        if self.n_cut.is_some() {
            assert!(
                strategy.prefix_stable(),
                "SortCut decoding requires a prefix-stable strategy (backend {})",
                strategy.backend().name()
            );
        }
        self.strategy = strategy;
        self
    }

    /// The sort backend this state recomputes its cached mixing with.
    pub fn strategy(&self) -> &Arc<dyn SortStrategy> {
        &self.strategy
    }

    /// Tokens decoded so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Token capacity (`nb_cap * b`).
    pub fn capacity(&self) -> usize {
        self.nb_cap * self.b
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn block_size(&self) -> usize {
        self.b
    }

    pub fn is_paged(&self) -> bool {
        matches!(self.store, Store::Paged { .. })
    }

    /// Pages this state currently references (0 for monolithic states;
    /// shared pages count once per state — the pool's `pages_in_use`
    /// counts them once globally).
    pub fn resident_pages(&self) -> usize {
        match &self.store {
            Store::Mono { .. } => 0,
            Store::Paged { k, v, sk, sv, .. } => {
                k.resident_pages()
                    + v.resident_pages()
                    + usize::from(sk.is_some())
                    + usize::from(sv.is_some())
            }
        }
    }

    /// The live rows of the gathered sorted K/V cache — what the sorted
    /// streaming segment reads this step. Exposed for the append-only and
    /// differential tests.
    pub fn sorted_cache(&self) -> (&[f32], &[f32]) {
        let n = self.sorted_rows * self.d;
        match &self.store {
            Store::Mono { sk, sv, .. } => (&sk[..n], &sv[..n]),
            Store::Paged { sk, sv, .. } => match (sk, sv) {
                (Some(a), Some(b)) => (&a.as_slice()[..n], &b.as_slice()[..n]),
                _ => (&[], &[]),
            },
        }
    }

    /// f32 elements this state holds — the measured side of
    /// [`super::memory::decode_state_bytes`] (monolithic: worst-case
    /// buffers) and of the paged resident model (pages actually
    /// referenced), asserted in `tests/decode_props.rs` /
    /// `tests/pages_props.rs`.
    pub fn f32_elems(&self) -> usize {
        let cached = match &self.store {
            Store::Mono { k, v, sk, sv } => k.len() + v.len() + sk.len() + sv.len(),
            Store::Paged { k, v, sk, sv, .. } => {
                k.resident_elems()
                    + v.resident_elems()
                    + sk.as_ref().map_or(0, Page::elems)
                    + sv.as_ref().map_or(0, Page::elems)
            }
        };
        cached + self.r.data.len()
    }

    /// Append one token and compute its attention output. This is the
    /// serving entry: `server::fallback::generate_batch` fans whole
    /// sequences over its pool and drives each one serially through here
    /// with a per-worker [`DecodeScratch`].
    /// [`super::engine::SinkhornEngine::decode_step_into`] is the
    /// alternative *lockstep* entry — one step across a batch of
    /// sequences at a time — and is bit-identical to this path
    /// (`tests/decode_props.rs`).
    pub fn step_into(
        &mut self,
        q_row: &[f32],
        k_row: &[f32],
        v_row: &[f32],
        sort_logits: &Mat,
        scratch: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        self.step_with(q_row, k_row, v_row, sort_logits, &mut scratch.stream, out);
    }

    /// The decode step (DESIGN.md §Decode): append K/V, rebalance on a
    /// filled block boundary, stream `[sorted | local causal]`.
    ///
    /// `sort_logits` is the caller-maintained raw sort-logit matrix; only
    /// its top-left `(m, m)` corner is read, where `m` is the number of
    /// blocks started — rows for unstarted blocks may hold anything.
    ///
    /// Unwind safety (DESIGN.md §Faults): the paged writes below allocate
    /// on first touch of a block, and the pool's injected allocation
    /// failure panics *before* any ledger mutation. A state unwound
    /// mid-step is torn (K/V written, `len` not yet bumped) and must be
    /// discarded, never stepped again — dropping it returns every page it
    /// still holds, which is exactly what the serving layer's panic
    /// containment does.
    pub(crate) fn step_with(
        &mut self,
        q_row: &[f32],
        k_row: &[f32],
        v_row: &[f32],
        sort_logits: &Mat,
        stream: &mut StreamState,
        out: &mut [f32],
    ) {
        let (b, d) = (self.b, self.d);
        assert!(self.len < self.capacity(), "decode capacity exhausted ({} tokens)", self.len);
        assert_eq!(q_row.len(), d, "q row must have d elements");
        assert_eq!(k_row.len(), d, "k row must have d elements");
        assert_eq!(v_row.len(), d, "v row must have d elements");
        assert_eq!(out.len(), d, "out row must have d elements");
        let t = self.len;
        let i = t / b; // the token's block
        match &mut self.store {
            Store::Mono { k, v, .. } => {
                k[t * d..(t + 1) * d].copy_from_slice(k_row);
                v[t * d..(t + 1) * d].copy_from_slice(v_row);
            }
            Store::Paged { k, v, .. } => {
                // first touch of a block allocates its page; a write into
                // a page still shared with a forked sibling splits it
                // (copy-on-write) — this is the one divergence point
                let o = (t - i * b) * d;
                k.block_mut(i)[o..o + d].copy_from_slice(k_row);
                v.block_mut(i)[o..o + d].copy_from_slice(v_row);
            }
        }
        self.len += 1;

        // Rebalance-on-boundary rule: the first token of block i makes m =
        // i + 1 blocks live; re-run the strategy's strict prefix mixing
        // over their logits and refresh the gathered sorted cache (for
        // SinkhornSort: Causal Sinkhorn Balancing). Every other step
        // reuses the caches untouched. Under SortCut, once the cut cache is
        // complete (cut_rows == c) no balanced row is ever read again —
        // prefix-stability froze them — so boundaries stop rebalancing
        // entirely and the per-step cost truly stops growing with the
        // prefix. For paged states the frozen cut is also the zero-copy
        // fast path: its pages are never written again, so forked sessions
        // share them forever.
        let m = i + 1;
        let cache_live = match self.n_cut {
            None => true,
            Some(c) => self.cut_rows < c,
        };
        if self.balanced < m && !cache_live {
            self.balanced = m;
        }
        if self.balanced < m {
            assert!(
                sort_logits.rows >= m && sort_logits.cols >= m,
                "sort_logits must cover the {m} started blocks (got {}x{})",
                sort_logits.rows,
                sort_logits.cols
            );
            // the strategy owns the boundary recompute (DESIGN.md
            // §Backends): SinkhornSort replays the historical (m, m)
            // strict-causal balance bit for bit; other backends return
            // their own strict prefix mixing
            let rm = self.strategy.mix_prefix(sort_logits, m, self.n_iters);
            assert_eq!((rm.rows, rm.cols), (m, m), "mix_prefix must return an (m, m) matrix");
            for row in 0..m {
                self.r.row_mut(row)[..m].copy_from_slice(rm.row(row));
            }
            self.balanced = m;
            // strict rows never weight the in-progress block, so gathering
            // over the first m blocks only ever reads complete ones (the
            // tail of block i is still zero-initialized and unused)
            let cut_elems = self.n_cut.unwrap_or(1) * b * d;
            match &mut self.store {
                Store::Mono { k, v, sk, sv } => {
                    let blocks = BlockedView::from_slice(&k[..m * b * d], m, b, d);
                    let vblocks = BlockedView::from_slice(&v[..m * b * d], m, b, d);
                    match self.n_cut {
                        None => {
                            // full causal: cache block i's own sorted row
                            let w = &self.r.row(i)[..m];
                            if w.iter().sum::<f32>() > SUPPORT_EPS {
                                gather_block_into(w, &blocks, &mut sk[..b * d]);
                                gather_block_into(w, &vblocks, &mut sv[..b * d]);
                                self.sorted_rows = b;
                            } else {
                                self.sorted_rows = 0; // block 0: no sorted term
                            }
                        }
                        Some(c) => {
                            // SortCut: append the newly live cut rows (rows
                            // already cached are prefix-stable — module docs)
                            for j in self.cut_rows..c.min(m) {
                                let w = &self.r.row(j)[..m];
                                if w.iter().sum::<f32>() > SUPPORT_EPS {
                                    let o = self.sorted_rows * d;
                                    gather_block_into(w, &blocks, &mut sk[o..o + b * d]);
                                    gather_block_into(w, &vblocks, &mut sv[o..o + b * d]);
                                    self.sorted_rows += b;
                                }
                                self.cut_rows = j + 1;
                            }
                        }
                    }
                }
                Store::Paged { k, v, sk, sv, pool } => {
                    // the same gather over page-resident whole blocks
                    // (gather_pages_into shares gather_block_into's fold,
                    // so the bytes written are identical). The cut pages
                    // are allocated at the first rebalance — not at first
                    // support — so a session's resident page count is a
                    // pure function of its length (memory.rs).
                    let kb: Vec<&[f32]> = (0..m).map(|j| k.block(j)).collect();
                    let vb: Vec<&[f32]> = (0..m).map(|j| v.block(j)).collect();
                    let skp = sk.get_or_insert_with(|| pool.alloc(cut_elems));
                    let svp = sv.get_or_insert_with(|| pool.alloc(cut_elems));
                    match self.n_cut {
                        None => {
                            let w = &self.r.row(i)[..m];
                            if w.iter().sum::<f32>() > SUPPORT_EPS {
                                gather_pages_into(w, &kb, &mut skp.make_mut()[..b * d]);
                                gather_pages_into(w, &vb, &mut svp.make_mut()[..b * d]);
                                self.sorted_rows = b;
                            } else {
                                self.sorted_rows = 0; // block 0: no sorted term
                            }
                        }
                        Some(c) => {
                            for j in self.cut_rows..c.min(m) {
                                let w = &self.r.row(j)[..m];
                                if w.iter().sum::<f32>() > SUPPORT_EPS {
                                    let o = self.sorted_rows * d;
                                    gather_pages_into(w, &kb, &mut skp.make_mut()[o..o + b * d]);
                                    gather_pages_into(w, &vb, &mut svp.make_mut()[o..o + b * d]);
                                    self.sorted_rows += b;
                                }
                                self.cut_rows = j + 1;
                            }
                        }
                    }
                }
            }
        }

        // Streamed joint softmax for the single-row query: sorted segment
        // (if any), then the local causal window — rows i*b..=t of the K/V
        // cache. The causal bound is the segment length itself, so no mask
        // flag is needed. Both stores expose the same contiguous slices
        // (pages hold whole blocks and the local window never crosses
        // one), so the streamed op order is identical.
        let scale = 1.0 / (d as f32).sqrt();
        out.fill(0.0);
        stream.reset(1);
        let qv = MatView::contiguous(q_row, 1, d);
        let mut y = MatViewMut::contiguous(out, 1, d);
        if self.sorted_rows > 0 {
            let n = self.sorted_rows * d;
            let (sks, svs) = match &self.store {
                Store::Mono { sk, sv, .. } => (&sk[..n], &sv[..n]),
                Store::Paged { sk, sv, .. } => (
                    &sk.as_ref().expect("sorted rows imply a cut page").as_slice()[..n],
                    &sv.as_ref().expect("sorted rows imply a cut page").as_slice()[..n],
                ),
            };
            let ks = MatView::contiguous(sks, self.sorted_rows, d);
            let vs = MatView::contiguous(svs, self.sorted_rows, d);
            stream_segment_one(&qv, &ks, &vs, scale, stream, &mut y);
        }
        let lo = i * b;
        let nl = t - lo + 1;
        let (lks, lvs) = match &self.store {
            Store::Mono { k, v, .. } => (&k[lo * d..(t + 1) * d], &v[lo * d..(t + 1) * d]),
            Store::Paged { k, v, .. } => (&k.block(i)[..nl * d], &v.block(i)[..nl * d]),
        };
        let lk = MatView::contiguous(lks, nl, d);
        let lv = MatView::contiguous(lvs, nl, d);
        stream_segment_one(&qv, &lk, &lv, scale, stream, &mut y);
        normalize_rows(&mut y, &stream.l);
    }

    /// Append a multi-token chunk — `n` rows of `(n, d)` row-major Q/K/V —
    /// and compute every row's attention output in one call (DESIGN.md
    /// §Prefill). This is the prompt-ingestion entry: where decoding pays
    /// one call per generated token, prefill hands the state a whole
    /// block-aligned chunk and the engine fans *chunks* (one per session ×
    /// head) over its pool instead of tokens.
    ///
    /// Bitwise contract: each row runs the exact [`Self::step_with`] op
    /// order — same K/V writes, same boundary rebalances, same frozen
    /// SortCut cuts, same streamed `[sorted | local]` softmax — so the
    /// outputs and the resulting state are *bit-identical* to `n`
    /// sequential `step_into` calls (`tests/prefill_props.rs`). Chunk
    /// boundaries may land anywhere: mid-block tails just leave the state
    /// where token-by-token decoding would have left it.
    ///
    /// `sort_logits` must already hold every row the chunk's boundary
    /// rebalances will read (rows `0..=⌈(len+n)/b⌉-1`); the stack's
    /// prefill writes them all before any head consumes the chunk, in the
    /// same write-once order as its decode rule.
    ///
    /// Unwind safety is inherited from `step_with`: a panic mid-chunk
    /// leaves a torn state that must be discarded, never stepped again.
    pub fn append_chunk(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        sort_logits: &Mat,
        scratch: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        self.append_chunk_with(q, k, v, sort_logits, &mut scratch.stream, out);
    }

    /// [`Self::append_chunk`] against a caller-owned [`StreamState`] — the
    /// engine's per-worker entry, mirroring `step_with` vs `step_into`.
    pub(crate) fn append_chunk_with(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        sort_logits: &Mat,
        stream: &mut StreamState,
        out: &mut [f32],
    ) {
        let d = self.d;
        assert!(q.len() % d == 0, "chunk q must be (n, d) row-major");
        let n = q.len() / d;
        assert_eq!(k.len(), n * d, "chunk k must match q's (n, d) shape");
        assert_eq!(v.len(), n * d, "chunk v must match q's (n, d) shape");
        assert_eq!(out.len(), n * d, "chunk out must match q's (n, d) shape");
        assert!(
            self.len + n <= self.capacity(),
            "chunk of {n} tokens overflows decode capacity ({} + {n} > {})",
            self.len,
            self.capacity()
        );
        for j in 0..n {
            let s = j * d..(j + 1) * d;
            self.step_with(&q[s.clone()], &k[s.clone()], &v[s.clone()], sort_logits, stream, &mut out[s]);
        }
    }
}

/// Thin wrapper so the engine's `stream_segment` reads as a decode step:
/// single-row query, no in-segment causal mask (the local segment is
/// already bounded to the visible rows).
fn stream_segment_one(
    q: &MatView,
    kseg: &MatView,
    vseg: &MatView,
    scale: f32,
    st: &mut StreamState,
    y: &mut MatViewMut,
) {
    super::engine::stream_segment(q, kseg, vseg, scale, false, st, y);
}

/// One layer's incremental decode state inside a depth-L stack
/// (DESIGN.md §Model, §Decode): one [`DecodeState`] per attention head —
/// each head owns its K/V cache and cached balanced sort matrix in its
/// head dimension — plus the *caller-maintained* raw sort-logit matrix the
/// heads share (the layer has one SortNet; rows become live as blocks
/// complete, exactly like the single-layer decode rule). The
/// prefix-consistency argument is unchanged per head: every head balances
/// the same logits with the same strict-causal iteration, so each head's
/// caches stay sound independently, and the layer adds no new coupling.
pub struct LayerDecodeState {
    heads: Vec<DecodeState>,
    /// raw per-layer sort logits; the model writes row `i + 1` when block
    /// `i` completes (`sinkhorn::model::SinkhornStack::decode_step`)
    pub sort_logits: Mat,
}

impl LayerDecodeState {
    /// Fresh per-layer monolithic state: `n_heads` head caches of block
    /// shape `(b, d_head)` with `nb_cap` blocks of capacity each.
    pub fn new(
        n_heads: usize,
        b: usize,
        d_head: usize,
        nb_cap: usize,
        n_iters: usize,
        n_cut: Option<usize>,
    ) -> Self {
        assert!(n_heads > 0, "n_heads must be positive");
        LayerDecodeState {
            heads: (0..n_heads)
                .map(|_| DecodeState::new(b, d_head, nb_cap, n_iters, n_cut))
                .collect(),
            sort_logits: Mat::zeros(nb_cap, nb_cap),
        }
    }

    /// Fresh per-layer paged state over `pool` (DESIGN.md §Pages).
    pub fn new_paged(
        n_heads: usize,
        b: usize,
        d_head: usize,
        nb_cap: usize,
        n_iters: usize,
        n_cut: Option<usize>,
        pool: &PagePool,
        blocks_per_page: usize,
    ) -> Self {
        assert!(n_heads > 0, "n_heads must be positive");
        LayerDecodeState {
            heads: (0..n_heads)
                .map(|_| DecodeState::new_paged(b, d_head, nb_cap, n_iters, n_cut, pool, blocks_per_page))
                .collect(),
            sort_logits: Mat::zeros(nb_cap, nb_cap),
        }
    }

    /// Rebuild every (fresh) head state around a different sort backend —
    /// see [`DecodeState::with_strategy`] for the preconditions. All heads
    /// of a layer share one strategy, exactly as they share one SortNet.
    pub fn with_strategy(mut self, strategy: Arc<dyn SortStrategy>) -> Self {
        self.heads =
            self.heads.into_iter().map(|h| h.with_strategy(strategy.clone())).collect();
        self
    }

    /// Share every head's caches with a new layer state (refcount bumps
    /// for paged heads, deep copies for monolithic ones — see
    /// [`DecodeState::fork`]).
    pub fn fork(&self) -> Self {
        LayerDecodeState {
            heads: self.heads.iter().map(DecodeState::fork).collect(),
            sort_logits: self.sort_logits.clone(),
        }
    }

    pub fn n_heads(&self) -> usize {
        self.heads.len()
    }

    /// Pages referenced across all heads (0 for monolithic layers).
    pub fn resident_pages(&self) -> usize {
        self.heads.iter().map(DecodeState::resident_pages).sum()
    }

    /// Split the layer state into its per-head decode states and the
    /// shared sort-logit matrix — the borrow shape the batched stack step
    /// needs (DESIGN.md §Scheduler): each head state becomes one mutable
    /// engine decode task while every task reads the layer's logits.
    pub fn split_heads(&mut self) -> (&mut [DecodeState], &Mat) {
        let LayerDecodeState { heads, sort_logits } = self;
        (heads.as_mut_slice(), &*sort_logits)
    }

    /// Tokens decoded so far (all heads advance in lockstep).
    pub fn len(&self) -> usize {
        self.heads[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.heads[0].capacity()
    }

    /// f32 elements this layer state holds — the measured side of
    /// [`super::memory::stack_decode_state_bytes`] (per layer), asserted
    /// in `tests/model_props.rs`.
    pub fn f32_elems(&self) -> usize {
        self.heads.iter().map(DecodeState::f32_elems).sum::<usize>() + self.sort_logits.data.len()
    }

    /// Step every head one token: `q`/`k`/`v`/`out` are flat
    /// `n_heads * d_head` rows (head-major), each head's slice fed through
    /// its own [`DecodeState::step_into`] against the shared sort logits.
    pub fn step_heads(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        scratch: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        let LayerDecodeState { heads, sort_logits } = self;
        let dh = heads[0].d();
        let flat = heads.len() * dh;
        assert_eq!(q.len(), flat, "q must hold n_heads * d_head elements");
        assert_eq!(k.len(), flat, "k must hold n_heads * d_head elements");
        assert_eq!(v.len(), flat, "v must hold n_heads * d_head elements");
        assert_eq!(out.len(), flat, "out must hold n_heads * d_head elements");
        for (h, head) in heads.iter_mut().enumerate() {
            let s = h * dh..(h + 1) * dh;
            let (qs, ks, vs) = (&q[s.clone()], &k[s.clone()], &v[s.clone()]);
            head.step_into(qs, ks, vs, sort_logits, scratch, &mut out[s]);
        }
    }
}

/// Per-step scratch for the serial decode entry ([`DecodeState::step_into`]):
/// the streaming-softmax carry for a single-row query. Reused across steps
/// and sequences; the engine's batched entry uses its per-worker
/// `Workspace` instead.
pub struct DecodeScratch {
    stream: StreamState,
}

impl DecodeScratch {
    pub fn new() -> Self {
        DecodeScratch { stream: StreamState::new(1) }
    }
}

impl Default for DecodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    // The heavy property suites (incremental == oracle across shapes,
    // boundaries and cuts; thread bit-invariance; memory accounting; the
    // paged differential battery) live in tests/decode_props.rs and
    // tests/pages_props.rs — only edge cases are covered here.
    use super::*;
    use crate::sinkhorn::attention::causal_decode_attention;
    use crate::util::rng::Rng;

    fn rand_rows(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
    }

    #[test]
    fn first_block_is_local_only_and_matches_oracle() {
        let (b, d, nb) = (3usize, 5usize, 2usize);
        let mut rng = Rng::new(0xDEC0);
        let q = rand_rows(&mut rng, b, d);
        let k = rand_rows(&mut rng, b, d);
        let v = rand_rows(&mut rng, b, d);
        let logits = rand_rows(&mut rng, nb, nb);
        let want = causal_decode_attention(&q, &k, &v, &logits, b, 4, None);
        let mut st = DecodeState::new(b, d, nb, 4, None);
        let mut scratch = DecodeScratch::new();
        let mut out = vec![0.0f32; d];
        for t in 0..b {
            st.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut out);
            assert_eq!(st.sorted_rows, 0, "block 0 has no sorted support");
            for (c, &got) in out.iter().enumerate() {
                assert!((got - want[(t, c)]).abs() <= 1e-5, "t={t} c={c}");
            }
        }
        assert_eq!(st.len(), b);
    }

    #[test]
    #[should_panic(expected = "decode capacity exhausted")]
    fn overflowing_capacity_panics() {
        let mut st = DecodeState::new(2, 3, 1, 2, None);
        let mut scratch = DecodeScratch::new();
        let (row, logits) = (vec![0.0f32; 3], Mat::zeros(1, 1));
        let mut out = vec![0.0f32; 3];
        for _ in 0..3 {
            st.step_into(&row, &row, &row, &logits, &mut scratch, &mut out);
        }
    }

    #[test]
    #[should_panic(expected = "n_cut must be in 1..=nb_cap")]
    fn rejects_oversized_cut() {
        DecodeState::new(2, 3, 2, 2, Some(3));
    }

    #[test]
    #[should_panic(expected = "SortCut decoding requires a prefix-stable strategy")]
    fn cut_state_rejects_non_prefix_stable_strategy() {
        use crate::sinkhorn::strategy::Backend;
        struct Unstable;
        impl SortStrategy for Unstable {
            fn backend(&self) -> Backend {
                Backend::Routing
            }
            fn mix(&self, feats: &Mat, _iters: usize, _causal: bool) -> Mat {
                Mat::zeros(feats.rows, feats.rows)
            }
            fn mix_prefix(&self, _feats: &Mat, m: usize, _iters: usize) -> Mat {
                Mat::zeros(m, m)
            }
            fn prefix_stable(&self) -> bool {
                false
            }
        }
        let _ = DecodeState::new(2, 3, 4, 2, Some(2)).with_strategy(Arc::new(Unstable));
    }

    #[test]
    #[should_panic(expected = "strategy must be set before the first decode step")]
    fn strategy_swap_after_steps_panics() {
        let mut st = DecodeState::new(2, 3, 2, 2, None);
        let mut scratch = DecodeScratch::new();
        let (row, logits) = (vec![0.0f32; 3], Mat::zeros(2, 2));
        let mut out = vec![0.0f32; 3];
        st.step_into(&row, &row, &row, &logits, &mut scratch, &mut out);
        let _ = st.with_strategy(Arc::new(SinkhornSort));
    }

    #[test]
    fn sortcut_cache_is_append_only() {
        let (b, d, nb) = (2usize, 4usize, 4usize);
        let mut rng = Rng::new(0xDEC1);
        let ell = nb * b;
        let q = rand_rows(&mut rng, ell, d);
        let k = rand_rows(&mut rng, ell, d);
        let v = rand_rows(&mut rng, ell, d);
        let logits = rand_rows(&mut rng, nb, nb);
        let mut st = DecodeState::new(b, d, nb, 4, Some(2));
        let mut scratch = DecodeScratch::new();
        let mut out = vec![0.0f32; d];
        let mut snapshot: Option<Vec<f32>> = None;
        for t in 0..ell {
            st.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut out);
            if st.sorted_rows == 2 * b {
                // the full cut is live: its contents must never change again
                let sk = st.sorted_cache().0;
                match &snapshot {
                    None => snapshot = Some(sk.to_vec()),
                    Some(s) => assert_eq!(sk, &s[..], "cut cache moved at t={t}"),
                }
            }
        }
        assert!(snapshot.is_some(), "cut never filled");
    }

    #[test]
    fn paged_steps_match_mono_bitwise() {
        // the full differential battery lives in tests/pages_props.rs;
        // this is the smallest witness that both stores step identically
        let (b, d, nb) = (2usize, 4usize, 3usize);
        let mut rng = Rng::new(0xDEC2);
        let ell = nb * b;
        let q = rand_rows(&mut rng, ell, d);
        let k = rand_rows(&mut rng, ell, d);
        let v = rand_rows(&mut rng, ell, d);
        let logits = rand_rows(&mut rng, nb, nb);
        let pool = PagePool::new();
        for cut in [None, Some(2)] {
            let mut mono = DecodeState::new(b, d, nb, 4, cut);
            let mut paged = DecodeState::new_paged(b, d, nb, 4, cut, &pool, 1);
            let mut scratch = DecodeScratch::new();
            let (mut om, mut op) = (vec![0.0f32; d], vec![0.0f32; d]);
            for t in 0..ell {
                mono.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut om);
                paged.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut op);
                assert_eq!(om, op, "cut={cut:?} t={t}");
                assert_eq!(mono.sorted_cache(), paged.sorted_cache(), "cut={cut:?} t={t}");
            }
            // resident follows actual length: 2 tables * nb pages + 2 cut pages
            assert_eq!(paged.resident_pages(), 2 * nb + 2);
        }
    }

    #[test]
    fn forked_paged_state_shares_then_diverges() {
        let (b, d, nb) = (2usize, 3usize, 4usize);
        let mut rng = Rng::new(0xDEC3);
        let ell = nb * b;
        let q = rand_rows(&mut rng, ell, d);
        let k = rand_rows(&mut rng, ell, d);
        let v = rand_rows(&mut rng, ell, d);
        let logits = rand_rows(&mut rng, nb, nb);
        let pool = PagePool::new();
        let mut base = DecodeState::new_paged(b, d, nb, 4, None, &pool, 1);
        let mut scratch = DecodeScratch::new();
        let mut out = vec![0.0f32; d];
        for t in 0..b {
            base.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut out);
        }
        let before = pool.stats().pages_in_use;
        let mut forked = base.fork();
        assert_eq!(pool.stats().pages_in_use, before, "fork must not allocate");
        // oracle: a deep-copied twin stepped identically
        let mut twin = DecodeState::new(b, d, nb, 4, None);
        for t in 0..b {
            twin.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut out);
        }
        let (mut of, mut ot) = (vec![0.0f32; d], vec![0.0f32; d]);
        for t in b..ell {
            forked.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut of);
            twin.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut ot);
            assert_eq!(of, ot, "t={t}");
        }
        // base never stepped past the fork point: still at length b
        assert_eq!(base.len(), b);
        assert_eq!(forked.len(), ell);
    }
}
