//! Pure-Rust inference fallback: a Sinkhorn Transformer stack
//! ([`SinkhornStack`], DESIGN.md §Model) over the blocked streaming engine
//! — no XLA, no compiled artifacts, no Python. The server selects it when
//! an experiment's HLO artifacts (or the PJRT runtime itself) are
//! unavailable, so the full serving stack — TCP frontend, dynamic batcher,
//! executor — works on any machine straight from `cargo run`.
//!
//! The model is deliberately small and deterministic from its seed:
//! embedding + learned-style positional table, a depth-`L` stack of
//! Sinkhorn Transformer layers (per-layer SortNet → multi-head blocked
//! sorted+local attention → residual → optional pre-LN GELU FFN), then a
//! task head. It is not trained (there is no training path without XLA);
//! what it demonstrates and exercises is the *serving* pipeline and the
//! engine hot path with production shapes.
//!
//! The default configuration (`depth = 1`, one head, no FFN) is
//! **bit-identical** to the historical pre-stack single-layer fallback:
//! same seeded weights in the same RNG order, same naive-order
//! projections, same engine attention path (`tests/model_props.rs` and the
//! unit tests below pin this). Deeper configurations stack full pre-LN
//! transformer layers.
//!
//! Two serving verbs share the weights: `classify` (batch stack forward,
//! pooled head) and `generate` (token-by-token greedy decoding on the
//! depth-L incremental decode path with a tied-embedding LM head —
//! DESIGN.md §Decode). Both are exposed through the TCP line protocol
//! (`super::tcp`, documented in `rust/README.md`), alongside the `model`
//! info verb that reports this configuration.

use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::Result;

use super::faults::{panic_msg, FaultPlan};
use crate::sinkhorn::model::{StackConfig, TransformerLayer};
use crate::sinkhorn::pages::PoolStats;
use crate::sinkhorn::{
    Backend, Mat, PagePool, SinkhornEngine, SinkhornStack, StackDecodeState, WorkerPool,
};
use crate::util::rng::Rng;

/// Configuration of the fallback model.
#[derive(Debug, Clone)]
pub struct FallbackConfig {
    /// token ids are wrapped into `[0, vocab)` so any client input is safe
    pub vocab: usize,
    /// fixed sequence length (requests are padded/truncated to this)
    pub seq_len: usize,
    pub d_model: usize,
    /// number of sort blocks; must divide `seq_len`
    pub nb: usize,
    pub n_classes: usize,
    /// Sinkhorn balance iterations for the sort matrix
    pub sinkhorn_iters: usize,
    pub seed: u64,
    /// engine worker threads (0 = auto)
    pub threads: usize,
    /// transformer layers (1 = the historical single-layer model)
    pub depth: usize,
    /// attention heads per layer; must divide `d_model`
    pub n_heads: usize,
    /// FFN hidden width; 0 = bare attention layers (the historical shape)
    pub d_ff: usize,
    /// decode sessions use the paged KV-cache arena (DESIGN.md §Pages);
    /// `false` falls back to monolithic worst-case decode states
    pub paged: bool,
    /// target bytes per K/V page; 0 = one Sinkhorn block per page (the
    /// serve `--page-bytes` flag — rounded down to whole blocks, floor 1)
    pub page_bytes: usize,
    /// share page-resident decode state across sessions opened on a
    /// common prompt prefix (`--no-prefix-share` disables)
    pub prefix_share: bool,
    /// sort backend for every layer of the stack (the serve `--backend`
    /// flag — DESIGN.md §Backends). [`Backend::Sinkhorn`] is the paper's
    /// path and the bitwise-pinned default
    pub backend: Backend,
}

impl Default for FallbackConfig {
    fn default() -> Self {
        let seq_len = 128;
        FallbackConfig {
            vocab: 512,
            seq_len,
            d_model: 64,
            // keep in sync with the `serve --fallback` CLI default, which
            // also derives nb from blocks_for(seq_len) — the auto-fallback
            // and the forced fallback must build the same model
            nb: Self::blocks_for(seq_len),
            n_classes: 2,
            sinkhorn_iters: 5,
            seed: 17,
            threads: 0,
            depth: 1,
            n_heads: 1,
            d_ff: 0,
            paged: true,
            page_bytes: 0,
            prefix_share: true,
            backend: Backend::Sinkhorn,
        }
    }
}

/// f32-element work (depth × seq_len × d_model) below which the engine's
/// per-call thread spawn costs more than it buys for a *single* request —
/// below it "auto" picks the serial engine. Large batches parallelize at
/// request granularity over `batch_pool`; batches too small to fill the
/// pool run sequentially on this same engine, so the cutoff governs them
/// too (`SinkhornStack::forward_batch`).
const SERIAL_WORK_CUTOFF: usize = 1 << 17;

impl FallbackConfig {
    /// Largest power of two <= 16 dividing `seq_len` (a reasonable block
    /// count when the manifest doesn't pin one).
    pub fn blocks_for(seq_len: usize) -> usize {
        for nb in [16usize, 8, 4, 2] {
            if seq_len % nb == 0 {
                return nb;
            }
        }
        1
    }

    /// The historical pre-stack shape: one bare single-head layer. This is
    /// the configuration whose outputs are pinned bit-identical to the
    /// pre-stack fallback.
    fn legacy_shape(&self) -> bool {
        self.depth == 1 && self.n_heads == 1 && self.d_ff == 0
    }

    /// Sinkhorn blocks per K/V page: `page_bytes` rounded down to whole
    /// `(b, d_head)` blocks, floor one block (the engine is block-aligned,
    /// so a page smaller than a block would split reads).
    pub fn blocks_per_page(&self) -> usize {
        let b = self.seq_len / self.nb.max(1);
        let d_head = self.d_model / self.n_heads.max(1);
        let block_bytes = (b * d_head * 4).max(1);
        (self.page_bytes / block_bytes).max(1)
    }

    fn stack_config(&self) -> StackConfig {
        StackConfig {
            seq_len: self.seq_len,
            d_model: self.d_model,
            n_heads: self.n_heads,
            depth: self.depth,
            d_ff: self.d_ff,
            nb: self.nb,
            sinkhorn_iters: self.sinkhorn_iters,
            causal: false,
            n_cut: None,
        }
    }
}

/// The deterministic fallback model: embeddings + a [`SinkhornStack`] +
/// task heads (linear classifier; tied-embedding LM head for decode).
pub struct FallbackModel {
    pub cfg: FallbackConfig,
    /// request-level parallelism for the batched paths: each worker runs
    /// whole requests through the stack with a private scratch (depth-L
    /// stacks make request tasks coarse enough to saturate the pool)
    batch_pool: WorkerPool,
    /// (vocab, d) token embeddings
    embed: Mat,
    /// (seq_len, d) positional table
    pos: Mat,
    /// the depth-L Sinkhorn Transformer stack
    stack: SinkhornStack,
    /// (d, n_classes) classification head
    w_cls: Mat,
    /// shared page arena every paged decode session allocates from
    /// (DESIGN.md §Pages); unused when `cfg.paged` is false
    pool: PagePool,
    /// block-aligned prompt prefixes with their prefilled decode states:
    /// opening a session whose prompt extends one of these forks the
    /// cached state (refcount bumps, no float copies) instead of
    /// re-decoding the prefix
    prefix_cache: Mutex<Vec<PrefixEntry>>,
    /// deterministic fault schedule threaded through the pool and the
    /// session step (DESIGN.md §Faults); the empty plan in production
    faults: FaultPlan,
}

/// One cached prompt prefix: the tokens fed so far (always a multiple of
/// the block size) and the paged decode state at exactly that length.
struct PrefixEntry {
    tokens: Vec<i32>,
    st: StackDecodeState,
}

/// Cached prompt prefixes kept per model — bounds the pages the cache
/// itself pins (oldest entries evict first).
const PREFIX_CACHE_CAP: usize = 16;

impl FallbackModel {
    pub fn new(cfg: FallbackConfig) -> Result<FallbackModel> {
        Self::with_faults(cfg, FaultPlan::none())
    }

    /// Build the model with a fault-injection schedule (DESIGN.md
    /// §Faults): the plan is wired into the page pool (allocation
    /// failures) and consulted at every session step point. Production
    /// callers use [`FallbackModel::new`] — the empty plan's injection
    /// points are single relaxed atomic increments.
    pub fn with_faults(cfg: FallbackConfig, faults: FaultPlan) -> Result<FallbackModel> {
        if cfg.seq_len % cfg.nb != 0 {
            anyhow::bail!("fallback: nb {} must divide seq_len {}", cfg.nb, cfg.seq_len);
        }
        if cfg.vocab == 0 || cfg.n_classes == 0 {
            anyhow::bail!("fallback: vocab and n_classes must be positive");
        }
        let scfg = cfg.stack_config();
        scfg.validate()?;
        let d = cfg.d_model;
        let mut rng = Rng::new(cfg.seed);
        let mut init = |rows: usize, cols: usize, scale: f64| {
            let mut r = rng.fork((rows * 31 + cols) as u64);
            Mat::from_fn(rows, cols, |_, _| (r.normal() * scale) as f32)
        };
        let wscale = 1.0 / (d as f64).sqrt();
        // At serving shapes (seq_len ~128) one request's blocks are
        // microseconds of work — below the pool's per-call thread-spawn
        // cost — so for *single* requests "auto" means serial unless the
        // request (depth included) is big enough for the parallel engine
        // to pay off. An explicit threads count wins. Batches fan whole
        // requests over `batch_pool` instead.
        let single_work = cfg.depth * cfg.seq_len * cfg.d_model;
        let engine = if cfg.threads == 0 && single_work < SERIAL_WORK_CUTOFF {
            SinkhornEngine::serial()
        } else {
            SinkhornEngine::new(cfg.threads)
        };
        // The legacy shape must draw its weights with exactly the
        // historical fork sequence (embed, pos, wq, wk, wv, wo, sortnet,
        // w_cls) so the depth-1 model stays bit-identical to the pre-stack
        // fallback; deeper/wider stacks seed per layer instead.
        let embed = init(cfg.vocab, d, 0.1);
        let pos = init(cfg.seq_len, d, 0.05);
        let layers: Vec<TransformerLayer> = if cfg.legacy_shape() {
            vec![TransformerLayer::bare_single_head(
                init(d, d, wscale),
                init(d, d, wscale),
                init(d, d, wscale),
                init(d, d, wscale),
                init(d, cfg.nb, wscale),
            )]
        } else {
            // non-legacy shapes have no bitwise heritage: seed the layers
            // from their own stream (`init` still holds the main rng)
            let mut layer_rng = Rng::new(cfg.seed ^ 0x57AC_11A9);
            (0..cfg.depth)
                .map(|l| {
                    let mut lr = layer_rng.fork(0x57AC + l as u64);
                    TransformerLayer::seeded(&scfg, &mut lr)
                })
                .collect()
        };
        let w_cls = init(d, cfg.n_classes, wscale);
        let mut stack = SinkhornStack::new(scfg, layers, engine)?;
        // the stack defaults to SinkhornSort; only a non-default backend
        // swaps strategies, keeping the default path untouched (and the
        // legacy shape bitwise)
        if cfg.backend != Backend::Sinkhorn {
            stack.set_strategy(cfg.backend.strategy(cfg.nb));
        }
        Ok(FallbackModel {
            batch_pool: WorkerPool::new(cfg.threads),
            embed,
            pos,
            stack,
            w_cls,
            pool: PagePool::with_faults(Arc::new(faults.clone())),
            prefix_cache: Mutex::new(Vec::new()),
            faults,
            cfg,
        })
    }

    /// Lock the prefix cache, tolerating poison: the lock is held across
    /// prefill steps that can panic under injected faults, but every
    /// mutation under it is a push/remove of a *complete* entry — a
    /// poisoned cache is still a valid cache, and abandoning it would
    /// leak the pages its entries pin.
    fn lock_prefix_cache(&self) -> MutexGuard<'_, Vec<PrefixEntry>> {
        self.prefix_cache.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// One-line `key=value` description of the served model (the TCP
    /// `model` verb's payload — `super::tcp`).
    pub fn describe(&self) -> String {
        let c = &self.cfg;
        format!(
            "backend=fallback sort_backend={} depth={} heads={} d_model={} d_ff={} nb={} \
             seq_len={} vocab={} classes={} sinkhorn_iters={} engine_threads={} \
             batch_workers={} params={} paged={} page_blocks={} prefix_share={}",
            c.backend.name(),
            c.depth,
            c.n_heads,
            c.d_model,
            c.d_ff,
            c.nb,
            c.seq_len,
            c.vocab,
            c.n_classes,
            c.sinkhorn_iters,
            self.stack.engine().threads(),
            self.batch_pool.threads(),
            self.n_params(),
            c.paged,
            c.blocks_per_page(),
            c.prefix_share,
        )
    }

    /// Total parameters (embeddings + stack + classifier head; the LM head
    /// is tied to the embeddings).
    pub fn n_params(&self) -> usize {
        self.embed.data.len() + self.pos.data.len() + self.stack.n_params() + self.w_cls.data.len()
    }

    /// Embed tokens (wrapped into the vocab, padded/truncated to
    /// `seq_len`) plus positions.
    fn embed_seq(&self, tokens: &[i32]) -> Mat {
        let (ell, d) = (self.cfg.seq_len, self.cfg.d_model);
        let mut x = Mat::zeros(ell, d);
        for t in 0..ell {
            let tok = tokens.get(t).copied().unwrap_or(0); // PAD
            let id = tok.rem_euclid(self.cfg.vocab as i32) as usize;
            let (er, pr) = (self.embed.row(id), self.pos.row(t));
            for (c, o) in x.row_mut(t).iter_mut().enumerate() {
                *o = er[c] + pr[c];
            }
        }
        x
    }

    /// Mean-pool the stack's final hidden states and apply the linear
    /// classification head.
    fn pool_head(&self, y: &Mat) -> Vec<f32> {
        let (ell, d) = (self.cfg.seq_len, self.cfg.d_model);
        let mut h = vec![0.0f32; d];
        for t in 0..ell {
            let yr = y.row(t);
            for c in 0..d {
                h[c] += yr[c];
            }
        }
        for v in &mut h {
            *v /= ell as f32;
        }
        let mut logits = vec![0.0f32; self.cfg.n_classes];
        for (c, &hc) in h.iter().enumerate() {
            let wr = self.w_cls.row(c);
            for (j, l) in logits.iter_mut().enumerate() {
                *l += hc * wr[j];
            }
        }
        logits
    }

    /// Class logits for one request. Batched traffic goes through
    /// [`Self::classify_batch`] instead — same math per request.
    pub fn class_logits(&self, tokens: &[i32]) -> Vec<f32> {
        let mut x = self.embed_seq(tokens);
        let mut scratch = self.stack.new_scratch();
        self.stack.forward_with(&mut x, self.stack.engine(), &mut scratch);
        self.pool_head(&x)
    }

    /// Predicted label for one request.
    pub fn classify(&self, tokens: &[i32]) -> i32 {
        argmax(&self.class_logits(tokens))
    }

    /// Labels for a batch of requests (executor entry point): embed
    /// request-parallel, run the whole batch through
    /// [`SinkhornStack::forward_batch`] (request-level tasks, one private
    /// scratch per worker, serial engine inside the pool), then pool the
    /// heads. Per-request math is identical to the single-request path, so
    /// batched and single labels agree exactly.
    pub fn classify_batch(&self, batch: &[Vec<i32>]) -> Vec<i32> {
        if batch.is_empty() {
            return Vec::new();
        }
        let mut xs: Vec<Mat> = batch.iter().map(|toks| self.embed_seq(toks)).collect();
        self.stack.forward_batch(&mut xs, &self.batch_pool);
        let mut labels = vec![0i32; batch.len()];
        let tasks: Vec<(usize, &mut i32)> = labels.iter_mut().enumerate().collect();
        self.batch_pool.run(tasks, || (), |_, (i, slot)| {
            *slot = argmax(&self.pool_head(&xs[i]));
        });
        labels
    }

    /// Greedy autoregressive generation on the depth-L incremental decode
    /// path (DESIGN.md §Model, §Decode): feed `prompt` through a
    /// per-sequence [`crate::sinkhorn::StackDecodeState`] token by token,
    /// then keep sampling the argmax of the tied-embedding LM head
    /// (`h_t · Eᵀ` — the same embedding matrix that encodes the input)
    /// until `max_new` tokens exist or the positional table runs out.
    /// Returns only the newly generated ids.
    ///
    /// Capacity rule: the model has `seq_len` positions. The prompt is
    /// truncated to the first `seq_len - 1` tokens (mirroring `classify`'s
    /// head-truncation while always leaving room to generate), and the
    /// number of generated tokens is `min(max_new, seq_len - prompt_len)`.
    /// An empty prompt decodes from the PAD token 0. Deterministic: same
    /// prompt, same model seed, same output — batched or not.
    pub fn generate(&self, prompt: &[i32], max_new: usize) -> Vec<i32> {
        let mut scratch = self.stack.new_decode_scratch();
        self.generate_one(prompt, max_new, &mut scratch)
    }

    /// [`Self::generate`] for a batch of `(prompt, max_new)` requests
    /// (executor entry point): requests fan out over the worker pool, one
    /// sequence per task, each worker reusing one decode scratch. Per
    /// sequence the math is identical to the single-request path, so
    /// batched and single generations agree exactly.
    pub fn generate_batch(&self, reqs: &[(Vec<i32>, usize)]) -> Vec<Vec<i32>> {
        if reqs.is_empty() {
            return Vec::new();
        }
        let mut outs: Vec<Vec<i32>> = reqs.iter().map(|_| Vec::new()).collect();
        let tasks: Vec<(usize, &mut Vec<i32>)> = outs.iter_mut().enumerate().collect();
        self.batch_pool.run(
            tasks,
            || self.stack.new_decode_scratch(),
            |scratch, (i, slot)| {
                *slot = self.generate_one(&reqs[i].0, reqs[i].1, scratch);
            },
        );
        outs
    }

    /// One sequence's greedy decode loop. Per step: embed the token, one
    /// [`SinkhornStack::decode_step`] through every layer (cached causal
    /// Sinkhorn state per layer per head, O(depth·b·d)), then the tied LM
    /// head when a new token is due.
    fn generate_one(
        &self,
        prompt: &[i32],
        max_new: usize,
        scratch: &mut crate::sinkhorn::StackDecodeScratch,
    ) -> Vec<i32> {
        let (ell_cap, d) = (self.cfg.seq_len, self.cfg.d_model);
        let seeded = [0i32]; // empty prompt: decode from PAD
        let prompt: &[i32] = if prompt.is_empty() { &seeded } else { prompt };
        let keep = prompt.len().min(ell_cap.saturating_sub(1).max(1));
        let budget = max_new.min(ell_cap - keep);
        if budget == 0 {
            return Vec::new();
        }
        let mut st = self.stack.decode_state();
        let mut x = vec![0.0f32; d];
        let mut h = vec![0.0f32; d];
        let mut gen: Vec<i32> = Vec::with_capacity(budget);
        // the final generated token needs no step of its own
        for t in 0..keep + budget - 1 {
            let tok = if t < keep { prompt[t] } else { gen[t - keep] };
            self.embed_token_into(tok, t, &mut x);
            self.stack.decode_step(&mut st, &x, scratch, &mut h);
            if t + 1 >= keep {
                gen.push(self.lm_argmax(&h));
            }
        }
        gen
    }

    /// Embed one token at position `t` (`embed[tok mod vocab] + pos[t]`)
    /// into `x` — the per-step half of [`Self::embed_seq`], shared by the
    /// serial decode loop and the scheduler's session steps so the two
    /// paths are the same float ops in the same order.
    fn embed_token_into(&self, tok: i32, t: usize, x: &mut [f32]) {
        let id = tok.rem_euclid(self.cfg.vocab as i32) as usize;
        let (er, pr) = (self.embed.row(id), self.pos.row(t));
        for (c, xo) in x.iter_mut().enumerate() {
            *xo = er[c] + pr[c];
        }
    }

    /// Greedy tied-embedding LM head: argmax over `h · Eᵀ` (the same
    /// embedding matrix that encodes the input), accumulated in vocab
    /// order — the historical `generate` head loop, bit for bit.
    pub fn lm_argmax(&self, h: &[f32]) -> i32 {
        let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
        for vtok in 0..self.cfg.vocab {
            let ev = self.embed.row(vtok);
            let mut acc = 0.0f32;
            for (c, &hc) in h.iter().enumerate() {
                acc += hc * ev[c];
            }
            if acc > best_v {
                best_v = acc;
                best = vtok;
            }
        }
        best as i32
    }

    /// Open a decode session for the continuous-batching scheduler
    /// (DESIGN.md §Scheduler, §Pages): allocate the per-sequence
    /// [`crate::sinkhorn::StackDecodeState`] and pin the capacity rule —
    /// the *same* clamping as [`Self::generate`] (prompt truncated to the
    /// first `seq_len - 1` tokens, budget clamped to the remaining
    /// positions, empty prompts decode from PAD) — so a session stepped to
    /// completion emits exactly `generate(prompt, max_new)`, bit for bit,
    /// regardless of what other sessions share its ticks.
    ///
    /// Paged models additionally detect shareable prompt prefixes: the
    /// longest cached block-aligned prefix of the clamped prompt is
    /// *forked* — page refcount bumps, no float copies — and only the
    /// uncached remainder is prefilled, through the chunked
    /// block-parallel path ([`SinkhornStack::prefill`], DESIGN.md
    /// §Prefill), which is bit-identical to the `decode_step` loop the
    /// scheduler's ticks replay, so the session's stream is unchanged
    /// token for token. The prefix never extends past `keep - 1` tokens:
    /// step `keep - 1` emits the first generated token, so the session
    /// itself must still take it.
    pub fn open_session(&self, prompt: &[i32], max_new: usize) -> GenSession {
        let (ell_cap, d) = (self.cfg.seq_len, self.cfg.d_model);
        let seeded = [0i32]; // empty prompt: decode from PAD
        let prompt: &[i32] = if prompt.is_empty() { &seeded } else { prompt };
        let keep = prompt.len().min(ell_cap.saturating_sub(1).max(1));
        let budget = max_new.min(ell_cap - keep);
        let (st, shared) = if budget == 0 {
            // retires before its first tick: skip prefill and caching
            (self.fresh_session_state(), 0)
        } else {
            self.session_state_for(&prompt[..keep])
        };
        let committed = st.len();
        GenSession {
            st,
            prompt: prompt[..keep].to_vec(),
            budget,
            shared,
            committed,
            gen: Vec::with_capacity(budget),
            x: vec![0.0; d],
            h: vec![0.0; d],
        }
    }

    /// Fresh empty decode state in the configured storage mode.
    fn fresh_session_state(&self) -> StackDecodeState {
        if self.cfg.paged {
            self.stack.decode_state_paged(&self.pool, self.cfg.blocks_per_page())
        } else {
            self.stack.decode_state()
        }
    }

    /// The block-aligned prefix length of a `keep`-token clamped prompt
    /// that prefix sharing may reuse: one short of `keep`, rounded down
    /// to whole blocks (the session itself must still take the step that
    /// emits its first token).
    fn shareable_len(&self, keep: usize) -> usize {
        let b = self.cfg.seq_len / self.cfg.nb;
        keep.saturating_sub(1) / b * b
    }

    /// Build the decode state for a clamped prompt: fork the longest
    /// matching cached prefix, prefill the uncached remainder, and leave
    /// the full shareable prefix in the cache for the next session.
    /// Returns the state (always at `shareable_len` tokens) and how many
    /// of those tokens were forked from the cache (page-shared).
    fn session_state_for(&self, kept: &[i32]) -> (StackDecodeState, usize) {
        if !self.cfg.paged || !self.cfg.prefix_share {
            return (self.fresh_session_state(), 0);
        }
        let target = self.shareable_len(kept.len());
        if target == 0 {
            return (self.fresh_session_state(), 0);
        }
        // lock #1: match only. The lock used to cover match + prefill +
        // insert, serializing every concurrent open behind one session's
        // prompt ingestion; now disjoint prompts prefill in parallel and
        // only the cheap cache scans are serialized
        // (`tests/prefill_props.rs::concurrent_opens_of_disjoint_prompts_both_progress`).
        let (mut st, shared) = {
            let cache = self.lock_prefix_cache();
            match cache
                .iter()
                .filter(|e| e.tokens.len() <= target && kept.starts_with(&e.tokens))
                .max_by_key(|e| e.tokens.len())
            {
                Some(e) => (e.st.fork(), e.tokens.len()),
                None => (self.fresh_session_state(), 0),
            }
        };
        if shared < target {
            // chunked block-parallel prefill (DESIGN.md §Prefill):
            // `shared` and `target` are both block-aligned, so the
            // uncached remainder ingests one whole block per
            // [`SinkhornStack::prefill`] call — a fused (head × block)
            // engine pass — instead of one `decode_step` per token.
            // Block-boundary snapshots are forked outside the lock; a
            // later prompt sharing any whole-block prefix then hits
            let b = self.cfg.seq_len / self.cfg.nb.max(1);
            let d = self.cfg.d_model;
            let mut scratch = self.stack.new_prefill_scratch();
            let mut xs = vec![0.0f32; b.max(1) * d];
            let mut snapshots: Vec<(usize, StackDecodeState)> = Vec::new();
            let mut t = shared;
            while t < target {
                let n = b.min(target - t).max(1);
                for (j, &tok) in kept[t..t + n].iter().enumerate() {
                    self.embed_token_into(tok, t + j, &mut xs[j * d..(j + 1) * d]);
                }
                self.stack.prefill(&mut st, &xs[..n * d], &mut scratch, None);
                t += n;
                if t % b == 0 {
                    snapshots.push((t, st.fork()));
                }
            }
            // lock #2: insert only, deduped against entries a concurrent
            // open may have raced in while we prefilled unlocked (losing
            // a race costs a dropped fork, never a wrong entry)
            let mut cache = self.lock_prefix_cache();
            for (end, snap) in snapshots {
                if !cache.iter().any(|e| e.tokens == kept[..end]) {
                    if cache.len() >= PREFIX_CACHE_CAP {
                        cache.remove(0);
                    }
                    cache.push(PrefixEntry { tokens: kept[..end].to_vec(), st: snap });
                }
            }
        }
        (st, shared)
    }

    /// Is this model serving paged decode sessions (DESIGN.md §Pages)?
    pub fn paged(&self) -> bool {
        self.cfg.paged
    }

    /// Ledger snapshot of the model's page arena: what decode sessions
    /// (and the prefix cache) actually have resident right now.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// The page arena itself (tests and the pages bench inspect it).
    pub fn page_pool(&self) -> &PagePool {
        &self.pool
    }

    /// The model's fault-injection schedule (the empty plan in
    /// production) — chaos tests inspect its event counters.
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// Scratch for [`Self::step_sessions`] (one per scheduler, reused
    /// across every tick).
    pub fn new_batch_scratch(&self) -> crate::sinkhorn::StackBatchScratch {
        self.stack.new_batch_scratch()
    }

    /// Scratch for [`Self::prefill_session`] (one per scheduler, reused
    /// across every prefill chunk; `session_state_for` builds its own).
    pub fn new_prefill_scratch(&self) -> crate::sinkhorn::StackPrefillScratch {
        self.stack.new_prefill_scratch()
    }

    /// Bytes of decode state one session holds at full capacity — the
    /// analytic [`crate::sinkhorn::memory::stack_decode_state_bytes`]
    /// model at this stack's shape, which the scheduler's admission
    /// control budgets against (DESIGN.md §Scheduler).
    pub fn session_state_bytes(&self) -> usize {
        let c = &self.stack.cfg;
        crate::sinkhorn::memory::stack_decode_state_bytes(
            c.depth,
            c.n_heads,
            c.block_rows(),
            c.d_head(),
            c.nb,
            c.n_cut,
        )
    }

    /// Peak *new* bytes admitting `(prompt, max_new)` will pin — what the
    /// scheduler's reservation-based admission charges against the memory
    /// budget (DESIGN.md §Scheduler, §Pages). For paged models this is
    /// the analytic resident model at the session's final length
    /// ([`crate::sinkhorn::memory::paged_session_peak_bytes`]), discounted
    /// by the full K/V pages a currently-cached prompt prefix would be
    /// forked rather than allocated. Monolithic models fall back to the
    /// worst-case [`Self::session_state_bytes`]. Applies the same
    /// prompt/budget clamping as [`Self::open_session`], so the charge
    /// matches the session actually opened.
    pub fn session_admission_bytes(&self, prompt: &[i32], max_new: usize) -> usize {
        if !self.cfg.paged {
            return self.session_state_bytes();
        }
        let ell_cap = self.cfg.seq_len;
        let seeded = [0i32];
        let prompt: &[i32] = if prompt.is_empty() { &seeded } else { prompt };
        let keep = prompt.len().min(ell_cap.saturating_sub(1).max(1));
        let budget = max_new.min(ell_cap - keep);
        if budget == 0 {
            // retires at open: empty state, only the fixed R/desc footprint
            return self.paged_peak_bytes(0, 0);
        }
        let target_len = keep + budget - 1;
        let mut shared = 0usize;
        if self.cfg.prefix_share {
            let target = self.shareable_len(keep);
            if target > 0 {
                let cache = self.lock_prefix_cache();
                shared = cache
                    .iter()
                    .filter(|e| {
                        e.tokens.len() <= target && prompt[..keep].starts_with(&e.tokens)
                    })
                    .map(|e| e.tokens.len())
                    .max()
                    .unwrap_or(0);
            }
        }
        self.paged_peak_bytes(target_len, shared)
    }

    /// [`crate::sinkhorn::memory::paged_session_peak_bytes`] at this
    /// stack's shape and the configured page size.
    fn paged_peak_bytes(&self, target_len: usize, shared_len: usize) -> usize {
        let c = &self.stack.cfg;
        crate::sinkhorn::memory::paged_session_peak_bytes(
            c.depth,
            c.n_heads,
            c.block_rows(),
            c.d_head(),
            c.nb,
            c.n_cut,
            self.cfg.blocks_per_page(),
            target_len,
            shared_len,
        )
    }

    /// Advance every session one token — the scheduler's tick (DESIGN.md
    /// §Scheduler). Embeds each session's next token (prompt tokens first,
    /// then its own greedy continuations), drives all sessions through one
    /// [`SinkhornStack::decode_step_batch`] (the fused `(session, layer,
    /// head)` engine pass), then samples the tied LM head for sessions
    /// past their prompt. Returns the token each session emitted this tick
    /// (`None` while a session is still consuming its prompt — prefill
    /// rides the same tick loop).
    ///
    /// Per session the math is identical to [`Self::generate`]'s serial
    /// loop, so streams are bit-identical to single-request generation for
    /// any cohort composition, arrival order, or retirement pattern
    /// (`tests/decode_props.rs`).
    pub fn step_sessions(
        &self,
        sessions: &mut [&mut GenSession],
        scratch: &mut crate::sinkhorn::StackBatchScratch,
    ) -> Vec<Option<i32>> {
        use crate::sinkhorn::StackStepReq;
        if sessions.is_empty() {
            return Vec::new();
        }
        for s in sessions.iter_mut() {
            assert!(!s.done(), "step_sessions called on a finished session");
            let t = s.st.len();
            let tok =
                if t < s.prompt.len() { s.prompt[t] } else { s.gen[t - s.prompt.len()] };
            self.embed_token_into(tok, t, &mut s.x);
        }
        let reqs: Vec<StackStepReq> = sessions
            .iter_mut()
            .map(|s| {
                let GenSession { st, x, h, .. } = &mut **s;
                StackStepReq { st, x: x.as_slice(), out: h.as_mut_slice() }
            })
            .collect();
        self.stack.decode_step_batch(reqs, scratch);
        sessions.iter_mut().map(|s| self.session_epilogue(s)).collect()
    }

    /// Commit a step the engine just took for `s` and sample the LM head
    /// when the session is past its prompt — the shared tail of
    /// [`Self::step_sessions`], [`Self::step_sessions_isolated`] and the
    /// fault-recovery replay.
    fn session_epilogue(&self, s: &mut GenSession) -> Option<i32> {
        s.committed = s.st.len();
        let t = s.st.len() - 1; // the step just taken
        if t + 1 >= s.prompt.len() {
            let id = self.lm_argmax(&s.h);
            s.gen.push(id);
            Some(id)
        } else {
            None
        }
    }

    /// [`Self::step_sessions`] with panic containment (DESIGN.md §Faults):
    /// the scheduler's tick when sessions must not take each other — or
    /// the scheduler — down. Per session the emitted floats are identical
    /// to the unisolated path; what changes is failure behavior:
    ///
    /// * **phase A** (fault point + embed) runs per session under
    ///   `catch_unwind`. Nothing in it mutates decode state, so a panic
    ///   fails that session alone and the rest of the tick proceeds.
    /// * **phase B** (the fused [`SinkhornStack::decode_step_batch`]) runs
    ///   once under `catch_unwind`. A panic mid-pass (an injected
    ///   allocation failure, a worker panic resurfaced by the scoped
    ///   pool) can leave any live session's paged K/V torn mid-write, so
    ///   every live session is then recovered by [`Self::replay_and_step`]
    ///   — deterministic replay from its last committed token. Transient
    ///   faults (a single scheduled allocation ordinal, now consumed)
    ///   recover **bitwise**; persistent ones fail that session with its
    ///   stable message.
    ///
    /// Sessions that return [`StepOutcome::Failed`] are dead — the caller
    /// must retire them (dropping the session frees its pages).
    pub fn step_sessions_isolated(
        &self,
        sessions: &mut [&mut GenSession],
        scratch: &mut crate::sinkhorn::StackBatchScratch,
    ) -> Vec<StepOutcome> {
        use std::panic::{catch_unwind, AssertUnwindSafe};

        use crate::sinkhorn::StackStepReq;
        if sessions.is_empty() {
            return Vec::new();
        }
        let mut failed: Vec<Option<&'static str>> = Vec::with_capacity(sessions.len());
        for s in sessions.iter_mut() {
            assert!(!s.done(), "step_sessions_isolated called on a finished session");
            let r = catch_unwind(AssertUnwindSafe(|| {
                self.faults.step_point();
                let t = s.st.len();
                let tok =
                    if t < s.prompt.len() { s.prompt[t] } else { s.gen[t - s.prompt.len()] };
                self.embed_token_into(tok, t, &mut s.x);
            }));
            failed.push(r.err().map(|p| panic_msg(&*p)));
        }
        if failed.iter().all(Option::is_some) {
            return failed.into_iter().map(|e| StepOutcome::Failed(e.unwrap())).collect();
        }
        let batch = catch_unwind(AssertUnwindSafe(|| {
            let reqs: Vec<StackStepReq> = sessions
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| failed[*i].is_none())
                .map(|(_, s)| {
                    let GenSession { st, x, h, .. } = &mut **s;
                    StackStepReq { st, x: x.as_slice(), out: h.as_mut_slice() }
                })
                .collect();
            self.stack.decode_step_batch(reqs, scratch);
        }));
        let batch_ok = batch.is_ok();
        failed
            .into_iter()
            .zip(sessions.iter_mut())
            .map(|(e, s)| match e {
                Some(msg) => StepOutcome::Failed(msg),
                None if batch_ok => StepOutcome::Token(self.session_epilogue(s)),
                None => match catch_unwind(AssertUnwindSafe(|| self.replay_and_step(s))) {
                    Ok(tok) => StepOutcome::Token(tok),
                    Err(p) => StepOutcome::Failed(panic_msg(&*p)),
                },
            })
            .collect()
    }

    /// Fault recovery (DESIGN.md §Faults): rebuild `s`'s decode state
    /// from scratch up to its last committed token, then take the step
    /// the fused pass failed to land — serially, through the same
    /// [`SinkhornStack::decode_step`] the batch path is bit-identical to,
    /// so a recovered session's stream is indistinguishable from one that
    /// never faulted. The torn state is dropped first (its pages return
    /// to the pool before the rebuild allocates). Panics propagate — the
    /// caller contains them; a replay that hits a still-scheduled
    /// allocation fault fails for good. The injected *step* fault is not
    /// re-consulted: its ordinal was consumed when it fired.
    fn replay_and_step(&self, s: &mut GenSession) -> Option<i32> {
        let (committed, keep) = (s.committed, s.prompt.len());
        s.gen.truncate((committed + 1).saturating_sub(keep));
        s.st = self.fresh_session_state();
        s.shared = 0;
        let mut scratch = self.stack.new_decode_scratch();
        for t in 0..=committed {
            let tok = if t < keep { s.prompt[t] } else { s.gen[t - keep] };
            self.embed_token_into(tok, t, &mut s.x);
            self.stack.decode_step(&mut s.st, &s.x, &mut scratch, &mut s.h);
        }
        self.session_epilogue(s)
    }

    /// Ingest up to `max_tokens` of `s`'s remaining prompt through the
    /// chunked prefill path (DESIGN.md §Prefill): the scheduler calls
    /// this between decode ticks with its `--prefill-chunk-tokens`
    /// budget, so a long prompt is absorbed in block-parallel engine
    /// chunks instead of one `decode_step` per tick — while the budget
    /// bounds how long any single chunk can hold the tick loop
    /// (Sarathi-style chunking). The *final* prompt token is never
    /// ingested here: its step emits the session's first token, so it
    /// must ride the tick loop like every emitting step — which keeps
    /// the stream's token cadence and the LM-head math untouched.
    ///
    /// Bit-identical to consuming the same tokens one tick at a time
    /// (`tests/prefill_props.rs`): the chunk replays the step path's op
    /// order exactly. Advances the session's committed point past the
    /// chunk; returns the number of tokens ingested (0 when the prompt
    /// is already absorbed). A panic mid-chunk (an injected allocation
    /// fault) leaves the state torn — recover with
    /// [`Self::replay_prefill`], mirroring the tick loop's phase-B
    /// containment (DESIGN.md §Faults).
    pub fn prefill_session(
        &self,
        s: &mut GenSession,
        max_tokens: usize,
        scratch: &mut crate::sinkhorn::StackPrefillScratch,
    ) -> usize {
        let n = s.prefill_remaining().min(max_tokens);
        if n == 0 {
            return 0;
        }
        let d = self.cfg.d_model;
        let t0 = s.st.len();
        let mut xs = vec![0.0f32; n * d];
        for j in 0..n {
            self.embed_token_into(s.prompt[t0 + j], t0 + j, &mut xs[j * d..(j + 1) * d]);
        }
        self.stack.prefill(&mut s.st, &xs, scratch, None);
        s.committed = s.st.len();
        n
    }

    /// Recovery for a panic inside [`Self::prefill_session`] (DESIGN.md
    /// §Faults, §Prefill): the chunk may have left `s.st` torn mid-write,
    /// so drop it (returning its pages) and rebuild serially up to the
    /// last committed token — [`Self::replay_and_step`]'s contract minus
    /// the step that was never taken, so no token is emitted. Panics
    /// propagate; the caller contains them and retires the session on a
    /// persistent fault.
    pub fn replay_prefill(&self, s: &mut GenSession) {
        let (committed, keep) = (s.committed, s.prompt.len());
        s.gen.truncate((committed + 1).saturating_sub(keep));
        s.st = self.fresh_session_state();
        s.shared = 0;
        let mut scratch = self.stack.new_decode_scratch();
        for t in 0..committed {
            let tok = if t < keep { s.prompt[t] } else { s.gen[t - keep] };
            self.embed_token_into(tok, t, &mut s.x);
            self.stack.decode_step(&mut s.st, &s.x, &mut scratch, &mut s.h);
        }
    }
}

/// What one session's tick produced under [`FallbackModel::
/// step_sessions_isolated`]: a (possibly recovered) step, or a contained
/// failure with its stable client-facing message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// The step landed; `Some(tok)` once the session is past its prompt
    /// (same meaning as [`FallbackModel::step_sessions`]'s entries).
    Token(Option<i32>),
    /// The session is dead: a panic was contained and could not be
    /// recovered. The message is one of the stable `error=` payloads
    /// (rust/README.md failure modes).
    Failed(&'static str),
}

/// One in-flight generation inside the continuous-batching scheduler
/// (DESIGN.md §Scheduler): the per-sequence depth-L decode state, the
/// capacity-clamped prompt, the greedy continuations emitted so far, and
/// the session's embedded-input/hidden rows. Created by
/// [`FallbackModel::open_session`], advanced one token per tick by
/// [`FallbackModel::step_sessions`], retired when [`GenSession::done`].
pub struct GenSession {
    st: crate::sinkhorn::StackDecodeState,
    prompt: Vec<i32>,
    budget: usize,
    shared: usize,
    /// tokens known fully landed in `st` — the recovery point
    /// [`FallbackModel::step_sessions_isolated`] replays from when a
    /// fused tick panics mid-write (DESIGN.md §Faults). Equal to
    /// `st.len()` except transiently inside a failed tick.
    committed: usize,
    gen: Vec<i32>,
    x: Vec<f32>,
    h: Vec<f32>,
}

impl GenSession {
    /// All budgeted tokens emitted — the session can retire. A session
    /// whose budget clamped to zero (capacity-filled model) is done
    /// before its first tick.
    pub fn done(&self) -> bool {
        self.gen.len() >= self.budget
    }

    /// Tokens emitted so far (a prefix of the final generation).
    pub fn generated(&self) -> &[i32] {
        &self.gen
    }

    /// Retire the session, yielding its full generation.
    pub fn into_generated(self) -> Vec<i32> {
        self.gen
    }

    /// The capacity-clamped token budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Tokens fed through the stack so far (prompt + continuations).
    pub fn pos(&self) -> usize {
        self.st.len()
    }

    /// Prompt tokens whose pages were forked from the prefix cache at
    /// open time (0 for monolithic sessions and cache misses).
    pub fn shared_len(&self) -> usize {
        self.shared
    }

    /// Tokens known fully landed in the decode state — the replay point
    /// fault recovery rebuilds from (DESIGN.md §Faults).
    pub fn committed(&self) -> usize {
        self.committed
    }

    /// Prompt tokens still eligible for chunked prefill: everything up
    /// to — but not including — the final prompt token, whose step emits
    /// the session's first generated token and therefore rides the tick
    /// loop (DESIGN.md §Prefill). Zero once the session is emitting.
    pub fn prefill_remaining(&self) -> usize {
        self.prompt.len().saturating_sub(1).saturating_sub(self.st.len())
    }
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0;
    for (j, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = j;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sinkhorn::balance;

    fn model() -> FallbackModel {
        FallbackModel::new(FallbackConfig {
            seq_len: 32,
            d_model: 16,
            nb: 4,
            vocab: 64,
            ..Default::default()
        })
        .unwrap()
    }

    fn deep_model() -> FallbackModel {
        FallbackModel::new(FallbackConfig {
            seq_len: 32,
            d_model: 16,
            nb: 4,
            vocab: 64,
            depth: 2,
            n_heads: 2,
            d_ff: 32,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn deterministic_across_instances() {
        let (a, b) = (model(), model());
        let toks: Vec<i32> = (0..32).map(|i| (i * 7) % 64).collect();
        assert_eq!(a.class_logits(&toks), b.class_logits(&toks));
        assert_eq!(a.classify(&toks), b.classify(&toks));
    }

    /// The depth-1 default must reproduce the *pre-stack* fallback math
    /// bitwise: embed + position, q/k/v via `Mat::matmul`, SortNet from
    /// mean-pooled block descriptors, one engine attention pass, `ctx @
    /// wo`, residual mean-pool, linear head — the historical inline body,
    /// reconstructed here from the model's own weights.
    #[test]
    fn depth1_stack_matches_legacy_inline_math_bitwise() {
        let m = model();
        let layer = &m.stack.layers[0];
        let (ell, d, nb) = (m.cfg.seq_len, m.cfg.d_model, m.cfg.nb);
        let toks: Vec<i32> = (0..32).map(|i| (i * 11 + 3) % 64).collect();
        // legacy prep
        let x = m.embed_seq(&toks);
        let q = x.matmul(&layer.wq[0]);
        let k = x.matmul(&layer.wk[0]);
        let v = x.matmul(&layer.wv[0]);
        let b = ell / nb;
        let mut blk = Mat::zeros(nb, d);
        for i in 0..nb {
            for t in 0..b {
                let xr = x.row(i * b + t);
                for (c, o) in blk.row_mut(i).iter_mut().enumerate() {
                    *o += xr[c];
                }
            }
        }
        blk.scale(1.0 / b as f32);
        let r = balance::sinkhorn(&blk.matmul(&layer.sortnet), m.cfg.sinkhorn_iters);
        let mut ctx = Mat::zeros(ell, d);
        m.stack.engine().attention_into(&q, &k, &v, &r, nb, false, &mut ctx);
        // legacy head
        let ctxp = ctx.matmul(&layer.wo[0]);
        let mut h = vec![0.0f32; d];
        for t in 0..ell {
            let (xr, cr) = (x.row(t), ctxp.row(t));
            for c in 0..d {
                h[c] += xr[c] + cr[c];
            }
        }
        for hv in &mut h {
            *hv /= ell as f32;
        }
        let mut want = vec![0.0f32; m.cfg.n_classes];
        for (c, &hc) in h.iter().enumerate() {
            let wr = m.w_cls.row(c);
            for (j, l) in want.iter_mut().enumerate() {
                *l += hc * wr[j];
            }
        }
        assert_eq!(m.class_logits(&toks), want, "depth-1 stack drifted from the legacy math");
    }

    #[test]
    fn labels_in_range_and_inputs_matter() {
        for m in [model(), deep_model()] {
            let mut seen = std::collections::HashSet::new();
            for s in 0..24 {
                let toks: Vec<i32> = (0..32).map(|i| (i * (s + 3) + s) % 64).collect();
                let label = m.classify(&toks);
                assert!((0..m.cfg.n_classes as i32).contains(&label));
                let lg = m.class_logits(&toks);
                assert!(lg.iter().all(|x| x.is_finite()));
                seen.insert(format!("{lg:?}"));
            }
            assert!(seen.len() > 1, "logits must depend on the input (depth {})", m.cfg.depth);
        }
    }

    #[test]
    fn handles_short_long_and_hostile_token_ids() {
        let m = deep_model();
        // short (padded), long (truncated), out-of-range ids (wrapped)
        let short = m.classify(&[1, 2, 3]);
        let long = m.classify(&vec![5; 500]);
        let hostile = m.classify(&[i32::MIN, i32::MAX, -1, 1 << 30]);
        for l in [short, long, hostile] {
            assert!((0..m.cfg.n_classes as i32).contains(&l));
        }
    }

    #[test]
    fn batch_matches_single() {
        for m in [model(), deep_model()] {
            let reqs: Vec<Vec<i32>> =
                (0..5).map(|s| (0..32).map(|i| (i + s) % 64).collect()).collect();
            let batch = m.classify_batch(&reqs);
            for (r, &want) in reqs.iter().zip(&batch) {
                assert_eq!(m.classify(r), want, "depth {}", m.cfg.depth);
            }
        }
    }

    #[test]
    fn generate_is_deterministic_and_in_vocab() {
        for m in [model(), deep_model()] {
            let prompt: Vec<i32> = (0..10).map(|i| (i * 5) % 64).collect();
            let a = m.generate(&prompt, 8);
            let b = m.generate(&prompt, 8);
            assert_eq!(a, b);
            assert_eq!(a.len(), 8);
            assert!(a.iter().all(|&t| (0..m.cfg.vocab as i32).contains(&t)));
        }
    }

    #[test]
    fn generate_prefix_stable() {
        // greedy decoding is incremental: asking for fewer tokens yields a
        // prefix of asking for more — through the full depth-L stack
        for m in [model(), deep_model()] {
            let prompt: Vec<i32> = (0..7).map(|i| i * 3 + 1).collect();
            let long = m.generate(&prompt, 6);
            for n in 1..6 {
                assert_eq!(&m.generate(&prompt, n)[..], &long[..n], "depth {} n={n}", m.cfg.depth);
            }
        }
    }

    #[test]
    fn generate_respects_capacity() {
        let m = model(); // seq_len = 32
        // near-capacity prompt: budget shrinks to the remaining positions
        let prompt: Vec<i32> = (0..30).map(|i| i % 64).collect();
        assert_eq!(m.generate(&prompt, 10).len(), 2);
        // over-capacity prompt: truncated to seq_len - 1, one token left
        let huge: Vec<i32> = (0..100).map(|i| i % 64).collect();
        assert_eq!(m.generate(&huge, 10).len(), 1);
        // zero tokens requested
        assert!(m.generate(&prompt, 0).is_empty());
    }

    #[test]
    fn generate_handles_empty_and_hostile_prompts() {
        let m = model();
        assert_eq!(m.generate(&[], 3).len(), 3);
        let hostile = m.generate(&[i32::MIN, i32::MAX, -1], 4);
        assert_eq!(hostile.len(), 4);
        assert!(hostile.iter().all(|&t| (0..m.cfg.vocab as i32).contains(&t)));
    }

    #[test]
    fn generate_batch_matches_single() {
        for m in [model(), deep_model()] {
            let reqs: Vec<(Vec<i32>, usize)> = (0..5)
                .map(|s| ((0..8).map(|i| (i * 7 + s) % 64).collect(), 3 + s as usize % 3))
                .collect();
            let batch = m.generate_batch(&reqs);
            for ((prompt, max_new), got) in reqs.iter().zip(&batch) {
                assert_eq!(&m.generate(prompt, *max_new), got, "depth {}", m.cfg.depth);
            }
        }
    }

    /// Sessions stepped in mixed cohorts (different prompt lengths and
    /// budgets, so they retire mid-wave while survivors keep ticking) must
    /// reproduce single-request `generate` exactly — the scheduler's
    /// core correctness contract (DESIGN.md §Scheduler).
    #[test]
    fn sessions_stepped_in_cohorts_match_generate() {
        for m in [model(), deep_model()] {
            let reqs: Vec<(Vec<i32>, usize)> = (0..6)
                .map(|s| {
                    let plen = 1 + (s * 5) % 11;
                    let toks = (0..plen).map(|i| ((i * 7 + s) % 64) as i32).collect();
                    (toks, 2 + s % 5)
                })
                .collect();
            let want: Vec<Vec<i32>> =
                reqs.iter().map(|(p, n)| m.generate(p, *n)).collect();
            let mut sessions: Vec<GenSession> =
                reqs.iter().map(|(p, n)| m.open_session(p, *n)).collect();
            let mut scratch = m.new_batch_scratch();
            loop {
                let mut live: Vec<&mut GenSession> =
                    sessions.iter_mut().filter(|s| !s.done()).collect();
                if live.is_empty() {
                    break;
                }
                m.step_sessions(&mut live, &mut scratch);
            }
            for ((sess, w), (p, _)) in sessions.into_iter().zip(&want).zip(&reqs) {
                assert_eq!(
                    &sess.into_generated(),
                    w,
                    "depth {} prompt {p:?} diverged from single-request generate",
                    m.cfg.depth
                );
            }
        }
    }

    /// `open_session` applies exactly `generate`'s capacity rule: prompt
    /// truncation, budget clamping, empty-prompt PAD seeding.
    #[test]
    fn open_session_mirrors_generate_capacity_rule() {
        let m = model(); // seq_len = 32
        assert_eq!(m.open_session(&(0..30).map(|i| i % 64).collect::<Vec<_>>(), 10).budget(), 2);
        let huge: Vec<i32> = (0..100).map(|i| i % 64).collect();
        let s = m.open_session(&huge, 10);
        assert_eq!(s.budget(), 1);
        assert!(!s.done());
        let zero = m.open_session(&[1, 2], 0);
        assert_eq!(zero.budget(), 0);
        assert!(zero.done(), "zero-budget session retires before its first tick");
        // empty prompt seeds PAD: one prompt token, still generates
        let empty = m.open_session(&[], 3);
        assert_eq!(empty.budget(), 3);
        assert_eq!(empty.pos(), 0);
    }

    #[test]
    fn session_state_bytes_matches_memory_model() {
        let m = deep_model();
        let c = crate::sinkhorn::memory::stack_decode_state_bytes(2, 2, 8, 8, 4, None);
        assert_eq!(m.session_state_bytes(), c);
        assert!(m.session_state_bytes() > 0);
    }

    #[test]
    fn describe_reports_the_stack_shape() {
        let m = deep_model();
        let s = m.describe();
        for want in
            ["backend=fallback", "sort_backend=sinkhorn", "depth=2", "heads=2", "d_ff=32",
             "seq_len=32"]
        {
            assert!(s.contains(want), "describe() missing {want}: {s}");
        }
        assert_eq!(s.lines().count(), 1, "describe() must stay one line");
    }

    /// `--backend` threads through to the stack's strategies, the `model`
    /// info verb reports it as a stable key, and the non-default backends
    /// serve both verbs deterministically (DESIGN.md §Backends).
    #[test]
    fn non_default_backends_serve_and_describe() {
        for backend in [Backend::Routing, Backend::Local] {
            let mk = || {
                FallbackModel::new(FallbackConfig {
                    seq_len: 32,
                    d_model: 16,
                    nb: 4,
                    vocab: 64,
                    backend,
                    ..Default::default()
                })
                .unwrap()
            };
            let m = mk();
            assert_eq!(m.stack.uniform_backend(), Some(backend));
            let key = format!("sort_backend={}", backend.name());
            assert!(m.describe().contains(&key), "missing {key}: {}", m.describe());
            let toks: Vec<i32> = (0..32).map(|i| (i * 7 + 1) % 64).collect();
            assert_eq!(m.class_logits(&toks), mk().class_logits(&toks), "{backend:?}");
            let prompt: Vec<i32> = (0..9).map(|i| (i * 5) % 64).collect();
            let gen = m.generate(&prompt, 6);
            assert_eq!(gen.len(), 6, "{backend:?}");
            assert_eq!(gen, mk().generate(&prompt, 6), "{backend:?}");
            // scheduler cohorts must keep matching serial generate under
            // every backend, not just the default
            let mut sess = m.open_session(&prompt, 6);
            let mut scratch = m.new_batch_scratch();
            while !sess.done() {
                let mut live = vec![&mut sess];
                m.step_sessions(&mut live, &mut scratch);
            }
            assert_eq!(sess.into_generated(), gen, "{backend:?} cohort diverged");
        }
    }

    /// Sessions opened with a common prompt prefix fork cached pages
    /// instead of allocating: a same-prefix cohort pins strictly fewer
    /// pool pages than a distinct-prompt cohort of the same shape, while
    /// still reproducing the monolithic `generate` oracle token for token
    /// (DESIGN.md §Pages).
    #[test]
    fn shared_prefix_cohort_pins_fewer_pages() {
        let shared = deep_model();
        let distinct = deep_model();
        assert!(shared.paged() && shared.cfg.prefix_share);
        let base: Vec<i32> = (0..17).map(|i| (i * 7 + 2) % 64).collect();
        // same prompt 4x vs 4 prompts differing inside the first block
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for s in 0..4i32 {
            let mut p = base.clone();
            p[0] = (p[0] + s) % 64;
            same.push(shared.open_session(&base, 3));
            diff.push(distinct.open_session(&p, 3));
        }
        let (sp, dp) =
            (shared.pool_stats().pages_in_use, distinct.pool_stats().pages_in_use);
        assert!(sp > 0, "paged sessions must hold pages");
        assert!(
            sp < dp,
            "shared-prefix cohort must pin strictly fewer pages ({sp} vs {dp})"
        );
        assert!(same.iter().skip(1).all(|s| s.shared_len() == 16), "cache hits fork 2 blocks");
        assert_eq!(same[0].shared_len(), 0, "first open misses the cache");
        // both cohorts still reproduce the monolithic single-request oracle
        for (m, sessions) in [(&shared, &mut same), (&distinct, &mut diff)] {
            let want: Vec<Vec<i32>> = sessions
                .iter()
                .map(|s| m.generate(&s.prompt, s.budget()))
                .collect();
            let mut scratch = m.new_batch_scratch();
            loop {
                let mut live: Vec<&mut GenSession> =
                    sessions.iter_mut().filter(|s| !s.done()).collect();
                if live.is_empty() {
                    break;
                }
                m.step_sessions(&mut live, &mut scratch);
            }
            for (s, w) in sessions.iter().zip(&want) {
                assert_eq!(s.generated(), &w[..], "paged session diverged from generate");
            }
        }
        // retiring every session and dropping the prefix cache frees all pages
        drop(same);
        *shared.prefix_cache.lock().unwrap() = Vec::new();
        assert_eq!(shared.pool_stats().pages_in_use, 0);
        assert_eq!(shared.pool_stats().created, shared.pool_stats().freed);
    }

    /// Reservation-based admission charges the analytic paged peak, and
    /// discounts prefixes that are actually cached right now — while the
    /// monolithic configuration still charges the worst-case state bytes.
    #[test]
    fn session_admission_bytes_tracks_cache_and_mode() {
        let m = deep_model();
        let prompt: Vec<i32> = (0..17).map(|i| (i * 7 + 2) % 64).collect();
        let cold = m.session_admission_bytes(&prompt, 3);
        assert!(cold > 0 && cold < m.session_state_bytes(), "paged peak beats worst-case");
        let _s = m.open_session(&prompt, 3); // fills the prefix cache
        let warm = m.session_admission_bytes(&prompt, 3);
        assert!(warm < cold, "cached prefix must discount admission ({warm} vs {cold})");
        // an unrelated prompt gets no discount
        let other: Vec<i32> = (0..17).map(|i| (i * 5 + 33) % 64).collect();
        assert_eq!(m.session_admission_bytes(&other, 3), cold);
        // zero-budget sessions charge only the fixed per-layer footprint
        assert!(m.session_admission_bytes(&prompt, 0) < warm);
        // monolithic mode falls back to the worst-case model
        let mono = FallbackModel::new(FallbackConfig {
            seq_len: 32,
            d_model: 16,
            nb: 4,
            vocab: 64,
            depth: 2,
            n_heads: 2,
            d_ff: 32,
            paged: false,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(mono.session_admission_bytes(&prompt, 3), mono.session_state_bytes());
        let sess = mono.open_session(&prompt, 3);
        assert_eq!(sess.pos(), 0, "monolithic sessions never prefill at open");
        assert_eq!(sess.shared_len(), 0);
    }

    /// With the empty fault plan the isolated tick is the plain tick:
    /// same cohort, same tokens, bit for bit.
    #[test]
    fn isolated_step_matches_plain_step_bitwise() {
        let m = deep_model();
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|k| (0..(5 + k * 4)).map(|i| ((i * 7 + k) % 64) as i32).collect())
            .collect();
        let want: Vec<Vec<i32>> = prompts.iter().map(|p| m.generate(p, 6)).collect();
        let mut sessions: Vec<GenSession> =
            prompts.iter().map(|p| m.open_session(p, 6)).collect();
        let mut scratch = m.new_batch_scratch();
        loop {
            let mut live: Vec<&mut GenSession> =
                sessions.iter_mut().filter(|s| !s.done()).collect();
            if live.is_empty() {
                break;
            }
            for o in m.step_sessions_isolated(&mut live, &mut scratch) {
                assert!(matches!(o, StepOutcome::Token(_)), "no faults, no failures: {o:?}");
            }
        }
        for (s, w) in sessions.iter().zip(&want) {
            assert_eq!(s.generated(), &w[..], "isolated tick diverged from generate");
        }
    }

    /// An injected step panic kills exactly the session whose ordinal
    /// fired; cohort-mates keep generating and stay bitwise identical to
    /// the fault-free oracle.
    #[test]
    fn injected_step_panic_fails_one_session_survivors_bitwise() {
        use crate::server::faults::{FaultPlan, FaultSpec, STEP_PANIC_MSG};
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, vocab: 64, ..Default::default() };
        let oracle = FallbackModel::new(cfg.clone()).unwrap();
        // 3 sessions: tick 0 consumes step ordinals 0..3, tick 1 consumes
        // 3..6 — ordinal 4 is tick 1, session index 1
        let m = FallbackModel::with_faults(
            cfg,
            FaultPlan::from_spec(&FaultSpec { step_panic: vec![4], ..Default::default() }),
        )
        .unwrap();
        let prompts: Vec<Vec<i32>> =
            (0..3).map(|k| (0..6).map(|i| ((i * 11 + k * 5) % 64) as i32).collect()).collect();
        let want: Vec<Vec<i32>> = prompts.iter().map(|p| oracle.generate(p, 5)).collect();
        let mut sessions: Vec<Option<GenSession>> =
            prompts.iter().map(|p| Some(m.open_session(p, 5))).collect();
        let mut failures = Vec::new();
        let mut scratch = m.new_batch_scratch();
        loop {
            let mut idx: Vec<usize> = Vec::new();
            let mut live: Vec<&mut GenSession> = Vec::new();
            for (i, s) in sessions.iter_mut().enumerate() {
                if let Some(s) = s.as_mut() {
                    if !s.done() {
                        idx.push(i);
                        live.push(s);
                    }
                }
            }
            if live.is_empty() {
                break;
            }
            let outs = m.step_sessions_isolated(&mut live, &mut scratch);
            for (i, o) in idx.into_iter().zip(outs) {
                if let StepOutcome::Failed(msg) = o {
                    failures.push((i, msg));
                    sessions[i] = None; // retire: dropping frees its pages
                }
            }
        }
        assert_eq!(failures, vec![(1, STEP_PANIC_MSG)]);
        for (i, w) in want.iter().enumerate() {
            if i != 1 {
                let got = sessions[i].as_ref().unwrap().generated();
                assert_eq!(got, &w[..], "survivor {i} diverged");
            }
        }
        drop(sessions);
        let s = m.pool_stats();
        assert!(s.conserved(), "ledger must conserve after a contained panic: {s:?}");
    }

    /// A single scheduled allocation fault tears the fused tick mid-write;
    /// replay-from-committed recovers the session **bitwise** (the fault
    /// ordinal is consumed, so the rebuild sails through) and the pool
    /// ledger balances to zero afterwards.
    #[test]
    fn transient_alloc_fault_recovers_bitwise() {
        use crate::server::faults::{FaultPlan, FaultSpec};
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, vocab: 64, ..Default::default() };
        let oracle = FallbackModel::new(cfg.clone()).unwrap();
        let m = FallbackModel::with_faults(
            cfg,
            FaultPlan::from_spec(&FaultSpec { alloc_fail: vec![2], ..Default::default() }),
        )
        .unwrap();
        // prompt shorter than one block: no prefill allocation at open, so
        // every pool ordinal lands inside ticks
        let prompt: Vec<i32> = (0..5).map(|i| (i * 13 + 1) % 64).collect();
        let want = oracle.generate(&prompt, 8);
        let mut sess = m.open_session(&prompt, 8);
        let mut scratch = m.new_batch_scratch();
        while !sess.done() {
            let mut live = vec![&mut sess];
            let outs = m.step_sessions_isolated(&mut live, &mut scratch);
            assert!(
                matches!(outs[0], StepOutcome::Token(_)),
                "a transient alloc fault must recover, not fail: {outs:?}"
            );
        }
        assert_eq!(sess.generated(), &want[..], "recovered stream must be bitwise identical");
        assert!(m.faults().seen().0 > 2, "the scheduled alloc ordinal must have been reached");
        drop(sess);
        let s = m.pool_stats();
        assert_eq!(s.pages_in_use, 0);
        assert!(s.conserved(), "{s:?}");
    }

    #[test]
    fn blocks_for_divides() {
        for ell in [128, 96, 64, 30, 7] {
            assert_eq!(ell % FallbackConfig::blocks_for(ell), 0);
        }
    }

    #[test]
    fn rejects_bad_config() {
        assert!(FallbackModel::new(FallbackConfig { seq_len: 30, nb: 8, ..Default::default() })
            .is_err());
        // n_heads must divide d_model
        assert!(FallbackModel::new(FallbackConfig {
            d_model: 64,
            n_heads: 3,
            d_ff: 16,
            ..Default::default()
        })
        .is_err());
    }
}
