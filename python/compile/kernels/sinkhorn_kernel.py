"""Pallas kernel for (causal) Sinkhorn balancing of sorting logits.

Normalizes a batch of ``(nb, nb)`` block-permutation logits into relaxed
doubly-stochastic matrices by ``n_iters`` of log-domain row/column
normalization (paper §3.1.1), with the causal masked variant of §3.3.2.

The matrix is tiny (``nb`` is 4–32 in every experiment) so one program owns
one full matrix; the iteration count is a static closure so the loop
unrolls into straight-line VPU code. Backward: this op is O(nb^2 * k) —
negligible next to attention — so the custom VJP simply differentiates the
jnp reference (``ref.sinkhorn_log``), which the tests pin to the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e9


def _kernel(r_ref, s_ref, *, n_iters, causal, strict):
    # single program owns the whole (G, nb, nb) slab: the matrices are tiny
    # (nb <= 32) so grid-level parallelism buys nothing and interpret-mode
    # grid emulation costs a serial loop per program.
    x = r_ref[...].astype(jnp.float32)  # (G, nb, nb)
    nb = x.shape[-1]
    if causal:
        i = jax.lax.broadcasted_iota(jnp.int32, (nb, nb), 0)
        j = jax.lax.broadcasted_iota(jnp.int32, (nb, nb), 1)
        mask = (j < i) if strict else (j <= i)
        x = jnp.where(mask, x, NEG_INF)
    else:
        mask = None

    def logsumexp(a, axis):
        m = jnp.max(a, axis=axis, keepdims=True)
        m = jnp.maximum(m, NEG_INF)  # guard all-masked slices
        return jnp.log(jnp.sum(jnp.exp(a - m), axis=axis, keepdims=True) + 1e-30) + m

    if n_iters == 0:
        # softmax rows (paper Table 8 row 6 ablation)
        s = jnp.exp(x - logsumexp(x, -1))
    else:
        for _ in range(n_iters):
            x = x - jnp.maximum(logsumexp(x, -1), NEG_INF)
            if mask is not None:
                x = jnp.where(mask, x, NEG_INF)
            if mask is None:
                x = x - jnp.maximum(logsumexp(x, -2), NEG_INF)
            else:
                # causal column normalization: entry (i, j) may only be
                # normalized by rows j..i (a full column sum would leak
                # future block content through the normalizer — §3.3.2).
                # cumulative sum as tril-matmul: same math as jnp.cumsum,
                # but compiles fast on xla_extension 0.5.1 (see ref.py)
                cmax = jnp.maximum(jnp.max(x, axis=-2, keepdims=True), NEG_INF)
                e = jnp.where(mask, jnp.exp(x - cmax), 0.0)
                tril = jnp.tril(jnp.ones((nb, nb), jnp.float32))
                csum = jnp.einsum("ik,...kj->...ij", tril, e)
                ncol = jnp.log(csum + 1e-30) + cmax
                x = jnp.where(mask, x - jnp.maximum(ncol, NEG_INF), NEG_INF)
        s = jnp.exp(x)
    if mask is not None:
        s = jnp.where(mask, s, 0.0)
    s_ref[...] = s.astype(s_ref.dtype)


def _pallas_sinkhorn(r, *, n_iters, causal, strict):
    g, nb, _ = r.shape
    spec = pl.BlockSpec((g, nb, nb), lambda i: (0, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, n_iters=n_iters, causal=causal, strict=strict),
        grid=(1,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
        interpret=True,
    )(r)


@functools.lru_cache(maxsize=None)
def _make(n_iters: int, causal: bool, strict: bool):
    if causal:
        ref_fn = jax.vmap(lambda r: ref.causal_sinkhorn_log(r, n_iters, strict=strict))
    else:
        ref_fn = jax.vmap(lambda r: ref.sinkhorn_log(r, n_iters))

    @jax.custom_vjp
    def balance(r):
        return _pallas_sinkhorn(r, n_iters=n_iters, causal=causal, strict=strict)

    def fwd(r):
        return balance(r), r

    def bwd(r, ds):
        _, vjp = jax.vjp(ref_fn, r)
        return vjp(ds)

    balance.defvjp(fwd, bwd)
    return balance


def sinkhorn_balance(r, n_iters: int, causal: bool = False, strict: bool = False):
    """Balance a batch of sorting logits ``r`` (G, nb, nb).

    Returns (relaxed) doubly-stochastic matrices; with ``causal=True``
    entries sending a block to an earlier position are zeroed (``strict``
    additionally zeroes the diagonal — used for the sorted-key term).
    """
    return _make(int(n_iters), bool(causal), bool(strict))(r)
