"""The meta Sorting Network (paper §3.1, §3.3.1, Table 8 variants).

Pipeline per attention layer (and per head — the paper does *not* share R
across heads):

  1. ``psi_pool``    — block descriptors: sum pooling over each block, or
                       the causal cumulative-sum variant (eq. 5).
  2. ``P(·)``        — a small network mapping a descriptor (d_model) to an
                       ``nb``-dim row of sorting logits. Four variants from
                       Table 8, selected by ``p_variant``:
                         1: relu(F2(relu(F1(x))))   2: F2(relu(F1(x)))
                         3: relu(F1(x))             4: F1(x)        (default)
  3. Gumbel noise + temperature tau (§3.2.1) on the logits.
  4. Sinkhorn balancing (L1 Pallas kernel) -> relaxed permutation S.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import sinkhorn_kernel
from . import layers


def sortnet_init(key, d_model: int, nb: int, n_heads: int, p_variant: int = 4):
    """Per-head sorting network parameters."""
    k1, k2 = jax.random.split(key)
    p = {}
    if p_variant in (1, 2):
        p["f1"] = {
            "w": jax.random.normal(k1, (n_heads, d_model, d_model), jnp.float32)
            / jnp.sqrt(d_model),
            "b": jnp.zeros((n_heads, d_model), jnp.float32),
        }
        d_in2 = d_model
        k_f2 = k2
    else:
        d_in2 = d_model
        k_f2 = k1
    p["f2"] = {
        "w": jax.random.normal(k_f2, (n_heads, d_in2, nb), jnp.float32) / jnp.sqrt(d_in2),
        "b": jnp.zeros((n_heads, nb), jnp.float32),
    }
    return p


def psi_pool(x: jnp.ndarray, nb: int, causal: bool) -> jnp.ndarray:
    """Block descriptors. ``x``: (B, ell, d) -> (B, nb, d).

    Non-causal: sum of the block's tokens (paper eq. 2). Causal: cumulative
    sum of all tokens up to and including the block's *first* token
    (paper eq. 5) — conditioning only on past context.
    """
    bsz, ell, d = x.shape
    b = ell // nb
    if not causal:
        return x.reshape(bsz, nb, b, d).sum(axis=2)
    csum = jnp.cumsum(x, axis=1)  # (B, ell, d)
    idx = jnp.arange(nb) * b  # first token of each block
    return csum[:, idx, :]


def sorting_logits(params, x_pooled: jnp.ndarray, p_variant: int) -> jnp.ndarray:
    """Apply P(·) per head: (B, nb, d) -> (B, H, nb, nb)."""
    h = x_pooled
    if p_variant in (1, 2):
        h = jnp.einsum("bnd,hde->bhne", h, params["f1"]["w"]) + params["f1"]["b"][None, :, None, :]
        h = jax.nn.relu(h)
    else:
        h = h[:, None]  # (B, 1, nb, d) broadcast over heads in einsum below
    r = jnp.einsum("bhnd,hdm->bhnm", jnp.broadcast_to(h, (h.shape[0], params["f2"]["w"].shape[0]) + h.shape[-2:]), params["f2"]["w"])
    r = r + params["f2"]["b"][None, :, None, :]
    if p_variant in (1, 3):
        r = jax.nn.relu(r)
    return r  # (B, H, nb, nb)


def gumbel_noise(key, shape, dtype=jnp.float32):
    u = jax.random.uniform(key, shape, dtype, minval=1e-6, maxval=1.0 - 1e-6)
    return -jnp.log(-jnp.log(u))


def sort_matrix(
    params,
    x: jnp.ndarray,
    *,
    nb: int,
    n_iters: int,
    tau: float,
    p_variant: int,
    causal: bool,
    key=None,
) -> jnp.ndarray:
    """Full SortNet: input sequence -> per-head relaxed permutation.

    Returns ``S``: (B, H, nb, nb). ``key=None`` disables Gumbel noise
    (deterministic eval). Causal mode masks strictly (j < i) so a sorted
    key block never contains same-block future tokens (see ref.causal_mask).
    """
    pooled = psi_pool(x, nb, causal)  # (B, nb, d)
    r = sorting_logits(params, pooled, p_variant)  # (B, H, nb, nb)
    if key is not None and tau > 0:
        r = (r + gumbel_noise(key, r.shape, r.dtype)) / tau
    bsz, nh = r.shape[0], r.shape[1]
    flat = r.reshape(bsz * nh, nb, nb)
    s = sinkhorn_kernel.sinkhorn_balance(flat, n_iters, causal=causal, strict=causal)
    return s.reshape(bsz, nh, nb, nb)
