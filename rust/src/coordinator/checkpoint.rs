//! Checkpoint store: a simple length-prefixed binary tensor format
//! (`SNKH1` magic). Saves the full Adam state so training resumes exactly.
//!
//! Layout (little-endian):
//!   magic "SNKH1" | name_len u32 | name bytes | step f32 | n_tensors u32
//!   then per tensor: name_len u32 | name | dtype u8 (0=f32, 1=i32)
//!                    | ndim u32 | dims u64... | data bytes

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{HostTensor, Manifest, TrainState};

const MAGIC: &[u8; 5] = b"SNKH1";

pub struct Checkpoint {
    pub exp_name: String,
    pub step: f32,
    /// params, then m, then v — in manifest leaf order.
    pub tensors: Vec<(String, HostTensor)>,
}

fn put_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn get_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn put_str(w: &mut impl Write, s: &str) -> Result<()> {
    put_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn get_str(r: &mut impl Read) -> Result<String> {
    let n = get_u32(r)? as usize;
    if n > 1 << 20 {
        bail!("unreasonable string length {n}");
    }
    let mut b = vec![0u8; n];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

impl Checkpoint {
    /// Capture a training state (downloads literals to host).
    pub fn capture(manifest: &Manifest, state: &TrainState) -> Result<Checkpoint> {
        let mut tensors = Vec::with_capacity(3 * state.params.len());
        for (group, lits) in [("p", &state.params), ("m", &state.m), ("v", &state.v)] {
            for (spec, lit) in manifest.params.iter().zip(lits.iter()) {
                let t = HostTensor::from_literal(lit)?;
                t.check_spec(spec)?;
                tensors.push((format!("{group}/{}", spec.name), t));
            }
        }
        Ok(Checkpoint { exp_name: manifest.name.clone(), step: state.step, tensors })
    }

    /// Rebuild a runtime training state (uploads to literals).
    pub fn restore(&self, manifest: &Manifest) -> Result<TrainState> {
        if self.exp_name != manifest.name {
            bail!("checkpoint is for '{}', not '{}'", self.exp_name, manifest.name);
        }
        let n = manifest.n_leaves();
        if self.tensors.len() != 3 * n {
            bail!("checkpoint has {} tensors, expected {}", self.tensors.len(), 3 * n);
        }
        let lits = |offset: usize| -> Result<Vec<xla::Literal>> {
            manifest
                .params
                .iter()
                .enumerate()
                .map(|(i, spec)| {
                    let (name, t) = &self.tensors[offset + i];
                    if !name.ends_with(&spec.name) {
                        bail!("leaf order mismatch: '{name}' vs '{}'", spec.name);
                    }
                    t.check_spec(spec)?;
                    t.to_literal()
                })
                .collect()
        };
        Ok(TrainState { params: lits(0)?, m: lits(n)?, v: lits(2 * n)?, step: self.step })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        {
            let mut w = std::io::BufWriter::new(
                std::fs::File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?,
            );
            w.write_all(MAGIC)?;
            put_str(&mut w, &self.exp_name)?;
            w.write_all(&self.step.to_le_bytes())?;
            put_u32(&mut w, self.tensors.len() as u32)?;
            for (name, t) in &self.tensors {
                put_str(&mut w, name)?;
                let (tag, bytes): (u8, Vec<u8>) = match t {
                    HostTensor::F32 { data, .. } => {
                        (0, data.iter().flat_map(|x| x.to_le_bytes()).collect())
                    }
                    HostTensor::I32 { data, .. } => {
                        (1, data.iter().flat_map(|x| x.to_le_bytes()).collect())
                    }
                };
                w.write_all(&[tag])?;
                put_u32(&mut w, t.shape().len() as u32)?;
                for &d in t.shape() {
                    w.write_all(&(d as u64).to_le_bytes())?;
                }
                w.write_all(&bytes)?;
            }
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 5];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a SNKH1 checkpoint");
        }
        let exp_name = get_str(&mut r)?;
        let mut stepb = [0u8; 4];
        r.read_exact(&mut stepb)?;
        let step = f32::from_le_bytes(stepb);
        let n = get_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name = get_str(&mut r)?;
            let mut tag = [0u8; 1];
            r.read_exact(&mut tag)?;
            let ndim = get_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut d = [0u8; 8];
                r.read_exact(&mut d)?;
                shape.push(u64::from_le_bytes(d) as usize);
            }
            let count: usize = shape.iter().product();
            let mut raw = vec![0u8; count * 4];
            r.read_exact(&mut raw)?;
            let t = match tag[0] {
                0 => HostTensor::f32(
                    &shape,
                    raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
                ),
                1 => HostTensor::i32(
                    &shape,
                    raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
                ),
                t => bail!("bad dtype tag {t}"),
            };
            tensors.push((name, t));
        }
        Ok(Checkpoint { exp_name, step, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sinkhorn-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn file_roundtrip() {
        let ck = Checkpoint {
            exp_name: "demo".into(),
            step: 42.0,
            tensors: vec![
                ("p/w".into(), HostTensor::f32(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 9.9, -7.0])),
                ("m/w".into(), HostTensor::i32(&[4], vec![1, 2, 3, 4])),
            ],
        };
        let path = tmpfile("roundtrip.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.exp_name, "demo");
        assert_eq!(back.step, 42.0);
        assert_eq!(back.tensors, ck.tensors);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("bad.ckpt");
        std::fs::write(&path, b"NOPE!xxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn property_roundtrip_random_tensors() {
        use crate::util::prop::forall;
        forall(
            10,
            0xCC,
            |g| {
                let n = 1 + g.usize(0, 4);
                (0..n)
                    .map(|i| {
                        let r = 1 + g.usize(0, 5);
                        let c = 1 + g.usize(0, 5);
                        (format!("t{i}"), HostTensor::f32(&[r, c], g.vec_f32(r * c, -10.0, 10.0)))
                    })
                    .collect::<Vec<_>>()
            },
            |tensors| {
                let ck = Checkpoint { exp_name: "x".into(), step: 1.0, tensors: tensors.clone() };
                let path = tmpfile("prop.ckpt");
                ck.save(&path).map_err(|e| e.to_string())?;
                let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
                if back.tensors == *tensors {
                    Ok(())
                } else {
                    Err("tensors differ after roundtrip".into())
                }
            },
        );
    }
}
