//! The L3 coordination layer: training orchestration, evaluation drivers,
//! checkpointing and metric conversion. Everything here runs on the
//! compiled artifacts — Python is never on this path.

pub mod checkpoint;
pub mod eval;
pub mod metrics;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use eval::{eval_cls, eval_lm, eval_sort, eval_sort_teacher_forced};
pub use metrics::{bpc, bpd, perplexity, LossCurve};
pub use trainer::{train, train_from_scratch, TrainOptions, TrainReport};
