# Sparse Sinkhorn Attention — repo-level targets.
# `check-docs` is the CI documentation gate; the rest are conveniences.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test check-docs doc-refs fmt-check clippy bench bench-engine bench-decode serve-fallback artifacts all

all: build

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

## CI documentation gate: rustdoc must be warning-free and every
## `DESIGN.md §` citation in rust/src/ must resolve to a real section.
check-docs: doc-refs
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

## The reference check alone needs no Rust toolchain (plain python3).
doc-refs:
	python3 tools/check_design_refs.py --all

## Formatting gate. Loudly skipped when no Rust toolchain is on PATH (the
## offline build container), like the toolchain half of check-docs.
fmt-check:
	@if command -v $(CARGO) >/dev/null 2>&1; then \
		$(CARGO) fmt --all --manifest-path $(MANIFEST) -- --check; \
	else \
		echo "WARNING: fmt-check SKIPPED — no '$(CARGO)' toolchain on PATH"; \
	fi

## Lint gate, same toolchain guard as fmt-check.
clippy:
	@if command -v $(CARGO) >/dev/null 2>&1; then \
		$(CARGO) clippy --all-targets --manifest-path $(MANIFEST) -- -D warnings; \
	else \
		echo "WARNING: clippy SKIPPED — no '$(CARGO)' toolchain on PATH"; \
	fi

## Regenerate the perf numbers: the engine naive/fused/parallel table and
## the decode tokens/sec table, plus machine-readable medians in
## BENCH_engine.json and BENCH_decode.json at the repo root.
bench: bench-engine bench-decode

bench-engine:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target engine

bench-decode:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench --target decode

## Serve the pure-Rust fallback engine over TCP (no artifacts needed):
##   echo "4 8 15 16 23 42" | nc 127.0.0.1 7878     # classify
##   echo "gen 8 4 8 15 16" | nc 127.0.0.1 7878     # generate 8 tokens
serve-fallback:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- serve --fallback --port 7878 --wait

## AOT-compile the XLA artifacts (needs the python env + real xla crate).
artifacts:
	cd python && python -m compile.aot
