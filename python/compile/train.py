"""Loss functions, hand-rolled Adam, and train/eval step builders.

No optax offline — Adam is implemented directly on the parameter pytree.
The exported ``train_step`` signature (flattened by aot.py) is:

    (params..., m..., v..., step, seed, batch...) ->
    (params'..., m'..., v'..., step+1, loss)

``step`` is f32 (drives warmup/inv-sqrt LR in-graph), ``seed`` is int32
(PRNGKey for Gumbel noise). Eval graphs are deterministic (no noise).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers, model


# ---------------------------------------------------------------------------
# Adam (Kingma & Ba) on pytrees
# ---------------------------------------------------------------------------

B1, B2, EPS = 0.9, 0.98, 1e-9


def adam_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return jax.tree_util.tree_map(zeros, params), jax.tree_util.tree_map(zeros, params)


def lr_schedule(step, d_model: int, warmup: float):
    """Transformer inverse-sqrt schedule (Vaswani et al., 2017)."""
    s = jnp.maximum(step, 1.0)
    return (d_model ** -0.5) * jnp.minimum(s ** -0.5, s * warmup ** -1.5)


def adam_update(params, grads, m, v, step, d_model, warmup, lr_mult=1.0):
    lr = lr_schedule(step, d_model, warmup) * lr_mult
    m = jax.tree_util.tree_map(lambda a, g: B1 * a + (1 - B1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: B2 * a + (1 - B2) * g * g, v, grads)
    mh = jax.tree_util.tree_map(lambda a: a / (1 - B1 ** step), m)
    vh = jax.tree_util.tree_map(lambda a: a / (1 - B2 ** step), v)
    params = jax.tree_util.tree_map(
        lambda p, a, b: p - lr * a / (jnp.sqrt(b) + EPS), params, mh, vh
    )
    return params, m, v


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def lm_loss(params, tokens, cfg, key=None):
    """tokens (B, ell+1) -> mean next-token xent over ell positions."""
    logits = model.lm_logits(params, tokens[:, :-1], cfg, key=key)
    return layers.xent_loss(logits, tokens[:, 1:])


def classifier_loss(params, tokens, labels, cfg, key=None):
    logits = model.classifier_logits(params, tokens, cfg, key=key)
    onehot_ll = jax.nn.log_softmax(logits)[jnp.arange(labels.shape[0]), labels]
    return -jnp.mean(onehot_ll)


def seq2seq_loss(params, src, tgt, cfg, key=None):
    """tgt (B, lt+1): teacher forcing on tgt[:, :-1] -> predict tgt[:, 1:].
    Pad token 0 is excluded from the loss."""
    logits = model.seq2seq_logits(params, src, tgt[:, :-1], cfg, key=key)
    mask = (tgt[:, 1:] != 0).astype(jnp.float32)
    return layers.xent_loss(logits, tgt[:, 1:], mask)


# ---------------------------------------------------------------------------
# step builders — each returns (fn, example_args) ready for jax.jit().lower()
# ---------------------------------------------------------------------------


def make_train_step(family: str, cfg, train_cfg):
    d_model, warmup = cfg["d_model"], float(train_cfg.get("warmup", 400))
    lr_mult = float(train_cfg.get("lr_mult", 1.0))

    def step_fn(params, m, v, step, seed, *batch):
        key = jax.random.PRNGKey(seed)
        if family == "lm":
            loss_fn = lambda p: lm_loss(p, batch[0], cfg, key=key)
        elif family == "cls":
            loss_fn = lambda p: classifier_loss(p, batch[0], batch[1], cfg, key=key)
        elif family == "seq2seq":
            loss_fn = lambda p: seq2seq_loss(p, batch[0], batch[1], cfg, key=key)
        else:
            raise ValueError(family)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        step = step + 1.0
        params, m, v = adam_update(params, grads, m, v, step, d_model, warmup, lr_mult)
        return params, m, v, step, loss

    return step_fn


def make_eval_step(family: str, cfg):
    """Deterministic eval graph.

    lm      : (params, tokens)      -> (loss,)
    cls     : (params, tokens, labels) -> (loss, n_correct, pred (B,) i32)
    seq2seq : (params, src, tgt_in) -> (loss_like_dummy, argmax (B, lt) i32)
    """

    def eval_fn(params, *batch):
        if family == "lm":
            return (lm_loss(params, batch[0], cfg),)
        if family == "cls":
            logits = model.classifier_logits(params, batch[0], cfg)
            loss = classifier_loss(params, batch[0], batch[1], cfg)
            pred = jnp.argmax(logits, -1).astype(jnp.int32)
            correct = jnp.sum((pred == batch[1]).astype(jnp.int32))
            return (loss, correct, pred)
        if family == "seq2seq":
            logits = model.seq2seq_logits(params, batch[0], batch[1], cfg)
            pred = jnp.argmax(logits, -1).astype(jnp.int32)  # (B, lt)
            mask = (batch[1] != 0) | (jnp.arange(batch[1].shape[1])[None] == 0)
            loss = layers.xent_loss(logits, jnp.maximum(batch[1], 0), mask.astype(jnp.float32))
            return (loss, pred)
        raise ValueError(family)

    return eval_fn


def batch_shapes(family: str, cfg, train_cfg):
    """ShapeDtypeStructs of the batch inputs for train graphs."""
    bsz = train_cfg["batch"]
    i32 = jnp.int32
    if family == "lm":
        return [jax.ShapeDtypeStruct((bsz, cfg["ell"] + 1), i32)]
    if family == "cls":
        return [
            jax.ShapeDtypeStruct((bsz, cfg["ell"]), i32),
            jax.ShapeDtypeStruct((bsz,), i32),
        ]
    if family == "seq2seq":
        return [
            jax.ShapeDtypeStruct((bsz, cfg["ell"]), i32),
            jax.ShapeDtypeStruct((bsz, cfg["ell_tgt"] + 1), i32),
        ]
    raise ValueError(family)


def eval_batch_shapes(family: str, cfg, train_cfg):
    bsz = train_cfg.get("eval_batch", train_cfg["batch"])
    i32 = jnp.int32
    if family == "lm":
        return [jax.ShapeDtypeStruct((bsz, cfg["ell"] + 1), i32)]
    if family == "cls":
        return [
            jax.ShapeDtypeStruct((bsz, cfg["ell"]), i32),
            jax.ShapeDtypeStruct((bsz,), i32),
        ]
    if family == "seq2seq":
        return [
            jax.ShapeDtypeStruct((bsz, cfg["ell"]), i32),
            jax.ShapeDtypeStruct((bsz, cfg["ell_tgt"]), i32),
        ]
    raise ValueError(family)
