//! Minimal property-based testing harness (no `proptest` offline).
//!
//! `forall(cases, gen, prop)` runs `prop` against `cases` generated inputs.
//! Each case derives its own deterministic seed; on failure the harness
//! retries with progressively "smaller" generator budgets (a lightweight
//! stand-in for shrinking) and reports the failing seed so the case can be
//! replayed exactly with `replay(seed, gen, prop)`.

use super::rng::Rng;

/// Generation budget passed to generators — generators should scale their
/// output size with `size` so the harness can shrink on failure.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.usize_below((hi - lo).max(1))
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| lo + self.rng.f32() * (hi - lo)).collect()
    }

    pub fn vec_i64(&mut self, len: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..len).map(|_| self.rng.range_i64(lo, hi)).collect()
    }
}

/// Outcome of a property check.
pub type PropResult = Result<(), String>;

/// Run `prop` on `cases` generated inputs; panics with a replayable report
/// on the first failure.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    base_seed: u64,
    gen: impl Fn(&mut Gen) -> T,
    prop: impl Fn(&T) -> PropResult,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let full_size = 2 + case % 32; // grow sizes across cases
        let mut rng = Rng::new(seed);
        let mut g = Gen { rng: &mut rng, size: full_size };
        let input = gen(&mut g);
        if let Err(msg) = prop(&input) {
            // shrink-lite: retry smaller budgets with the same seed to find
            // a smaller failing example for the report
            let mut smallest: Option<(usize, String, String)> = None;
            for size in 1..full_size {
                let mut rng = Rng::new(seed);
                let mut g = Gen { rng: &mut rng, size };
                let small = gen(&mut g);
                if let Err(m) = prop(&small) {
                    smallest = Some((size, format!("{small:?}"), m));
                    break;
                }
            }
            let (ssize, sdbg, smsg) = smallest.unwrap_or((full_size, format!("{input:?}"), msg));
            panic!(
                "property failed (seed={seed}, case={case}, size={ssize}):\n  input: {}\n  error: {smsg}",
                truncate(&sdbg, 400)
            );
        }
    }
}

/// Replay a single failing case by seed (use the seed from the panic).
pub fn replay<T>(seed: u64, size: usize, gen: impl Fn(&mut Gen) -> T) -> T {
    let mut rng = Rng::new(seed);
    let mut g = Gen { rng: &mut rng, size };
    gen(&mut g)
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}… ({} bytes)", &s[..n], s.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        forall(
            64,
            1,
            |g| g.vec_i64(g.size, -100, 100),
            |v| {
                let mut s = v.clone();
                s.sort();
                if s.len() == v.len() {
                    Ok(())
                } else {
                    Err("len changed".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn catches_bad_property() {
        forall(
            64,
            2,
            |g| g.usize(0, 100),
            |&x| if x < 90 { Ok(()) } else { Err(format!("{x} >= 90")) },
        );
    }

    #[test]
    fn replay_reproduces() {
        let a: usize = replay(99, 4, |g| g.usize(0, 1000));
        let b: usize = replay(99, 4, |g| g.usize(0, 1000));
        assert_eq!(a, b);
    }
}
