//! Derive-free typed JSON for the HTTP gateway (DESIGN.md §Gateway).
//!
//! The gateway's wire format is hand-rolled in the nanoserde/miniserde
//! style: every request and response is a *typed struct* with explicit
//! [`ToJson`]/[`FromJson`] impls — no reflection, no `Value` tree on the
//! hot path, no derive macros (the offline container carries no extra
//! crates). This module is distinct from `util::json`, the dynamic
//! `Json` value enum the bench tables use for file output: the gateway
//! parses *untrusted network bytes*, so its decoder is strict by
//! construction:
//!
//! * a hard input-size cap ([`MAX_INPUT`]) and nesting-depth cap
//!   ([`MAX_DEPTH`]) — a hostile body cannot recurse the stack away;
//! * strict number grammar (no `NaN`/`Infinity` literals, no leading
//!   zeros or `+`, integer fields reject fractions and exponents,
//!   floats reject values that overflow to infinity);
//! * full string escapes (`\uXXXX` with surrogate-pair combining; lone
//!   surrogates decode to U+FFFD) and rejection of raw control bytes;
//! * trailing garbage after the document is an error;
//! * every failure is an `Err` with a stable one-line message — the
//!   decoder never panics, which the hostile-corpus unit tests pin
//!   under `catch_unwind` (the same isolation invariant as the
//!   scheduler's fault plane, DESIGN.md §Faults).
//!
//! Unknown object keys are *skipped* (their values are still fully
//! validated), so clients may send supersets; missing required fields
//! are stable errors naming the field.

use anyhow::{bail, Result};

/// Hard cap on a JSON document fed to [`FromJson::from_json`]; the HTTP
/// body caps (`server::http`) are tighter, this is the decoder's own
/// backstop.
pub const MAX_INPUT: usize = 1 << 20;

/// Maximum container nesting depth; deeper input is an error, not a
/// stack overflow.
pub const MAX_DEPTH: usize = 64;

// ---------------------------------------------------------------------
// encoder
// ---------------------------------------------------------------------

/// Append `s` as a JSON string literal (quotes and escapes included).
pub fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Append a float. JSON has no non-finite literals, so NaN/±Inf encode
/// as `null` (the miniserde convention); finite values use Rust's
/// shortest round-trip formatting.
pub fn push_json_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push_str("null");
    }
}

/// Serialize to a JSON fragment. `to_json` is the whole-document
/// convenience; `write_json` appends in place (what struct impls call
/// for their fields).
pub trait ToJson {
    fn write_json(&self, out: &mut String);

    fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_json(&mut s);
        s
    }
}

impl ToJson for bool {
    fn write_json(&self, out: &mut String) {
        out.push_str(if *self { "true" } else { "false" });
    }
}

macro_rules! int_to_json {
    ($($t:ty),*) => {$(
        impl ToJson for $t {
            fn write_json(&self, out: &mut String) {
                out.push_str(&self.to_string());
            }
        }
    )*};
}
int_to_json!(i32, i64, u32, u64, usize);

impl ToJson for f64 {
    fn write_json(&self, out: &mut String) {
        push_json_f64(out, *self);
    }
}

impl ToJson for f32 {
    fn write_json(&self, out: &mut String) {
        // f32 -> f64 is exact, so the shortest f64 repr round-trips the
        // f32 bit pattern through decode + cast
        push_json_f64(out, f64::from(*self));
    }
}

impl ToJson for String {
    fn write_json(&self, out: &mut String) {
        push_json_str(out, self);
    }
}

impl ToJson for &str {
    fn write_json(&self, out: &mut String) {
        push_json_str(out, self);
    }
}

impl<T: ToJson> ToJson for Option<T> {
    fn write_json(&self, out: &mut String) {
        match self {
            Some(v) => v.write_json(out),
            None => out.push_str("null"),
        }
    }
}

impl<T: ToJson> ToJson for Vec<T> {
    fn write_json(&self, out: &mut String) {
        out.push('[');
        for (i, v) in self.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            v.write_json(out);
        }
        out.push(']');
    }
}

// ---------------------------------------------------------------------
// decoder
// ---------------------------------------------------------------------

/// Byte-cursor pull parser over one JSON document. Struct impls consume
/// exactly one value; [`FromJson::from_json`] wraps a full parse and
/// rejects trailing bytes.
pub struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    pub fn new(input: &'a str) -> Result<Parser<'a>> {
        if input.len() > MAX_INPUT {
            bail!("json document too large ({} bytes)", input.len());
        }
        Ok(Parser { bytes: input.as_bytes(), pos: 0, depth: 0 })
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, want: u8) -> Result<()> {
        match self.peek() {
            Some(b) if b == want => {
                self.pos += 1;
                Ok(())
            }
            Some(b) => bail!("expected '{}' at byte {}, found '{}'", want as char, self.pos, b as char),
            None => bail!("expected '{}' at byte {}, found end of input", want as char, self.pos),
        }
    }

    /// Consume `word` if it is next (after whitespace); `true` on match.
    fn eat_word(&mut self, word: &str) -> bool {
        self.skip_ws();
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            true
        } else {
            false
        }
    }

    /// After the whole document: only trailing whitespace may remain.
    pub fn end(&mut self) -> Result<()> {
        self.skip_ws();
        if self.pos != self.bytes.len() {
            bail!("trailing garbage at byte {}", self.pos);
        }
        Ok(())
    }

    pub fn parse_bool(&mut self) -> Result<bool> {
        if self.eat_word("true") {
            Ok(true)
        } else if self.eat_word("false") {
            Ok(false)
        } else {
            bail!("expected boolean at byte {}", self.pos)
        }
    }

    /// `true` if the next value is `null` (consumed) — how `Option`
    /// fields decode.
    pub fn eat_null(&mut self) -> bool {
        self.eat_word("null")
    }

    /// The raw text of one number token, strict JSON grammar:
    /// `-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?`. `NaN`,
    /// `Infinity`, leading `+`, leading zeros and bare `.5`/`1.` all
    /// fail here.
    fn number_token(&mut self) -> Result<&'a str> {
        self.skip_ws();
        let start = self.pos;
        let b = self.bytes;
        let mut i = self.pos;
        if b.get(i) == Some(&b'-') {
            i += 1;
        }
        match b.get(i) {
            Some(b'0') => i += 1,
            Some(c) if c.is_ascii_digit() => {
                while b.get(i).is_some_and(|c| c.is_ascii_digit()) {
                    i += 1;
                }
            }
            _ => bail!("expected number at byte {start}"),
        }
        if b.get(i) == Some(&b'.') {
            i += 1;
            if !b.get(i).is_some_and(|c| c.is_ascii_digit()) {
                bail!("bad number at byte {start}: digit must follow '.'");
            }
            while b.get(i).is_some_and(|c| c.is_ascii_digit()) {
                i += 1;
            }
        }
        if matches!(b.get(i), Some(b'e') | Some(b'E')) {
            i += 1;
            if matches!(b.get(i), Some(b'+') | Some(b'-')) {
                i += 1;
            }
            if !b.get(i).is_some_and(|c| c.is_ascii_digit()) {
                bail!("bad number at byte {start}: digit must follow exponent");
            }
            while b.get(i).is_some_and(|c| c.is_ascii_digit()) {
                i += 1;
            }
        }
        self.pos = i;
        // the token is ASCII by construction, so the slice is valid UTF-8
        Ok(std::str::from_utf8(&b[start..i]).expect("ascii number token"))
    }

    pub fn parse_f64(&mut self) -> Result<f64> {
        let at = self.pos;
        let tok = self.number_token()?;
        let v: f64 = tok.parse().map_err(|_| anyhow::anyhow!("bad number at byte {at}"))?;
        if !v.is_finite() {
            bail!("number out of range at byte {at}");
        }
        Ok(v)
    }

    pub fn parse_i64(&mut self) -> Result<i64> {
        let at = self.pos;
        let tok = self.number_token()?;
        if tok.bytes().any(|b| matches!(b, b'.' | b'e' | b'E')) {
            bail!("expected integer at byte {at}");
        }
        tok.parse().map_err(|_| anyhow::anyhow!("integer out of range at byte {at}"))
    }

    pub fn parse_u64(&mut self) -> Result<u64> {
        let at = self.pos;
        let v = self.parse_i64()?;
        u64::try_from(v).map_err(|_| anyhow::anyhow!("expected non-negative integer at byte {at}"))
    }

    pub fn parse_usize(&mut self) -> Result<usize> {
        let at = self.pos;
        let v = self.parse_u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("integer out of range at byte {at}"))
    }

    pub fn parse_i32(&mut self) -> Result<i32> {
        let at = self.pos;
        let v = self.parse_i64()?;
        i32::try_from(v).map_err(|_| anyhow::anyhow!("integer out of range at byte {at}"))
    }

    /// One string literal, escapes decoded. Surrogate pairs combine;
    /// a lone surrogate decodes to U+FFFD (never an invalid `char`).
    pub fn parse_string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                bail!("unterminated string at byte {}", self.pos);
            };
            match b {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let Some(&e) = self.bytes.get(self.pos) else {
                        bail!("unterminated escape at byte {}", self.pos);
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // high surrogate: combine with a
                                // following \uDC00..DFFF, else U+FFFD
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    let save = self.pos;
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        let cp = 0x10000
                                            + ((hi - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        char::from_u32(cp).unwrap_or('\u{FFFD}')
                                    } else {
                                        self.pos = save;
                                        '\u{FFFD}'
                                    }
                                } else {
                                    '\u{FFFD}'
                                }
                            } else {
                                char::from_u32(hi).unwrap_or('\u{FFFD}')
                            };
                            out.push(c);
                        }
                        _ => bail!("bad escape '\\{}' at byte {}", e as char, self.pos - 1),
                    }
                }
                b if b < 0x20 => {
                    bail!("raw control byte in string at byte {}", self.pos);
                }
                _ => {
                    // copy one UTF-8 scalar (input is a &str, so the
                    // boundaries are valid by construction)
                    let len = utf8_len(b);
                    let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + len])
                        .expect("parser input is valid UTF-8");
                    out.push_str(s);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let at = self.pos;
        let mut v = 0u32;
        for _ in 0..4 {
            let Some(&b) = self.bytes.get(self.pos) else {
                bail!("truncated \\u escape at byte {at}");
            };
            let d = (b as char).to_digit(16).ok_or_else(|| {
                anyhow::anyhow!("bad \\u escape at byte {at}")
            })?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn descend(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH}");
        }
        Ok(())
    }

    /// Parse `{...}`, calling `field(self, key)` once per key; the
    /// callback must consume exactly the key's value. Unknown keys are
    /// the *callback's* concern — struct impls call [`Self::skip_value`].
    pub fn parse_object(
        &mut self,
        mut field: impl FnMut(&mut Parser<'a>, &str) -> Result<()>,
    ) -> Result<()> {
        self.descend()?;
        self.expect(b'{')?;
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(());
        }
        loop {
            let key = self.parse_string()?;
            self.expect(b':')?;
            field(self, &key)?;
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(());
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    /// Parse `[...]`, calling `elem` once per element.
    pub fn parse_array(&mut self, mut elem: impl FnMut(&mut Parser<'a>) -> Result<()>) -> Result<()> {
        self.descend()?;
        self.expect(b'[')?;
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(());
        }
        loop {
            elem(self)?;
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(());
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    /// Consume one value of any shape (how unknown fields are skipped)
    /// — still depth-capped and fully validated.
    pub fn skip_value(&mut self) -> Result<()> {
        match self.peek() {
            Some(b'{') => self.parse_object(|p, _| p.skip_value()),
            Some(b'[') => self.parse_array(|p| p.skip_value()),
            Some(b'"') => self.parse_string().map(|_| ()),
            Some(b't') | Some(b'f') => self.parse_bool().map(|_| ()),
            Some(b'n') => {
                if self.eat_null() {
                    Ok(())
                } else {
                    bail!("bad literal at byte {}", self.pos)
                }
            }
            Some(_) => self.parse_f64().map(|_| ()),
            None => bail!("expected value at byte {}, found end of input", self.pos),
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        b if b < 0x80 => 1,
        b if b < 0xE0 => 2,
        b if b < 0xF0 => 3,
        _ => 4,
    }
}

/// Deserialize from a JSON document. `parse_json` consumes one value
/// mid-stream; `from_json` parses a whole document (rejecting trailing
/// garbage) and is what the gateway calls on request bodies.
pub trait FromJson: Sized {
    fn parse_json(p: &mut Parser) -> Result<Self>;

    fn from_json(input: &str) -> Result<Self> {
        let mut p = Parser::new(input)?;
        let v = Self::parse_json(&mut p)?;
        p.end()?;
        Ok(v)
    }
}

impl FromJson for bool {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        p.parse_bool()
    }
}

impl FromJson for i32 {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        p.parse_i32()
    }
}

impl FromJson for i64 {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        p.parse_i64()
    }
}

impl FromJson for u64 {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        p.parse_u64()
    }
}

impl FromJson for usize {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        p.parse_usize()
    }
}

impl FromJson for f64 {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        p.parse_f64()
    }
}

impl FromJson for f32 {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        Ok(p.parse_f64()? as f32)
    }
}

impl FromJson for String {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        p.parse_string()
    }
}

impl<T: FromJson> FromJson for Option<T> {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        if p.eat_null() {
            Ok(None)
        } else {
            Ok(Some(T::parse_json(p)?))
        }
    }
}

impl<T: FromJson> FromJson for Vec<T> {
    fn parse_json(p: &mut Parser) -> Result<Self> {
        let mut out = Vec::new();
        p.parse_array(|p| {
            out.push(T::parse_json(p)?);
            Ok(())
        })?;
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// gateway message types
// ---------------------------------------------------------------------

/// `POST /v1/classify` body: `{"tokens": [1, 2, 3]}`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ClassifyRequest {
    pub tokens: Vec<i32>,
}

/// `POST /v1/classify` 200 body.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ClassifyResponse {
    pub label: i32,
    pub batch: usize,
    pub queue_us: u64,
    pub total_us: u64,
}

/// `POST /v1/generate` body: `{"max_new": 8, "tokens": [...],
/// "deadline_ms": 250}` (`deadline_ms` optional, like the TCP
/// `deadline=<ms>` option — DESIGN.md §Faults).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GenerateRequest {
    pub max_new: usize,
    pub tokens: Vec<i32>,
    pub deadline_ms: Option<u64>,
}

/// One streamed token, the `data:` payload of an SSE `tok` event — the
/// JSON twin of the TCP `tok <i> <id>` line.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TokEvent {
    pub index: usize,
    pub id: i32,
}

/// The generation summary: the `data:` payload of the final SSE `done`
/// event (or the whole 200 body when the executor streamed nothing —
/// the request-batch mode).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GenerateSummary {
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub queue_us: u64,
    pub total_us: u64,
}

/// `GET /v1/model` 200 body: the served configuration as the same
/// `key=value ...` line the TCP `model` verb returns.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ModelResponse {
    pub info: String,
}

/// `POST /v1/shutdown` 200 body (`{"ok": "draining"}`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ShutdownResponse {
    pub ok: String,
}

/// Every non-200 body: `{"error": "<one stable line>"}` — the JSON twin
/// of the TCP `error=` line, same clipping policy.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ErrorBody {
    pub error: String,
}

/// One field of a route's request or response schema (`GET /v1/schema`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FieldSchema {
    pub name: String,
    pub kind: String,
    pub required: bool,
}

/// One route of the gateway (`GET /v1/schema`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RouteSchema {
    pub method: String,
    pub path: String,
    pub stream: bool,
    pub request: Vec<FieldSchema>,
    pub response: Vec<FieldSchema>,
}

/// `GET /v1/schema` 200 body: the machine-readable route listing that
/// load-gen harnesses (wrk/k6/oha) and the conformance tests consume.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SchemaResponse {
    pub routes: Vec<RouteSchema>,
}

/// Write one `"key":value` pair, with the leading comma when needed.
fn field(out: &mut String, first: &mut bool, key: &str, v: &impl ToJson) {
    if !*first {
        out.push(',');
    }
    *first = false;
    push_json_str(out, key);
    out.push(':');
    v.write_json(out);
}

/// `ToJson` for a field struct: required fields always emitted,
/// optional (`Option`) fields omitted entirely when `None` — absent and
/// `null` decode the same.
macro_rules! to_json_struct {
    ($name:ident, req: [$($rf:ident),* $(,)?], opt: [$($of:ident),* $(,)?]) => {
        impl ToJson for $name {
            fn write_json(&self, out: &mut String) {
                out.push('{');
                let mut first = true;
                $(field(out, &mut first, stringify!($rf), &self.$rf);)*
                $(if self.$of.is_some() {
                    field(out, &mut first, stringify!($of), &self.$of);
                })*
                let _ = first;
                out.push('}');
            }
        }
    };
}

to_json_struct!(ClassifyRequest, req: [tokens], opt: []);
to_json_struct!(ClassifyResponse, req: [label, batch, queue_us, total_us], opt: []);
to_json_struct!(GenerateRequest, req: [max_new, tokens], opt: [deadline_ms]);
to_json_struct!(TokEvent, req: [index, id], opt: []);
to_json_struct!(GenerateSummary, req: [tokens, batch, queue_us, total_us], opt: []);
to_json_struct!(ModelResponse, req: [info], opt: []);
to_json_struct!(ShutdownResponse, req: [ok], opt: []);
to_json_struct!(ErrorBody, req: [error], opt: []);
to_json_struct!(FieldSchema, req: [name, kind, required], opt: []);
to_json_struct!(RouteSchema, req: [method, path, stream, request, response], opt: []);
to_json_struct!(SchemaResponse, req: [routes], opt: []);

/// `FromJson` for a field struct: required fields must appear, optional
/// ones default, unknown keys are skipped (values still validated).
macro_rules! from_json_struct {
    ($name:ident, req: [$($rf:ident),* $(,)?], opt: [$($of:ident),* $(,)?]) => {
        impl FromJson for $name {
            fn parse_json(p: &mut Parser) -> Result<Self> {
                let mut v = $name::default();
                #[allow(unused_mut)]
                let mut missing: Vec<&'static str> = vec![$(stringify!($rf)),*];
                p.parse_object(|p, key| match key {
                    $(stringify!($rf) => {
                        missing.retain(|f| *f != stringify!($rf));
                        v.$rf = FromJson::parse_json(p)?;
                        Ok(())
                    })*
                    $(stringify!($of) => {
                        v.$of = FromJson::parse_json(p)?;
                        Ok(())
                    })*
                    _ => p.skip_value(),
                })?;
                if let Some(f) = missing.first() {
                    bail!("{}: missing field '{}'", stringify!($name), f);
                }
                Ok(v)
            }
        }
    };
}

from_json_struct!(ClassifyRequest, req: [tokens], opt: []);
from_json_struct!(ClassifyResponse, req: [label, batch, queue_us, total_us], opt: []);
from_json_struct!(GenerateRequest, req: [max_new, tokens], opt: [deadline_ms]);
from_json_struct!(TokEvent, req: [index, id], opt: []);
from_json_struct!(GenerateSummary, req: [tokens, batch, queue_us, total_us], opt: []);
from_json_struct!(ModelResponse, req: [info], opt: []);
from_json_struct!(ShutdownResponse, req: [ok], opt: []);
from_json_struct!(ErrorBody, req: [error], opt: []);
from_json_struct!(FieldSchema, req: [name, kind, required], opt: []);
from_json_struct!(RouteSchema, req: [method, path, stream, request, response], opt: []);
from_json_struct!(SchemaResponse, req: [routes], opt: []);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn typed_structs_encode_stably() {
        assert_eq!(
            ClassifyRequest { tokens: vec![1, -2, 3] }.to_json(),
            r#"{"tokens":[1,-2,3]}"#
        );
        assert_eq!(
            GenerateRequest { max_new: 4, tokens: vec![7], deadline_ms: None }.to_json(),
            r#"{"max_new":4,"tokens":[7]}"#
        );
        assert_eq!(
            GenerateRequest { max_new: 4, tokens: vec![], deadline_ms: Some(250) }.to_json(),
            r#"{"max_new":4,"tokens":[],"deadline_ms":250}"#
        );
        assert_eq!(TokEvent { index: 0, id: -9 }.to_json(), r#"{"index":0,"id":-9}"#);
        assert_eq!(
            ErrorBody { error: "deadline exceeded".into() }.to_json(),
            r#"{"error":"deadline exceeded"}"#
        );
    }

    #[test]
    fn decode_skips_unknown_fields_and_accepts_any_order() {
        let r = GenerateRequest::from_json(
            r#"{"tokens":[1,2],"future_knob":{"a":[1,2,{"b":null}]},"max_new":3}"#,
        )
        .unwrap();
        assert_eq!(r, GenerateRequest { max_new: 3, tokens: vec![1, 2], deadline_ms: None });
        // null and absent decode identically for optional fields
        let a = GenerateRequest::from_json(r#"{"max_new":1,"tokens":[],"deadline_ms":null}"#);
        let b = GenerateRequest::from_json(r#"{"max_new":1,"tokens":[]}"#);
        assert_eq!(a.unwrap(), b.unwrap());
    }

    #[test]
    fn decode_rejects_missing_required_fields_by_name() {
        let e = ClassifyRequest::from_json(r#"{}"#).unwrap_err();
        assert_eq!(e.to_string(), "ClassifyRequest: missing field 'tokens'");
        let e = GenerateRequest::from_json(r#"{"tokens":[1]}"#).unwrap_err();
        assert_eq!(e.to_string(), "GenerateRequest: missing field 'max_new'");
    }

    #[test]
    fn string_escapes_round_trip() {
        for s in [
            "",
            "plain",
            "with \"quotes\" and \\backslash\\",
            "newline\n tab\t return\r",
            "control \u{0001}\u{001f} bytes",
            "unicode: ドキュメント 🚀 ñ",
            "solidus / stays",
        ] {
            let enc = String::from(s).to_json();
            assert_eq!(String::from_json(&enc).unwrap(), s, "via {enc}");
        }
        // escaped-form inputs decode too
        assert_eq!(String::from_json(r#""\u0041\u00e9\n""#).unwrap(), "Aé\n");
        // surrogate pair combines; lone surrogate becomes U+FFFD
        assert_eq!(String::from_json(r#""\ud83d\ude80""#).unwrap(), "🚀");
        assert_eq!(String::from_json(r#""\ud83d x""#).unwrap(), "\u{FFFD} x");
        assert_eq!(String::from_json(r#""\udc00""#).unwrap(), "\u{FFFD}");
    }

    #[test]
    fn integer_edges_round_trip_and_overflow_rejects() {
        for v in [i64::MIN, -1, 0, 1, i64::MAX] {
            assert_eq!(i64::from_json(&v.to_json()).unwrap(), v);
        }
        for v in [i32::MIN, i32::MAX] {
            assert_eq!(i32::from_json(&v.to_json()).unwrap(), v);
        }
        assert!(i64::from_json("99999999999999999999").is_err());
        assert!(i32::from_json("2147483648").is_err());
        assert!(u64::from_json("-1").is_err());
        assert!(i64::from_json("1.5").is_err());
        assert!(i64::from_json("1e3").is_err());
    }

    #[test]
    fn float_edges_round_trip_and_nonfinite_encode_null() {
        for v in [0.0f32, -0.0, 1.5, f32::MIN, f32::MAX, f32::MIN_POSITIVE, 1e-40] {
            let enc = v.to_json();
            let back = f32::from_json(&enc).unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v} via {enc}");
        }
        assert_eq!(f32::NAN.to_json(), "null");
        assert_eq!(f64::INFINITY.to_json(), "null");
    }

    /// Satellite: encode→decode round-trip identity over randomized
    /// typed structs — escapes, unicode, integer/f32 edge values and
    /// nesting, driven by the repo's property harness.
    #[test]
    fn fuzz_typed_struct_round_trip() {
        fn gen_string(g: &mut Gen) -> String {
            let n = g.usize(0, 12);
            (0..n)
                .map(|_| {
                    match g.usize(0, 6) {
                        0 => '"',
                        1 => '\\',
                        2 => char::from_u32(g.usize(0, 0x20) as u32).unwrap(),
                        3 => '🚀',
                        4 => 'é',
                        _ => char::from_u32(g.usize(0x20, 0x7f) as u32).unwrap(),
                    }
                })
                .collect()
        }
        forall(
            200,
            0x15_08,
            |g| {
                let edge = [i32::MIN, i32::MAX, 0, -1, 7];
                let toks: Vec<i32> = (0..g.usize(0, 9))
                    .map(|_| edge[g.usize(0, edge.len())])
                    .collect();
                let req = GenerateRequest {
                    max_new: g.usize(0, 1 << 20),
                    tokens: toks.clone(),
                    deadline_ms: if g.usize(0, 2) == 0 {
                        None
                    } else {
                        Some(g.rng.next_u64() >> g.usize(0, 64))
                    },
                };
                let schema = RouteSchema {
                    method: gen_string(g),
                    path: gen_string(g),
                    stream: g.usize(0, 2) == 0,
                    request: (0..g.usize(0, 4))
                        .map(|_| FieldSchema {
                            name: gen_string(g),
                            kind: gen_string(g),
                            required: g.usize(0, 2) == 0,
                        })
                        .collect(),
                    response: vec![],
                };
                let err = ErrorBody { error: gen_string(g) };
                (req, schema, err)
            },
            |(req, schema, err)| {
                let back = GenerateRequest::from_json(&req.to_json())
                    .map_err(|e| format!("req decode: {e}"))?;
                if back != *req {
                    return Err(format!("req round-trip: {back:?} != {req:?}"));
                }
                let back = RouteSchema::from_json(&schema.to_json())
                    .map_err(|e| format!("schema decode: {e}"))?;
                if back != *schema {
                    return Err(format!("schema round-trip: {back:?} != {schema:?}"));
                }
                let back = ErrorBody::from_json(&err.to_json())
                    .map_err(|e| format!("err decode: {e}"))?;
                if back != *err {
                    return Err(format!("err round-trip: {back:?} != {err:?}"));
                }
                Ok(())
            },
        );
    }

    /// Satellite: the hostile corpus — every malformed input returns
    /// `Err` (and never panics, pinned under `catch_unwind`, the same
    /// isolation invariant as the scheduler's fault plane).
    #[test]
    fn hostile_corpus_errors_without_panicking() {
        let deep_arrays = "[".repeat(10_000);
        let deep_objects = r#"{"a":"#.repeat(10_000);
        let huge_claim = format!(r#"{{"tokens":[{}"#, "1,".repeat(100));
        let corpus: Vec<String> = vec![
            String::new(),
            "   ".into(),
            "nul".into(),
            "NaN".into(),
            "Infinity".into(),
            "-Infinity".into(),
            "nan".into(),
            "+1".into(),
            "01".into(),
            ".5".into(),
            "1.".into(),
            "1e".into(),
            "1e+".into(),
            "0x10".into(),
            "1e999".into(),          // overflows f64 to infinity
            "--1".into(),
            "tru".into(),
            "truex".into(),
            "\"unterminated".into(),
            "\"bad \\q escape\"".into(),
            "\"trunc \\u12".into(),
            "\"raw \u{0}control\"".into(), // raw NUL inside a string
            "[1,2".into(),
            "[1,,2]".into(),
            "[1 2]".into(),
            "{\"a\" 1}".into(),
            "{\"a\":1,}".into(),
            "{\"a\":}".into(),
            "{1:2}".into(),
            "{\"tokens\":[]}x".into(), // trailing garbage
            "[] []".into(),
            "{} null".into(),
            deep_arrays,
            deep_objects,
            huge_claim,                       // truncated mid-array
            "\u{1}".into(),
            "[\"\\ud800\"".into(),
        ];
        for input in &corpus {
            let r = catch_unwind(AssertUnwindSafe(|| {
                (
                    ClassifyRequest::from_json(input).err().map(|e| e.to_string()),
                    GenerateRequest::from_json(input).err().map(|e| e.to_string()),
                    SchemaResponse::from_json(input).err().map(|e| e.to_string()),
                )
            }));
            let head: String = input.chars().take(40).collect();
            match r {
                Err(_) => panic!("decoder panicked on {head:?}"),
                Ok((a, b, c)) => {
                    assert!(a.is_some(), "ClassifyRequest accepted {head:?}");
                    assert!(b.is_some(), "GenerateRequest accepted {head:?}");
                    assert!(c.is_some(), "SchemaResponse accepted {head:?}");
                }
            }
        }
    }

    /// A 100MB-claimed document is refused by the input cap before any
    /// allocation proportional to the claim.
    #[test]
    fn oversized_document_is_rejected_cheaply() {
        let body = format!(r#"{{"tokens":[{}]}}"#, "7,".repeat(MAX_INPUT / 2).trim_end_matches(','));
        assert!(body.len() > MAX_INPUT);
        let e = ClassifyRequest::from_json(&body).unwrap_err();
        assert!(e.to_string().starts_with("json document too large"), "{e}");
    }

    /// Randomized hostile bytes: whatever the input, the decoder
    /// returns (never panics) — the fuzz twin of the curated corpus.
    #[test]
    fn fuzz_random_bytes_never_panic() {
        forall(
            300,
            0xF0_0D,
            |g| {
                let n = g.usize(0, 64);
                // bias toward structural bytes so inputs get past byte 0
                let alphabet: &[u8] = b"{}[]\",:0123456789.eE+-\\untrfals \n\u{1}";
                (0..n)
                    .map(|_| alphabet[g.usize(0, alphabet.len())])
                    .collect::<Vec<u8>>()
            },
            |bytes| {
                let Ok(s) = std::str::from_utf8(bytes) else {
                    return Ok(());
                };
                let r = catch_unwind(AssertUnwindSafe(|| {
                    let _ = GenerateRequest::from_json(s);
                    let _ = TokEvent::from_json(s);
                    let _ = GenerateSummary::from_json(s);
                }));
                r.map_err(|_| format!("panicked on {s:?}"))
            },
        );
    }

    #[test]
    fn depth_cap_is_exact() {
        // MAX_DEPTH nested arrays parse; one more is an error
        let ok = format!("{}0{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        let mut p = Parser::new(&ok).unwrap();
        assert!(p.skip_value().is_ok() && p.end().is_ok());
        let too_deep = format!("{}0{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let mut p = Parser::new(&too_deep).unwrap();
        let e = p.skip_value().unwrap_err();
        assert!(e.to_string().contains("nesting deeper"), "{e}");
    }
}
