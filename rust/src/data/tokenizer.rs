//! Tokenizers: frequency-based word vocabulary and a char vocabulary.
//!
//! The paper's tasks use 32k wordpieces / raw characters; our synthetic
//! corpora use a word vocab built the same way (frequency cutoff, specials
//! first) and a printable-ASCII char vocab.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const UNK: i32 = 1;
pub const BOS: i32 = 2;
pub const SEP: i32 = 3;
pub const N_SPECIALS: i32 = 4;

/// Frequency-ranked word vocabulary.
#[derive(Debug, Clone)]
pub struct WordVocab {
    id_of: HashMap<String, i32>,
    words: Vec<String>,
    pub capacity: usize,
}

impl WordVocab {
    /// Build from a corpus iterator, keeping the `capacity - N_SPECIALS`
    /// most frequent words (ties broken lexicographically for determinism).
    pub fn build<'a>(tokens: impl Iterator<Item = &'a str>, capacity: usize) -> Self {
        let mut freq: HashMap<&str, usize> = HashMap::new();
        for t in tokens {
            *freq.entry(t).or_default() += 1;
        }
        let mut ranked: Vec<(&str, usize)> = freq.into_iter().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        ranked.truncate(capacity.saturating_sub(N_SPECIALS as usize));

        let mut id_of = HashMap::new();
        let mut words: Vec<String> =
            ["<pad>", "<unk>", "<bos>", "<sep>"].iter().map(|s| s.to_string()).collect();
        for (w, _) in ranked {
            id_of.insert(w.to_string(), words.len() as i32);
            words.push(w.to_string());
        }
        WordVocab { id_of, words, capacity }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn encode(&self, word: &str) -> i32 {
        *self.id_of.get(word).unwrap_or(&UNK)
    }

    pub fn decode(&self, id: i32) -> &str {
        self.words.get(id as usize).map(|s| s.as_str()).unwrap_or("<bad>")
    }

    pub fn encode_seq(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|w| self.encode(w)).collect()
    }
}

/// Char vocabulary over a fixed printable alphabet.
#[derive(Debug, Clone)]
pub struct CharVocab {
    alphabet: Vec<char>,
    id_of: HashMap<char, i32>,
}

impl CharVocab {
    /// lowercase letters + digits + space + basic punctuation (fits the
    /// vocab=64 char-level configs).
    pub fn ascii() -> Self {
        let alphabet: Vec<char> =
            "abcdefghijklmnopqrstuvwxyz0123456789 .,!?'-:;()".chars().collect();
        let id_of = alphabet
            .iter()
            .enumerate()
            .map(|(i, &c)| (c, i as i32 + N_SPECIALS))
            .collect();
        CharVocab { alphabet, id_of }
    }

    pub fn len(&self) -> usize {
        self.alphabet.len() + N_SPECIALS as usize
    }

    pub fn encode(&self, c: char) -> i32 {
        *self.id_of.get(&c.to_ascii_lowercase()).unwrap_or(&UNK)
    }

    pub fn encode_str(&self, s: &str) -> Vec<i32> {
        s.chars().map(|c| self.encode(c)).collect()
    }

    pub fn decode(&self, id: i32) -> char {
        if id < N_SPECIALS {
            return match id {
                x if x == PAD => '_',
                x if x == BOS => '^',
                x if x == SEP => '|',
                _ => '?',
            };
        }
        self.alphabet.get((id - N_SPECIALS) as usize).copied().unwrap_or('?')
    }
}

/// Fit (or truncate) a token sequence into `len`, padding with PAD.
pub fn pad_to(mut seq: Vec<i32>, len: usize) -> Vec<i32> {
    seq.truncate(len);
    while seq.len() < len {
        seq.push(PAD);
    }
    seq
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_ranks_by_frequency() {
        let text = "b b b a a c";
        let v = WordVocab::build(text.split_whitespace(), 16);
        assert_eq!(v.encode("b"), N_SPECIALS); // most frequent = first slot
        assert_eq!(v.encode("a"), N_SPECIALS + 1);
        assert_eq!(v.encode("zzz"), UNK);
        assert_eq!(v.decode(v.encode("c")), "c");
    }

    #[test]
    fn capacity_enforced() {
        let text = "a a a b b c d e f";
        let v = WordVocab::build(text.split_whitespace(), 6);
        assert!(v.len() <= 6);
        assert_eq!(v.encode("f"), UNK); // rare word out of budget
    }

    #[test]
    fn word_roundtrip() {
        let v = WordVocab::build("x y z".split_whitespace(), 10);
        let ids = v.encode_seq("x z y");
        let back: Vec<&str> = ids.iter().map(|&i| v.decode(i)).collect();
        assert_eq!(back, vec!["x", "z", "y"]);
    }

    #[test]
    fn char_roundtrip() {
        let v = CharVocab::ascii();
        let ids = v.encode_str("hello, world!");
        let back: String = ids.iter().map(|&i| v.decode(i)).collect();
        assert_eq!(back, "hello, world!");
        assert!(v.len() <= 64);
    }

    #[test]
    fn char_unknown_maps_unk() {
        let v = CharVocab::ascii();
        assert_eq!(v.encode('\u{1F600}'), UNK);
    }

    #[test]
    fn pad_to_works() {
        assert_eq!(pad_to(vec![5, 6], 4), vec![5, 6, 0, 0]);
        assert_eq!(pad_to(vec![1, 2, 3], 2), vec![1, 2]);
    }
}
