//! Serving example: the L3 router/batcher in its natural habitat. Spins up
//! the inference server on a (SortCut) classification experiment, fires
//! concurrent request traffic from multiple client threads, and reports
//! throughput + latency percentiles and batch-size distribution.
//!
//! Run: `cargo run --release --example serve_classify -- [--requests N]`

use std::sync::{Arc, Mutex};

use anyhow::Result;
use sinkhorn::data::TaskData;
use sinkhorn::runtime::{artifacts_dir, Experiment, Runtime};
use sinkhorn::server::{BatchPolicy, Server};
use sinkhorn::util::cli::Args;
use sinkhorn::util::stats::percentile;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize("requests", 192)?;
    let n_clients = args.usize("clients", 4)?;
    let exp_name = args.str("exp", "imdbw__sortcut_2x8");
    let artifacts = artifacts_dir();

    // quick sanity that the experiment exists before spawning the server
    let probe = Experiment::load(&artifacts, &exp_name)?;
    let seq_len = probe.manifest.eval_batch_inputs[0].shape[1];
    println!(
        "serving {exp_name} (seq_len {seq_len}, {} params) with {n_clients} clients",
        probe.manifest.n_params()
    );
    drop(probe);
    // warm up runtime check (the server owns its own runtime thread)
    Runtime::cpu()?;

    let server = Server::start(
        artifacts.clone(),
        exp_name.clone(),
        None,
        BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(4),
            ..Default::default()
        },
        11,
    )?;

    let latencies = Arc::new(Mutex::new(Vec::<f64>::new()));
    let batch_sizes = Arc::new(Mutex::new(Vec::<usize>::new()));
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let handle = server.handle.clone();
        let latencies = latencies.clone();
        let batch_sizes = batch_sizes.clone();
        let exp_name = exp_name.clone();
        let artifacts = artifacts.clone();
        joins.push(std::thread::spawn(move || -> Result<()> {
            // each client generates its own traffic stream
            let exp = Experiment::load(&artifacts, &exp_name)?;
            let mut data = TaskData::for_experiment(&exp.manifest)?;
            for _ in 0..n_requests / n_clients {
                let batch = data.train_batch();
                let toks = batch[0].as_i32()?[..handle.seq_len].to_vec();
                let resp = handle.classify(toks)?;
                latencies.lock().unwrap().push(resp.total.as_secs_f64() * 1e3);
                batch_sizes.lock().unwrap().push(resp.batch_size);
                let _ = c;
            }
            Ok(())
        }));
    }
    for j in joins {
        j.join().unwrap()?;
    }
    let secs = t0.elapsed().as_secs_f64();
    server.shutdown()?;

    let mut lat = latencies.lock().unwrap().clone();
    let served = lat.len();
    let bs = batch_sizes.lock().unwrap();
    let mean_bs = bs.iter().sum::<usize>() as f64 / bs.len() as f64;
    println!("served {served} requests in {secs:.2}s -> {:.1} req/s", served as f64 / secs);
    println!(
        "latency p50 {:.1}ms p90 {:.1}ms p99 {:.1}ms | mean batch size {mean_bs:.1}",
        percentile(&mut lat, 50.0),
        percentile(&mut lat, 90.0),
        percentile(&mut lat, 99.0),
    );
    println!("serve_classify OK");
    Ok(())
}
