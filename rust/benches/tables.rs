//! `cargo bench` target: regenerates every paper table/figure at reduced
//! step budgets (a fast regression of the full `sinkhorn bench --target all`
//! run used for EXPERIMENTS.md). Pass harness args after `--`:
//!   cargo bench --bench tables -- --target table1 --scale 0.3
//!
//! No criterion offline — this is a plain main() harness on
//! `sinkhorn::bench` (see util::stats for the timing substrate).

use sinkhorn::bench::{tables, BenchOptions};
use sinkhorn::runtime::{artifacts_dir, Registry, Runtime};
use sinkhorn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let opts = BenchOptions {
        artifacts: args.opt_str("artifacts").map(Into::into).unwrap_or_else(artifacts_dir),
        // default: quick regression pass (≈1/8 of the full budget)
        scale: args.f64("scale", 0.125)?,
        steps: args.opt_str("steps").map(|s| s.parse()).transpose()?,
        seed: 17,
        eval_batches: args.usize("eval-batches", 2)?,
        verbose: args.bool("verbose"),
        // teacher-forced seq2seq eval keeps the bench fast; the example
        // sort_seq2seq and `sinkhorn bench table1` do true greedy decode
        fast_decode: !args.has("full-decode"),
    };
    let rt = Runtime::cpu()?;
    let reg = Registry::load(&opts.artifacts)?;
    let target = args.str("target", "all");
    let t0 = std::time::Instant::now();
    if target == "all" {
        for t in tables::ALL_TARGETS {
            tables::run_target(&rt, &reg, &opts, t)?;
        }
    } else {
        tables::run_target(&rt, &reg, &opts, &target)?;
    }
    let (csecs, cn) = *rt.compile_stats.borrow();
    println!(
        "[bench tables] done in {:.1}s (compile: {cn} graphs, {csecs:.1}s)",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
