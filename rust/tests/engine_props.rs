//! Property tests for the streaming blocked engine against the naive
//! reference path — these run with no artifacts and no XLA, in every
//! build. The contract under test (DESIGN.md §Engine, §Streaming):
//!
//! 1. engine output is within 1e-5 max-abs of the naive oracle — causal
//!    and not, any thread count, including tile-tail shapes (`b`/`d` not
//!    multiples of the microkernel widths) and blocks wider than one
//!    streaming key tile;
//! 2. the engine is *self*-deterministic: every thread count reproduces
//!    the single-thread engine output bit for bit;
//! 3. SortCut streams to within epsilon of the naive cut for every
//!    `n_cut`, and `n_cut = nb` recovers full quasi-global attention;
//! 4. per-worker workspace memory is linear in `b` — the `(b, 2b)` logits
//!    and probability buffers are gone — and the real allocation matches
//!    `memory::engine_workspace_bytes`.

use sinkhorn::sinkhorn::engine::{workspace_f32_elems, ENGINE_TOL as TOL, STREAM_TILE_W};
use sinkhorn::sinkhorn::memory::engine_workspace_bytes;
use sinkhorn::sinkhorn::{
    causal_sinkhorn, dense_attention, sinkhorn, sinkhorn_attention, sortcut_attention,
    AttentionReq, Mat, SinkhornEngine,
};
use sinkhorn::util::prop::{forall, Gen};
use sinkhorn::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
}

struct Case {
    q: Mat,
    k: Mat,
    v: Mat,
    logits: Mat,
    nb: usize,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Case(ell={}, d={}, nb={})", self.q.rows, self.q.cols, self.nb)
    }
}

fn case_with(rng: &mut Rng, nb: usize, b: usize, d: usize) -> Case {
    let ell = nb * b;
    Case {
        q: rand_mat(rng, ell, d),
        k: rand_mat(rng, ell, d),
        v: rand_mat(rng, ell, d),
        logits: rand_mat(rng, nb, nb),
        nb,
    }
}

fn gen_case(g: &mut Gen) -> Case {
    // b in 2..=7 and d in 4..=11 deliberately straddle the microkernel
    // tile widths (4-row tiles, 8-lane chunks): most cases are tails
    let nb = 2 + g.usize(0, 5);
    let b = 2 + g.usize(0, 5);
    let d = 4 + g.usize(0, 8);
    let mut rng = Rng::new(g.rng.next_u64());
    case_with(&mut rng, nb, b, d)
}

fn check_epsilon_and_thread_invariance(c: &Case) -> Result<(), String> {
    for causal in [false, true] {
        let r = if causal {
            causal_sinkhorn(&c.logits, 6, true)
        } else {
            sinkhorn(&c.logits, 8)
        };
        let naive = sinkhorn_attention(&c.q, &c.k, &c.v, &r, c.nb, causal);
        let serial = SinkhornEngine::serial().attention(&c.q, &c.k, &c.v, &r, c.nb, causal);
        let diff = serial.max_abs_diff(&naive);
        if diff > TOL {
            return Err(format!("causal={causal}: engine vs naive max-abs {diff}"));
        }
        for threads in [2usize, 5] {
            let got = SinkhornEngine::new(threads).attention(&c.q, &c.k, &c.v, &r, c.nb, causal);
            // engine self-determinism is bitwise, not a tolerance check
            if got != serial {
                return Err(format!(
                    "threads={threads} causal={causal}: engine not thread-invariant (max diff {})",
                    got.max_abs_diff(&serial)
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn engine_within_epsilon_of_naive_across_modes() {
    forall(32, 0xF00D, gen_case, check_epsilon_and_thread_invariance);
}

#[test]
fn streaming_handles_tile_tails_and_multi_tile_blocks() {
    // fixed shapes targeting the seams: b/d off the 4-row and 8-lane
    // tiles, d < LANES, and b > STREAM_TILE_W so one block spans several
    // streaming key tiles (with a causal boundary crossing tiles too)
    let shapes = [
        (2usize, 5usize, 7usize),
        (3, 9, 13),
        (4, 6, 20),
        (2, 2, 4),
        (5, 3, 9),
        (2, STREAM_TILE_W + 8, 24),
        (3, STREAM_TILE_W + 1, 7),
    ];
    let mut rng = Rng::new(0x7A11);
    for (nb, b, d) in shapes {
        let c = case_with(&mut rng, nb, b, d);
        if let Err(e) = check_epsilon_and_thread_invariance(&c) {
            panic!("shape (nb={nb}, b={b}, d={d}): {e}");
        }
    }
}

#[test]
fn engine_sortcut_within_epsilon_of_naive() {
    forall(24, 0xF00E, gen_case, |c| {
        let r = sinkhorn(&c.logits, 8);
        for n_cut in 1..=c.nb {
            let naive = sortcut_attention(&c.q, &c.k, &c.v, &r, c.nb, n_cut);
            let got = SinkhornEngine::new(4).sortcut_attention(&c.q, &c.k, &c.v, &r, c.nb, n_cut);
            let diff = got.max_abs_diff(&naive);
            if diff > TOL {
                return Err(format!("n_cut={n_cut}: max-abs {diff}"));
            }
        }
        Ok(())
    });
}

#[test]
fn sortcut_with_full_cut_equals_full_attention() {
    // paper §3.3: k = nb keeps every sorted block, so SortCut degrades to
    // full (quasi-global) attention. With a hard permutation sort this
    // equals dense attention over the original sequence (softmax is
    // permutation-invariant up to fp summation order).
    forall(
        24,
        0xF00F,
        |g| {
            let nb = 2 + g.usize(0, 5);
            let b = 2 + g.usize(0, 5);
            let d = 4 + g.usize(0, 8);
            let mut rng = Rng::new(g.rng.next_u64());
            let mut perm: Vec<usize> = (0..nb).collect();
            rng.shuffle(&mut perm);
            (
                rand_mat(&mut rng, nb * b, d),
                rand_mat(&mut rng, nb * b, d),
                rand_mat(&mut rng, nb * b, d),
                perm,
                nb,
            )
        },
        |(q, k, v, perm, nb)| {
            let r = Mat::from_fn(*nb, *nb, |i, j| if perm[i] == j { 1.0 } else { 0.0 });
            let cut = SinkhornEngine::auto().sortcut_attention(q, k, v, &r, *nb, *nb);
            let dense = dense_attention(q, k, v, false);
            let diff = cut.max_abs_diff(&dense);
            if diff < 1e-4 {
                Ok(())
            } else {
                Err(format!("sortcut(k=nb) vs dense diff {diff}"))
            }
        },
    );
}

#[test]
fn engine_handles_degenerate_single_block() {
    // nb = 1: the sorted and local terms both see the whole sequence
    let mut rng = Rng::new(42);
    let (q, k, v) = (rand_mat(&mut rng, 6, 4), rand_mat(&mut rng, 6, 4), rand_mat(&mut rng, 6, 4));
    let r = Mat::eye(1);
    let naive = sinkhorn_attention(&q, &k, &v, &r, 1, false);
    let got = SinkhornEngine::auto().attention(&q, &k, &v, &r, 1, false);
    assert!(got.max_abs_diff(&naive) <= TOL);
}

#[test]
fn batched_requests_match_single_requests_bitwise() {
    // the (request, head, block) flattened path must reproduce the
    // one-request path exactly — serving correctness rides on this
    let mut rng = Rng::new(0xBB);
    let cases: Vec<Case> = (0..4)
        .map(|i| case_with(&mut rng, 2 + i % 3, 3 + i, 5 + 2 * i))
        .collect();
    let rs: Vec<Mat> = cases.iter().map(|c| sinkhorn(&c.logits, 8)).collect();
    let eng = SinkhornEngine::new(3);
    let reqs: Vec<AttentionReq> = cases
        .iter()
        .zip(&rs)
        .map(|(c, r)| AttentionReq { q: &c.q, k: &c.k, v: &c.v, r, nb: c.nb, causal: false })
        .collect();
    let mut outs: Vec<Mat> = cases.iter().map(|c| Mat::zeros(c.q.rows, c.q.cols)).collect();
    eng.attention_batch_into(&reqs, &mut outs);
    for ((c, r), got) in cases.iter().zip(&rs).zip(&outs) {
        let single = eng.attention(&c.q, &c.k, &c.v, r, c.nb, false);
        assert_eq!(got, &single, "{c:?}");
    }
}

#[test]
fn workspace_is_linear_in_b_and_matches_accounting() {
    for (b, d) in [(8usize, 8usize), (16, 32), (64, 64), (256, 64)] {
        // measured allocation == analytic model (memory.rs)
        assert_eq!(
            workspace_f32_elems(b, d) * 4,
            engine_workspace_bytes(b, d),
            "accounting drifted at b={b} d={d}"
        );
        // linear in b: no (b, 2b) logits/probability tile remains
        assert_eq!(workspace_f32_elems(2 * b, d), 2 * workspace_f32_elems(b, d));
        // strictly smaller than the pre-streaming workspace, which staged
        // the (b, 2b) joint logits plus a (b, d) combine scratch
        if b >= STREAM_TILE_W {
            let old = 3 * b * d + 2 * b * b;
            assert!(workspace_f32_elems(b, d) < old, "b={b} d={d}");
        }
    }
}
