//! Inference service: a router thread owns the execution backend (the
//! PJRT client is not `Send`-shareable, so all execution funnels through
//! one executor — the vllm-router shape: N frontends -> channel ->
//! batcher -> executor).
//!
//! Two backends serve inference requests, two verbs each batch can mix:
//! **classify** (token ids in, predicted label out) and **generate**
//! (prompt + token budget in, greedily decoded ids out — the incremental
//! decode path, DESIGN.md §Decode):
//!
//! * **Artifacts** — the AOT-compiled XLA eval graph, when the
//!   experiment's HLO artifacts and a PJRT runtime are available
//!   (classify only: the exported graphs have no decode entry, so
//!   generate requests get a stable per-request error).
//! * **Pure-Rust fallback** — [`super::fallback::FallbackModel`] on the
//!   parallel blocked engine, selected automatically when no compiled HLO
//!   artifact is present (or the build links the offline `xla` stub), so
//!   the serving stack runs on any machine. Serves both verbs. See
//!   DESIGN.md §Engine, §Decode.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Checkpoint;
use crate::runtime::{Experiment, HostTensor, Runtime, TrainState};

use super::batch::{gather, BatchPolicy};
use super::fallback::{FallbackConfig, FallbackModel};

/// What a request asks the executor to do.
enum Work {
    Classify(Vec<i32>),
    Generate { tokens: Vec<i32>, max_new: usize },
    /// report the served model's configuration (one `key=value` line)
    Info,
}

/// One inference request.
struct Request {
    work: Work,
    enqueued: Instant,
    resp: Sender<Result<Response>>,
}

/// Executor inbox message: a request, or an explicit stop. The sentinel
/// lets `shutdown` terminate the executor even while detached frontends
/// (e.g. the TCP acceptor) still hold live `ServerHandle` clones.
enum Msg {
    Req(Request),
    Stop,
}

/// Server reply.
#[derive(Debug, Clone)]
pub struct Response {
    /// classify: the predicted label. generate: the last generated token
    /// id (0 when the capacity-clamped budget came out empty) — the full
    /// sequence is in [`Response::gen`].
    pub label: i32,
    /// `Some(ids)` for generate requests: the newly generated token ids.
    pub gen: Option<Vec<i32>>,
    /// `Some(line)` for model-info requests: the served model described as
    /// one `key=value` line (depth/heads/config — the TCP `model` verb).
    pub info: Option<String>,
    /// time spent waiting in the batcher
    pub queue: Duration,
    /// total time from submit to reply
    pub total: Duration,
    /// how many requests shared the executed batch
    pub batch_size: usize,
}

/// Handle to a running server; cloneable across client threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
    pub seq_len: usize,
}

impl ServerHandle {
    /// Blocking classify call.
    pub fn classify(&self, tokens: Vec<i32>) -> Result<Response> {
        self.submit(Work::Classify(tokens))
    }

    /// Blocking generate call: greedily decode up to `max_new` tokens
    /// after `tokens` (fallback backend only — see the module docs).
    pub fn generate(&self, tokens: Vec<i32>, max_new: usize) -> Result<Response> {
        self.submit(Work::Generate { tokens, max_new })
    }

    /// Blocking model-info call: the served model's configuration as one
    /// `key=value` line ([`Response::info`] — the TCP `model` verb).
    pub fn model_info(&self) -> Result<Response> {
        self.submit(Work::Info)
    }

    fn submit(&self, work: Work) -> Result<Response> {
        let (rtx, rrx) = channel();
        let req = Request { work, enqueued: Instant::now(), resp: rtx };
        self.tx.send(Msg::Req(req)).map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

/// A running inference server (executor joins on drop of the handle + stop).
pub struct Server {
    pub handle: ServerHandle,
    join: Option<JoinHandle<Result<()>>>,
}

/// The shared executor: pull batches off the channel under `policy`, split
/// each batch by verb, hand classify rows to `classify` and generate
/// requests to `generate`, fan the results back out. Both backends run
/// this loop; only the closures differ. `generate: None` (the artifact
/// backend — its exported graphs have no decode entry) answers every
/// generate request with a stable per-request error instead of failing the
/// batch. Model-info requests are answered from the precomputed `info`
/// line without touching the backend. Token rows are moved out of the
/// requests (no per-request copies on this path).
fn executor_loop<C, G>(
    rx: &Receiver<Msg>,
    policy: &BatchPolicy,
    info: &str,
    mut classify: C,
    mut generate: Option<G>,
) -> Result<()>
where
    C: FnMut(&[Vec<i32>]) -> Result<Vec<i32>>,
    G: FnMut(&[(Vec<i32>, usize)]) -> Result<Vec<Vec<i32>>>,
{
    'serve: while let Some(msgs) = gather(rx, policy) {
        let mut stop = false;
        let mut cls_rows: Vec<Vec<i32>> = Vec::new();
        let mut cls_meta: Vec<(Instant, Sender<Result<Response>>)> = Vec::new();
        let mut gen_rows: Vec<(Vec<i32>, usize)> = Vec::new();
        let mut gen_meta: Vec<(Instant, Sender<Result<Response>>)> = Vec::new();
        let mut info_meta: Vec<(Instant, Sender<Result<Response>>)> = Vec::new();
        for m in msgs {
            match m {
                Msg::Req(r) => match r.work {
                    Work::Classify(tokens) => {
                        cls_rows.push(tokens);
                        cls_meta.push((r.enqueued, r.resp));
                    }
                    Work::Generate { tokens, max_new } => {
                        gen_rows.push((tokens, max_new));
                        gen_meta.push((r.enqueued, r.resp));
                    }
                    Work::Info => info_meta.push((r.enqueued, r.resp)),
                },
                Msg::Stop => stop = true,
            }
        }
        let n = cls_rows.len() + gen_rows.len() + info_meta.len();
        if n == 0 {
            if stop {
                break 'serve;
            }
            continue;
        }
        let exec_start = Instant::now();
        for (enqueued, resp) in info_meta {
            let _ = resp.send(Ok(Response {
                label: 0,
                gen: None,
                info: Some(info.to_string()),
                queue: exec_start - enqueued,
                total: enqueued.elapsed(),
                batch_size: n,
            }));
        }
        if !cls_rows.is_empty() {
            match classify(&cls_rows) {
                Ok(labels) => {
                    for (i, (enqueued, resp)) in cls_meta.into_iter().enumerate() {
                        let _ = resp.send(Ok(Response {
                            label: labels[i],
                            gen: None,
                            info: None,
                            queue: exec_start - enqueued,
                            total: enqueued.elapsed(),
                            batch_size: n,
                        }));
                    }
                }
                Err(e) => {
                    for (_, resp) in cls_meta {
                        let _ = resp.send(Err(anyhow!("exec failed: {e}")));
                    }
                }
            }
        }
        if !gen_rows.is_empty() {
            match &mut generate {
                None => {
                    for (_, resp) in gen_meta {
                        let _ = resp.send(Err(anyhow!(
                            "generate requires the pure-Rust fallback backend"
                        )));
                    }
                }
                Some(g) => match g(&gen_rows) {
                    Ok(seqs) => {
                        for (seq, (enqueued, resp)) in seqs.into_iter().zip(gen_meta) {
                            let _ = resp.send(Ok(Response {
                                label: seq.last().copied().unwrap_or(0),
                                gen: Some(seq),
                                info: None,
                                queue: exec_start - enqueued,
                                total: enqueued.elapsed(),
                                batch_size: n,
                            }));
                        }
                    }
                    Err(e) => {
                        for (_, resp) in gen_meta {
                            let _ = resp.send(Err(anyhow!("exec failed: {e}")));
                        }
                    }
                },
            }
        }
        if stop {
            break 'serve;
        }
    }
    Ok(())
}

impl Server {
    /// Start a server for `exp_name`: the artifact-backed executor when
    /// the compiled HLO artifacts and a PJRT runtime are available,
    /// otherwise the pure-Rust fallback engine (unless a checkpoint was
    /// requested — checkpoints only restore into artifact graphs).
    pub fn start(
        artifacts: PathBuf,
        exp_name: String,
        checkpoint: Option<PathBuf>,
        policy: BatchPolicy,
        init_seed: i32,
    ) -> Result<Server> {
        // a present registry means the operator *has* artifacts: a bad
        // experiment name or corrupt manifest must then fail loudly, not
        // silently demote to the untrained fallback model. Runtime (PJRT)
        // startup failures still fall back — the offline-stub case.
        let artifacts_present = artifacts.join("registry.json").exists();
        // start_artifact reports executor startup failures (missing
        // manifest, stub/broken PJRT runtime, bad artifacts) synchronously
        match Self::start_artifact(
            artifacts,
            exp_name.clone(),
            checkpoint.clone(),
            policy,
            init_seed,
        ) {
            Ok(server) => Ok(server),
            Err(e) if checkpoint.is_some() => {
                Err(e.context(format!("'{exp_name}' needs its artifacts to restore a checkpoint")))
            }
            // "server runtime" is the context start_artifact puts on the
            // PJRT construction failure — the one artifact-present error
            // that legitimately falls back
            Err(e) if artifacts_present && !format!("{e:#}").contains("server runtime") => {
                Err(e.context(format!(
                    "experiment '{exp_name}' failed to start (artifacts are present, so not \
                     falling back — check the name with `sinkhorn list`)"
                )))
            }
            Err(e) => {
                eprintln!(
                    "[server] no usable HLO artifact for '{exp_name}' ({e:#}); \
                     serving with the pure-Rust fallback engine"
                );
                let cfg = FallbackConfig { seed: init_seed as u64, ..Default::default() };
                Self::start_fallback(cfg, policy)
            }
        }
    }

    /// Artifact-backed executor: loads the experiment, restores or inits
    /// parameters, then serves until all handles are dropped. The
    /// executor thread owns the PJRT runtime (it is not `Send`); its
    /// startup outcome is funneled back over a channel so failures
    /// surface here without constructing a throwaway probe runtime.
    fn start_artifact(
        artifacts: PathBuf,
        exp_name: String,
        checkpoint: Option<PathBuf>,
        policy: BatchPolicy,
        init_seed: i32,
    ) -> Result<Server> {
        let probe = Experiment::load(&artifacts, &exp_name)?;
        if probe.manifest.eval_outputs.len() < 3 {
            bail!("experiment '{exp_name}' has no pred output; re-run make artifacts");
        }
        let seq_len = probe.manifest.eval_batch_inputs[0].shape[1];
        let graph_batch = probe.manifest.eval_batch_inputs[0].shape[0];
        let policy = policy.clamped(graph_batch);

        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::spawn(move || -> Result<()> {
            // executor startup: anything failing here aborts the server
            // before it accepts traffic (reported via ready_tx)
            let startup = || -> Result<(Runtime, Experiment, TrainState)> {
                let rt = Runtime::cpu().context("server runtime")?;
                let exp = Experiment::load(&artifacts, &exp_name)?;
                let state = match checkpoint {
                    Some(path) => Checkpoint::load(&path)?.restore(&exp.manifest)?,
                    None => exp.init_state(&rt, init_seed)?,
                };
                // warm the compile cache before accepting traffic
                let zeros =
                    HostTensor::i32(&[graph_batch, seq_len], vec![0; graph_batch * seq_len]);
                let zlabels = HostTensor::i32(&[graph_batch], vec![0; graph_batch]);
                exp.eval(&rt, &state.params, &[zeros.to_literal()?, zlabels.to_literal()?])?;
                Ok((rt, exp, state))
            };
            let (rt, exp, state) = match startup() {
                Ok(x) => {
                    let _ = ready_tx.send(Ok(()));
                    x
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return Ok(()); // failure already reported to the caller
                }
            };

            let info = format!(
                "backend=artifact exp={} seq_len={} graph_batch={} verbs=classify",
                exp_name, seq_len, graph_batch
            );
            executor_loop(
                &rx,
                &policy,
                &info,
                |rows| {
                    // assemble fixed-shape tensors, padding unused rows
                    let mut toks = Vec::with_capacity(graph_batch * seq_len);
                    for r in rows {
                        let take = r.len().min(seq_len);
                        toks.extend_from_slice(&r[..take]);
                        toks.resize(toks.len() + (seq_len - take), 0);
                    }
                    toks.resize(graph_batch * seq_len, 0);
                    let labels = vec![0i32; graph_batch];
                    let t_tok = HostTensor::i32(&[graph_batch, seq_len], toks);
                    let t_lab = HostTensor::i32(&[graph_batch], labels);
                    let out =
                        exp.eval(&rt, &state.params, &[t_tok.to_literal()?, t_lab.to_literal()?])?;
                    let pred = HostTensor::from_literal(&out[2])?;
                    Ok(pred.as_i32()?[..rows.len()].to_vec())
                },
                // the exported eval graphs have no incremental decode
                // entry; generate requests get per-request errors
                None::<fn(&[(Vec<i32>, usize)]) -> Result<Vec<Vec<i32>>>>,
            )
        });

        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Server { handle: ServerHandle { tx, seq_len }, join: Some(join) }),
            Ok(Err(e)) => {
                let _ = join.join();
                Err(e)
            }
            Err(_) => {
                let _ = join.join();
                bail!("server executor died during startup")
            }
        }
    }

    /// Pure-Rust executor on the blocked engine — works with no artifacts
    /// directory at all.
    pub fn start_fallback(cfg: FallbackConfig, policy: BatchPolicy) -> Result<Server> {
        // build the model synchronously so config errors surface here
        let model = FallbackModel::new(cfg)?;
        let seq_len = model.cfg.seq_len;
        let (tx, rx) = channel::<Msg>();
        let join = std::thread::spawn(move || -> Result<()> {
            let info = model.describe();
            executor_loop(
                &rx,
                &policy,
                &info,
                |rows| Ok(model.classify_batch(rows)),
                Some(|reqs: &[(Vec<i32>, usize)]| Ok(model.generate_batch(reqs))),
            )
        });
        Ok(Server { handle: ServerHandle { tx, seq_len }, join: Some(join) })
    }

    /// Close the intake channel and wait for the executor to drain.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.handle.tx.send(Msg::Stop);
        drop(self.handle);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fallback backend end to end: concurrent clients, batching,
    /// deterministic labels — all without artifacts or XLA.
    #[test]
    fn fallback_server_classifies_concurrently() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(3) };
        let server = Server::start_fallback(cfg.clone(), policy).unwrap();
        assert_eq!(server.handle.seq_len, 32);
        let mut joins = Vec::new();
        for t in 0..3i32 {
            let h = server.handle.clone();
            joins.push(std::thread::spawn(move || {
                (0..6)
                    .map(|i| {
                        let toks: Vec<i32> = (0..32).map(|p| p * 13 + t * 7 + i).collect();
                        let resp = h.classify(toks).unwrap();
                        assert!(resp.batch_size >= 1);
                        resp.label
                    })
                    .collect::<Vec<i32>>()
            }));
        }
        let labels: Vec<Vec<i32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        server.shutdown().unwrap();
        // replies must be deterministic: same requests against a fresh
        // server give identical labels
        let server2 = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        for (t, row) in labels.iter().enumerate() {
            for (i, &want) in row.iter().enumerate() {
                let toks: Vec<i32> = (0..32).map(|p| p * 13 + (t as i32) * 7 + i as i32).collect();
                assert_eq!(server2.handle.classify(toks).unwrap().label, want);
            }
        }
        server2.shutdown().unwrap();
    }

    /// The generate verb end to end through the batcher: tokens come back,
    /// match the bare model exactly, and classify still works beside it.
    #[test]
    fn fallback_server_generates() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg.clone(), BatchPolicy::default()).unwrap();
        let prompt: Vec<i32> = (0..8).map(|i| i * 3).collect();
        let r = server.handle.generate(prompt.clone(), 5).unwrap();
        let toks = r.gen.clone().expect("generate reply carries tokens");
        assert_eq!(toks.len(), 5);
        assert_eq!(r.label, *toks.last().unwrap());
        let model = FallbackModel::new(cfg).unwrap();
        assert_eq!(model.generate(&prompt, 5), toks);
        let c = server.handle.classify(prompt).unwrap();
        assert!(c.label >= 0 && c.gen.is_none());
        server.shutdown().unwrap();
    }

    /// The model-info verb end to end: the reply carries the fallback
    /// stack's configuration as one `key=value` line.
    #[test]
    fn fallback_server_reports_model_info() {
        let cfg = FallbackConfig {
            seq_len: 32,
            d_model: 16,
            nb: 4,
            depth: 2,
            n_heads: 2,
            d_ff: 32,
            ..Default::default()
        };
        let server = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        let r = server.handle.model_info().unwrap();
        let info = r.info.expect("model-info reply carries the description");
        for want in ["backend=fallback", "depth=2", "heads=2", "seq_len=32"] {
            assert!(info.contains(want), "info missing {want}: {info}");
        }
        assert!(r.gen.is_none());
        server.shutdown().unwrap();
    }

    #[test]
    fn missing_artifacts_fall_back() {
        let server = Server::start(
            PathBuf::from("/definitely/not/artifacts"),
            "sstw__sinkhorn_b8".into(),
            None,
            BatchPolicy::default(),
            3,
        )
        .unwrap();
        let resp = server.handle.classify(vec![1, 2, 3, 4]).unwrap();
        assert!(resp.label >= 0);
        server.shutdown().unwrap();
    }

    #[test]
    fn typo_with_artifacts_present_errors_instead_of_falling_back() {
        // a registry.json marks artifacts as present: unknown experiment
        // names must fail loudly rather than serve the toy fallback
        let dir = std::env::temp_dir().join("sinkhorn-svc-typo-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("registry.json"), "{\"experiments\": []}").unwrap();
        let err = Server::start(
            dir,
            "definitely_not_an_experiment".into(),
            None,
            BatchPolicy::default(),
            3,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("not falling back"), "{err:#}");
    }

    #[test]
    fn checkpoint_without_artifacts_errors() {
        let err = Server::start(
            PathBuf::from("/definitely/not/artifacts"),
            "sstw__sinkhorn_b8".into(),
            Some(PathBuf::from("some.ckpt")),
            BatchPolicy::default(),
            3,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("restore a checkpoint"), "{err:#}");
    }
}
