"""L2 model tests: shapes for every attention variant, causality (no
gradient from future targets to past inputs), SortNet behavior, and
trainability (loss decreases on a memorizable batch)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import attention, configs, model, sortnet, train

TINY = dict(
    d_model=16, n_heads=2, d_ff=32, n_layers=2, vocab=32, ell=16,
    block=4, nb=4, sinkhorn_iters=3, tau=0.75, p_variant=4, share_kv=False,
)

VARIANTS = ["vanilla", "local", "sparse", "sinkhorn", "mixture", "sortcut"]


def cfg_for(variant, **kw):
    c = dict(TINY)
    c["variant"] = variant
    if variant == "sortcut":
        c["n_cut"] = 2
    c.update(kw)
    return c


@pytest.mark.parametrize("variant", VARIANTS)
def test_lm_logits_shape(variant):
    cfg = cfg_for(variant)
    params = model.lm_init(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((2, cfg["ell"]), jnp.int32)
    out = model.lm_logits(params, toks, cfg, key=jax.random.PRNGKey(1))
    assert out.shape == (2, cfg["ell"], cfg["vocab"])
    assert jnp.isfinite(out).all()


@pytest.mark.parametrize("variant", VARIANTS)
def test_classifier_logits_shape(variant):
    cfg = cfg_for(variant, n_classes=3)
    params = model.classifier_init(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((4, cfg["ell"]), jnp.int32)
    out = model.classifier_logits(params, toks, cfg)
    assert out.shape == (4, 3)


@pytest.mark.parametrize("variant", ["vanilla", "local", "sinkhorn"])
def test_seq2seq_logits_shape(variant):
    cfg = cfg_for(variant)
    cfg["ell_tgt"] = cfg["ell"]
    params = model.seq2seq_init(jax.random.PRNGKey(0), cfg)
    src = jnp.zeros((2, cfg["ell"]), jnp.int32)
    tgt = jnp.zeros((2, cfg["ell"]), jnp.int32)
    out = model.seq2seq_logits(params, src, tgt, cfg)
    assert out.shape == (2, cfg["ell"], cfg["vocab"])


@pytest.mark.parametrize("variant", ["vanilla", "local", "sparse", "sinkhorn", "mixture"])
def test_lm_causality_no_future_grad(variant):
    """d loss(position t) / d embedding(token u) must vanish for u > t."""
    cfg = cfg_for(variant)
    params = model.lm_init(jax.random.PRNGKey(0), cfg)
    # distinct tokens so "future token id" never appears in the past
    perm = jax.random.permutation(jax.random.PRNGKey(1), cfg["vocab"])[: cfg["ell"]]
    toks = perm[None, :]
    t_probe = cfg["ell"] // 2

    def loss_at_t(table):
        p2 = dict(params)
        p2["embed"] = {"table": table}
        logits = model.lm_logits(p2, toks, cfg, key=jax.random.PRNGKey(2))
        return logits[0, t_probe].sum()

    g = jax.grad(loss_at_t)(params["embed"]["table"])
    # token at a future position u > t_probe, unique in the sequence
    future_tok = int(toks[0, t_probe + 2])
    past_toks = set(int(x) for x in np.asarray(toks[0, : t_probe + 1]))
    if future_tok in past_toks:
        pytest.skip("token collision; causality unverifiable for this draw")
    leak = float(jnp.abs(g[future_tok]).max())
    assert leak < 1e-6, f"future leak {leak} in {variant}"


def test_sortnet_doubly_stochastic():
    cfg = cfg_for("sinkhorn")
    p = sortnet.sortnet_init(jax.random.PRNGKey(0), cfg["d_model"], cfg["nb"], cfg["n_heads"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, cfg["ell"], cfg["d_model"]))
    s = sortnet.sort_matrix(
        p, x, nb=cfg["nb"], n_iters=20, tau=0.75, p_variant=4, causal=False,
        key=jax.random.PRNGKey(2),
    )
    assert s.shape == (2, cfg["n_heads"], cfg["nb"], cfg["nb"])
    np.testing.assert_allclose(s.sum(-1), 1.0, atol=2e-2)
    np.testing.assert_allclose(s.sum(-2), 1.0, atol=2e-2)


def test_sortnet_heads_differ():
    """Per-head sort matrices (paper: no sharing across heads)."""
    cfg = cfg_for("sinkhorn")
    p = sortnet.sortnet_init(jax.random.PRNGKey(3), cfg["d_model"], cfg["nb"], cfg["n_heads"])
    x = jax.random.normal(jax.random.PRNGKey(4), (1, cfg["ell"], cfg["d_model"]))
    s = sortnet.sort_matrix(p, x, nb=cfg["nb"], n_iters=5, tau=0.75, p_variant=4, causal=False)
    assert not np.allclose(s[0, 0], s[0, 1])


def test_causal_pooling_uses_only_past():
    """psi_pool causal: block descriptor i must not change when tokens after
    the block's first token are perturbed."""
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 8))
    base = sortnet.psi_pool(x, 4, causal=True)
    x2 = x.at[0, 5:].add(100.0)  # block 1 starts at index 4
    pert = sortnet.psi_pool(x2, 4, causal=True)
    np.testing.assert_allclose(base[0, 1], pert[0, 1], rtol=1e-6)
    assert not np.allclose(base[0, 2], pert[0, 2])


@pytest.mark.parametrize("pv", [1, 2, 3, 4])
def test_sortnet_p_variants(pv):
    cfg = cfg_for("sinkhorn", p_variant=pv)
    p = sortnet.sortnet_init(jax.random.PRNGKey(0), cfg["d_model"], cfg["nb"], cfg["n_heads"], p_variant=pv)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, cfg["ell"], cfg["d_model"]))
    s = sortnet.sort_matrix(p, x, nb=cfg["nb"], n_iters=3, tau=0.75, p_variant=pv, causal=False)
    assert s.shape == (2, cfg["n_heads"], cfg["nb"], cfg["nb"])
    assert jnp.isfinite(s).all()


def test_gumbel_noise_changes_with_key_and_tau():
    cfg = cfg_for("sinkhorn")
    p = sortnet.sortnet_init(jax.random.PRNGKey(0), cfg["d_model"], cfg["nb"], cfg["n_heads"])
    x = jax.random.normal(jax.random.PRNGKey(1), (1, cfg["ell"], cfg["d_model"]))
    kw = dict(nb=cfg["nb"], n_iters=5, p_variant=4, causal=False)
    s1 = sortnet.sort_matrix(p, x, tau=0.75, key=jax.random.PRNGKey(2), **kw)
    s2 = sortnet.sort_matrix(p, x, tau=0.75, key=jax.random.PRNGKey(3), **kw)
    s_det = sortnet.sort_matrix(p, x, tau=0.75, key=None, **kw)
    assert not np.allclose(s1, s2)
    assert np.isfinite(np.asarray(s_det)).all()


@pytest.mark.parametrize("family,variant", [("lm", "sinkhorn"), ("cls", "sortcut"), ("seq2seq", "sinkhorn")])
def test_train_step_loss_decreases(family, variant):
    """Memorize one small batch: loss after 25 Adam steps must drop."""
    cfg = cfg_for(variant)
    tcfg = dict(batch=4, warmup=10, default_steps=10)
    if family == "cls":
        cfg["n_classes"] = 2
    if family == "seq2seq":
        cfg["ell_tgt"] = cfg["ell"]
    step = jax.jit(train.make_train_step(family, cfg, tcfg))
    init = {"lm": model.lm_init, "cls": model.classifier_init, "seq2seq": model.seq2seq_init}[family]
    params = init(jax.random.PRNGKey(0), cfg)
    m, v = train.adam_init(params)
    key = jax.random.PRNGKey(9)
    if family == "lm":
        batch = (jax.random.randint(key, (4, cfg["ell"] + 1), 0, cfg["vocab"]),)
    elif family == "cls":
        batch = (
            jax.random.randint(key, (4, cfg["ell"]), 0, cfg["vocab"]),
            jnp.array([0, 1, 0, 1], jnp.int32),
        )
    else:
        src = jax.random.randint(key, (4, cfg["ell"]), 4, cfg["vocab"])
        tgt = jnp.concatenate([jnp.full((4, 1), 2, jnp.int32), jnp.sort(src, axis=1)], axis=1)
        batch = (src, tgt)
    s = jnp.float32(0.0)
    losses = []
    for i in range(25):
        params, m, v, s, loss = step(params, m, v, s, i, *batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_share_kv_changes_output():
    cfg = cfg_for("sinkhorn")
    cfg2 = cfg_for("sinkhorn", share_kv=True)
    params = model.lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg["ell"]), 0, cfg["vocab"])
    y1 = model.lm_logits(params, toks, cfg)
    y2 = model.lm_logits(params, toks, cfg2)
    assert not np.allclose(y1, y2)


def test_registry_configs_consistent():
    for e in configs.EXPERIMENTS:
        cfg = e["cfg"]
        assert cfg["ell"] % cfg["nb"] == 0, e["name"]
        assert cfg["d_model"] % cfg["n_heads"] == 0, e["name"]
        if cfg["variant"] == "sortcut":
            assert cfg["n_cut"] <= cfg["nb"], e["name"]
        if "ell_eval" in cfg:
            assert cfg["ell_eval"] % cfg["nb"] == 0, e["name"]
    names = [e["name"] for e in configs.EXPERIMENTS]
    assert len(names) == len(set(names)), "duplicate experiment names"
