"""Pallas kernel for the Sparse Sinkhorn block attention hot-spot.

This is the paper's O(ell^2) -> O(ell*b) core (§3.2): each query block
attends to exactly two length-``b`` key blocks — its *sorted* block (the
quasi-global term, keys pre-mixed by the Sinkhorn matrix R) and its *local*
block — under one shared softmax.

Two grid layouts, selected by ``mode`` (kernels are identical math, both
tested against ``ref.py``):

  * ``tile`` — grid ``(G, nb)`` (G = batch*heads): one ``(b, d)`` query
    tile + two key and two value tiles per program, VMEM working set
    ``5*b*d + 2*b^2`` floats independent of ``ell``. This is the TPU
    mapping (DESIGN.md §Hardware-Adaptation): per-tile ``b x d x b``
    contractions are MXU-shaped.
  * ``slab`` — grid ``(nb,)``: one program per block position holding the
    whole ``(G, b, d)`` slab and doing batched contractions. interpret
    mode emulates the grid with a serial XLA loop, so fewer/fatter
    programs are dramatically faster on CPU; this is the default for the
    AOT artifacts (the CPU testbed), with ``tile`` kept for TPU lowering.

Autodiff: ``pallas_call`` has no reverse-mode rule, so the public entry
points carry a ``jax.custom_vjp`` whose backward pass is a *second* Pallas
kernel (flash-attention style: the (·, b, 2b) probability tile is
recomputed from the saved q/k/v tiles instead of materialized).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9

# AOT artifacts are built for the CPU testbed -> slab; set
# SINKHORN_KERNEL_MODE=tile when lowering for real TPUs.
DEFAULT_MODE = os.environ.get("SINKHORN_KERNEL_MODE", "slab")


def _prob_tile(q, ks, kl, valid, causal):
    """Softmax tile over [sorted | local] keys. Shapes: q/ks/kl (..., b, d),
    valid (...,) broadcastable; returns (..., b, 2b). Shared fwd/bwd."""
    b = q.shape[-2]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    ls = jnp.einsum("...td,...ud->...tu", q, ks) * scale
    ll = jnp.einsum("...td,...ud->...tu", q, kl) * scale
    ls = jnp.where(valid[..., None, None] > 0.5, ls, NEG_INF)
    if causal:
        t = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
        u = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
        ll = jnp.where(u <= t, ll, NEG_INF)
    logits = jnp.concatenate([ls, ll], axis=-1)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _fwd_body(q, ks, kl, vs, vl, valid, causal):
    p = _prob_tile(q, ks, kl, valid, causal)
    b = q.shape[-2]
    return jnp.einsum("...tu,...ud->...td", p[..., :b], vs) + jnp.einsum(
        "...tu,...ud->...td", p[..., b:], vl
    )


def _bwd_body(q, ks, kl, vs, vl, valid, dy, causal):
    b, d = q.shape[-2], q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    p = _prob_tile(q, ks, kl, valid, causal)
    dp = jnp.concatenate(
        [jnp.einsum("...td,...ud->...tu", dy, vs), jnp.einsum("...td,...ud->...tu", dy, vl)],
        axis=-1,
    )
    dlog = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))  # softmax vjp
    ds_s, ds_l = dlog[..., :b], dlog[..., b:]
    dq = (jnp.einsum("...tu,...ud->...td", ds_s, ks) + jnp.einsum("...tu,...ud->...td", ds_l, kl)) * scale
    dks = jnp.einsum("...tu,...td->...ud", ds_s, q) * scale
    dkl = jnp.einsum("...tu,...td->...ud", ds_l, q) * scale
    dvs = jnp.einsum("...tu,...td->...ud", p[..., :b], dy)
    dvl = jnp.einsum("...tu,...td->...ud", p[..., b:], dy)
    return dq, dks, dkl, dvs, dvl


# --- tile mode: grid (G, nb), (b, d) tiles --------------------------------


def _tile_fwd_kernel(q_ref, ks_ref, kl_ref, vs_ref, vl_ref, valid_ref, y_ref, *, causal):
    f32 = jnp.float32
    y = _fwd_body(
        q_ref[0, 0].astype(f32), ks_ref[0, 0].astype(f32), kl_ref[0, 0].astype(f32),
        vs_ref[0, 0].astype(f32), vl_ref[0, 0].astype(f32), valid_ref[0, 0], causal,
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)


def _tile_bwd_kernel(
    q_ref, ks_ref, kl_ref, vs_ref, vl_ref, valid_ref, dy_ref,
    dq_ref, dks_ref, dkl_ref, dvs_ref, dvl_ref, *, causal,
):
    f32 = jnp.float32
    outs = _bwd_body(
        q_ref[0, 0].astype(f32), ks_ref[0, 0].astype(f32), kl_ref[0, 0].astype(f32),
        vs_ref[0, 0].astype(f32), vl_ref[0, 0].astype(f32), valid_ref[0, 0],
        dy_ref[0, 0].astype(f32), causal,
    )
    for ref, val in zip((dq_ref, dks_ref, dkl_ref, dvs_ref, dvl_ref), outs):
        ref[0, 0] = val.astype(ref.dtype)


# --- slab mode: grid (nb,), (G, b, d) slabs -------------------------------


def _slab_fwd_kernel(q_ref, ks_ref, kl_ref, vs_ref, vl_ref, valid_ref, y_ref, *, causal):
    f32 = jnp.float32
    y = _fwd_body(
        q_ref[:, 0].astype(f32), ks_ref[:, 0].astype(f32), kl_ref[:, 0].astype(f32),
        vs_ref[:, 0].astype(f32), vl_ref[:, 0].astype(f32), valid_ref[:, 0], causal,
    )
    y_ref[:, 0] = y.astype(y_ref.dtype)


def _slab_bwd_kernel(
    q_ref, ks_ref, kl_ref, vs_ref, vl_ref, valid_ref, dy_ref,
    dq_ref, dks_ref, dkl_ref, dvs_ref, dvl_ref, *, causal,
):
    f32 = jnp.float32
    outs = _bwd_body(
        q_ref[:, 0].astype(f32), ks_ref[:, 0].astype(f32), kl_ref[:, 0].astype(f32),
        vs_ref[:, 0].astype(f32), vl_ref[:, 0].astype(f32), valid_ref[:, 0],
        dy_ref[:, 0].astype(f32), causal,
    )
    for ref, val in zip((dq_ref, dks_ref, dkl_ref, dvs_ref, dvl_ref), outs):
        ref[:, 0] = val.astype(ref.dtype)


def _specs(g, nb, b, d, mode):
    if mode == "tile":
        spec = pl.BlockSpec((1, 1, b, d), lambda gi, i: (gi, i, 0, 0))
        vspec = pl.BlockSpec((1, 1), lambda gi, i: (gi, i))
        grid = (g, nb)
    else:
        spec = pl.BlockSpec((g, 1, b, d), lambda i: (0, i, 0, 0))
        vspec = pl.BlockSpec((g, 1), lambda i: (0, i))
        grid = (nb,)
    return grid, spec, vspec


def _pallas_fwd(q, ks, kl, vs, vl, valid, *, causal, mode):
    g, nb, b, d = q.shape
    grid, spec, vspec = _specs(g, nb, b, d, mode)
    kern = _tile_fwd_kernel if mode == "tile" else _slab_fwd_kernel
    return pl.pallas_call(
        functools.partial(kern, causal=causal),
        grid=grid,
        in_specs=[spec] * 5 + [vspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((g, nb, b, d), q.dtype),
        interpret=True,
    )(q, ks, kl, vs, vl, valid)


def _pallas_bwd(q, ks, kl, vs, vl, valid, dy, *, causal, mode):
    g, nb, b, d = q.shape
    grid, spec, vspec = _specs(g, nb, b, d, mode)
    kern = _tile_bwd_kernel if mode == "tile" else _slab_bwd_kernel
    shape = jax.ShapeDtypeStruct((g, nb, b, d), q.dtype)
    return pl.pallas_call(
        functools.partial(kern, causal=causal),
        grid=grid,
        in_specs=[spec] * 5 + [vspec, spec],
        out_specs=[spec] * 5,
        out_shape=[shape] * 5,
        interpret=True,
    )(q, ks, kl, vs, vl, valid, dy)


@functools.lru_cache(maxsize=None)
def _make(causal: bool, mode: str):
    @jax.custom_vjp
    def attn(q, ks, kl, vs, vl, valid):
        return _pallas_fwd(q, ks, kl, vs, vl, valid, causal=causal, mode=mode)

    def fwd(q, ks, kl, vs, vl, valid):
        return attn(q, ks, kl, vs, vl, valid), (q, ks, kl, vs, vl, valid)

    def bwd(res, dy):
        q, ks, kl, vs, vl, valid = res
        dq, dks, dkl, dvs, dvl = _pallas_bwd(q, ks, kl, vs, vl, valid, dy, causal=causal, mode=mode)
        return dq, dks, dkl, dvs, dvl, None

    attn.defvjp(fwd, bwd)
    return attn


def sinkhorn_block_attention(q_blk, k_blk, v_blk, k_sorted, v_sorted, valid, causal=False, mode=None):
    """Sparse Sinkhorn attention over blocked inputs.

    Args:
      q_blk, k_blk, v_blk: ``(G, nb, b, d)`` — local (original-order) blocks.
      k_sorted, v_sorted:  ``(G, nb, b, d)`` — Sinkhorn-sorted blocks
        (``R @ blocked``, computed by the caller so K and V share one R).
      valid: ``(G, nb)`` float 1/0 — 0 disables the sorted term for a block
        (empty support row of a strict-causal R).
      causal: apply the within-block causal mask to the local term.
      mode: "slab" (CPU default) or "tile" (TPU grid layout).

    Returns ``(G, nb, b, d)``. Differentiable (custom VJP, Pallas bwd kernel).
    """
    fn = _make(bool(causal), mode or DEFAULT_MODE)
    return fn(q_blk, k_sorted, k_blk, v_sorted, v_blk, valid)


def local_block_attention(q_blk, k_blk, v_blk, causal=False, mode=None):
    """Local-attention baseline via the same kernel: sorted term disabled
    (valid=0 everywhere), so each block attends only to itself."""
    g, nb = q_blk.shape[:2]
    valid = jnp.zeros((g, nb), q_blk.dtype)
    fn = _make(bool(causal), mode or DEFAULT_MODE)
    return fn(q_blk, k_blk, k_blk, v_blk, v_blk, valid)
