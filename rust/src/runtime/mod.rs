//! L3 -> XLA bridge: load AOT artifacts (HLO text + JSON manifest), compile
//! once on the PJRT CPU client, execute from the coordinator hot path.

pub mod client;
pub mod experiment;
pub mod manifest;
pub mod tensor;

pub use client::Runtime;
pub use experiment::{Experiment, TrainState};
pub use manifest::{Dtype, Family, LeafSpec, Manifest, Registry, RegistryEntry};
pub use tensor::HostTensor;

use std::path::PathBuf;

/// Default artifacts directory: `$SINKHORN_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SINKHORN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
