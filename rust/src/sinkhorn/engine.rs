//! Parallel, allocation-free blocked execution engine for Sparse Sinkhorn
//! Attention (DESIGN.md §Engine, §Streaming).
//!
//! The naive reference path in [`super::attention`] exists to be obviously
//! correct: it materializes every block, the full `(b, 2b)` joint logits
//! and both probability matrices, and runs on one thread. This module is
//! the production path over the *same* algorithm:
//!
//! * **Zero-copy blocking** — [`BlockedView`] carves `nb` blocks out of a
//!   contiguous `(ell, d)` buffer without copying (the strided-view
//!   conventions shared with `runtime::tensor`).
//! * **Fused gather-matmul sort** — the balanced matrix `r` is nearly a
//!   permutation, so block mixing skips zero weights and accumulates
//!   `w * block` directly into a preallocated workspace tile
//!   ([`gather_block_into`]): no clone, no scale pass, no temporaries.
//! * **Streaming joint softmax** (DESIGN.md §Streaming) — the
//!   `[sorted | local]` key range is consumed in [`STREAM_TILE_W`]-wide
//!   tiles with a flash-style running max/denominator, accumulating the
//!   unnormalized context straight into the output tile. The `(b, 2b)`
//!   logits and split `ps`/`pl` probability matrices are never
//!   materialized; per-worker scratch is linear in `b`
//!   (`memory::engine_workspace_bytes`).
//! * **SortCut** (paper §3.3) — gathers only the first `n_cut` sorted
//!   blocks and streams every query block over them through the same loop.
//! * **Backend-agnostic layout** (DESIGN.md §Backends) — the engine never
//!   computes a mixing matrix; it executes whatever [`SortLayout`] a
//!   [`SortStrategy`](super::strategy::SortStrategy) produced
//!   ([`SinkhornEngine::layout_attention_into`]), Sinkhorn-balanced or
//!   not. Zero-support rows mask their sorted term, which is how the
//!   `local` backend rides the same task list for free.
//! * **Incremental decode** (DESIGN.md §Decode) —
//!   [`SinkhornEngine::decode_step_into`] steps a batch of
//!   [`super::decode::DecodeState`]s one token each: cached causal sort
//!   state, rebalance only at block boundaries, O(b·d) per step.
//! * **Worker pool** — work is flattened to `(request, head, block)` tasks
//!   ([`SinkhornEngine::attention_batch_into`]) and fanned out over
//!   [`WorkerPool`], one private `Workspace` per worker. Inner loops
//!   allocate nothing.
//!
//! **Numerics contract:** the streaming softmax and the tiled microkernels
//! (`matrix.rs`, DESIGN.md §Microkernels) change float summation order, so
//! engine outputs are *epsilon-equal* — within 1e-5 max-abs on the
//! property-test shapes — to the naive reference, which remains the
//! oracle. The engine itself stays deterministic: outputs are identical
//! bit for bit across thread counts, because every task owns its output
//! chunk and per-block math never depends on which worker runs it.
//! `tests/engine_props.rs` pins both halves; `bench engine` re-checks the
//! epsilon gate before every timing run.

use super::decode::DecodeState;
use super::matrix::{matmul_acc_into, matmul_t_scaled_into, Mat, MatView, MatViewMut};
use super::pool::WorkerPool;

/// Streamed key-tile width of the flash-style joint softmax: logits are
/// computed `(b, STREAM_TILE_W)` at a time, so per-worker scratch carries
/// no `(b, 2b)` tile (DESIGN.md §Streaming; `memory::engine_workspace_bytes`
/// does the analytic accounting, [`workspace_f32_elems`] the measured one).
pub const STREAM_TILE_W: usize = 32;

/// The engine's numerics contract in one number: max-abs divergence
/// allowed between any engine path and the naive `attention.rs` oracle
/// (module docs; DESIGN.md §Streaming). Shared by the bench gates
/// (`bench engine`, `benches/engine.rs`) and the property tests so the
/// contract can only be changed in one place.
pub const ENGINE_TOL: f32 = 1e-5;

/// Zero-copy view of an `(ell, d)` matrix as `nb` contiguous `(b, d)`
/// blocks sharing one buffer.
#[derive(Debug, Clone, Copy)]
pub struct BlockedView<'a> {
    pub nb: usize,
    /// rows per block
    pub b: usize,
    /// model dim
    pub d: usize,
    data: &'a [f32],
}

impl<'a> BlockedView<'a> {
    pub fn from_seq(x: &'a Mat, nb: usize) -> Self {
        assert!(nb > 0, "nb must be positive");
        assert_eq!(x.rows % nb, 0, "nb must divide ell");
        BlockedView { nb, b: x.rows / nb, d: x.cols, data: &x.data }
    }

    /// View a raw block-aligned buffer as `nb` blocks of `(b, d)` — how the
    /// incremental decoder ([`super::decode`]) exposes the prefix of its
    /// appended K/V cache to [`gather_block_into`] without owning a `Mat`.
    pub fn from_slice(data: &'a [f32], nb: usize, b: usize, d: usize) -> Self {
        assert!(nb > 0, "nb must be positive");
        assert_eq!(data.len(), nb * b * d, "buffer must hold exactly nb*b*d elements");
        BlockedView { nb, b, d, data }
    }

    /// Block `i` as a strided matrix view.
    pub fn block(&self, i: usize) -> MatView<'a> {
        MatView::contiguous(self.block_slice(i), self.b, self.d)
    }

    /// Block `i`'s raw contiguous storage.
    pub fn block_slice(&self, i: usize) -> &'a [f32] {
        let n = self.b * self.d;
        &self.data[i * n..(i + 1) * n]
    }
}

/// Fused gather-matmul over the near-permutation sort weights: write
/// `sum_j weights[j] * block_j` into `out`, skipping zero entries and
/// folding two source blocks per pass over the output tile (halving the
/// number of read-modify-write sweeps when the balanced matrix is not yet
/// a hard permutation).
pub fn gather_block_into(weights: &[f32], src: &BlockedView, out: &mut [f32]) {
    debug_assert_eq!(weights.len(), src.nb);
    debug_assert_eq!(out.len(), src.b * src.d);
    gather_indexed(weights, |j| src.block_slice(j), out);
}

/// The same fused gather over page-resident blocks (`sinkhorn::pages`,
/// DESIGN.md §Pages): `blocks[j]` is block `j`'s contiguous storage,
/// wherever its page lives. Delegating to the one shared fold
/// ([`gather_indexed`]) is what makes the paged decode path *bitwise*
/// identical to the monolithic one — same skip rule, same pairing, same
/// accumulation order (`tests/pages_props.rs`).
pub fn gather_pages_into(weights: &[f32], blocks: &[&[f32]], out: &mut [f32]) {
    debug_assert_eq!(weights.len(), blocks.len());
    gather_indexed(weights, |j| blocks[j], out);
}

/// The one gather fold both entries share: zero weights are skipped and
/// two source blocks are folded per pass over the output tile, with a
/// trailing single-block pass when the live count is odd.
fn gather_indexed<'a>(weights: &[f32], block: impl Fn(usize) -> &'a [f32], out: &mut [f32]) {
    out.fill(0.0);
    let mut pending: Option<usize> = None;
    for (j, &w) in weights.iter().enumerate() {
        if w == 0.0 {
            continue;
        }
        match pending.take() {
            None => pending = Some(j),
            Some(p) => {
                let (wp, xp, xj) = (weights[p], block(p), block(j));
                for ((o, a), b) in out.iter_mut().zip(xp).zip(xj) {
                    *o += wp * a + w * b;
                }
            }
        }
    }
    if let Some(p) = pending {
        let wp = weights[p];
        for (o, x) in out.iter_mut().zip(block(p)) {
            *o += wp * x;
        }
    }
}

/// Per-row running state of the streaming softmax — max `m`, denominator
/// `l`, and the `(b, STREAM_TILE_W)` logit/probability tile. Everything
/// here is linear in `b`; this is what replaced the `(b, 2b)` joint-logits
/// buffer. Crate-visible so the incremental decoder ([`super::decode`])
/// can carry the same state between its sorted and local segments.
pub(crate) struct StreamState {
    pub(crate) m: Vec<f32>,
    pub(crate) l: Vec<f32>,
    stile: Vec<f32>,
}

impl StreamState {
    pub(crate) fn new(b: usize) -> Self {
        StreamState { m: vec![0.0; b], l: vec![0.0; b], stile: vec![0.0; b * STREAM_TILE_W] }
    }

    /// Prepare for a fresh query block of `b` rows (buffers may be sized
    /// for a larger block when the batch mixes shapes).
    pub(crate) fn reset(&mut self, b: usize) {
        self.m[..b].fill(f32::NEG_INFINITY);
        self.l[..b].fill(0.0);
    }

    fn f32_elems(&self) -> usize {
        self.m.len() + self.l.len() + self.stile.len()
    }
}

/// Stream one key/value segment through the flash-style joint softmax for
/// query block `q`: per [`STREAM_TILE_W`]-wide key tile, compute the
/// scaled logit tile (one microkernel call), fold it into the per-row
/// running max `m` and denominator `l` — rescaling whatever `out` has
/// accumulated so far by `exp(m_old - m_new)` when the max moves —
/// exponentiate the tile in place, and accumulate the unnormalized
/// context `exp(s - m) @ V_tile` straight into `out`.
///
/// `causal == true` restricts query row `t` to keys `0..=t` (the segment
/// is position-aligned with the query block, i.e. the local band). Masked
/// keys are skipped by bounding the row's visible width — no sentinel
/// logits — which matches the reference's `NEG_INF` masking exactly:
/// there, `exp(-1e9 - m)` underflows to zero probability.
///
/// The caller divides `out` rows by `l` after the last segment.
pub(crate) fn stream_segment(
    q: &MatView,
    kseg: &MatView,
    vseg: &MatView,
    scale: f32,
    causal: bool,
    st: &mut StreamState,
    out: &mut MatViewMut,
) {
    let b = q.rows;
    let n_keys = kseg.rows;
    let mut u0 = 0;
    while u0 < n_keys {
        let w = STREAM_TILE_W.min(n_keys - u0);
        {
            let ktile = kseg.row_range(u0, w);
            let mut sv = MatViewMut::contiguous(&mut st.stile[..b * w], b, w);
            matmul_t_scaled_into(q, &ktile, scale, &mut sv);
        }
        for t in 0..b {
            // width visible to row t (causal: keys u <= t only)
            let wv = if causal { (t + 1).saturating_sub(u0).min(w) } else { w };
            let srow = &mut st.stile[t * w..(t + 1) * w];
            if wv == 0 {
                // fully masked tile row: contribute nothing to the combine
                srow.fill(0.0);
                continue;
            }
            let mut tile_max = f32::NEG_INFINITY;
            for &s in &srow[..wv] {
                tile_max = tile_max.max(s);
            }
            let new_m = st.m[t].max(tile_max); // finite: wv >= 1 real logits
            let corr = (st.m[t] - new_m).exp(); // 0.0 when m was -inf
            if corr != 1.0 {
                st.l[t] *= corr;
                for o in out.row_mut(t) {
                    *o *= corr;
                }
            }
            st.m[t] = new_m;
            let mut psum = 0.0f32;
            for s in &mut srow[..wv] {
                *s = (*s - new_m).exp();
                psum += *s;
            }
            st.l[t] += psum;
            srow[wv..].fill(0.0); // masked tail must not combine
        }
        // out += P_tile @ V_tile, unnormalized (P rows already exp'd)
        let ptile = MatView::contiguous(&st.stile[..b * w], b, w);
        let vtile = vseg.row_range(u0, w);
        matmul_acc_into(&ptile, &vtile, out);
        u0 += w;
    }
}

/// Divide each accumulated context row by its softmax denominator. A zero
/// denominator (only possible when a row saw no keys at all, which the
/// always-visible local diagonal prevents) leaves the zero row in place.
pub(crate) fn normalize_rows(y: &mut MatViewMut, l: &[f32]) {
    for t in 0..y.rows {
        let lt = l[t];
        if lt > 0.0 {
            let inv = 1.0 / lt;
            for o in y.row_mut(t) {
                *o *= inv;
            }
        }
    }
}

/// Per-worker scratch tiles; sized once for the largest block shape in the
/// batch, reused for every `(request, head, block)` task the worker runs
/// (the per-task loop is allocation-free).
struct Workspace {
    /// gathered (sorted) keys, `(b, d)`
    ks: Vec<f32>,
    /// gathered (sorted) values, `(b, d)`
    vs: Vec<f32>,
    /// streaming-softmax running state, linear in `b`
    stream: StreamState,
}

impl Workspace {
    fn new(b: usize, d: usize) -> Self {
        Workspace { ks: vec![0.0; b * d], vs: vec![0.0; b * d], stream: StreamState::new(b) }
    }

    fn f32_elems(&self) -> usize {
        self.ks.len() + self.vs.len() + self.stream.f32_elems()
    }
}

/// The f32 elements one worker's scratch actually allocates for block
/// shape `(b, d)` — the measured side of `memory::engine_workspace_bytes`.
/// `tests/engine_props.rs` asserts the two agree, i.e. that the engine
/// really dropped the `(b, 2b)` logits/probability buffers.
pub fn workspace_f32_elems(b: usize, d: usize) -> usize {
    Workspace::new(b, d).f32_elems()
}

/// A caller-owned set of per-worker engine workspaces, reusable across
/// engine calls: the layer stack (`sinkhorn::model`) sizes one set for its
/// deepest layer and feeds it to [`SinkhornEngine::attention_chunks_into`]
/// once per layer, so a depth-L forward pass allocates its attention
/// scratch exactly once instead of L times. `attention_batch_into` remains
/// the self-contained entry that builds a throwaway set per call.
pub struct EngineWorkspaces {
    spaces: Vec<Workspace>,
    /// largest block rows the workspaces are sized for
    b: usize,
    /// largest model dim the workspaces are sized for
    d: usize,
}

impl EngineWorkspaces {
    /// One workspace per worker of an engine with `threads` workers
    /// (`threads == 0` is clamped to 1 — workspaces are per *worker*, and
    /// a pool never runs with fewer than one), each sized for block shape
    /// `(b, d)`.
    pub fn new(threads: usize, b: usize, d: usize) -> Self {
        EngineWorkspaces {
            spaces: (0..threads.max(1)).map(|_| Workspace::new(b, d)).collect(),
            b,
            d,
        }
    }

    /// Total f32 elements across all per-worker workspaces — the measured
    /// side of the stack's scratch accounting (`memory::stack_scratch_elems`).
    pub fn f32_elems(&self) -> usize {
        self.spaces.iter().map(Workspace::f32_elems).sum()
    }

    fn fits(&self, b: usize, d: usize, workers: usize) -> bool {
        self.b >= b && self.d >= d && self.spaces.len() >= workers
    }
}

/// The gather/window layout one layer's attention executes, as produced
/// by a sort backend (DESIGN.md §Backends): the block-mixing matrix, the
/// block count, and the window shape (full `[sorted | local]` vs a
/// SortCut over the first `n_cut` sorted blocks, causal or not). The
/// engine consumes this with no knowledge of which
/// [`SortStrategy`](super::strategy::SortStrategy) built `r` — an
/// all-zero row simply masks that block's sorted term (the row-support
/// skip in the per-block task).
#[derive(Debug, Clone, Copy)]
pub struct SortLayout<'a> {
    /// `(nb, nb)` block-mixing matrix (near-permutation for Sinkhorn,
    /// cluster-uniform for routing, all-zero for local)
    pub r: &'a Mat,
    pub nb: usize,
    /// `Some(c)`: SortCut window over the first `c` sorted blocks
    pub n_cut: Option<usize>,
    /// strict-causal local window + strict mixing rows
    pub causal: bool,
}

/// One attention instance inside a batched engine call — a
/// `(request, head)` pair in serving terms. Multi-head callers flatten
/// heads into one `AttentionReq` each; the engine flattens further into
/// `(request, head, block)` tasks before touching the pool.
#[derive(Debug, Clone, Copy)]
pub struct AttentionReq<'a> {
    pub q: &'a Mat,
    pub k: &'a Mat,
    pub v: &'a Mat,
    /// balanced `(nb, nb)` sort matrix
    pub r: &'a Mat,
    pub nb: usize,
    pub causal: bool,
}

/// The parallel blocked engine. Construction is free; `threads == 0`
/// auto-detects (see [`super::pool::auto_threads`]).
#[derive(Debug, Clone, Copy)]
pub struct SinkhornEngine {
    pool: WorkerPool,
}

impl SinkhornEngine {
    pub fn new(threads: usize) -> Self {
        SinkhornEngine { pool: WorkerPool::new(threads) }
    }

    /// Single-threaded streaming engine (the "fused" row of `bench engine`).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// One worker per available core (the "parallel" row).
    pub fn auto() -> Self {
        Self::new(0)
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Sparse Sinkhorn attention over `(ell, d)` q/k/v with balanced sort
    /// matrix `r` — semantics identical to
    /// [`super::attention::sinkhorn_attention`], output within 1e-5
    /// max-abs of it (module docs: numerics contract).
    pub fn attention(&self, q: &Mat, k: &Mat, v: &Mat, r: &Mat, nb: usize, causal: bool) -> Mat {
        let mut out = Mat::zeros(q.rows, q.cols);
        self.attention_into(q, k, v, r, nb, causal, &mut out);
        out
    }

    /// [`Self::attention`] into a caller-provided output (serving hot
    /// path: reuse the buffer across requests). `out` need not be zeroed.
    #[allow(clippy::too_many_arguments)]
    pub fn attention_into(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        r: &Mat,
        nb: usize,
        causal: bool,
        out: &mut Mat,
    ) {
        self.attention_batch_into(
            &[AttentionReq { q, k, v, r, nb, causal }],
            std::slice::from_mut(out),
        );
    }

    /// Batched attention: one [`AttentionReq`] per `(request, head)`,
    /// outputs written into `outs` (parallel to `reqs`). The work domain
    /// is flattened to `(request, head, block)` tasks before one
    /// [`WorkerPool::run`] pass, so a serving batch of many small requests
    /// saturates every worker instead of running requests serially through
    /// a per-request fan-out (`server::fallback::classify_batch` feeds its
    /// whole batch through here).
    pub fn attention_batch_into(&self, reqs: &[AttentionReq], outs: &mut [Mat]) {
        assert_eq!(reqs.len(), outs.len(), "one output per request");
        if reqs.is_empty() {
            return;
        }
        let (mut bmax, mut dmax) = (0, 0);
        for (rq, out) in reqs.iter().zip(outs.iter()) {
            assert_eq!((out.rows, out.cols), (rq.q.rows, rq.q.cols), "output shape");
            bmax = bmax.max(rq.q.rows / rq.nb.max(1));
            dmax = dmax.max(rq.q.cols);
        }
        let mut ws = EngineWorkspaces::new(self.threads(), bmax, dmax);
        let chunks: Vec<&mut [f32]> = outs.iter_mut().map(|o| o.data.as_mut_slice()).collect();
        self.attention_chunks_into(reqs, chunks, &mut ws);
    }

    /// The reusable-workspace core of [`Self::attention_batch_into`]: one
    /// flat output buffer per request (length `ell * d`) and a
    /// caller-owned [`EngineWorkspaces`] that survives the call. The layer
    /// stack calls this once per layer with the same workspace set and
    /// with output slices into its pooled activation buffers, so a forward
    /// pass re-allocates neither scratch nor outputs
    /// (DESIGN.md §Model). Identical math and task order to
    /// `attention_batch_into` — the two entries are bit-identical.
    pub fn attention_chunks_into(
        &self,
        reqs: &[AttentionReq],
        outs: Vec<&mut [f32]>,
        ws: &mut EngineWorkspaces,
    ) {
        assert_eq!(reqs.len(), outs.len(), "one output per request");
        if reqs.is_empty() {
            return;
        }
        let (mut bmax, mut dmax, mut n_tasks) = (0, 0, 0);
        for (rq, out) in reqs.iter().zip(outs.iter()) {
            check_qkv(rq.q, rq.k, rq.v);
            assert!(rq.nb > 0, "nb must be positive");
            assert_eq!(rq.q.rows % rq.nb, 0, "nb must divide ell");
            assert_eq!((rq.r.rows, rq.r.cols), (rq.nb, rq.nb), "sort matrix must be (nb, nb)");
            assert_eq!(out.len(), rq.q.rows * rq.q.cols, "output buffer length");
            bmax = bmax.max(rq.q.rows / rq.nb);
            dmax = dmax.max(rq.q.cols);
            n_tasks += rq.nb;
        }
        assert!(
            ws.fits(bmax, dmax, self.threads().min(n_tasks).max(1)),
            "EngineWorkspaces sized (b={}, d={}, workers={}) cannot serve (b={bmax}, d={dmax}, \
             threads={})",
            ws.b,
            ws.d,
            ws.spaces.len(),
            self.threads()
        );
        let mut tasks: Vec<(usize, usize, &mut [f32])> = Vec::new();
        for (ri, out) in outs.into_iter().enumerate() {
            let chunk = (reqs[ri].q.rows / reqs[ri].nb) * reqs[ri].q.cols;
            for (bi, c) in out.chunks_mut(chunk).enumerate() {
                tasks.push((ri, bi, c));
            }
        }
        self.pool.run_with(tasks, &mut ws.spaces, |w, (ri, bi, chunk)| {
            let rq = &reqs[ri];
            let qb = BlockedView::from_seq(rq.q, rq.nb);
            let kb = BlockedView::from_seq(rq.k, rq.nb);
            let vb = BlockedView::from_seq(rq.v, rq.nb);
            let scale = 1.0 / (qb.d as f32).sqrt();
            block_attention(w, bi, chunk, &qb, &kb, &vb, rq.r, rq.causal, scale);
        });
    }

    /// Multi-head attention over a backend-agnostic [`SortLayout`]
    /// (DESIGN.md §Backends): one call per layer, all heads sharing the
    /// layout's mixing matrix, dispatched to the full `[sorted | local]`
    /// task list or the SortCut loop by the layout's window shape. This is
    /// the seam between [`SortStrategy`](super::strategy::SortStrategy)
    /// and the engine — task-list construction here never knows *which*
    /// backend produced the mixing matrix, only what gather/window shape
    /// to execute. Bit-identical to calling
    /// [`Self::attention_chunks_into`] / [`Self::sortcut_attention_into`]
    /// directly (it is exactly that dispatch).
    pub fn layout_attention_into(
        &self,
        layout: &SortLayout,
        qh: &[Mat],
        kh: &[Mat],
        vh: &[Mat],
        outs: &mut [Mat],
        ws: &mut EngineWorkspaces,
    ) {
        let heads = qh.len();
        assert_eq!(kh.len(), heads, "one k buffer per head");
        assert_eq!(vh.len(), heads, "one v buffer per head");
        assert_eq!(outs.len(), heads, "one output per head");
        match layout.n_cut {
            None => {
                let reqs: Vec<AttentionReq> = (0..heads)
                    .map(|h| AttentionReq {
                        q: &qh[h],
                        k: &kh[h],
                        v: &vh[h],
                        r: layout.r,
                        nb: layout.nb,
                        causal: layout.causal,
                    })
                    .collect();
                let chunks: Vec<&mut [f32]> =
                    outs.iter_mut().map(|m| m.data.as_mut_slice()).collect();
                self.attention_chunks_into(&reqs, chunks, ws);
            }
            Some(c) => {
                for h in 0..heads {
                    self.sortcut_attention_into(
                        &qh[h],
                        &kh[h],
                        &vh[h],
                        layout.r,
                        layout.nb,
                        c,
                        &mut outs[h],
                    );
                }
            }
        }
    }

    /// SortCut truncated attention (paper §3.3): every query attends to
    /// the first `n_cut` *sorted* blocks. Semantics identical to
    /// [`super::attention::sortcut_attention`] within the same 1e-5
    /// epsilon contract; only `n_cut` of the `nb` gather rows are ever
    /// computed.
    pub fn sortcut_attention(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        r: &Mat,
        nb: usize,
        n_cut: usize,
    ) -> Mat {
        let mut out = Mat::zeros(q.rows, q.cols);
        self.sortcut_attention_into(q, k, v, r, nb, n_cut, &mut out);
        out
    }

    #[allow(clippy::too_many_arguments)]
    pub fn sortcut_attention_into(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        r: &Mat,
        nb: usize,
        n_cut: usize,
        out: &mut Mat,
    ) {
        check_qkv(q, k, v);
        assert_eq!((r.rows, r.cols), (nb, nb), "sort matrix must be (nb, nb)");
        assert!((1..=nb).contains(&n_cut), "n_cut must be in 1..=nb, got {n_cut}");
        assert_eq!((out.rows, out.cols), (q.rows, q.cols), "output shape");
        let qb = BlockedView::from_seq(q, nb);
        let kb = BlockedView::from_seq(k, nb);
        let vb = BlockedView::from_seq(v, nb);
        let (b, d) = (qb.b, qb.d);
        let scale = 1.0 / (d as f32).sqrt();

        // gather the truncated sorted K/V once (n_cut blocks, not nb)
        let mut kcut = vec![0.0f32; n_cut * b * d];
        let mut vcut = vec![0.0f32; n_cut * b * d];
        for i in 0..n_cut {
            gather_block_into(r.row(i), &kb, &mut kcut[i * b * d..(i + 1) * b * d]);
            gather_block_into(r.row(i), &vb, &mut vcut[i * b * d..(i + 1) * b * d]);
        }
        let kcutv = MatView::contiguous(&kcut, n_cut * b, d);
        let vcutv = MatView::contiguous(&vcut, n_cut * b, d);

        // query blocks stream independently over the shared cut — same
        // flash loop as the sorted+local path, single segment, no mask
        let tasks: Vec<(usize, &mut [f32])> = out.data.chunks_mut(b * d).enumerate().collect();
        self.pool.run(
            tasks,
            || StreamState::new(b),
            |st, (i, chunk)| {
                let qi = qb.block(i);
                chunk.fill(0.0);
                st.reset(b);
                let mut y = MatViewMut::contiguous(chunk, b, d);
                stream_segment(&qi, &kcutv, &vcutv, scale, false, st, &mut y);
                normalize_rows(&mut y, &st.l);
            },
        );
    }

    /// One incremental autoregressive decode step for a batch of sequences
    /// (DESIGN.md §Decode): each [`DecodeReq`] appends one token's K/V rows
    /// to its [`DecodeState`], rebalances the causal sort matrix if a block
    /// boundary filled, and streams the new token's query over
    /// `[cached sorted blocks | local causal window]` — O(b·d) per step
    /// instead of recomputing full-prefix attention.
    ///
    /// Sequences fan out over the worker pool, one per task; the
    /// per-worker `Workspace`'s streaming state is reused as the step's
    /// softmax carry (queries are single rows, so the scratch is sized
    /// `(1, d)`).
    /// Outputs are bit-identical across thread counts for the same reason
    /// the batch path's are: every step owns its state and output, and the
    /// per-step math never depends on worker placement. Each step matches
    /// the naive full-prefix oracle
    /// [`super::attention::causal_decode_attention`] within [`ENGINE_TOL`]
    /// (`tests/decode_props.rs`).
    ///
    /// This entry allocates a throwaway workspace set per call; repeated
    /// callers (the stack's batched step, the serving scheduler's tick
    /// loop) use [`Self::decode_steps_with`] with a pooled
    /// [`EngineWorkspaces`] instead — the two are bit-identical.
    pub fn decode_step_into(&self, reqs: Vec<DecodeReq>) {
        if reqs.is_empty() {
            return;
        }
        let dmax = reqs.iter().map(|rq| rq.state.d()).max().unwrap_or(1);
        let mut ws = EngineWorkspaces::new(self.threads().min(reqs.len()).max(1), 1, dmax);
        self.decode_steps_with(reqs, &mut ws);
    }

    /// The reusable-workspace core of [`Self::decode_step_into`]
    /// (DESIGN.md §Decode, §Scheduler): the `(sequence, head)` decode tasks
    /// fan out over the pool with one caller-owned per-worker `Workspace`
    /// each, so a scheduler ticking thousands of times reuses one
    /// [`EngineWorkspaces`] instead of allocating streaming state per
    /// token. Identical math and task partitioning to `decode_step_into` —
    /// the two entries are bit-identical — and, like every engine entry,
    /// bit-identical across thread counts.
    pub fn decode_steps_with(&self, reqs: Vec<DecodeReq>, ws: &mut EngineWorkspaces) {
        if reqs.is_empty() {
            return;
        }
        let mut dmax = 0;
        for rq in &reqs {
            let d = rq.state.d();
            assert_eq!(rq.q.len(), d, "q row must have d elements");
            assert_eq!(rq.k.len(), d, "k row must have d elements");
            assert_eq!(rq.v.len(), d, "v row must have d elements");
            assert_eq!(rq.out.len(), d, "out row must have d elements");
            dmax = dmax.max(d);
        }
        let workers = self.threads().min(reqs.len()).max(1);
        assert!(
            ws.fits(1, dmax, workers),
            "EngineWorkspaces sized (b={}, d={}, workers={}) cannot serve decode steps \
             (d={dmax}, threads={})",
            ws.b,
            ws.d,
            ws.spaces.len(),
            self.threads()
        );
        self.pool.run_with(reqs, &mut ws.spaces, |w, rq| {
            rq.state.step_with(rq.q, rq.k, rq.v, rq.sort_logits, &mut w.stream, rq.out);
        });
    }

    /// Chunked prompt ingestion for a batch of `(sequence, head)` tasks
    /// (DESIGN.md §Prefill): each [`PrefillReq`] appends a whole `(n, d)`
    /// chunk of projected Q/K/V rows to its [`DecodeState`] via
    /// [`DecodeState::append_chunk`], so a prompt costs `ℓ/b` parallel
    /// chunk tasks instead of `ℓ` lockstep decode ticks. Parallelism lives
    /// *across* tasks — each chunk replays the step-path op order serially
    /// inside its task — which is exactly why the result is bit-identical
    /// to token-by-token decoding and across thread counts
    /// (`tests/prefill_props.rs`).
    ///
    /// Allocates a throwaway workspace set per call; the stack's
    /// `prefill_batch` loop uses [`Self::prefill_chunks_with`] with a
    /// pooled [`EngineWorkspaces`] instead — the two are bit-identical.
    pub fn prefill_chunks_into(&self, reqs: Vec<PrefillReq>) {
        if reqs.is_empty() {
            return;
        }
        let dmax = reqs.iter().map(|rq| rq.state.d()).max().unwrap_or(1);
        let mut ws = EngineWorkspaces::new(self.threads().min(reqs.len()).max(1), 1, dmax);
        self.prefill_chunks_with(reqs, &mut ws);
    }

    /// The reusable-workspace core of [`Self::prefill_chunks_into`]
    /// (DESIGN.md §Prefill): chunk tasks fan out over the pool with one
    /// caller-owned per-worker `Workspace` each. The streaming scratch is
    /// the same `(1, d)` single-row carry the decode step uses — a chunk
    /// is its tokens stepped serially — so one [`EngineWorkspaces`] serves
    /// both the tick loop and prefill.
    pub fn prefill_chunks_with(&self, reqs: Vec<PrefillReq>, ws: &mut EngineWorkspaces) {
        if reqs.is_empty() {
            return;
        }
        let mut dmax = 0;
        for rq in &reqs {
            let d = rq.state.d();
            assert!(d > 0 && rq.q.len() % d == 0, "chunk q must be (n, d) row-major");
            let n = rq.q.len() / d;
            assert_eq!(rq.k.len(), n * d, "chunk k must match q's (n, d) shape");
            assert_eq!(rq.v.len(), n * d, "chunk v must match q's (n, d) shape");
            assert_eq!(rq.out.len(), n * d, "chunk out must match q's (n, d) shape");
            dmax = dmax.max(d);
        }
        let workers = self.threads().min(reqs.len()).max(1);
        assert!(
            ws.fits(1, dmax, workers),
            "EngineWorkspaces sized (b={}, d={}, workers={}) cannot serve prefill chunks \
             (d={dmax}, threads={})",
            ws.b,
            ws.d,
            ws.spaces.len(),
            self.threads()
        );
        self.pool.run_with(reqs, &mut ws.spaces, |w, rq| {
            rq.state.append_chunk_with(rq.q, rq.k, rq.v, rq.sort_logits, &mut w.stream, rq.out);
        });
    }
}

/// One sequence's slice of a batched decode step: the per-sequence
/// [`DecodeState`], the new token's projected q/k/v rows (`d` elements
/// each), the caller-maintained sort-logit matrix (rows become live as
/// blocks complete — DESIGN.md §Decode), and the `d`-element output row.
pub struct DecodeReq<'a> {
    pub state: &'a mut DecodeState,
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub sort_logits: &'a Mat,
    pub out: &'a mut [f32],
}

/// One `(sequence, head)` slice of a chunked prefill pass: the head's
/// [`DecodeState`], `(n, d)` row-major projected Q/K/V for the whole
/// chunk, the caller-maintained sort-logit matrix (every row the chunk's
/// boundary rebalances will read must already be live — DESIGN.md
/// §Prefill), and the `(n, d)` output buffer.
pub struct PrefillReq<'a> {
    pub state: &'a mut DecodeState,
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub sort_logits: &'a Mat,
    pub out: &'a mut [f32],
}

fn check_qkv(q: &Mat, k: &Mat, v: &Mat) {
    assert_eq!(q.rows, k.rows, "q/k rows");
    assert_eq!(q.rows, v.rows, "q/v rows");
    assert_eq!(q.cols, k.cols, "q/k cols");
    assert_eq!(k.cols, v.cols, "k/v cols");
}

/// One `(request, head, block)` task: streaming sorted+local attention for
/// output block `i` (DESIGN.md §Streaming). `out_chunk` holds the
/// unnormalized context while streaming and is divided by the final
/// denominators at the end — it never holds logits.
#[allow(clippy::too_many_arguments)]
fn block_attention(
    ws: &mut Workspace,
    i: usize,
    out_chunk: &mut [f32],
    qb: &BlockedView,
    kb: &BlockedView,
    vb: &BlockedView,
    r: &Mat,
    causal: bool,
    scale: f32,
) {
    let (b, d) = (qb.b, qb.d);
    let rrow = r.row(i);
    let row_support: f32 = rrow.iter().sum();
    let valid = row_support > 1e-6;

    out_chunk.fill(0.0);
    ws.stream.reset(b);
    let qi = qb.block(i);
    let mut y = MatViewMut::contiguous(out_chunk, b, d);

    // sorted term: gather this block's sorted K/V, then stream them. A
    // block with no sort support masks the whole sorted term to NEG_INF in
    // the reference — exactly zero probability — so here it is skipped.
    if valid {
        gather_block_into(rrow, kb, &mut ws.ks[..b * d]);
        gather_block_into(rrow, vb, &mut ws.vs[..b * d]);
        let ks = MatView::contiguous(&ws.ks[..b * d], b, d);
        let vs = MatView::contiguous(&ws.vs[..b * d], b, d);
        stream_segment(&qi, &ks, &vs, scale, false, &mut ws.stream, &mut y);
    }
    // local term, causally bounded per row when asked
    stream_segment(&qi, &kb.block(i), &vb.block(i), scale, causal, &mut ws.stream, &mut y);

    normalize_rows(&mut y, &ws.stream.l);
}

#[cfg(test)]
mod tests {
    // The heavy property suites (engine within epsilon of naive across
    // modes/threads/shapes, sortcut cuts, workspace accounting) live in
    // tests/engine_props.rs — only edge cases are covered here.
    use super::*;
    use crate::sinkhorn::balance::sinkhorn;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
    }

    #[test]
    fn paged_gather_is_bitwise_equal_to_blocked_gather() {
        // gather_pages_into is the paged decode path's view of the same
        // fold — any drift here breaks the pages differential battery
        let mut rng = Rng::new(0x6A7);
        let (nb, b, d) = (5usize, 3usize, 4usize);
        let data = rand_mat(&mut rng, nb * b, d);
        let src = BlockedView::from_slice(&data.data, nb, b, d);
        // weights with exact zeros so the skip rule is exercised
        let mut w: Vec<f32> = (0..nb).map(|_| rng.normal() as f32).collect();
        w[1] = 0.0;
        w[3] = 0.0;
        let mut a = vec![f32::NAN; b * d];
        let mut p = vec![f32::NAN; b * d];
        gather_block_into(&w, &src, &mut a);
        let blocks: Vec<&[f32]> = (0..nb).map(|j| src.block_slice(j)).collect();
        gather_pages_into(&w, &blocks, &mut p);
        assert_eq!(a, p, "the two gather entries must agree bit for bit");
    }

    #[test]
    fn attention_into_reuses_dirty_buffer() {
        let mut rng = Rng::new(0xE5);
        let (nb, b, d) = (3, 4, 6);
        let ell = nb * b;
        let q = rand_mat(&mut rng, ell, d);
        let k = rand_mat(&mut rng, ell, d);
        let v = rand_mat(&mut rng, ell, d);
        let r = sinkhorn(&rand_mat(&mut rng, nb, nb), 8);
        let eng = SinkhornEngine::serial();
        let want = eng.attention(&q, &k, &v, &r, nb, false);
        let mut out = Mat::from_fn(ell, d, |_, _| f32::NAN); // dirty
        eng.attention_into(&q, &k, &v, &r, nb, false, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn batch_mixing_shapes_matches_singles() {
        // the worker Workspace is sized for the batch max and sliced per
        // task — mixed (ell, d, nb) requests must reproduce the
        // one-request path bit for bit
        let mut rng = Rng::new(0xBA7);
        let shapes = [(2usize, 3usize, 5usize), (4, 6, 8), (3, 2, 4)];
        let cases: Vec<(Mat, Mat, Mat, Mat, usize)> = shapes
            .iter()
            .map(|&(nb, b, d)| {
                let ell = nb * b;
                (
                    rand_mat(&mut rng, ell, d),
                    rand_mat(&mut rng, ell, d),
                    rand_mat(&mut rng, ell, d),
                    sinkhorn(&rand_mat(&mut rng, nb, nb), 8),
                    nb,
                )
            })
            .collect();
        let eng = SinkhornEngine::new(3);
        let reqs: Vec<AttentionReq> = cases
            .iter()
            .map(|(q, k, v, r, nb)| AttentionReq { q, k, v, r, nb: *nb, causal: false })
            .collect();
        let mut outs: Vec<Mat> =
            cases.iter().map(|(q, _, _, _, _)| Mat::zeros(q.rows, q.cols)).collect();
        eng.attention_batch_into(&reqs, &mut outs);
        for ((q, k, v, r, nb), got) in cases.iter().zip(&outs) {
            let want = eng.attention(q, k, v, r, *nb, false);
            assert_eq!(got, &want);
        }
    }

    #[test]
    #[should_panic(expected = "nb must divide ell")]
    fn rejects_indivisible_block_count() {
        let q = Mat::zeros(10, 4);
        SinkhornEngine::serial().attention(&q, &q, &q, &Mat::zeros(3, 3), 3, false);
    }

    #[test]
    #[should_panic(expected = "n_cut must be in 1..=nb")]
    fn rejects_zero_cut() {
        let q = Mat::zeros(8, 4);
        SinkhornEngine::serial().sortcut_attention(&q, &q, &q, &Mat::eye(4), 4, 0);
    }
}
